//! Quantization explorer: trade accuracy proxies against speed across the
//! whole bit-width × polarity grid — the design-space study §VI motivates
//! ("the best choice in terms of quantization for a given ARM processor").
//!
//! ```bash
//! cargo run --release --example quantization_explorer -- [--profile a72] [--layer C5]
//! ```
//!
//! For one conv layer it sweeps float32, int8 QNN, and bit-serial 1–8 bit
//! (both polarities), reporting simulated latency on the calibrated ARM
//! profile, the eq. (5) required bandwidth (is it cache-bound?), the
//! native-operator numerics (quantization error vs float32 on real data),
//! and a latency-vs-precision Pareto summary.
//!
//! A final section turns to the serving tiers (DESIGN.md §Tiers): for the
//! synthetic serving menu it prints each artifact's traced L2 demand, how
//! many copies fit per worker, its downshift target on the precision
//! lattice, and the interference-free worker count per tier — the numbers
//! behind the `servtier` bench records and `serve --tiers`.

use std::collections::BTreeMap;

use anyhow::Result;
use cachebound::analysis::required_bw::{bitserial_d, required_bandwidth};
use cachebound::analysis::InterferenceModel;
use cachebound::coordinator::min_workers_interference_free;
use cachebound::hw::{profile_by_name, MemLevel};
use cachebound::operators::workloads::{self, layer_by_name, Tier};
use cachebound::operators::{bitserial, conv, qnn, Tensor};
use cachebound::sim::timing;
use cachebound::telemetry::{serving_tier_mix_profiles, CacheProfile};
use cachebound::util::csv::Csv;
use cachebound::util::table::{Align, Table};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let profile = flag(&args, "--profile").unwrap_or_else(|| "a72".into());
    let layer_name = flag(&args, "--layer").unwrap_or_else(|| "C5".into());
    let cpu = profile_by_name(&profile)?.cpu;
    let layer = layer_by_name(&layer_name)
        .ok_or_else(|| anyhow::anyhow!("unknown layer {layer_name} (C2..C11)"))?;

    println!(
        "=== quantization explorer: layer {} ({}x{}x{}x{}, k={}, s={}) on {} ===\n",
        layer.name, layer.cin, layer.cout, layer.h, layer.w, layer.k, layer.stride, cpu.name
    );

    // --- simulated latency for every quantization option -------------------
    let f32_tb = timing::simulate_conv_time(&cpu, &layer, conv::ConvSchedule::default_tuned(), 32);
    let qnn_tb = timing::simulate_conv_time(&cpu, &layer, conv::ConvSchedule::default_tuned(), 8);
    let eq_n = cachebound::coordinator::pipeline::bitserial_equiv_n(&layer);
    let scale = layer.macs() as f64 / (eq_n as f64).powi(3);

    let mut table = Table::new(
        format!("Latency & cache-boundness, layer {} on {}", layer.name, cpu.name),
        &["config", "sim ms", "speedup", "bw_req MiB/s", "vs L1 bw", "bound?"],
    )
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Left]);
    let mut csv = Csv::new(&["config", "sim_ms", "speedup", "bw_req_mibs", "l1_frac", "binding"]);
    let flops = 2.0 * layer.macs() as f64;
    let mut add = |name: &str, secs: f64, d_bytes: f64, bound: &str| {
        let req = required_bandwidth(flops / secs, d_bytes);
        let frac = req.utilization(&cpu, MemLevel::L1);
        table.row(vec![
            name.into(),
            format!("{:.3}", secs * 1e3),
            format!("{:.2}x", f32_tb.total_s / secs),
            format!("{:.0}", req.bw_req / (1 << 20) as f64),
            format!("{:.0}%", frac * 100.0),
            bound.into(),
        ]);
        csv.row(vec![
            name.into(),
            format!("{:.6}", secs * 1e3),
            format!("{:.3}", f32_tb.total_s / secs),
            format!("{:.0}", req.bw_req / (1 << 20) as f64),
            format!("{frac:.3}"),
            bound.into(),
        ]);
    };
    add("float32", f32_tb.total_s, 4.0, f32_tb.bound.name());
    add("qnn-int8", qnn_tb.total_s, 1.0, qnn_tb.bound.name());
    for bits in [1usize, 2, 4, 8] {
        for unipolar in [true, false] {
            let tb = timing::simulate_bitserial_gemm_time(
                &cpu, eq_n, eq_n, eq_n, bits, bits, unipolar,
            );
            let secs = tb.total_s * scale;
            add(
                &format!("bs-{}bit-{}", bits, if unipolar { "uni" } else { "bi" }),
                secs,
                bitserial_d(bits as u32),
                tb.bound.name(),
            );
        }
    }
    println!("{}", table.to_markdown());
    csv.write(format!("results/quantization_explorer_{}_{}.csv", cpu.name, layer.name))?;

    // --- numerics: quantization error on real data -------------------------
    println!("numerics check (native operators, scaled-down layer geometry):");
    let (cin, cout, h) = (8usize, 8usize, 14usize);
    let x = Tensor::<f32>::rand_f32(&[1, cin, h, h], 1);
    let w = Tensor::<f32>::rand_f32(&[cout, cin, layer.k, layer.k], 2);
    let exact = conv::naive(&x, &w, layer.stride, layer.pad);

    // int8 quantization: symmetric, scale to [-127, 127]
    let absmax = |t: &Tensor<f32>| t.data.iter().fold(0f32, |m, v| m.max(v.abs()));
    let (sx, sw) = (absmax(&x) / 127.0, absmax(&w) / 127.0);
    let q = |t: &Tensor<f32>, s: f32| {
        Tensor::from_vec(
            &t.shape.clone(),
            t.data.iter().map(|v| (v / s).round().clamp(-127.0, 127.0) as i8).collect(),
        )
    };
    let acc = qnn::conv2d(&q(&x, sx), &q(&w, sw), layer.stride, layer.pad);
    let deq: Vec<f32> = acc.data.iter().map(|&v| v as f32 * sx * sw).collect();
    let err8: f64 = deq
        .iter()
        .zip(&exact.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
        / exact.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    println!("  int8 relative error: {:.4}", err8);

    for bits in [1usize, 2, 4] {
        // unipolar quantization of |values| into `bits` levels (toy proxy)
        let levels = (1 << bits) - 1;
        let quant = |t: &Tensor<f32>, s: f32| -> Vec<i32> {
            t.data
                .iter()
                .map(|v| ((v.abs() / s) * levels as f32).round().min(levels as f32) as i32)
                .collect()
        };
        let xi = quant(&x, absmax(&x));
        let wi = quant(&w, absmax(&w));
        // pack along a flattened K (pad to 32) and dot the first rows as a
        // smoke check of the bit-serial arithmetic on quantized real data
        let k = 32 * xi.len().min(wi.len()).div_euclid(32).max(1);
        let a = Tensor::from_vec(&[1, k], xi[..k].to_vec());
        let b = Tensor::from_vec(&[1, k], wi[..k].to_vec());
        let ap = bitserial::pack_unipolar(&a, bits);
        let bp = bitserial::pack_unipolar(&b, bits);
        let dot = bitserial::gemm_unipolar(&ap, &bp).data[0] as i64;
        let expect: i64 = a.data.iter().zip(&b.data).map(|(x, y)| *x as i64 * *y as i64).sum();
        assert_eq!(dot, expect, "bit-serial arithmetic exact at {bits} bits");
        println!("  bs-{bits}bit popcount dot == integer dot over {k} real quantized values ✓");
    }

    // --- serving tiers: traced L2 demand, density, downshift walk ----------
    println!(
        "\nserving tiers (DESIGN.md §Tiers): the same precision story at the \
         serving layer\nprofiling the tiered serving menu (telemetry traces)..."
    );
    let model = InterferenceModel::new(&cpu);
    let profiles = serving_tier_mix_profiles(&cpu);
    let mut tiers = Table::new(
        format!(
            "Tiered serving menu on {} ({} KiB shared L2)",
            cpu.name,
            cpu.l2.size_bytes / 1024
        ),
        &["artifact", "tier", "demand KiB", "fit/worker", "downshift ->"],
    )
    .align(&[Align::Left, Align::Left, Align::Right, Align::Right, Align::Left]);
    for (name, prof) in profiles.iter() {
        let Some((tier, _)) = workloads::synthetic_tier(name) else { continue };
        let d = model.demand_bytes(prof);
        tiers.row(vec![
            name.clone(),
            tier.name().into(),
            format!("{}", d / 1024),
            format!("{}", (cpu.l2.size_bytes as u64 / d.max(1)).max(1)),
            workloads::degrade_artifact(name).unwrap_or_else(|| "(shed: floor)".into()),
        ]);
    }
    println!("{}", tiers.to_markdown());
    let tail = |tier: Tier| -> BTreeMap<String, CacheProfile> {
        [64usize, 96, 128]
            .iter()
            .filter_map(|&n| {
                let a = workloads::tier_artifact(tier, n);
                profiles.get(&a).map(|p| (a, p.clone()))
            })
            .collect()
    };
    println!(
        "interference-free workers for the n∈{{64,96,128}} tail: fp32 {}  int8 {}  bit-serial {}",
        min_workers_interference_free(&model, &tail(Tier::F32), 0.05),
        min_workers_interference_free(&model, &tail(Tier::Int8), 0.05),
        min_workers_interference_free(&model, &tail(Tier::BitSerial), 0.05),
    );
    println!(
        "serve it: cachebound serve --synthetic --tiers --tier-policy downshift \
         --admission degrade"
    );

    println!("\nwrote results/quantization_explorer_{}_{}.csv", cpu.name, layer.name);
    Ok(())
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}
