//! Host hardware survey: the paper's §III-B experiments on *this* machine.
//!
//! ```bash
//! cargo run --release --example membench_survey
//! ```
//!
//! Reproduces the methodology of Tables I/II and the peak benchmark: a
//! block-size bandwidth sweep (RAMspeed analog, with a finer grid than the
//! paper's three points so the cache capacities are visible as knees) and
//! an FMA-saturating peak measurement, then derives this host's own
//! cache-bound GEMM prediction — i.e. applies the paper's model to new
//! hardware, which is exactly the generalization §VI calls for.

use anyhow::Result;
use cachebound::membench;
use cachebound::util::csv::Csv;
use cachebound::util::table::{Align, Table};

fn main() -> Result<()> {
    println!("=== host hardware survey (paper §III-B methodology) ===\n");

    // --- peak ---------------------------------------------------------------
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("[1/2] computational peak ({} threads)...", threads);
    let single = membench::measure_peak(1, 0.5);
    let multi = membench::measure_peak(threads, 0.5);
    println!(
        "  single-thread: {:.2} GFLOP/s   all-threads: {:.2} GFLOP/s",
        single.flops_per_sec / 1e9,
        multi.flops_per_sec / 1e9
    );

    // --- bandwidth sweep -----------------------------------------------------
    println!("\n[2/2] bandwidth sweep (block sizes 4 KB … 64 MB)...");
    let extra: Vec<usize> = vec![
        8 << 10,
        16 << 10,
        32 << 10,
        64 << 10,
        128 << 10,
        512 << 10,
        1 << 20,
        2 << 20,
        4 << 20,
        8 << 20,
        32 << 20,
    ];
    let pts = membench::bandwidth_sweep(&extra);
    let mut t = Table::new(
        "Host bandwidth sweep (RAMspeed analog)",
        &["block", "read MiB/s", "write MiB/s"],
    )
    .align(&[Align::Right, Align::Right, Align::Right]);
    let mut csv = Csv::new(&["block_bytes", "read_mibs", "write_mibs"]);
    for p in &pts {
        let label = if p.block_bytes >= 1 << 20 {
            format!("{} MB", p.block_bytes >> 20)
        } else {
            format!("{} KB", p.block_bytes >> 10)
        };
        t.row(vec![
            label,
            format!("{:.0}", p.read_bw / (1 << 20) as f64),
            format!("{:.0}", p.write_bw / (1 << 20) as f64),
        ]);
        csv.row(vec![
            p.block_bytes.to_string(),
            format!("{:.0}", p.read_bw / (1 << 20) as f64),
            format!("{:.0}", p.write_bw / (1 << 20) as f64),
        ]);
    }
    println!("{}", t.to_markdown());
    csv.write("results/membench_survey.csv")?;

    // --- the cache-bound prediction for this host ----------------------------
    // paper's model: fastest-level read bandwidth bounds GEMM at p = 2·bw/4
    let l1_like = pts.first().unwrap().read_bw; // smallest block ≈ L1
    let bound_gflops = 2.0 * l1_like / 4.0 / 1e9;
    let peak_gflops = multi.flops_per_sec / 1e9;
    println!(
        "cache-bound model applied to this host:\n  L1-read bound on f32 GEMM: {:.1} GFLOP/s vs measured FMA peak {:.1} GFLOP/s",
        bound_gflops, peak_gflops
    );
    if bound_gflops < peak_gflops {
        println!(
            "  -> like the paper's ARM parts, this host CANNOT feed its FMA units from L1 at one read per MAC ({}x short)",
            (peak_gflops / bound_gflops).round()
        );
    } else {
        println!("  -> this host's L1 can feed its FMA units (not cache-bound by the model)");
    }
    println!("\nwrote results/membench_survey.csv");
    Ok(())
}
