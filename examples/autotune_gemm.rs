//! Auto-tuning study: random vs GBT cost-model tuner, simulator vs real
//! AOT-codegen measurement targets — the §III-A methodology as a runnable
//! ablation.
//!
//! ```bash
//! make artifacts && cargo run --release --example autotune_gemm -- [--n 256] [--trials 48]
//! ```
//!
//! 1. tunes an N×N×N GEMM on the A53 and A72 simulators with both tuners,
//!    printing best-so-far convergence curves (the AutoTVM ablation);
//! 2. if artifact variants exist for N, re-runs the measurement loop over
//!    *real* Pallas codegen through PJRT (the paper's actual loop: propose
//!    schedule → compile → run on device → feed the cost model);
//! 3. cross-checks: does the simulator's best schedule rank near the top
//!    of the artifact measurements?

use anyhow::Result;
use cachebound::hw::profile_by_name;
use cachebound::operators::gemm::GemmSchedule;
use cachebound::runtime::Registry;
use cachebound::tuner::{
    tune, ArtifactGemmTarget, GemmSpace, MeasureTarget, SearchSpace, SimGemmTarget, Tuner,
    TunerKind,
};
use cachebound::util::bench::BenchConfig;
use cachebound::util::csv::Csv;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = flag(&args, "--n").unwrap_or_else(|| "256".into()).parse()?;
    let trials: usize = flag(&args, "--trials").unwrap_or_else(|| "48".into()).parse()?;

    println!("=== auto-tuning study: GEMM N={n}, {trials} trials ===\n");
    let mut csv = Csv::new(&["profile", "tuner", "trial", "best_so_far_ms"]);

    // --- 1. simulator targets, both tuners, both profiles ------------------
    for profile in ["a53", "a72"] {
        let cpu = profile_by_name(profile)?.cpu;
        let space = GemmSpace::new(&cpu, n, n, n);
        println!("{} (space: {} configs):", cpu.name, space.len());
        for kind in [TunerKind::Random, TunerKind::Gbt] {
            let mut target = SimGemmTarget::square(&cpu, n);
            let res = tune(&Tuner::new(kind, trials), &space, &mut target)?;
            let curve = res.best_curve();
            for (i, b) in curve.iter().enumerate() {
                csv.row(vec![
                    profile.to_string(),
                    format!("{kind:?}"),
                    i.to_string(),
                    format!("{:.6}", b * 1e3),
                ]);
            }
            let gflops = 2.0 * (n as f64).powi(3) / res.best_seconds / 1e9;
            println!(
                "  {:<8} best {:?} -> {:.3} ms ({:.2} GFLOP/s); half-budget best {:.3} ms",
                format!("{kind:?}"),
                res.best_config,
                res.best_seconds * 1e3,
                gflops,
                curve[curve.len() / 2] * 1e3,
            );
        }
    }

    // --- 2. real-codegen measurement loop (artifact variants) --------------
    println!("\nreal-codegen measurement loop (PJRT artifact variants):");
    match Registry::open("artifacts") {
        Ok(mut reg) => {
            let variant_names = reg.names(Some("gemm_variant"));
            let available: Vec<GemmSchedule> = variant_names
                .iter()
                .filter(|name| name.contains(&format!("_n{n}_")))
                .filter_map(|name| parse_block(name))
                .collect();
            if available.is_empty() {
                println!("  no variants for N={n} (AOT grid covers N=128,256) — skipping");
            } else {
                let mut target = ArtifactGemmTarget {
                    registry: &mut reg,
                    n,
                    cfg: BenchConfig::quick(),
                };
                let mut measured: Vec<(GemmSchedule, f64)> = Vec::new();
                for s in &available {
                    let secs = target.measure(*s)?;
                    measured.push((*s, secs));
                    println!(
                        "  variant b{}x{}x{}: {:.3} ms/iter",
                        s.bm, s.bn, s.bk, secs * 1e3
                    );
                }
                measured.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                println!(
                    "  best real-codegen schedule: b{}x{}x{} ({:.3} ms)",
                    measured[0].0.bm,
                    measured[0].0.bn,
                    measured[0].0.bk,
                    measured[0].1 * 1e3
                );

                // --- 3. cross-check sim ranking vs artifact ranking ---------
                let cpu = profile_by_name("a53")?.cpu;
                let mut sim_target = SimGemmTarget::square(&cpu, n);
                let mut sim_ranked: Vec<(GemmSchedule, f64)> = available
                    .iter()
                    .map(|s| (*s, sim_target.measure(*s).unwrap()))
                    .collect();
                sim_ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                // the naive 8x8x8 variant must be ranked worst by both
                let worst_real = measured.last().unwrap().0;
                let worst_sim = sim_ranked.last().unwrap().0;
                println!(
                    "  worst by real codegen: b{}x{}x{}; worst by simulator: b{}x{}x{}",
                    worst_real.bm, worst_real.bn, worst_real.bk,
                    worst_sim.bm, worst_sim.bn, worst_sim.bk
                );
            }
        }
        Err(e) => println!("  skipping ({e:#}) — run `make artifacts`"),
    }

    csv.write("results/autotune_gemm_curves.csv")?;
    println!("\nwrote results/autotune_gemm_curves.csv");
    Ok(())
}

fn parse_block(name: &str) -> Option<GemmSchedule> {
    // gemm_f32_var_n128_b64x128x128
    let b = name.split("_b").nth(1)?;
    let mut it = b.split('x');
    Some(GemmSchedule::new(
        it.next()?.parse().ok()?,
        it.next()?.parse().ok()?,
        it.next()?.parse().ok()?,
        4,
    ))
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}
