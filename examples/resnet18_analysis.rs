//! End-to-end driver: the complete paper pipeline on the real ResNet-18
//! workload (Table III layers C2–C11).
//!
//! ```bash
//! make artifacts && cargo run --release --example resnet18_analysis
//! ```
//!
//! Exercises every layer of the system on a real workload:
//!  1. host hardware survey (peak + bandwidth, the Tables I/II analog),
//!  2. AOT artifact validation — all Pallas/JAX conv + GEMM variants
//!     execute through PJRT with cross-language checksum checks,
//!  3. auto-tuning of every conv layer (GBT cost model) on both calibrated
//!     ARM profiles,
//!  4. the full float32 analysis: per-layer times vs hardware bounds,
//!     boundedness classification (Figs 2/3),
//!  5. the quantized study: QNN int8 + bit-serial speedups (Figs 6–8),
//!  6. a paper-vs-reproduction summary table.
//!
//! Results land in `results/resnet18_analysis/`.  This run is recorded in
//! EXPERIMENTS.md as the headline end-to-end validation.

use anyhow::Result;
use cachebound::analysis::bounds::workload_bounds;
use cachebound::analysis::classify::classify;
use cachebound::coordinator::pipeline::{Pipeline, PipelineConfig};
use cachebound::hw::profile_by_name;
use cachebound::membench;
use cachebound::operators::workloads;
use cachebound::report;
use cachebound::runtime::Registry;
use cachebound::util::csv::Csv;
use cachebound::util::table::{Align, Table};

fn main() -> Result<()> {
    let out_dir = "results/resnet18_analysis";
    println!("=== cachebound: ResNet-18 end-to-end analysis ===\n");

    // --- 1. host hardware survey -----------------------------------------
    println!("[1/6] host hardware survey (membench)...");
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let peak = membench::measure_peak(threads, 0.5);
    let bw = membench::bandwidth_sweep(&[]);
    println!(
        "  host peak: {:.2} GFLOP/s ({} threads); L1-block read {:.0} MiB/s, RAM-block read {:.0} MiB/s",
        peak.flops_per_sec / 1e9,
        threads,
        bw[0].read_bw / (1 << 20) as f64,
        bw[2].read_bw / (1 << 20) as f64,
    );

    // --- 2. artifact validation ------------------------------------------
    println!("\n[2/6] validating AOT artifacts through PJRT...");
    let registry = match Registry::open("artifacts") {
        Ok(r) => Some(r),
        Err(e) => {
            println!("  WARNING: {e:#} — continuing without the PJRT path");
            None
        }
    };
    let mut pipeline = Pipeline::new(PipelineConfig {
        tune_trials: 48,
        skip_native: true,
        ..Default::default()
    });
    if let Some(reg) = registry {
        pipeline = pipeline.with_registry(reg);
        let results = pipeline.validate_artifacts()?;
        let passed = results.iter().filter(|(_, p)| *p).count();
        println!("  {passed}/{} artifacts validated (cross-language checksums)", results.len());
        assert_eq!(passed, results.len(), "artifact validation must be clean");

        // whole-model inference: the full ResNet-18 graph (stem + 8
        // residual blocks + head, every conv a Pallas kernel) through PJRT
        let reg = pipeline.registry.as_mut().unwrap();
        if reg.manifest.by_name("resnet18_full_i32").is_some() {
            let cfg = cachebound::util::bench::BenchConfig::quick();
            let m = reg.measure("resnet18_full_i32", &cfg)?;
            let macs = reg.manifest.by_name("resnet18_full_i32").unwrap().macs as f64;
            println!(
                "  whole-model ResNet-18 (32x32 input, {:.1} MMACs): {:.1} ms/inference via PJRT",
                macs / 1e6,
                m.seconds.median * 1e3
            );
        }
    }

    // --- 3. auto-tune every conv layer on both profiles -------------------
    println!("\n[3/6] auto-tuning conv schedules (GBT cost model)...");
    for profile in ["a53", "a72"] {
        pipeline.conv_layers(profile)?;
        let cpu = profile_by_name(profile)?.cpu;
        let tuned: Vec<String> = pipeline
            .store
            .by_prefix(&format!("tune_conv/{}/", cpu.name))
            .iter()
            .map(|(k, v)| {
                format!(
                    "{} -> {}",
                    k.split('/').nth(2).unwrap_or("?"),
                    v.detail.clone().unwrap_or_default()
                )
            })
            .collect();
        println!("  {}: tuned {} layers", cpu.name, tuned.len());
    }

    // --- 4. float32 analysis (Figs 2/3) ------------------------------------
    println!("\n[4/6] float32 conv analysis vs hardware bounds...");
    let mut table = Table::new(
        "ResNet-18 float32 (cortex-a53 simulation)",
        &["layer", "MACs", "sim ms", "L1 bound ms", "GFLOP/s", "classified"],
    )
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Left]);
    let cpu = profile_by_name("a53")?.cpu;
    let (fig23, csv23) = report::fig2_fig3(&mut pipeline, "a53")?;
    let mut l1_bound_layers = 0;
    for (i, lname) in fig23.layers.iter().enumerate() {
        let l = workloads::layer_by_name(lname).unwrap();
        let t = fig23.measured_s[i];
        let b = workload_bounds(&cpu, l.macs(), 4.0, 32);
        let class = classify(t, &b, 2.5);
        // the paper's Fig 2 caption: "mostly execution time correlates
        // with L1 or L2 cache read times"
        if class.name().contains("L1") || class.name().contains("L2") {
            l1_bound_layers += 1;
        }
        table.row(vec![
            lname.clone(),
            l.macs().to_string(),
            format!("{:.2}", t * 1e3),
            format!("{:.2}", b.l1_read_s * 1e3),
            format!("{:.2}", 2.0 * l.macs() as f64 / t / 1e9),
            class.name(),
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "  {}/{} layers classified as L1/L2-cache-bound (paper: 'mostly correlates with L1 or L2')",
        l1_bound_layers, fig23.layers.len()
    );
    csv23.write(format!("{out_dir}/fig2_fig3_a53.csv"))?;

    // --- 5. quantized study (Figs 6-8) --------------------------------------
    println!("\n[5/6] quantized operators: QNN int8 + bit-serial...");
    let (f678, csv6, csv7, csv8) = report::fig6_fig7_fig8(&mut pipeline, "a72")?;
    csv6.write(format!("{out_dir}/fig6_a72.csv"))?;
    csv7.write(format!("{out_dir}/fig7_a72.csv"))?;
    csv8.write(format!("{out_dir}/fig8_a72.csv"))?;
    let mut qtab = Table::new(
        "Speedup over float32 (cortex-a72 simulation)",
        &["layer", "qnn8", "bs-1bit", "bs-2bit", "bs-8bit"],
    );
    for r in &f678.rows {
        qtab.row(vec![
            r.layer.clone(),
            format!("{:.2}", r.speedup_qnn()),
            format!("{:.2}", r.speedup_bits(1, true).unwrap_or(f64::NAN)),
            format!("{:.2}", r.speedup_bits(2, true).unwrap_or(f64::NAN)),
            format!("{:.2}", r.speedup_bits(8, true).unwrap_or(f64::NAN)),
        ]);
    }
    println!("{}", qtab.to_markdown());

    // --- 6. paper-vs-reproduction summary ----------------------------------
    println!("[6/6] summary vs paper claims:");
    let (fig1, csv1) = report::fig1(&mut pipeline, "a53")?;
    csv1.write(format!("{out_dir}/fig1_a53.csv"))?;
    let mut summary = Csv::new(&["claim", "paper", "reproduction"]);
    let checks: Vec<(&str, &str, String)> = vec![
        (
            "GEMM binding constraint",
            "L1-read",
            fig1.best_bound.clone(),
        ),
        (
            "3x3 conv outperforms 1x1",
            "yes",
            {
                let top = &fig23.sorted_perf[0].0;
                if ["C2", "C5", "C8", "C11"].contains(&top.as_str()) { "yes" } else { "no" }
                    .to_string()
            },
        ),
        (
            "1-bit speedup > 8-bit speedup (geomean)",
            "yes",
            {
                let g = |bits: usize| {
                    let v: Vec<f64> = f678
                        .rows
                        .iter()
                        .filter_map(|r| r.speedup_bits(bits, true))
                        .collect();
                    cachebound::util::stats::geomean(&v)
                };
                if g(1) > g(8) { "yes" } else { "no" }.to_string()
            },
        ),
    ];
    for (claim, paper, ours) in &checks {
        println!("  {claim:<42} paper: {paper:<16} ours: {ours}");
        summary.row(vec![claim.to_string(), paper.to_string(), ours.clone()]);
    }
    summary.write(format!("{out_dir}/summary.csv"))?;
    let all_match = checks.iter().all(|(_, p, o)| *p == o.as_str());
    println!(
        "\n=== end-to-end analysis complete: {} ===",
        if all_match { "ALL PAPER CLAIMS REPRODUCED" } else { "MISMATCHES FOUND" }
    );
    println!("results in {out_dir}/");
    assert!(all_match);
    Ok(())
}
