//! Serving demo: batched operator requests through the PJRT registry —
//! the deployment loop of the three-layer architecture with **no python
//! anywhere on the request path**.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving_demo -- [--requests 64]
//! ```
//!
//! A synthetic client submits a mixed stream of requests (whole ResNet-18
//! inferences + individual GEMM/conv operators of several quantizations);
//! the server groups consecutive same-model requests, executes through
//! compiled XLA executables, and reports per-model latency percentiles and
//! aggregate throughput.

use anyhow::Result;
use cachebound::coordinator::server::{BatchPolicy, Request, Server};
use cachebound::runtime::Registry;
use cachebound::util::rng::Xoshiro256;
use cachebound::util::stats::Summary;
use cachebound::util::table::{fmt_time, Align, Table};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64);

    println!("=== serving demo: {n_requests} mixed requests ===\n");
    let registry = Registry::open("artifacts")?;
    let mut server = Server::new(registry, BatchPolicy { max_batch: 8 });

    // the served "models": whole-network + operators across quantizations
    let menu = [
        "resnet18_full_i32",
        "gemm_f32_tuned_n256",
        "gemm_qnn8_n256",
        "gemm_bs_uni_a2w2_n256_prepacked",
        "conv_f32_c11",
        "conv_qnn8_c11",
    ];
    let mut rng = Xoshiro256::new(0xD15C);
    // bursty traffic: runs of the same model (batching-friendly), random
    // model per burst — a plausible inference-serving arrival pattern
    let mut id = 0u64;
    while (id as usize) < n_requests {
        let model = *rng.choose(&menu);
        let burst = 1 + rng.below(6);
        for _ in 0..burst.min((n_requests - id as usize) as u64) {
            server.submit(Request { id, artifact: model.to_string() });
            id += 1;
        }
    }

    let t0 = std::time::Instant::now();
    let responses = server.drain();
    let wall = t0.elapsed().as_secs_f64();

    // per-model breakdown
    let mut table = Table::new(
        "Per-model serving latency (exec time, excludes cold compile)",
        &["model", "requests", "p50", "p95", "max"],
    )
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for model in menu {
        let lat: Vec<f64> = responses
            .iter()
            .filter(|r| r.artifact == model && r.ok)
            .map(|r| r.exec_seconds)
            .collect();
        if lat.is_empty() {
            continue;
        }
        let s = Summary::of(&lat);
        let mut sorted = lat.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = cachebound::util::stats::percentile_sorted(&sorted, 95.0);
        table.row(vec![
            model.into(),
            lat.len().to_string(),
            fmt_time(s.median),
            fmt_time(p95),
            fmt_time(s.max),
        ]);
    }
    println!("{}", table.to_markdown());

    let ok = responses.iter().filter(|r| r.ok).count();
    println!(
        "served {ok}/{} requests in {:.2}s -> {:.1} req/s across {} batches",
        responses.len(),
        wall,
        server.metrics.throughput(wall),
        server.metrics.batches
    );
    assert_eq!(ok, responses.len(), "all requests must succeed");
    Ok(())
}
