//! Quickstart: the cache-bound model in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the paper's core argument on the Cortex-A53 profile:
//! 1. eq. (1) theoretical peak vs the measured bandwidths (Tables I/II),
//! 2. a tuned GEMM simulated under the calibrated machine model,
//! 3. classification: which hardware bound explains the time,
//! 4. (if `make artifacts` was run) the same operator as a real Pallas→
//!    PJRT artifact executing from rust.

use anyhow::Result;
use cachebound::analysis::bounds::gemm_bounds;
use cachebound::analysis::classify::classify;
use cachebound::analysis::required_bw::required_bandwidth;
use cachebound::hw::{profile_by_name, MemLevel};
use cachebound::operators::gemm::GemmSchedule;
use cachebound::runtime::Registry;
use cachebound::sim::timing::simulate_gemm_time;

fn main() -> Result<()> {
    let profile = profile_by_name("a53")?;
    let cpu = &profile.cpu;
    println!("== cachebound quickstart ==\n");
    println!(
        "machine: {} ({}) — {} cores @ {:.1} GHz, NEON {} bit",
        cpu.name,
        cpu.soc,
        cpu.cores,
        cpu.frequency_hz / 1e9,
        cpu.simd_bits
    );
    println!(
        "eq.(1) theoretical peak: {:.1} GFLOP/s (float32)",
        cpu.peak_flops(32) / 1e9
    );
    println!(
        "measured bandwidths (Table I): L1 {:.0} / L2 {:.0} / RAM {:.0} MiB/s read\n",
        cpu.l1.read_bw, cpu.l2.read_bw, cpu.ram_read_bw
    );

    // 2. simulate a tuned 512x512 GEMM
    let n = 512;
    let schedule = GemmSchedule::new(64, 64, 64, 4);
    let tb = simulate_gemm_time(cpu, n, n, n, schedule, 32);
    let flops = 2.0 * (n as f64).powi(3);
    println!(
        "simulated tuned GEMM N={n}: {:.3} ms -> {:.2} GFLOP/s (binding: {})",
        tb.total_s * 1e3,
        flops / tb.total_s / 1e9,
        tb.bound.name()
    );

    // 3. classify against the paper's bound lines
    let bounds = gemm_bounds(cpu, n);
    println!(
        "bound lines: compute {:.3} ms | L1 {:.3} ms | L2 {:.3} ms | RAM {:.3} ms",
        bounds.compute_s * 1e3,
        bounds.l1_read_s * 1e3,
        bounds.l2_read_s * 1e3,
        bounds.ram_read_s * 1e3
    );
    let class = classify(tb.total_s, &bounds, 2.0);
    println!("classification: **{}** (the paper's central finding)\n", class.name());

    // eq. (5): what bandwidth would the peak need?
    let req = required_bandwidth(cpu.peak_flops(32), 4.0);
    println!(
        "to sustain the {:.1} GFLOP/s peak, eq.(5) demands {:.1} GiB/s from L1 — {:.1}x what it has",
        cpu.peak_flops(32) / 1e9,
        req.bw_req / (1 << 30) as f64,
        req.utilization(cpu, MemLevel::L1)
    );

    // 4. the real artifact path (optional)
    match Registry::open("artifacts") {
        Ok(mut reg) => {
            let name = "gemm_f32_tuned_n512";
            let v = reg.validate(name)?;
            println!(
                "\nPJRT artifact '{name}': checksum {} (expected {:.3}, got {:.3})",
                if v.passed { "OK" } else { "MISMATCH" },
                v.details[0].0,
                v.details[0].1
            );
            let m = reg.measure(name, &cachebound::util::bench::BenchConfig::quick())?;
            println!(
                "host wallclock via PJRT: {:.3} ms/iter (interpret-mode Pallas; structural, not ARM-comparable)",
                m.seconds.median * 1e3
            );
        }
        Err(_) => println!("\n(run `make artifacts` to exercise the Pallas → PJRT path)"),
    }
    Ok(())
}
