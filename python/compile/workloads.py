"""Shared workload definitions — the paper's Table III and GEMM sweeps.

This is the single python-side source of truth for the evaluated workloads;
``aot.py`` embeds it into ``artifacts/manifest.json`` so the rust coordinator
uses identical geometry (rust re-derives MACs and cross-checks, see
``operators::conv`` tests).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ConvLayer:
    """One ResNet-18 convolution layer (paper Table III)."""

    name: str
    b: int
    cin: int
    cout: int
    h: int
    w: int
    k: int
    stride: int
    pad: int

    @property
    def ho(self) -> int:
        """Real tensor output height (standard conv arithmetic)."""
        return (self.h + 2 * self.pad - self.k) // self.stride + 1

    @property
    def wo(self) -> int:
        return (self.w + 2 * self.pad - self.k) // self.stride + 1

    @property
    def ho_eq3(self) -> int:
        """Paper eq. (3): h_out = (h_in + 2p)/s — *without* the kernel-extent
        term.  Table III's MAC column is computed with this (verified: C2 =
        58*58*64*64*9 = 124,010,496), so all performance numbers in the paper
        use it; we keep it for MAC accounting and use ``ho`` for tensors."""
        return (self.h + 2 * self.pad) // self.stride

    @property
    def wo_eq3(self) -> int:
        return (self.w + 2 * self.pad) // self.stride

    @property
    def macs(self) -> int:
        """Paper eq. (4) with eq. (3) output sizes — matches Table III."""
        return (
            self.b * self.ho_eq3 * self.wo_eq3 * self.cin * self.cout
            * self.k * self.k
        )

    @property
    def macs_exact(self) -> int:
        """MACs actually executed by the real output geometry."""
        return self.b * self.ho * self.wo * self.cin * self.cout * self.k * self.k


# Paper Table III: ResNet-18 layers C2..C11 (C1 excluded: too shallow for
# bit packing and quantization-sensitive, per §III-C2).
RESNET18_LAYERS = [
    ConvLayer("C2", 1, 64, 64, 56, 56, 3, 1, 1),
    ConvLayer("C3", 1, 64, 128, 56, 56, 3, 2, 1),
    ConvLayer("C4", 1, 64, 128, 56, 56, 1, 2, 0),
    ConvLayer("C5", 1, 128, 128, 28, 28, 3, 1, 1),
    ConvLayer("C6", 1, 128, 256, 28, 28, 3, 2, 1),
    ConvLayer("C7", 1, 128, 256, 28, 28, 1, 2, 0),
    ConvLayer("C8", 1, 256, 256, 14, 14, 3, 1, 1),
    ConvLayer("C9", 1, 256, 512, 14, 14, 3, 2, 1),
    ConvLayer("C10", 1, 256, 512, 14, 14, 1, 2, 0),
    ConvLayer("C11", 1, 512, 512, 7, 7, 3, 1, 1),
]

# Paper Table III column "MACs" — used as a cross-check in tests.
PAPER_MACS = {
    "C2": 124_010_496,
    "C3": 62_005_248,
    "C4": 6_422_528,
    "C5": 132_710_400,
    "C6": 66_355_200,
    "C7": 6_422_528,
    "C8": 150_994_944,
    "C9": 75_497_472,
    "C10": 6_422_528,
    "C11": 191_102_976,
}

# GEMM sweep of Tables IV/V (AOT artifacts cover these; the native rust
# operators extend the sweep to the finer Fig 1/9 grid).
GEMM_SIZES = [32, 128, 256, 512, 1024]

# Schedule variants emitted per GEMM size so the rust tuner has a real
# artifact-backed measurement space (AutoTVM analog over codegen variants).
GEMM_VARIANT_SIZES = [128, 256]
GEMM_VARIANTS = [
    (8, 8, 8),
    (32, 32, 32),
    (64, 64, 64),
    (128, 128, 128),
    (64, 128, 128),
    (128, 64, 32),
]

# Bit-serial configurations (paper Figs 4-8): bits x {unipolar, bipolar}.
BITSERIAL_BITS = [1, 2, 4, 8]
BITSERIAL_GEMM_SIZES = [128, 256, 512]
QNN_GEMM_SIZES = [128, 256, 512]
