"""L1 Pallas kernels and their pure-jnp reference oracles."""

from . import bitpack, bitserial, conv2d, gemm, pooling, qnn, ref  # noqa: F401
