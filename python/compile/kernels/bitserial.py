"""L1 Pallas kernel: bit-serial GEMM over packed bit-planes.

The paper's Section V quantized-operator study (Figs 4–8) uses TVM's
bit-serial dense/conv operators (Cowan et al. CGO'20, BISMO-style): the
precision dimension is processed *serially* — one plane pair at a time —
while the K dimension is processed in parallel with vectorized full-word
logical ops and popcounts.

Arithmetic (see ``ref.py`` for the oracle):

* unipolar: ``out += 2^(i+j) * popcount(a_i & w_j)``
* bipolar:  ``out += 2^(i+j) * (K - 2*popcount(a_i ^ w_j))``  — one extra
  subtract per word pair, which is why the paper finds bipolar *faster*
  than unipolar's extra ``AND``+popcount-correction variant in TVM; here the
  cost difference is one subtract, kept for fidelity.

Schedule: grid over (M blocks, N blocks); the (ba·bw) plane loop and the
packed-K reduction run inside the kernel instance.  The packed operand rows
are the VMEM-resident panels; one 32-lane uint32 word carries 32 MACs, which
is exactly the data-volume reduction the cache-bound model credits
quantization with (eq. 5: d = bits/8 bytes per MAC operand).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


class BitserialSchedule(NamedTuple):
    """Schedule knobs: output tile (bm × bn)."""

    bm: int = 64
    bn: int = 64

    def clamp(self, m: int, n: int) -> "BitserialSchedule":
        return BitserialSchedule(min(self.bm, m), min(self.bn, n))

    def vmem_bytes(self, ba: int, bw: int, kw: int) -> int:
        """Packed A rows + packed W rows + int32 accumulator tile."""
        return ba * self.bm * kw * 4 + bw * self.bn * kw * 4 + self.bm * self.bn * 4


def _bitserial_kernel(a_ref, w_ref, o_ref, *, ba: int, bw: int, unipolar: bool, k: int):
    """One (bm, bn) int32 output tile; serial loop over plane pairs.

    a_ref: (ba, bm, kw) uint32; w_ref: (bw, bn, kw) uint32.
    """
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for i in range(ba):
        a_plane = a_ref[i]  # (bm, kw)
        for j in range(bw):
            w_plane = w_ref[j]  # (bn, kw)
            if unipolar:
                words = a_plane[:, None, :] & w_plane[None, :, :]
                pc = jax.lax.population_count(words).astype(jnp.int32).sum(-1)
                acc = acc + (pc << (i + j))
            else:
                words = a_plane[:, None, :] ^ w_plane[None, :, :]
                pc = jax.lax.population_count(words).astype(jnp.int32).sum(-1)
                acc = acc + ((k - 2 * pc) << (i + j))
    o_ref[...] = acc


def bitserial_gemm(
    a_planes: jax.Array,
    w_planes: jax.Array,
    k: int,
    unipolar: bool = True,
    schedule: BitserialSchedule = BitserialSchedule(),
    interpret: bool = True,
) -> jax.Array:
    """Bit-serial GEMM over packed planes.

    a_planes: (ba, M, K/32) uint32, w_planes: (bw, N, K/32) uint32 ->
    int32 (M, N).  ``k`` is the unpacked reduction length (for bipolar).
    """
    ba, m, kw = a_planes.shape
    bw, n, kw2 = w_planes.shape
    assert kw == kw2, (a_planes.shape, w_planes.shape)
    s = schedule.clamp(m, n)
    if m % s.bm or n % s.bn:
        raise ValueError(f"schedule {s} does not divide ({m},{n})")
    kernel = functools.partial(
        _bitserial_kernel, ba=ba, bw=bw, unipolar=unipolar, k=k
    )
    return pl.pallas_call(
        kernel,
        grid=(m // s.bm, n // s.bn),
        in_specs=[
            pl.BlockSpec((ba, s.bm, kw), lambda i, j: (0, i, 0)),
            pl.BlockSpec((bw, s.bn, kw), lambda i, j: (0, j, 0)),
        ],
        out_specs=pl.BlockSpec((s.bm, s.bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_planes, w_planes)
