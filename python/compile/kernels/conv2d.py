"""L1 Pallas kernel: spatial-pack style conv2d (NCHW, OIHW weights).

The paper benchmarks TVM's ARM ``conv2d spatial pack`` operator on the
ResNet-18 layers of Table III.  The spatial-pack idea — tile the output
spatially, keep a weight panel resident, and unroll the small k×k window so
each tap becomes a dense MAC sweep — maps onto Pallas as:

* grid over (output-channel blocks, output-row blocks): each instance owns a
  ``(bco, brow, wo)`` output tile in VMEM (the paper's register tile);
* the ``(bco, cin, k, k)`` weight panel stays VMEM-resident across row blocks
  (the L1-hot operand of the cache-bound model);
* the k×k taps are a Python-unrolled loop — each tap is one MXU contraction
  over ``cin`` (the paper's unrolled NEON MAC chain);
* the input rows for a tile are fetched with ``pl.ds`` dynamic slices because
  overlapping windows cannot be expressed in block-unit ``BlockSpec``s; this
  is exactly the HBM→VMEM streaming schedule the paper implements as the
  L1-cache streaming of the non-resident operand.

Kernels lower with ``interpret=True`` (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


class ConvSchedule(NamedTuple):
    """Schedule knobs: output-channel block and output-row block."""

    bco: int = 32
    brow: int = 8

    def clamp(self, cout: int, ho: int) -> "ConvSchedule":
        return ConvSchedule(min(self.bco, cout), min(self.brow, ho))

    def vmem_bytes(self, cin: int, k: int, wo: int, stride: int, dtype_bytes: int = 4) -> int:
        """Weight panel + streamed input rows + output tile, per instance."""
        in_rows = (self.brow - 1) * stride + k
        in_cols = (wo - 1) * stride + k
        return (
            self.bco * cin * k * k * dtype_bytes
            + cin * in_rows * in_cols * dtype_bytes
            + self.bco * self.brow * wo * 4
        )


NAIVE_CONV_SCHEDULE = ConvSchedule(4, 1)
TUNED_CONV_SCHEDULE = ConvSchedule(32, 8)


def padded_geometry(h: int, w: int, k: int, stride: int, pad: int, brow: int):
    """Output geometry plus the bottom over-padding that makes ho a multiple
    of ``brow`` (the wrapper crops the extra rows afterwards)."""
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    ho_pad = math.ceil(ho / brow) * brow
    extra = (ho_pad - 1) * stride + k - (h + 2 * pad)
    return ho, wo, ho_pad, max(extra, 0)


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int, wo: int, brow: int, relu: bool):
    """Compute a (bco, brow, wo) output tile from the full padded image.

    x_ref: (cin, hp, wp) full padded input (block index pinned to origin).
    w_ref: (bco, cin, k, k) resident weight panel.
    """
    r = pl.program_id(1)
    row0 = r * brow * stride
    span = (brow - 1) * stride + 1
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for dy in range(k):
        rows = x_ref[:, pl.ds(row0 + dy, span), :]
        rows = rows[:, ::stride, :]  # (cin, brow, wp)
        for dx in range(k):
            patch = rows[:, :, dx : dx + (wo - 1) * stride + 1 : stride]
            tap = w_ref[:, :, dy, dx]  # (bco, cin)
            acc += jnp.einsum(
                "oc,chw->ohw", tap, patch, preferred_element_type=jnp.float32
            )
    o_ref[...] = jnp.maximum(acc, 0.0) if relu else acc


def conv2d_nchw(
    x: jax.Array,
    w: jax.Array,
    stride: int,
    pad: int,
    schedule: ConvSchedule = TUNED_CONV_SCHEDULE,
    relu: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Spatial-pack conv: x (B,cin,H,W), w (cout,cin,k,k) -> (B,cout,ho,wo).

    Batch is handled by vmap — the paper uses batch size 1 throughout
    (Table III), so the batch axis never enters the schedule.
    """
    b, cin, h, wdt = x.shape
    cout, cin2, k, k2 = w.shape
    assert cin == cin2 and k == k2, (x.shape, w.shape)
    s = schedule.clamp(cout, (h + 2 * pad - k) // stride + 1)
    if cout % s.bco:
        raise ValueError(f"bco={s.bco} does not divide cout={cout}")
    ho, wo, ho_pad, extra = padded_geometry(h, wdt, k, stride, pad, s.brow)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad + extra), (pad, pad)))
    hp, wp = xp.shape[2], xp.shape[3]

    kernel = functools.partial(
        _conv_kernel, k=k, stride=stride, wo=wo, brow=s.brow, relu=relu
    )

    def one_image(xi):
        out = pl.pallas_call(
            kernel,
            grid=(cout // s.bco, ho_pad // s.brow),
            in_specs=[
                pl.BlockSpec((cin, hp, wp), lambda co, r: (0, 0, 0)),
                pl.BlockSpec((s.bco, cin, k, k), lambda co, r: (co, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((s.bco, s.brow, wo), lambda co, r: (co, r, 0)),
            out_shape=jax.ShapeDtypeStruct((cout, ho_pad, wo), jnp.float32),
            interpret=interpret,
        )(xi, w)
        return out[:, :ho, :]

    return jax.vmap(one_image)(xp)


# ---------------------------------------------------------------------------
# IM2COL + GEMM convolution — the paper's §III-C2 alternative algorithm
# ---------------------------------------------------------------------------


def _im2col_kernel(x_ref, o_ref, *, k: int, stride: int, wo: int, brow: int, cin: int):
    """Materialize the (brow*wo, cin*k*k) column block for one row block."""
    r = pl.program_id(0)
    row0 = r * brow * stride
    span = (brow - 1) * stride + 1
    cols = []
    for dy in range(k):
        rows = x_ref[:, pl.ds(row0 + dy, span), :]
        rows = rows[:, ::stride, :]
        for dx in range(k):
            patch = rows[:, :, dx : dx + (wo - 1) * stride + 1 : stride]
            cols.append(patch.reshape(cin, brow * wo))
    # (cin, P, k*k) -> (P, cin*k*k); column order (c, dy, dx) matches ref.im2col
    stacked = jnp.stack(cols, axis=-1)
    o_ref[...] = stacked.transpose(1, 0, 2).reshape(brow * wo, cin * k * k)


def im2col(
    x: jax.Array,
    k: int,
    stride: int,
    pad: int,
    brow: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """IM2COL lowering: x (B,cin,H,W) -> (B, ho*wo, cin*k*k)."""
    b, cin, h, wdt = x.shape
    ho, wo, ho_pad, extra = padded_geometry(h, wdt, k, stride, pad, min(brow, h))
    brow = min(brow, ho_pad)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad + extra), (pad, pad)))
    hp, wp = xp.shape[2], xp.shape[3]
    kernel = functools.partial(
        _im2col_kernel, k=k, stride=stride, wo=wo, brow=brow, cin=cin
    )

    def one_image(xi):
        out = pl.pallas_call(
            kernel,
            grid=(ho_pad // brow,),
            in_specs=[pl.BlockSpec((cin, hp, wp), lambda r: (0, 0, 0))],
            out_specs=pl.BlockSpec((brow * wo, cin * k * k), lambda r: (r, 0)),
            out_shape=jax.ShapeDtypeStruct((ho_pad * wo, cin * k * k), x.dtype),
            interpret=interpret,
        )(xi)
        return out[: ho * wo, :]

    return jax.vmap(one_image)(xp)
