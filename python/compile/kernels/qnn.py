"""L1 Pallas kernels: QNN-style int8 GEMM and conv2d.

The paper's "8-bit QNN" operators (TVM's QNN dialect, NCHW layout) are the
de-facto-standard quantization baseline in Figs 6–8.  Arithmetic: int8
operands, int32 accumulation, optional affine requantization back to int8.

The cache-bound significance is purely the 4× operand-size reduction
(d = 1 byte per MAC read in eq. 5); the schedule shape is identical to the
float32 kernels so measured differences isolate the data-volume effect —
exactly how the paper frames the comparison.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import GemmSchedule
from .conv2d import ConvSchedule, padded_geometry


def _qnn_gemm_kernel(x_ref, w_ref, o_ref):
    """int8 x int8 -> int32 tile with the k grid axis as accumulator walk."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def qnn_gemm(
    x: jax.Array,
    w: jax.Array,
    schedule: GemmSchedule = GemmSchedule(),
    interpret: bool = True,
) -> jax.Array:
    """int8 GEMM ``(M,K) @ (K,N) -> int32 (M,N)``."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    s = schedule.clamp(m, n, k)
    if not s.divides(m, n, k):
        raise ValueError(f"schedule {s} does not divide problem ({m},{n},{k})")
    return pl.pallas_call(
        _qnn_gemm_kernel,
        grid=(m // s.bm, n // s.bn, k // s.bk),
        in_specs=[
            pl.BlockSpec((s.bm, s.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((s.bk, s.bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((s.bm, s.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)


def _requant_kernel(acc_ref, o_ref, *, scale: float, zp: int):
    """Affine requantization: int32 -> int8 with round + clip."""
    v = acc_ref[...].astype(jnp.float32) * scale + zp
    o_ref[...] = jnp.clip(jnp.round(v), -128, 127).astype(jnp.int8)


def requantize(
    acc: jax.Array,
    scale: float,
    zp: int = 0,
    block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Requantize an int32 accumulator tensor (M, N) to int8."""
    m, n = acc.shape
    bm = min(block, m)
    if m % bm:
        raise ValueError(f"block={bm} does not divide M={m}")
    kernel = functools.partial(_requant_kernel, scale=scale, zp=zp)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        interpret=interpret,
    )(acc)


def _qnn_conv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int, wo: int, brow: int):
    """int8 spatial-pack conv tile with int32 accumulation."""
    r = pl.program_id(1)
    row0 = r * brow * stride
    span = (brow - 1) * stride + 1
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for dy in range(k):
        rows = x_ref[:, pl.ds(row0 + dy, span), :]
        rows = rows[:, ::stride, :].astype(jnp.int32)
        for dx in range(k):
            patch = rows[:, :, dx : dx + (wo - 1) * stride + 1 : stride]
            tap = w_ref[:, :, dy, dx].astype(jnp.int32)
            acc += jnp.einsum("oc,chw->ohw", tap, patch, preferred_element_type=jnp.int32)
    o_ref[...] = acc


def qnn_conv2d_nchw(
    x: jax.Array,
    w: jax.Array,
    stride: int,
    pad: int,
    schedule: ConvSchedule = ConvSchedule(),
    interpret: bool = True,
) -> jax.Array:
    """int8 conv: x (B,cin,H,W) int8, w (cout,cin,k,k) int8 -> int32 NCHW."""
    b, cin, h, wdt = x.shape
    cout, cin2, k, k2 = w.shape
    assert cin == cin2 and k == k2, (x.shape, w.shape)
    s = schedule.clamp(cout, (h + 2 * pad - k) // stride + 1)
    if cout % s.bco:
        raise ValueError(f"bco={s.bco} does not divide cout={cout}")
    ho, wo, ho_pad, extra = padded_geometry(h, wdt, k, stride, pad, s.brow)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad + extra), (pad, pad)))
    hp, wp = xp.shape[2], xp.shape[3]
    kernel = functools.partial(
        _qnn_conv_kernel, k=k, stride=stride, wo=wo, brow=s.brow
    )

    def one_image(xi):
        out = pl.pallas_call(
            kernel,
            grid=(cout // s.bco, ho_pad // s.brow),
            in_specs=[
                pl.BlockSpec((cin, hp, wp), lambda co, r: (0, 0, 0)),
                pl.BlockSpec((s.bco, cin, k, k), lambda co, r: (co, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((s.bco, s.brow, wo), lambda co, r: (co, r, 0)),
            out_shape=jax.ShapeDtypeStruct((cout, ho_pad, wo), jnp.int32),
            interpret=interpret,
        )(xi, w)
        return out[:, :ho, :]

    return jax.vmap(one_image)(xp)
