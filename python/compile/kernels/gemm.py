"""L1 Pallas kernels: tiled float32 GEMM.

The paper's GEMM study (Tables IV/V, Figs 1 & 9) compares *naive* and
*auto-tuned* TVM schedules.  We mirror that with a parameterized Pallas
schedule: the block shape ``(bm, bn, bk)`` is the schedule knob the tuner
searches over (the TPU analog of TVM's tiling factors), and "naive" is a
deliberately-untuned small-tile default.

Hardware adaptation (DESIGN.md §3): the paper keeps one operand panel hot in
L1 and streams the other through NEON registers.  Here the ``BlockSpec``
keeps an ``(bm, bk)`` A-panel and a ``(bk, bn)`` B-panel resident in VMEM and
the MXU consumes them; the grid's k axis plays the paper's outer-K loop and
the revisited output block is the accumulator.  Kernels are lowered with
``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


class GemmSchedule(NamedTuple):
    """Schedule knobs for the tiled GEMM — the tuner's search-space axes.

    ``bm``/``bn``/``bk`` are the VMEM block sizes of the M/N/K loops.  The
    MXU-friendly default is 128 (the systolic array edge); "naive" uses 8.
    """

    bm: int = 128
    bn: int = 128
    bk: int = 128

    def clamp(self, m: int, n: int, k: int) -> "GemmSchedule":
        """Clamp block sizes to the problem so tiny problems still lower."""
        return GemmSchedule(min(self.bm, m), min(self.bn, n), min(self.bk, k))

    def divides(self, m: int, n: int, k: int) -> bool:
        return m % self.bm == 0 and n % self.bn == 0 and k % self.bk == 0

    def vmem_bytes(self, dtype_bytes: int = 4) -> int:
        """Resident VMEM footprint: A panel + B panel + f32 output block."""
        return (
            self.bm * self.bk * dtype_bytes
            + self.bk * self.bn * dtype_bytes
            + self.bm * self.bn * 4
        )


NAIVE_SCHEDULE = GemmSchedule(8, 8, 8)
TUNED_SCHEDULE = GemmSchedule(128, 128, 128)


def _gemm_kernel(x_ref, w_ref, o_ref):
    """One (bm,bn) output tile; grid axis 2 walks the K panels.

    The output block index is independent of the k grid axis, so the same
    VMEM block is revisited across k steps and serves as the accumulator.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def gemm(
    x: jax.Array,
    w: jax.Array,
    schedule: GemmSchedule = TUNED_SCHEDULE,
    interpret: bool = True,
) -> jax.Array:
    """Tiled GEMM ``(M,K) @ (K,N) -> (M,N)`` float32."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    s = schedule.clamp(m, n, k)
    if not s.divides(m, n, k):
        raise ValueError(f"schedule {s} does not divide problem ({m},{n},{k})")
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // s.bm, n // s.bn, k // s.bk),
        in_specs=[
            pl.BlockSpec((s.bm, s.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((s.bk, s.bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((s.bm, s.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """Fused dense tile: GEMM accumulate + bias + optional ReLU on flush."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        o_ref[...] = jnp.maximum(acc, 0.0) if relu else acc


def dense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    schedule: GemmSchedule = TUNED_SCHEDULE,
    relu: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Dense layer ``relu(x @ w + b)`` — the paper's dense operator, with the
    bias/activation epilogue fused into the flush step of the GEMM."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    s = schedule.clamp(m, n, k)
    if not s.divides(m, n, k):
        raise ValueError(f"schedule {s} does not divide problem ({m},{n},{k})")
    kernel = functools.partial(_dense_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(m // s.bm, n // s.bn, k // s.bk),
        in_specs=[
            pl.BlockSpec((s.bm, s.bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((s.bk, s.bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((s.bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((s.bm, s.bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)
