"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: each Pallas kernel (gemm, conv2d,
bitpack, bitserial, qnn) is checked against the function here with
``numpy.testing.assert_allclose`` in ``python/tests``.  They are written for
clarity, not speed, and use only ``jax.numpy`` / ``jax.lax`` primitives.

The bit-serial arithmetic follows the paper's Section V (and Cowan et al.,
CGO'20):

* **unipolar** — values are unsigned ``bits``-bit integers
  ``v = sum_b 2^b * plane_b`` with ``plane_b in {0,1}``; a dot product over
  packed planes is ``sum_{i,j} 2^{i+j} * popcount(a_i & w_j)``.
* **bipolar** — each plane holds signs ``s_b in {-1,+1}`` encoded as bits
  (bit=1 -> +1), ``v = sum_b 2^b * s_b``; per plane pair the dot is
  ``K - 2*popcount(a_i ^ w_j)`` (matches minus mismatches).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


def gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """float32 GEMM oracle: ``(M,K) @ (K,N) -> (M,N)``."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Dense layer oracle: GEMM + bias + ReLU (the paper's dense operator)."""
    return jnp.maximum(gemm(x, w) + b, 0.0)


# ---------------------------------------------------------------------------
# Convolution (NCHW, OIHW weights) — the paper's conv2d operator family
# ---------------------------------------------------------------------------


def conv2d(x: jax.Array, w: jax.Array, stride: int, padding: int) -> jax.Array:
    """float32 conv oracle via lax.conv: x (B,C,H,W), w (O,I,kh,kw)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_relu(x, w, stride: int, padding: int):
    return jnp.maximum(conv2d(x, w, stride, padding), 0.0)


def im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int) -> jax.Array:
    """IM2COL oracle: x (B,C,H,W) -> (B, ho*wo, C*kh*kw).

    Column order is (c, dy, dx) to match the kernel implementation.
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            patch = xp[:, :, dy : dy + ho * stride : stride, dx : dx + wo * stride : stride]
            cols.append(patch.reshape(b, c, ho * wo))
    # (B, C, P) per (dy,dx) -> stack (B, C, P, kh*kw) -> (B, P, C*kh*kw)
    stacked = jnp.stack(cols, axis=-1)
    return stacked.transpose(0, 2, 1, 3).reshape(b, ho * wo, c * kh * kw)


# ---------------------------------------------------------------------------
# Bit packing
# ---------------------------------------------------------------------------

LANES = 32  # bits per packed word (uint32 planes)


def pack_unipolar(v: jax.Array, bits: int) -> jax.Array:
    """Pack unsigned ints ``v`` (.., K) with values < 2**bits into uint32
    bit-planes of shape (bits, .., K // 32).  K must be a multiple of 32."""
    assert v.shape[-1] % LANES == 0, "K must be a multiple of 32"
    v = v.astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(LANES, dtype=jnp.uint32)
    planes = []
    for b in range(bits):
        bitvals = (v >> jnp.uint32(b)) & jnp.uint32(1)
        grouped = bitvals.reshape(*v.shape[:-1], v.shape[-1] // LANES, LANES)
        planes.append(jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32))
    return jnp.stack(planes, axis=0)


def pack_bipolar(sign_planes: jax.Array) -> jax.Array:
    """Pack bipolar sign planes (bits, .., K) with entries in {-1,+1} into
    uint32 words (bits, .., K//32); bit=1 encodes +1."""
    assert sign_planes.shape[-1] % LANES == 0
    signs01 = ((sign_planes + 1) // 2).astype(jnp.uint32)  # -1 -> 0, +1 -> 1
    weights = jnp.uint32(1) << jnp.arange(LANES, dtype=jnp.uint32)
    grouped = signs01.reshape(
        *sign_planes.shape[:-1], sign_planes.shape[-1] // LANES, LANES
    )
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


def unpack_unipolar(planes: jax.Array) -> jax.Array:
    """Inverse of pack_unipolar -> int32 values (.., K)."""
    bits = planes.shape[0]
    shifts = jnp.arange(LANES, dtype=jnp.uint32)
    vals = jnp.zeros((*planes.shape[1:-1], planes.shape[-1] * LANES), jnp.int32)
    for b in range(bits):
        bitlanes = (planes[b][..., None] >> shifts) & jnp.uint32(1)
        flat = bitlanes.reshape(*planes.shape[1:-1], planes.shape[-1] * LANES)
        vals = vals + (flat.astype(jnp.int32) << b)
    return vals


def bipolar_values(sign_planes: jax.Array) -> jax.Array:
    """Materialize integer values from sign planes (bits, .., K) in {-1,+1}."""
    bits = sign_planes.shape[0]
    scale = (2 ** jnp.arange(bits, dtype=jnp.int32)).reshape(
        (bits,) + (1,) * (sign_planes.ndim - 1)
    )
    return jnp.sum(sign_planes.astype(jnp.int32) * scale, axis=0)


# ---------------------------------------------------------------------------
# Bit-serial GEMM
# ---------------------------------------------------------------------------


def bitserial_gemm_unipolar(a_planes: jax.Array, w_planes: jax.Array) -> jax.Array:
    """Oracle over packed planes: a (ba, M, Kw), w (bw, N, Kw) -> int32 (M,N)."""
    ba, m, kw = a_planes.shape
    bw, n, _ = w_planes.shape
    out = jnp.zeros((m, n), jnp.int32)
    for i in range(ba):
        for j in range(bw):
            ands = a_planes[i][:, None, :] & w_planes[j][None, :, :]
            pc = jax.lax.population_count(ands).astype(jnp.int32).sum(-1)
            out = out + (pc << (i + j))
    return out


def bitserial_gemm_bipolar(a_planes: jax.Array, w_planes: jax.Array, k: int) -> jax.Array:
    """Bipolar oracle: dot per plane pair is K - 2*popcount(xor)."""
    ba, m, kw = a_planes.shape
    bw, n, _ = w_planes.shape
    out = jnp.zeros((m, n), jnp.int32)
    for i in range(ba):
        for j in range(bw):
            xors = a_planes[i][:, None, :] ^ w_planes[j][None, :, :]
            pc = jax.lax.population_count(xors).astype(jnp.int32).sum(-1)
            out = out + ((k - 2 * pc) << (i + j))
    return out


def bitserial_gemm_from_ints(a: jax.Array, w: jax.Array, abits: int, wbits: int) -> jax.Array:
    """End-to-end unipolar oracle from integer operands (pack -> popcount)."""
    ap = pack_unipolar(a, abits)
    wp = pack_unipolar(w, wbits)
    return bitserial_gemm_unipolar(ap, wp)


# ---------------------------------------------------------------------------
# QNN int8
# ---------------------------------------------------------------------------


def qnn_gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 GEMM oracle."""
    return jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))


def qnn_gemm_requant(x, w, scale: float, zp: int):
    """Requantized int8 GEMM: int32 accumulate -> scale -> clip to int8."""
    acc = qnn_gemm(x, w).astype(jnp.float32) * scale + zp
    return jnp.clip(jnp.round(acc), -128, 127).astype(jnp.int8)


def qnn_conv2d(x: jax.Array, w: jax.Array, stride: int, padding: int) -> jax.Array:
    """int8 conv oracle with int32 accumulation (NCHW/OIHW)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


# ---------------------------------------------------------------------------
# Workload bookkeeping shared with the rust side (mirrors eq. (3)/(4))
# ---------------------------------------------------------------------------


def conv_out_size(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


def conv_macs(b, cin, cout, h, w, k, stride, pad) -> int:
    ho = conv_out_size(h, k, stride, pad)
    wo = conv_out_size(w, k, stride, pad)
    return b * ho * wo * cin * cout * k * k


def gemm_macs(n: int) -> int:
    return n * n * n


def np_i32(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int32)


# ---------------------------------------------------------------------------
# Pooling + residual (ResNet glue operators)
# ---------------------------------------------------------------------------


def maxpool2d(x: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """Max-pool oracle via reduce_window (NCHW)."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, k, k),
        window_strides=(1, 1, stride, stride),
        padding=((0, 0), (0, 0), (pad, pad), (pad, pad)),
    )


def global_avgpool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(2, 3))


def residual_add(x: jax.Array, y: jax.Array, relu: bool = True) -> jax.Array:
    s = x + y
    return jnp.maximum(s, 0.0) if relu else s
