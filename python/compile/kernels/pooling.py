"""L1 Pallas kernels: pooling + residual add — the ResNet glue operators.

The paper studies conv/dense in isolation, but its workload is ResNet-18;
composing the full network (examples/resnet18_analysis end-to-end graph)
needs max-pool, global-average-pool and the residual shortcut add.  These
are bandwidth-trivial operators (the cache-bound model classifies them as
pure streaming), included so the L2 network graph is complete.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref, *, k: int, stride: int, wo: int, ho: int):
    """Max-pool one (bc, ho, wo) channel block from the padded input."""
    acc = None
    for dy in range(k):
        rows = x_ref[:, dy : dy + (ho - 1) * stride + 1 : stride, :]
        for dx in range(k):
            patch = rows[:, :, dx : dx + (wo - 1) * stride + 1 : stride]
            acc = patch if acc is None else jnp.maximum(acc, patch)
    o_ref[...] = acc


def maxpool2d(
    x: jax.Array,
    k: int,
    stride: int,
    pad: int,
    bc: int = 16,
    interpret: bool = True,
) -> jax.Array:
    """Max pooling, NCHW: x (B, C, H, W) -> (B, C, ho, wo).

    Padding uses -inf so border maxima are exact.
    """
    b, c, h, w = x.shape
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)), constant_values=-jnp.inf)
    hp, wp = xp.shape[2], xp.shape[3]
    bc = min(bc, c)
    if c % bc:
        raise ValueError(f"bc={bc} does not divide C={c}")
    kernel = functools.partial(_maxpool_kernel, k=k, stride=stride, wo=wo, ho=ho)

    def one_image(xi):
        return pl.pallas_call(
            kernel,
            grid=(c // bc,),
            in_specs=[pl.BlockSpec((bc, hp, wp), lambda ci: (ci, 0, 0))],
            out_specs=pl.BlockSpec((bc, ho, wo), lambda ci: (ci, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((c, ho, wo), x.dtype),
            interpret=interpret,
        )(xi)

    return jax.vmap(one_image)(xp)


def _gap_kernel(x_ref, o_ref):
    """Global average pool one channel block: (bc, H, W) -> (bc,)."""
    o_ref[...] = jnp.mean(x_ref[...], axis=(1, 2))


def global_avgpool(x: jax.Array, bc: int = 16, interpret: bool = True) -> jax.Array:
    """Global average pooling: (B, C, H, W) -> (B, C)."""
    b, c, h, w = x.shape
    bc = min(bc, c)
    if c % bc:
        raise ValueError(f"bc={bc} does not divide C={c}")

    def one_image(xi):
        return pl.pallas_call(
            _gap_kernel,
            grid=(c // bc,),
            in_specs=[pl.BlockSpec((bc, h, w), lambda ci: (ci, 0, 0))],
            out_specs=pl.BlockSpec((bc,), lambda ci: (ci,)),
            out_shape=jax.ShapeDtypeStruct((c,), x.dtype),
            interpret=interpret,
        )(xi)

    return jax.vmap(one_image)(x)


def _residual_kernel(x_ref, y_ref, o_ref, *, relu: bool):
    s = x_ref[...] + y_ref[...]
    o_ref[...] = jnp.maximum(s, 0.0) if relu else s


def residual_add(
    x: jax.Array,
    y: jax.Array,
    relu: bool = True,
    bc: int = 16,
    interpret: bool = True,
) -> jax.Array:
    """Residual shortcut: relu(x + y), NCHW, shapes must match."""
    assert x.shape == y.shape, (x.shape, y.shape)
    b, c, h, w = x.shape
    bc = min(bc, c)
    if c % bc:
        raise ValueError(f"bc={bc} does not divide C={c}")
    kernel = functools.partial(_residual_kernel, relu=relu)

    def one_image(xi, yi):
        return pl.pallas_call(
            kernel,
            grid=(c // bc,),
            in_specs=[
                pl.BlockSpec((bc, h, w), lambda ci: (ci, 0, 0)),
                pl.BlockSpec((bc, h, w), lambda ci: (ci, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bc, h, w), lambda ci: (ci, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((c, h, w), x.dtype),
            interpret=interpret,
        )(xi, yi)

    return jax.vmap(one_image)(x, y)
