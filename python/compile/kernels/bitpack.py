"""L1 Pallas kernel: runtime activation bit-packing.

Section V-A of the paper: *"The weights can be pre-packed and thus do not
need to be packed during runtime, but the activations require bit-packing
just before the calculation."*  This kernel is that runtime step — it is part
of the measured quantized-operator hot path and its cost is exactly the
"mandatory bit-packing step" the paper calls out as un-modelled overhead.

Packing layout (matches ``ref.pack_unipolar``): values ``v < 2**bits`` along
the reduction axis K are split into ``bits`` planes; each plane groups 32
consecutive K positions into one little-endian uint32 word, so a ``(M, K)``
tensor becomes ``(bits, M, K/32)``.  Packing along K (the paper's "spatial"
bit-packing axis for dense) is what lets the bit-serial GEMM use full-word
AND/XOR + popcount vector ops.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 32


class PackSchedule(NamedTuple):
    """Row-block size for the packing sweep."""

    brow: int = 64

    def clamp(self, m: int) -> "PackSchedule":
        return PackSchedule(min(self.brow, m))


def _pack_kernel(v_ref, o_ref, *, bits: int, kw: int):
    """Pack a (brow, K) int block into (bits, brow, K/32) uint32 planes."""
    v = v_ref[...].astype(jnp.uint32)
    brow = v.shape[0]
    weights = jnp.uint32(1) << jnp.arange(LANES, dtype=jnp.uint32)
    planes = []
    for b in range(bits):
        bitvals = (v >> jnp.uint32(b)) & jnp.uint32(1)
        grouped = bitvals.reshape(brow, kw, LANES)
        planes.append(jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32))
    o_ref[...] = jnp.stack(planes, axis=0)


def pack_unipolar(
    v: jax.Array,
    bits: int,
    schedule: PackSchedule = PackSchedule(),
    interpret: bool = True,
) -> jax.Array:
    """Pack (M, K) unsigned ints (< 2**bits) into (bits, M, K/32) planes."""
    m, k = v.shape
    if k % LANES:
        raise ValueError(f"K={k} must be a multiple of {LANES}")
    s = schedule.clamp(m)
    if m % s.brow:
        raise ValueError(f"brow={s.brow} does not divide M={m}")
    kw = k // LANES
    kernel = functools.partial(_pack_kernel, bits=bits, kw=kw)
    return pl.pallas_call(
        kernel,
        grid=(m // s.brow,),
        in_specs=[pl.BlockSpec((s.brow, k), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((bits, s.brow, kw), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((bits, m, kw), jnp.uint32),
        interpret=interpret,
    )(v)


def _pack_bipolar_kernel(s_ref, o_ref, *, bits: int, kw: int):
    """Pack (bits, brow, K) sign planes in {-1,+1} into uint32 words."""
    signs = s_ref[...]
    brow = signs.shape[1]
    s01 = ((signs + 1) // 2).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(LANES, dtype=jnp.uint32)
    grouped = s01.reshape(bits, brow, kw, LANES)
    o_ref[...] = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


def pack_bipolar(
    sign_planes: jax.Array,
    schedule: PackSchedule = PackSchedule(),
    interpret: bool = True,
) -> jax.Array:
    """Pack (bits, M, K) sign planes (entries in {-1,+1}) to (bits, M, K/32)."""
    bits, m, k = sign_planes.shape
    if k % LANES:
        raise ValueError(f"K={k} must be a multiple of {LANES}")
    s = schedule.clamp(m)
    if m % s.brow:
        raise ValueError(f"brow={s.brow} does not divide M={m}")
    kw = k // LANES
    kernel = functools.partial(_pack_bipolar_kernel, bits=bits, kw=kw)
    return pl.pallas_call(
        kernel,
        grid=(m // s.brow,),
        in_specs=[pl.BlockSpec((bits, s.brow, k), lambda r: (0, r, 0))],
        out_specs=pl.BlockSpec((bits, s.brow, kw), lambda r: (0, r, 0)),
        out_shape=jax.ShapeDtypeStruct((bits, m, kw), jnp.uint32),
        interpret=interpret,
    )(sign_planes)
