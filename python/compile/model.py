"""L2: JAX operator graphs — the paper's "single-layer networks".

The paper's AutoTVM methodology (§III-A) evaluates operators by wrapping each
one in a single-layer network.  This module builds those networks as jax
functions over the L1 Pallas kernels, ready for ``aot.py`` to lower to HLO
text per (shape, dtype, schedule) variant.

Every function here is shape-specialized at trace time (XLA is static), so
``aot.py`` enumerates the workload grid from ``workloads.py`` and lowers one
artifact per point.  Python never runs at serving time: the rust runtime
executes the lowered HLO through PJRT.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import bitpack, bitserial, conv2d, gemm, qnn
from .workloads import ConvLayer

Array = jax.Array


# ---------------------------------------------------------------------------
# float32 GEMM / dense networks (Tables IV/V, Figs 1 & 9)
# ---------------------------------------------------------------------------


def gemm_net(schedule: gemm.GemmSchedule) -> Callable[[Array, Array], tuple[Array]]:
    """Single-operator GEMM network with a fixed schedule."""

    def fwd(x: Array, w: Array) -> tuple[Array]:
        return (gemm.gemm(x, w, schedule=schedule),)

    return fwd


def dense_net(schedule: gemm.GemmSchedule, relu: bool = True):
    """Dense layer network: relu(x @ w + b)."""

    def fwd(x: Array, w: Array, b: Array) -> tuple[Array]:
        return (gemm.dense(x, w, b, schedule=schedule, relu=relu),)

    return fwd


# ---------------------------------------------------------------------------
# float32 convolution networks (Figs 2 & 3)
# ---------------------------------------------------------------------------


def conv_net(layer: ConvLayer, schedule: conv2d.ConvSchedule, relu: bool = False):
    """Single conv layer network for one Table III row."""

    def fwd(x: Array, w: Array) -> tuple[Array]:
        return (
            conv2d.conv2d_nchw(
                x, w, stride=layer.stride, pad=layer.pad, schedule=schedule, relu=relu
            ),
        )

    return fwd


def conv_im2col_net(layer: ConvLayer, gemm_schedule: gemm.GemmSchedule):
    """IM2COL + GEMM convolution (§III-C2's alternative algorithm).

    The GEMM contraction dim is cin*k*k which is generally not
    schedule-divisible, so the matmul uses a clamped schedule over the
    column matrix; correctness is what matters for this variant.
    """

    def fwd(x: Array, w: Array) -> tuple[Array]:
        cols = conv2d.im2col(x, layer.k, layer.stride, layer.pad)  # (B,P,CKK)
        wmat = w.reshape(layer.cout, -1).T  # (CKK, cout); (c,dy,dx) col order
        out = jnp.einsum("bpc,cn->bpn", cols, wmat)
        b = x.shape[0]
        return (
            out.transpose(0, 2, 1).reshape(b, layer.cout, layer.ho, layer.wo),
        )

    return fwd


# ---------------------------------------------------------------------------
# Quantized networks (Figs 4-8)
# ---------------------------------------------------------------------------


def qnn_gemm_net(schedule: gemm.GemmSchedule):
    """int8 GEMM with int32 accumulate (QNN baseline for dense)."""

    def fwd(x: Array, w: Array) -> tuple[Array]:
        return (qnn.qnn_gemm(x, w, schedule=schedule),)

    return fwd


def qnn_conv_net(layer: ConvLayer, schedule: conv2d.ConvSchedule):
    """int8 conv with int32 accumulate (the paper's 8-bit QNN operator)."""

    def fwd(x: Array, w: Array) -> tuple[Array]:
        return (
            qnn.qnn_conv2d_nchw(x, w, stride=layer.stride, pad=layer.pad, schedule=schedule),
        )

    return fwd


def bitserial_gemm_net(
    k: int,
    abits: int,
    wbits: int,
    unipolar: bool,
    schedule: bitserial.BitserialSchedule,
):
    """Bit-serial GEMM network with *runtime activation packing*.

    Inputs: activations as (M, K) int32 (unipolar) and *pre-packed* weights
    (wbits, N, K/32) uint32 — mirroring the paper: "weights can be
    pre-packed ... the activations require bit-packing just before the
    calculation".  The packing kernel is part of the measured graph.
    """

    def fwd(a: Array, w_packed: Array) -> tuple[Array]:
        a_planes = bitpack.pack_unipolar(a, abits)
        return (
            bitserial.bitserial_gemm(
                a_planes, w_packed, k=k, unipolar=unipolar, schedule=schedule
            ),
        )

    return fwd


def bitserial_gemm_prepacked_net(
    k: int, unipolar: bool, schedule: bitserial.BitserialSchedule
):
    """Bit-serial GEMM over already-packed planes (isolates packing cost)."""

    def fwd(a_planes: Array, w_planes: Array) -> tuple[Array]:
        return (
            bitserial.bitserial_gemm(
                a_planes, w_planes, k=k, unipolar=unipolar, schedule=schedule
            ),
        )

    return fwd


def bitserial_conv_net(
    layer: ConvLayer,
    abits: int,
    wbits: int,
    unipolar: bool,
    schedule: bitserial.BitserialSchedule,
):
    """Bit-serial convolution via NHWC im2col + packed GEMM.

    The paper notes the bit-serial conv uses NHWC layout, whose interaction
    with bit-packing hurts small images (Fig 6, layer C11).  We reproduce
    that structure: im2col produces (P, cin*k*k) rows — NHWC-style
    channel-innermost columns — which are then runtime-packed along the
    reduction axis and contracted bit-serially.

    The contraction length cin*k*k must be padded to a multiple of 32 for
    packing; zero padding is exact for unipolar (zeros contribute nothing).
    """
    ckk = layer.cin * layer.k * layer.k
    kpad = (ckk + 31) // 32 * 32

    def fwd(x: Array, w_packed: Array) -> tuple[Array]:
        cols = conv2d.im2col(x, layer.k, layer.stride, layer.pad)  # f32 (B,P,CKK)
        b, p, _ = cols.shape
        cols_i = cols.astype(jnp.int32).reshape(b * p, ckk)
        cols_i = jnp.pad(cols_i, ((0, 0), (0, kpad - ckk)))
        # pad rows to the packing/gemm block grid
        m = cols_i.shape[0]
        mpad = (m + 63) // 64 * 64
        cols_i = jnp.pad(cols_i, ((0, mpad - m), (0, 0)))
        a_planes = bitpack.pack_unipolar(cols_i, abits)
        acc = bitserial.bitserial_gemm(
            a_planes, w_packed, k=kpad, unipolar=unipolar, schedule=schedule
        )[:m]
        out = acc.reshape(b, p, layer.cout).transpose(0, 2, 1)
        return (out.reshape(b, layer.cout, layer.ho, layer.wo),)

    return fwd


def pack_weights_unipolar(w: Array, wbits: int) -> Array:
    """Offline weight packing helper (not part of the runtime graph)."""
    return bitpack.pack_unipolar(w, wbits)
