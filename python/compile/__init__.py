"""Build-time compilation package: L1 Pallas kernels, L2 jax graphs, AOT."""
