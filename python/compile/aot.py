"""AOT compiler: lower every operator variant to HLO text + manifest.

This is the only place python runs in the whole system — ``make artifacts``
invokes it once; the rust coordinator then loads ``artifacts/*.hlo.txt``
through PJRT and never touches python again.

Interchange format is **HLO text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  Lowering goes stablehlo -> XlaComputation -> ``as_hlo_text`` with
``return_tuple=True`` (rust unwraps with ``to_tuple1``).

Cross-language numerics protocol: for every artifact we generate inputs with
a SplitMix64 stream (identical implementation in ``rust/src/util/rng.rs``),
execute the jitted graph, and record output checksums in the manifest.  The
rust integration tests regenerate the same inputs, execute the artifact via
PJRT, and compare — exact for integer outputs, 1e-3 relative for floats
(python jaxlib and xla_extension 0.5.1 are different XLA builds).
"""

from __future__ import annotations

import argparse
import json
import math
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, workloads
from .kernels import bitpack, bitserial, conv2d, gemm
from .workloads import RESNET18_LAYERS

# ---------------------------------------------------------------------------
# SplitMix64 — must match rust/src/util/rng.rs exactly
# ---------------------------------------------------------------------------

GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def splitmix64_stream(seed: int, n: int) -> np.ndarray:
    """Vectorized SplitMix64: element i is mix(seed + (i+1)*GOLDEN)."""
    with np.errstate(over="ignore"):
        i = np.arange(1, n + 1, dtype=np.uint64)
        z = np.uint64(seed) + i * GOLDEN
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def gen_input(seed: int, shape: tuple[int, ...], dtype: str) -> np.ndarray:
    """Deterministic input tensor; the rust side mirrors this bit-for-bit."""
    n = math.prod(shape)
    z = splitmix64_stream(seed, n)
    if dtype == "f32":
        # upper 24 bits -> [0,1) -> [-1,1)
        vals = (z >> np.uint64(40)).astype(np.float64) / float(1 << 24)
        return (vals * 2.0 - 1.0).astype(np.float32).reshape(shape)
    if dtype == "i8":
        # small symmetric range keeps int32 accumulators far from overflow
        return (((z >> np.uint64(40)) % np.uint64(15)).astype(np.int64) - 7).astype(
            np.int8
        ).reshape(shape)
    if dtype == "u32":
        return (z >> np.uint64(32)).astype(np.uint32).reshape(shape)
    if dtype.startswith("i32u"):  # unipolar activations with `bits` precision
        bits = int(dtype[4:])
        return ((z >> np.uint64(40)) % np.uint64(1 << bits)).astype(np.int32).reshape(
            shape
        )
    raise ValueError(f"unknown dtype spec {dtype}")


def checksum(arr: np.ndarray) -> float:
    """Order-stable float64 sum — the cross-language output fingerprint."""
    return float(np.asarray(arr, dtype=np.float64).sum())


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default ToString ELIDES big literals
    # ("constant({...})"), which the rust-side text parser reads back as
    # zeros — baked weights (e.g. the whole-network artifact) would
    # silently vanish.  Full literals round-trip exactly.
    return comp.as_hlo_text(print_large_constants=True)


class Artifact:
    """One lowered operator variant."""

    def __init__(self, name: str, fn, inputs: list[tuple[tuple[int, ...], str]], meta: dict):
        self.name = name
        self.fn = fn
        self.inputs = inputs
        self.meta = meta

    def build(self, out_dir: Path, seed_base: int, execute: bool) -> dict:
        specs = [
            jax.ShapeDtypeStruct(shape, _np_dtype(d)) for shape, d in self.inputs
        ]
        t0 = time.time()
        lowered = jax.jit(self.fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = out_dir / f"{self.name}.hlo.txt"
        path.write_text(text)
        entry = {
            "name": self.name,
            "file": path.name,
            "inputs": [
                {"shape": list(shape), "dtype": d, "seed": seed_base + idx}
                for idx, (shape, d) in enumerate(self.inputs)
            ],
            "meta": self.meta,
            "hlo_bytes": len(text),
        }
        if execute:
            args = [
                gen_input(seed_base + idx, shape, d)
                for idx, (shape, d) in enumerate(self.inputs)
            ]
            outs = jax.jit(self.fn)(*args)
            entry["outputs"] = [
                {
                    "shape": list(np.shape(o)),
                    "dtype": str(np.asarray(o).dtype),
                    "checksum": checksum(o),
                    "exact": np.asarray(o).dtype.kind in "iu",
                }
                for o in outs
            ]
        entry["lower_seconds"] = round(time.time() - t0, 3)
        return entry


def _np_dtype(d: str):
    if d == "f32":
        return jnp.float32
    if d == "i8":
        return jnp.int8
    if d == "u32":
        return jnp.uint32
    if d.startswith("i32"):
        return jnp.int32
    raise ValueError(d)


# ---------------------------------------------------------------------------
# Artifact catalog — the full workload grid from workloads.py
# ---------------------------------------------------------------------------


def catalog(quick: bool = False) -> list[Artifact]:
    arts: list[Artifact] = []

    # --- float32 GEMM: naive + tuned (Tables IV/V, Figs 1 & 9) -------------
    naive_sizes = [32, 128, 256]  # larger naive grids are interpret-hostile;
    # the rust native operator + simulator carry the naive column beyond 256.
    tuned_sizes = workloads.GEMM_SIZES
    if quick:
        naive_sizes, tuned_sizes = [32], [32, 128]
    for n in naive_sizes:
        arts.append(
            Artifact(
                f"gemm_f32_naive_n{n}",
                model.gemm_net(gemm.NAIVE_SCHEDULE),
                [((n, n), "f32"), ((n, n), "f32")],
                {
                    "kind": "gemm",
                    "dtype": "f32",
                    "schedule": "naive",
                    "n": n,
                    "macs": n**3,
                    "block": list(gemm.NAIVE_SCHEDULE),
                },
            )
        )
    for n in tuned_sizes:
        arts.append(
            Artifact(
                f"gemm_f32_tuned_n{n}",
                model.gemm_net(gemm.TUNED_SCHEDULE),
                [((n, n), "f32"), ((n, n), "f32")],
                {
                    "kind": "gemm",
                    "dtype": "f32",
                    "schedule": "tuned",
                    "n": n,
                    "macs": n**3,
                    "block": list(gemm.TUNED_SCHEDULE),
                },
            )
        )

    # --- GEMM schedule variants: the tuner's artifact-backed space ---------
    variant_sizes = [] if quick else workloads.GEMM_VARIANT_SIZES
    for n in variant_sizes:
        for bm, bn, bk in workloads.GEMM_VARIANTS:
            arts.append(
                Artifact(
                    f"gemm_f32_var_n{n}_b{bm}x{bn}x{bk}",
                    model.gemm_net(gemm.GemmSchedule(bm, bn, bk)),
                    [((n, n), "f32"), ((n, n), "f32")],
                    {
                        "kind": "gemm_variant",
                        "dtype": "f32",
                        "n": n,
                        "macs": n**3,
                        "block": [bm, bn, bk],
                    },
                )
            )

    # --- dense layer (fused epilogue) --------------------------------------
    if not quick:
        n = 256
        arts.append(
            Artifact(
                f"dense_f32_n{n}",
                model.dense_net(gemm.TUNED_SCHEDULE),
                [((n, n), "f32"), ((n, n), "f32"), ((n,), "f32")],
                {"kind": "dense", "dtype": "f32", "n": n, "macs": n**3},
            )
        )

    # --- float32 ResNet-18 convolutions (Figs 2 & 3) -----------------------
    layers = RESNET18_LAYERS[:1] if quick else RESNET18_LAYERS
    for layer in layers:
        arts.append(
            Artifact(
                f"conv_f32_{layer.name.lower()}",
                model.conv_net(layer, conv2d.TUNED_CONV_SCHEDULE),
                [
                    ((layer.b, layer.cin, layer.h, layer.w), "f32"),
                    ((layer.cout, layer.cin, layer.k, layer.k), "f32"),
                ],
                {
                    "kind": "conv",
                    "dtype": "f32",
                    "layer": layer.name,
                    "macs": layer.macs,
                    "geometry": [layer.cin, layer.cout, layer.h, layer.w,
                                 layer.k, layer.stride, layer.pad],
                },
            )
        )

    # --- IM2COL conv variant ------------------------------------------------
    if not quick:
        layer = RESNET18_LAYERS[3]  # C5
        arts.append(
            Artifact(
                f"conv_f32_im2col_{layer.name.lower()}",
                model.conv_im2col_net(layer, gemm.TUNED_SCHEDULE),
                [
                    ((layer.b, layer.cin, layer.h, layer.w), "f32"),
                    ((layer.cout, layer.cin, layer.k, layer.k), "f32"),
                ],
                {
                    "kind": "conv_im2col",
                    "dtype": "f32",
                    "layer": layer.name,
                    "macs": layer.macs,
                },
            )
        )

    # --- QNN int8 GEMM ------------------------------------------------------
    qnn_sizes = [] if quick else workloads.QNN_GEMM_SIZES
    for n in qnn_sizes:
        arts.append(
            Artifact(
                f"gemm_qnn8_n{n}",
                model.qnn_gemm_net(gemm.TUNED_SCHEDULE),
                [((n, n), "i8"), ((n, n), "i8")],
                {"kind": "qnn_gemm", "dtype": "i8", "n": n, "macs": n**3},
            )
        )

    # --- QNN int8 convolutions (Figs 6-8) -----------------------------------
    qnn_layers = [] if quick else ["C2", "C5", "C8", "C11"]
    for lname in qnn_layers:
        layer = next(l for l in RESNET18_LAYERS if l.name == lname)
        arts.append(
            Artifact(
                f"conv_qnn8_{layer.name.lower()}",
                model.qnn_conv_net(layer, conv2d.TUNED_CONV_SCHEDULE),
                [
                    ((layer.b, layer.cin, layer.h, layer.w), "i8"),
                    ((layer.cout, layer.cin, layer.k, layer.k), "i8"),
                ],
                {
                    "kind": "qnn_conv",
                    "dtype": "i8",
                    "layer": layer.name,
                    "macs": layer.macs,
                },
            )
        )

    # --- bit-serial GEMM (Figs 4 & 5) ---------------------------------------
    bs_cfgs = [] if quick else [
        (256, bits, pol) for bits in workloads.BITSERIAL_BITS for pol in ("uni", "bi")
    ]
    for n, bits, pol in bs_cfgs:
        kw = n // 32
        arts.append(
            Artifact(
                f"gemm_bs_{pol}_a{bits}w{bits}_n{n}_prepacked",
                model.bitserial_gemm_prepacked_net(
                    n, unipolar=(pol == "uni"), schedule=bitserial.BitserialSchedule()
                ),
                [((bits, n, kw), "u32"), ((bits, n, kw), "u32")],
                {
                    "kind": "bitserial_gemm",
                    "polarity": pol,
                    "abits": bits,
                    "wbits": bits,
                    "n": n,
                    "macs": n**3,
                    "prepacked": True,
                },
            )
        )
    # runtime-activation-packing variant (the measured configuration of §V-A)
    if not quick:
        for n, bits in [(256, 2)]:
            kw = n // 32
            arts.append(
                Artifact(
                    f"gemm_bs_uni_a{bits}w{bits}_n{n}_runtime_pack",
                    model.bitserial_gemm_net(
                        n, bits, bits, True, bitserial.BitserialSchedule()
                    ),
                    [((n, n), f"i32u{bits}"), ((bits, n, kw), "u32")],
                    {
                        "kind": "bitserial_gemm",
                        "polarity": "uni",
                        "abits": bits,
                        "wbits": bits,
                        "n": n,
                        "macs": n**3,
                        "prepacked": False,
                    },
                )
            )

    # --- whole-network ResNet-18 (end-to-end driver) -------------------------
    if not quick:
        from . import network

        hw = 32  # every block exercised; final feature map 1x1
        wspecs = network.weight_specs(classes=10)

        def resnet_fwd(x, *flat_ws):
            return (network.forward_flat(x, *flat_ws, classes=10),)

        # MACs: stem + blocks at 32x32-input geometry
        def conv_macs_at(cin, cout, h, k, s, p):
            ho = (h + 2 * p - k) // s + 1
            return ho * ho * cin * cout * k * k, ho

        total, h = conv_macs_at(3, 64, hw, 7, 2, 3)
        h = (h + 2 * 1 - 3) // 2 + 1  # stem maxpool
        for b in network.resnet18_blocks():
            m1, h1 = conv_macs_at(b.cin, b.cout, h, 3, b.stride, 1)
            m2, _ = conv_macs_at(b.cout, b.cout, h1, 3, 1, 1)
            total += m1 + m2
            if b.has_downsample:
                md, _ = conv_macs_at(b.cin, b.cout, h, 1, b.stride, 0)
                total += md
            h = h1
        arts.append(
            Artifact(
                f"resnet18_full_i{hw}",
                resnet_fwd,
                [((1, 3, hw, hw), "f32")] + [(shape, "f32") for _, shape, _ in wspecs],
                {
                    "kind": "network",
                    "dtype": "f32",
                    "input_hw": hw,
                    "classes": 10,
                    "macs": int(total),
                },
            )
        )

    # --- bit-serial convolutions (Figs 6-8) ---------------------------------
    bs_conv = [] if quick else [("C8", 1), ("C8", 2), ("C11", 1), ("C11", 2)]
    for lname, bits in bs_conv:
        layer = next(l for l in RESNET18_LAYERS if l.name == lname)
        ckk = layer.cin * layer.k * layer.k
        kpad = (ckk + 31) // 32 * 32
        arts.append(
            Artifact(
                f"conv_bs_uni_a{bits}w{bits}_{layer.name.lower()}",
                model.bitserial_conv_net(
                    layer, bits, bits, True, bitserial.BitserialSchedule()
                ),
                [
                    ((layer.b, layer.cin, layer.h, layer.w), f"i32u{bits}"),
                    ((bits, layer.cout, kpad // 32), "u32"),
                ],
                {
                    "kind": "bitserial_conv",
                    "polarity": "uni",
                    "abits": bits,
                    "wbits": bits,
                    "layer": layer.name,
                    "macs": layer.macs,
                },
            )
        )

    return arts


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="(compat) manifest path; implies out dir")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    ap.add_argument("--quick", action="store_true", help="tiny subset for smoke tests")
    ap.add_argument("--no-execute", action="store_true", help="skip checksum execution")
    args = ap.parse_args()

    out_dir = Path(args.out).parent if args.out else Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    arts = catalog(quick=args.quick)
    if args.only:
        rx = re.compile(args.only)
        arts = [a for a in arts if rx.search(a.name)]

    # --only must not clobber the rest of the manifest: start from any
    # existing entries and overwrite just the rebuilt ones.
    entries = []
    manifest_path = out_dir / "manifest.json"
    if args.only and manifest_path.exists():
        old = json.loads(manifest_path.read_text())
        rebuilt = {a.name for a in arts}
        entries = [e for e in old.get("artifacts", []) if e["name"] not in rebuilt]

    t0 = time.time()
    # seed_base is derived from the artifact's position in the FULL catalog
    # so --only rebuilds reproduce identical inputs/checksums.
    full_index = {a.name: i for i, a in enumerate(catalog(quick=args.quick))}
    for idx, art in enumerate(arts):
        print(f"[{idx + 1}/{len(arts)}] {art.name} ...", flush=True)
        pos = full_index.get(art.name, idx)
        entry = art.build(out_dir, seed_base=0xC0FFEE00 + pos * 64, execute=not args.no_execute)
        entries.append(entry)
    entries.sort(key=lambda e: e["name"])

    manifest = {
        "version": 1,
        "generated_by": "python/compile/aot.py",
        "artifact_count": len(entries),
        "workloads": {
            "resnet18_layers": [
                {
                    "name": l.name, "b": l.b, "cin": l.cin, "cout": l.cout,
                    "h": l.h, "w": l.w, "k": l.k, "stride": l.stride,
                    "pad": l.pad, "macs": l.macs,
                }
                for l in RESNET18_LAYERS
            ],
            "gemm_sizes": workloads.GEMM_SIZES,
            "bitserial_bits": workloads.BITSERIAL_BITS,
        },
        "artifacts": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(
        f"wrote {len(entries)} artifacts + manifest.json to {out_dir} "
        f"in {time.time() - t0:.1f}s"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
