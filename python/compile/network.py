"""L2: the full ResNet-18 network graph composed from L1 Pallas kernels.

The paper evaluates layers in isolation; this module composes them into the
complete inference graph (stem → 4 stages of 2 residual basic-blocks →
global average pool → fc), so the end-to-end example can run *whole-model*
inference through the AOT → PJRT path and the simulator can report
end-to-end latency per quantization mode.

Shapes follow torchvision's ResNet-18 (ImageNet geometry scaled down by
`input_hw` for tractable interpret-mode execution; the layer *structure*
and channel progression are exact).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import conv2d, pooling

Array = jax.Array


class BlockSpec(NamedTuple):
    """One basic residual block (two 3x3 convs + optional 1x1 downsample)."""

    cin: int
    cout: int
    stride: int

    @property
    def has_downsample(self) -> bool:
        return self.stride != 1 or self.cin != self.cout


# torchvision resnet18: stages of (2 blocks) x channels (64,128,256,512)
def resnet18_blocks() -> list[BlockSpec]:
    blocks = []
    cin = 64
    for cout, stride in [(64, 1), (128, 2), (256, 2), (512, 2)]:
        blocks.append(BlockSpec(cin, cout, stride))
        blocks.append(BlockSpec(cout, cout, 1))
        cin = cout
    return blocks


class Resnet18Params(NamedTuple):
    """Flat parameter container (weights only; batch-norm folded)."""

    stem_w: Array  # (64, 3, 7, 7)
    block_ws: tuple  # per block: (w1, w2, wd or None)
    fc_w: Array  # (512, classes)
    fc_b: Array  # (classes,)


def init_params(key: int, classes: int = 10) -> Resnet18Params:
    """He-style deterministic init from a seed (no training here)."""
    import numpy as np

    rng = np.random.default_rng(key)

    def w(shape, fan_in):
        return (rng.standard_normal(shape) * (2.0 / fan_in) ** 0.5).astype(np.float32)

    block_ws = []
    for b in resnet18_blocks():
        w1 = w((b.cout, b.cin, 3, 3), b.cin * 9)
        w2 = w((b.cout, b.cout, 3, 3), b.cout * 9)
        wd = w((b.cout, b.cin, 1, 1), b.cin) if b.has_downsample else None
        block_ws.append((w1, w2, wd))
    return Resnet18Params(
        stem_w=w((64, 3, 7, 7), 3 * 49),
        block_ws=tuple(block_ws),
        fc_w=w((512, classes), 512),
        fc_b=np.zeros(classes, np.float32),
    )


def forward(
    x: Array,
    params: Resnet18Params,
    conv_schedule: conv2d.ConvSchedule = conv2d.ConvSchedule(16, 4),
    interpret: bool = True,
) -> Array:
    """Full ResNet-18 inference: x (B, 3, H, W) -> logits (B, classes).

    Every conv is the spatial-pack Pallas kernel; shortcuts, pooling and
    the classifier head are Pallas too (pooling.py / gemm.py).
    """
    # stem: 7x7/2 conv + 3x3/2 maxpool
    h = conv2d.conv2d_nchw(x, params.stem_w, stride=2, pad=3,
                           schedule=conv_schedule, relu=True, interpret=interpret)
    h = pooling.maxpool2d(h, k=3, stride=2, pad=1, interpret=interpret)

    for spec, (w1, w2, wd) in zip(resnet18_blocks(), params.block_ws):
        shortcut = h
        out = conv2d.conv2d_nchw(h, w1, stride=spec.stride, pad=1,
                                 schedule=conv_schedule, relu=True, interpret=interpret)
        out = conv2d.conv2d_nchw(out, w2, stride=1, pad=1,
                                 schedule=conv_schedule, relu=False, interpret=interpret)
        if spec.has_downsample:
            shortcut = conv2d.conv2d_nchw(h, wd, stride=spec.stride, pad=0,
                                          schedule=conv_schedule, relu=False,
                                          interpret=interpret)
        h = pooling.residual_add(out, shortcut, relu=True, interpret=interpret)

    pooled = pooling.global_avgpool(h, interpret=interpret)  # (B, 512)
    # classifier head: plain jnp matmul — (B,512)x(512,classes) is tiny
    return (
        jnp.matmul(pooled, params.fc_w, preferred_element_type=jnp.float32)
        + params.fc_b
    )


def reference_forward(x: Array, params: Resnet18Params) -> Array:
    """Pure-jnp oracle of the same graph (lax.conv everywhere)."""
    from .kernels import ref

    h = jnp.maximum(ref.conv2d(x, params.stem_w, 2, 3), 0.0)
    h = ref.maxpool2d(h, 3, 2, 1)
    for spec, (w1, w2, wd) in zip(resnet18_blocks(), params.block_ws):
        shortcut = h
        out = jnp.maximum(ref.conv2d(h, w1, spec.stride, 1), 0.0)
        out = ref.conv2d(out, w2, 1, 1)
        if spec.has_downsample:
            shortcut = ref.conv2d(h, wd, spec.stride, 0)
        h = jnp.maximum(out + shortcut, 0.0)
    pooled = jnp.mean(h, axis=(2, 3))
    return jnp.matmul(pooled, params.fc_w) + params.fc_b


# ---------------------------------------------------------------------------
# Flat-weight interface for the AOT path
# ---------------------------------------------------------------------------
#
# Baking 11M f32 weights as HLO constants makes the text artifact ~200 MB
# (full literals must be printed — elided ones parse back as zeros), so the
# AOT artifact takes every weight as a *parameter* instead.  Weights come
# from the SplitMix64 input protocol (uniform [-1,1), std 1/sqrt(3)); the
# graph folds in a per-tensor He-scaling constant so activations stay O(1)
# through all 17 convs.

_UNIFORM_STD = 0.5773502691896258  # std of U(-1, 1)


def weight_specs(classes: int = 10) -> list[tuple[str, tuple, float]]:
    """(name, shape, he_scale) for every weight, in forward order."""
    specs = [("stem_w", (64, 3, 7, 7), (2.0 / (3 * 49)) ** 0.5 / _UNIFORM_STD)]
    for i, b in enumerate(resnet18_blocks()):
        specs.append((f"b{i}_w1", (b.cout, b.cin, 3, 3), (2.0 / (b.cin * 9)) ** 0.5 / _UNIFORM_STD))
        specs.append((f"b{i}_w2", (b.cout, b.cout, 3, 3), (2.0 / (b.cout * 9)) ** 0.5 / _UNIFORM_STD))
        if b.has_downsample:
            specs.append((f"b{i}_wd", (b.cout, b.cin, 1, 1), (2.0 / b.cin) ** 0.5 / _UNIFORM_STD))
    specs.append(("fc_w", (512, classes), (1.0 / 512) ** 0.5 / _UNIFORM_STD))
    specs.append(("fc_b", (classes,), 0.0))  # zero bias
    return specs


def params_from_flat(flat: list, classes: int = 10) -> Resnet18Params:
    """Assemble scaled parameters from flat protocol tensors."""
    specs = weight_specs(classes)
    assert len(flat) == len(specs), (len(flat), len(specs))
    scaled = {name: w * scale for (name, _, scale), w in zip(specs, flat)}
    block_ws = []
    for i, b in enumerate(resnet18_blocks()):
        block_ws.append((
            scaled[f"b{i}_w1"],
            scaled[f"b{i}_w2"],
            scaled.get(f"b{i}_wd") if b.has_downsample else None,
        ))
    return Resnet18Params(
        stem_w=scaled["stem_w"],
        block_ws=tuple(block_ws),
        fc_w=scaled["fc_w"],
        fc_b=scaled["fc_b"],
    )


def forward_flat(x: Array, *flat_weights, classes: int = 10,
                 conv_schedule: conv2d.ConvSchedule = conv2d.ConvSchedule(16, 4),
                 interpret: bool = True) -> Array:
    """Whole-network forward over flat protocol-weight parameters."""
    params = params_from_flat(list(flat_weights), classes)
    return forward(x, params, conv_schedule=conv_schedule, interpret=interpret)
