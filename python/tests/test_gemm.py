"""Pallas GEMM/dense kernels vs the pure-jnp oracle (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

# hypothesis is optional: skip collection cleanly where it is absent
# instead of failing the whole suite at import time
hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings  # noqa: E402

from compile.kernels import gemm, ref

RTOL = 2e-5
ATOL = 1e-5


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


class TestGemm:
    @pytest.mark.parametrize("n", [8, 32, 64, 128])
    def test_square_tuned(self, n):
        x, w = rand((n, n), 1), rand((n, n), 2)
        out = gemm.gemm(x, w, schedule=gemm.TUNED_SCHEDULE)
        assert_allclose(out, ref.gemm(x, w), rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("n", [8, 32, 64])
    def test_square_naive(self, n):
        x, w = rand((n, n), 3), rand((n, n), 4)
        out = gemm.gemm(x, w, schedule=gemm.NAIVE_SCHEDULE)
        assert_allclose(out, ref.gemm(x, w), rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize(
        "m,k,n", [(16, 32, 64), (64, 32, 16), (8, 128, 8), (128, 8, 32)]
    )
    def test_rectangular(self, m, k, n):
        x, w = rand((m, k), 5), rand((k, n), 6)
        out = gemm.gemm(x, w, schedule=gemm.GemmSchedule(8, 8, 8))
        assert_allclose(out, ref.gemm(x, w), rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (32, 8, 16), (64, 64, 64)])
    def test_schedule_grid(self, bm, bn, bk):
        n = 64
        x, w = rand((n, n), 7), rand((n, n), 8)
        out = gemm.gemm(x, w, schedule=gemm.GemmSchedule(bm, bn, bk))
        assert_allclose(out, ref.gemm(x, w), rtol=RTOL, atol=ATOL)

    def test_non_dividing_schedule_raises(self):
        x, w = rand((48, 48), 9), rand((48, 48), 10)
        with pytest.raises(ValueError):
            gemm.gemm(x, w, schedule=gemm.GemmSchedule(32, 32, 32))

    def test_identity(self):
        n = 32
        x = rand((n, n), 11)
        out = gemm.gemm(x, np.eye(n, dtype=np.float32), schedule=gemm.GemmSchedule(8, 8, 8))
        assert_allclose(out, x, rtol=RTOL, atol=ATOL)

    def test_zeros(self):
        n = 32
        out = gemm.gemm(
            np.zeros((n, n), np.float32), rand((n, n), 12),
            schedule=gemm.GemmSchedule(16, 16, 16),
        )
        assert np.all(np.asarray(out) == 0.0)

    @settings(max_examples=20, deadline=None)
    @given(
        mi=st.integers(1, 4),
        ki=st.integers(1, 4),
        ni=st.integers(1, 4),
        bm=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, mi, ki, ni, bm, seed):
        m, k, n = mi * bm, ki * bm, ni * bm
        x, w = rand((m, k), seed), rand((k, n), seed + 1)
        out = gemm.gemm(x, w, schedule=gemm.GemmSchedule(bm, bm, bm))
        assert_allclose(out, ref.gemm(x, w), rtol=RTOL, atol=ATOL * 10)

    def test_vmem_bytes_model(self):
        s = gemm.GemmSchedule(128, 128, 128)
        assert s.vmem_bytes() == 3 * 128 * 128 * 4


class TestDense:
    @pytest.mark.parametrize("n", [32, 64, 128])
    def test_dense_relu(self, n):
        x, w, b = rand((n, n), 20), rand((n, n), 21), rand((n,), 22)
        out = gemm.dense(x, w, b, schedule=gemm.GemmSchedule(32, 32, 32))
        assert_allclose(out, ref.dense(x, w, b), rtol=RTOL, atol=ATOL)

    def test_dense_no_relu_matches_affine(self):
        n = 64
        x, w, b = rand((n, n), 23), rand((n, n), 24), rand((n,), 25)
        out = gemm.dense(x, w, b, relu=False, schedule=gemm.GemmSchedule(32, 32, 32))
        assert_allclose(out, ref.gemm(x, w) + b, rtol=RTOL, atol=ATOL)

    def test_relu_clamps_negatives(self):
        n = 32
        x, w = rand((n, n), 26), rand((n, n), 27)
        b = np.full((n,), -1e6, np.float32)
        out = gemm.dense(x, w, b, schedule=gemm.GemmSchedule(16, 16, 16))
        assert np.all(np.asarray(out) == 0.0)
