"""QNN int8 GEMM/conv kernels vs oracles."""

import numpy as np
import pytest
from numpy.testing import assert_array_equal

pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings  # noqa: E402

from compile import workloads
from compile.kernels import gemm as gemm_mod
from compile.kernels import conv2d as conv2d_mod
from compile.kernels import qnn, ref


def rand_i8(shape, seed=0, lo=-7, hi=8):
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, size=shape).astype(np.int8)


class TestQnnGemm:
    @pytest.mark.parametrize("n", [8, 32, 64, 128])
    def test_vs_oracle(self, n):
        x, w = rand_i8((n, n), 1), rand_i8((n, n), 2)
        out = qnn.qnn_gemm(x, w, schedule=gemm_mod.GemmSchedule(8, 8, 8))
        assert_array_equal(np.asarray(out), np.asarray(ref.qnn_gemm(x, w)))

    def test_full_range_no_overflow(self):
        n = 64
        x = rand_i8((n, n), 3, -128, 128)
        w = rand_i8((n, n), 4, -128, 128)
        out = qnn.qnn_gemm(x, w, schedule=gemm_mod.GemmSchedule(32, 32, 32))
        expect = x.astype(np.int64) @ w.astype(np.int64)
        assert np.abs(expect).max() < 2**31
        assert_array_equal(np.asarray(out, np.int64), expect)

    @settings(max_examples=15, deadline=None)
    @given(
        mi=st.integers(1, 3), ki=st.integers(1, 3), ni=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, mi, ki, ni, seed):
        m, k, n = mi * 16, ki * 16, ni * 16
        x, w = rand_i8((m, k), seed), rand_i8((k, n), seed + 1)
        out = qnn.qnn_gemm(x, w, schedule=gemm_mod.GemmSchedule(16, 16, 16))
        assert_array_equal(np.asarray(out), np.asarray(ref.qnn_gemm(x, w)))


class TestRequantize:
    def test_matches_oracle(self):
        n = 32
        x, w = rand_i8((n, n), 5), rand_i8((n, n), 6)
        acc = np.asarray(ref.qnn_gemm(x, w), np.int32)
        out = np.asarray(qnn.requantize(acc, scale=0.05, zp=3, block=16), np.int32)
        expect = np.asarray(ref.qnn_gemm_requant(x, w, 0.05, 3), np.int32)
        # XLA may fuse mul+add into an FMA in one lowering and not the other,
        # flipping exact-half ties — allow 1 ULP on a small fraction.
        diff = np.abs(out - expect)
        assert diff.max() <= 1
        assert (diff == 0).mean() > 0.98

    def test_saturates(self):
        acc = np.array([[10_000_000, -10_000_000]], np.int32)
        out = np.asarray(qnn.requantize(acc, scale=1.0, zp=0, block=1))
        assert out.tolist() == [[127, -128]]


class TestQnnConv:
    @pytest.mark.parametrize(
        "cin,cout,h,k,stride,pad",
        [(4, 8, 10, 3, 1, 1), (4, 8, 10, 3, 2, 1), (4, 8, 10, 1, 2, 0), (8, 16, 9, 3, 1, 1)],
    )
    def test_vs_oracle(self, cin, cout, h, k, stride, pad):
        x = rand_i8((1, cin, h, h), 7)
        w = rand_i8((cout, cin, k, k), 8)
        out = qnn.qnn_conv2d_nchw(x, w, stride, pad, schedule=conv2d_mod.ConvSchedule(4, 2))
        assert_array_equal(np.asarray(out), np.asarray(ref.qnn_conv2d(x, w, stride, pad)))

    def test_resnet_c11_geometry(self):
        layer = next(l for l in workloads.RESNET18_LAYERS if l.name == "C11")
        x = rand_i8((1, layer.cin, layer.h, layer.w), 9)
        w = rand_i8((layer.cout, layer.cin, layer.k, layer.k), 10)
        out = qnn.qnn_conv2d_nchw(
            x, w, layer.stride, layer.pad, schedule=conv2d_mod.TUNED_CONV_SCHEDULE
        )
        assert out.shape == (1, layer.cout, layer.ho, layer.wo)
        assert_array_equal(
            np.asarray(out), np.asarray(ref.qnn_conv2d(x, w, layer.stride, layer.pad))
        )
