"""L2 model graphs + AOT machinery: shapes, checksum protocol, lowering."""

import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from compile import aot, model, workloads
from compile.kernels import bitpack, bitserial, conv2d, gemm, ref


class TestSplitMix:
    def test_known_vector(self):
        # SplitMix64(seed=0) first outputs — cross-checked against the rust
        # implementation (util::rng tests use the same constants).
        z = aot.splitmix64_stream(0, 3)
        assert z[0] == np.uint64(0xE220A8397B1DCDAF)
        assert z[1] == np.uint64(0x6E789E6AA1B965F4)
        assert z[2] == np.uint64(0x06C45D188009454F)

    def test_f32_range(self):
        v = aot.gen_input(42, (1000,), "f32")
        assert v.dtype == np.float32
        assert v.min() >= -1.0 and v.max() < 1.0

    def test_i8_range(self):
        v = aot.gen_input(42, (1000,), "i8")
        assert v.min() >= -7 and v.max() <= 7

    def test_unipolar_range(self):
        v = aot.gen_input(42, (1000,), "i32u3")
        assert v.min() >= 0 and v.max() < 8

    def test_deterministic(self):
        a = aot.gen_input(7, (64, 64), "f32")
        b = aot.gen_input(7, (64, 64), "f32")
        assert_array_equal(a, b)


class TestModelGraphs:
    def test_gemm_net(self):
        fn = model.gemm_net(gemm.GemmSchedule(16, 16, 16))
        x = aot.gen_input(1, (32, 32), "f32")
        w = aot.gen_input(2, (32, 32), "f32")
        (out,) = fn(x, w)
        assert_allclose(out, ref.gemm(x, w), rtol=2e-5, atol=1e-5)

    def test_conv_net_matches_oracle(self):
        layer = workloads.RESNET18_LAYERS[9]  # C11: 512x512x7x7
        # shrink to keep the test fast but keep geometry class (k=3,s=1,p=1)
        small = workloads.ConvLayer("t", 1, 8, 8, 7, 7, 3, 1, 1)
        fn = model.conv_net(small, conv2d.ConvSchedule(4, 1))
        x = aot.gen_input(3, (1, 8, 7, 7), "f32")
        w = aot.gen_input(4, (8, 8, 3, 3), "f32")
        (out,) = fn(x, w)
        assert_allclose(out, ref.conv2d(x, w, 1, 1), rtol=2e-4, atol=2e-4)
        assert layer.macs == workloads.PAPER_MACS["C11"]

    def test_conv_im2col_net_matches_direct(self):
        small = workloads.ConvLayer("t", 1, 4, 8, 8, 8, 3, 1, 1)
        fn = model.conv_im2col_net(small, gemm.GemmSchedule(16, 16, 16))
        x = aot.gen_input(5, (1, 4, 8, 8), "f32")
        w = aot.gen_input(6, (8, 4, 3, 3), "f32")
        (out,) = fn(x, w)
        assert_allclose(out, ref.conv2d(x, w, 1, 1), rtol=2e-4, atol=2e-4)

    def test_bitserial_net_runtime_pack(self):
        k = 64
        fn = model.bitserial_gemm_net(k, 2, 2, True, bitserial.BitserialSchedule(8, 8))
        a = aot.gen_input(7, (16, k), "i32u2")
        w = aot.gen_input(8, (16, k), "i32u2")
        wp = bitpack.pack_unipolar(w, 2)
        (out,) = fn(a, wp)
        assert_array_equal(
            np.asarray(out, np.int64),
            np.asarray(a, np.int64) @ np.asarray(w, np.int64).T,
        )

    def test_bitserial_conv_net_matches_int_conv(self):
        layer = workloads.ConvLayer("t", 1, 4, 8, 8, 8, 3, 1, 1)
        bits = 2
        fn = model.bitserial_conv_net(layer, bits, bits, True,
                                      bitserial.BitserialSchedule(64, 8))
        x = aot.gen_input(9, (1, 4, 8, 8), f"i32u{bits}")
        wfull = aot.gen_input(10, (8, 4 * 9), f"i32u{bits}")
        ckk, kpad = 36, 64
        wpad = np.pad(np.asarray(wfull), ((0, 0), (0, kpad - ckk)))
        wp = bitpack.pack_unipolar(wpad, bits)
        (out,) = fn(x, wp)
        # oracle: integer conv with the same (c, dy, dx) weight layout
        w4 = np.asarray(wfull).reshape(8, 4, 3, 3)
        expect = ref.qnn_conv2d(
            np.asarray(x, np.int8), w4.astype(np.int8), 1, 1
        )
        assert_array_equal(np.asarray(out), np.asarray(expect))


class TestAotCatalog:
    def test_catalog_names_unique(self):
        arts = aot.catalog()
        names = [a.name for a in arts]
        assert len(names) == len(set(names))

    def test_catalog_covers_paper_experiments(self):
        kinds = {a.meta["kind"] for a in aot.catalog()}
        assert {
            "gemm", "gemm_variant", "dense", "conv", "conv_im2col",
            "qnn_gemm", "qnn_conv", "bitserial_gemm", "bitserial_conv",
        } <= kinds

    def test_quick_catalog_is_small(self):
        assert len(aot.catalog(quick=True)) <= 6

    def test_lower_and_execute_quick_artifact(self, tmp_path):
        art = aot.catalog(quick=True)[0]
        entry = art.build(tmp_path, seed_base=123, execute=True)
        hlo = (tmp_path / entry["file"]).read_text()
        assert "HloModule" in hlo
        assert entry["outputs"][0]["checksum"] != 0.0
