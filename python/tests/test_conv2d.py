"""Pallas conv2d spatial-pack + im2col kernels vs lax.conv oracle."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings  # noqa: E402

from compile import workloads
from compile.kernels import conv2d, ref

RTOL = 2e-4
ATOL = 2e-4


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


class TestConvGeometry:
    @pytest.mark.parametrize("layer", workloads.RESNET18_LAYERS, ids=lambda l: l.name)
    def test_macs_match_paper_table3(self, layer):
        assert layer.macs == workloads.PAPER_MACS[layer.name]

    def test_out_size_eq3(self):
        # eq.(3) with floor semantics: 56 -> 28 at s=2,k=3,p=1
        assert ref.conv_out_size(56, 3, 2, 1) == 28
        assert ref.conv_out_size(56, 1, 2, 0) == 28
        assert ref.conv_out_size(7, 3, 1, 1) == 7


class TestConvSpatialPack:
    @pytest.mark.parametrize(
        "cin,cout,h,k,stride,pad",
        [
            (8, 16, 14, 3, 1, 1),
            (8, 16, 14, 3, 2, 1),
            (8, 16, 14, 1, 1, 0),
            (8, 16, 14, 1, 2, 0),
            (4, 8, 9, 3, 1, 1),  # odd size -> ho padding path
            (4, 8, 8, 5, 1, 2),  # larger kernel
            (3, 4, 12, 3, 3, 1),  # stride 3
        ],
    )
    def test_vs_oracle(self, cin, cout, h, k, stride, pad):
        x = rand((2, cin, h, h), 1)
        w = rand((cout, cin, k, k), 2)
        out = conv2d.conv2d_nchw(
            x, w, stride, pad, schedule=conv2d.ConvSchedule(4, 2)
        )
        assert_allclose(out, ref.conv2d(x, w, stride, pad), rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("bco,brow", [(4, 1), (8, 2), (16, 4), (16, 8)])
    def test_schedule_grid(self, bco, brow):
        x = rand((1, 8, 16, 16), 3)
        w = rand((16, 8, 3, 3), 4)
        out = conv2d.conv2d_nchw(x, w, 1, 1, schedule=conv2d.ConvSchedule(bco, brow))
        assert_allclose(out, ref.conv2d(x, w, 1, 1), rtol=RTOL, atol=ATOL)

    def test_relu_fused(self):
        x = rand((1, 4, 8, 8), 5)
        w = rand((8, 4, 3, 3), 6)
        out = conv2d.conv2d_nchw(x, w, 1, 1, schedule=conv2d.ConvSchedule(4, 2), relu=True)
        assert_allclose(out, ref.conv2d_relu(x, w, 1, 1), rtol=RTOL, atol=ATOL)
        assert np.all(np.asarray(out) >= 0.0)

    @pytest.mark.parametrize("lname", ["C4", "C8", "C11"])
    def test_resnet_layers_small_subset(self, lname):
        layer = next(l for l in workloads.RESNET18_LAYERS if l.name == lname)
        x = rand((1, layer.cin, layer.h, layer.w), 7)
        w = rand((layer.cout, layer.cin, layer.k, layer.k), 8)
        out = conv2d.conv2d_nchw(
            x, w, layer.stride, layer.pad, schedule=conv2d.TUNED_CONV_SCHEDULE
        )
        expect = ref.conv2d(x, w, layer.stride, layer.pad)
        assert out.shape == (1, layer.cout, layer.ho, layer.wo)
        assert_allclose(out, expect, rtol=RTOL, atol=ATOL * 10)

    def test_bad_bco_raises(self):
        x = rand((1, 4, 8, 8), 9)
        w = rand((6, 4, 3, 3), 10)
        with pytest.raises(ValueError):
            conv2d.conv2d_nchw(x, w, 1, 1, schedule=conv2d.ConvSchedule(4, 2))

    @settings(max_examples=15, deadline=None)
    @given(
        cin=st.sampled_from([2, 4, 8]),
        coutm=st.integers(1, 3),
        h=st.integers(6, 18),
        k=st.sampled_from([1, 3]),
        stride=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_geometry(self, cin, coutm, h, k, stride, seed):
        pad = k // 2
        cout = 4 * coutm
        x = rand((1, cin, h, h), seed)
        w = rand((cout, cin, k, k), seed + 1)
        out = conv2d.conv2d_nchw(x, w, stride, pad, schedule=conv2d.ConvSchedule(4, 2))
        assert_allclose(out, ref.conv2d(x, w, stride, pad), rtol=RTOL, atol=ATOL * 10)


class TestIm2col:
    @pytest.mark.parametrize(
        "k,stride,pad", [(3, 1, 1), (3, 2, 1), (1, 1, 0), (1, 2, 0), (5, 1, 2)]
    )
    def test_vs_oracle(self, k, stride, pad):
        x = rand((2, 4, 12, 12), 11)
        out = conv2d.im2col(x, k, stride, pad, brow=2)
        assert_allclose(out, ref.im2col(x, k, k, stride, pad), rtol=RTOL, atol=ATOL)

    def test_conv_via_im2col_matches_conv(self):
        x = rand((1, 4, 10, 10), 12)
        w = rand((8, 4, 3, 3), 13)
        cols = np.asarray(conv2d.im2col(x, 3, 1, 1, brow=2))  # (1, P, 36)
        wmat = w.reshape(8, -1).T
        out = (cols[0] @ wmat).T.reshape(1, 8, 10, 10)
        assert_allclose(out, ref.conv2d(x, w, 1, 1), rtol=RTOL, atol=ATOL * 10)
