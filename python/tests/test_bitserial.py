"""Bit-packing + bit-serial GEMM kernels: oracles, identities, properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_array_equal

pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings  # noqa: E402

from compile.kernels import bitpack, bitserial, ref


def rand_uint(shape, bits, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << bits, size=shape).astype(np.int32)


def rand_signs(shape, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 2, size=shape) * 2 - 1).astype(np.int32)


class TestPackUnipolar:
    @pytest.mark.parametrize("bits", [1, 2, 4, 8])
    def test_roundtrip(self, bits):
        v = rand_uint((16, 64), bits, seed=bits)
        planes = bitpack.pack_unipolar(v, bits)
        assert planes.shape == (bits, 16, 2)
        assert planes.dtype == jnp.uint32
        assert_array_equal(ref.unpack_unipolar(planes), v)

    def test_matches_ref_pack(self):
        v = rand_uint((8, 96), 3, seed=7)
        assert_array_equal(bitpack.pack_unipolar(v, 3), ref.pack_unipolar(v, 3))

    def test_bad_k_raises(self):
        with pytest.raises(ValueError):
            bitpack.pack_unipolar(np.zeros((4, 33), np.int32), 1)

    def test_all_ones_packs_to_full_words(self):
        v = np.full((4, 64), 1, np.int32)
        planes = np.asarray(bitpack.pack_unipolar(v, 1))
        assert np.all(planes == 0xFFFFFFFF)

    @settings(max_examples=20, deadline=None)
    @given(
        bits=st.integers(1, 8),
        rows=st.sampled_from([1, 2, 8]),
        kw=st.integers(1, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_roundtrip(self, bits, rows, kw, seed):
        v = rand_uint((rows * 8, kw * 32), bits, seed)
        planes = bitpack.pack_unipolar(v, bits, schedule=bitpack.PackSchedule(8))
        assert_array_equal(ref.unpack_unipolar(planes), v)


class TestPackBipolar:
    def test_matches_ref(self):
        s = rand_signs((2, 8, 64), seed=3)
        assert_array_equal(bitpack.pack_bipolar(s), ref.pack_bipolar(s))

    def test_all_plus_one(self):
        s = np.ones((1, 4, 32), np.int32)
        assert np.all(np.asarray(bitpack.pack_bipolar(s)) == 0xFFFFFFFF)

    def test_all_minus_one(self):
        s = -np.ones((1, 4, 32), np.int32)
        assert np.all(np.asarray(bitpack.pack_bipolar(s)) == 0)


class TestBitserialGemmUnipolar:
    @pytest.mark.parametrize("bits", [1, 2, 4, 8])
    def test_vs_integer_matmul(self, bits):
        m = n = 16
        k = 64
        a = rand_uint((m, k), bits, seed=bits)
        w = rand_uint((n, k), bits, seed=bits + 100)
        ap = bitpack.pack_unipolar(a, bits)
        wp = bitpack.pack_unipolar(w, bits)
        out = bitserial.bitserial_gemm(ap, wp, k=k, unipolar=True,
                                       schedule=bitserial.BitserialSchedule(8, 8))
        expect = a.astype(np.int64) @ w.T.astype(np.int64)
        assert_array_equal(np.asarray(out, np.int64), expect)

    def test_mixed_precision_a2_w1(self):
        m, n, k = 8, 8, 32
        a = rand_uint((m, k), 2, seed=1)
        w = rand_uint((n, k), 1, seed=2)
        out = bitserial.bitserial_gemm(
            bitpack.pack_unipolar(a, 2), bitpack.pack_unipolar(w, 1), k=k,
            unipolar=True, schedule=bitserial.BitserialSchedule(8, 8),
        )
        assert_array_equal(np.asarray(out), a @ w.T)

    def test_matches_ref_oracle(self):
        m, n, k = 16, 16, 96
        a, w = rand_uint((m, k), 3, 5), rand_uint((n, k), 3, 6)
        ap, wp = bitpack.pack_unipolar(a, 3), bitpack.pack_unipolar(w, 3)
        out = bitserial.bitserial_gemm(ap, wp, k=k, unipolar=True,
                                       schedule=bitserial.BitserialSchedule(16, 16))
        assert_array_equal(np.asarray(out), np.asarray(ref.bitserial_gemm_unipolar(ap, wp)))

    @settings(max_examples=15, deadline=None)
    @given(
        abits=st.integers(1, 4),
        wbits=st.integers(1, 4),
        kw=st.sampled_from([1, 2, 4]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_vs_matmul(self, abits, wbits, kw, seed):
        m = n = 8
        k = kw * 32
        a = rand_uint((m, k), abits, seed)
        w = rand_uint((n, k), wbits, seed + 1)
        out = bitserial.bitserial_gemm(
            bitpack.pack_unipolar(a, abits), bitpack.pack_unipolar(w, wbits),
            k=k, unipolar=True, schedule=bitserial.BitserialSchedule(8, 8),
        )
        assert_array_equal(np.asarray(out, np.int64), a.astype(np.int64) @ w.T.astype(np.int64))


class TestBitserialGemmBipolar:
    def test_single_bit_hamming_identity(self):
        # bipolar 1-bit dot == K - 2*hamming_distance
        m = n = 8
        k = 64
        sa = rand_signs((1, m, k), 11)
        sw = rand_signs((1, n, k), 12)
        out = bitserial.bitserial_gemm(
            bitpack.pack_bipolar(sa), bitpack.pack_bipolar(sw), k=k,
            unipolar=False, schedule=bitserial.BitserialSchedule(8, 8),
        )
        va, vw = ref.bipolar_values(sa), ref.bipolar_values(sw)
        assert_array_equal(np.asarray(out), va @ vw.T)

    @pytest.mark.parametrize("bits", [1, 2, 4])
    def test_multibit_vs_materialized_values(self, bits):
        m = n = 16
        k = 32
        sa = rand_signs((bits, m, k), bits + 20)
        sw = rand_signs((bits, n, k), bits + 30)
        out = bitserial.bitserial_gemm(
            bitpack.pack_bipolar(sa), bitpack.pack_bipolar(sw), k=k,
            unipolar=False, schedule=bitserial.BitserialSchedule(8, 8),
        )
        va, vw = ref.bipolar_values(sa), ref.bipolar_values(sw)
        assert_array_equal(np.asarray(out), va @ vw.T)

    def test_matches_ref_oracle(self):
        m, n, k = 8, 8, 64
        sa, sw = rand_signs((2, m, k), 41), rand_signs((2, n, k), 42)
        ap, wp = bitpack.pack_bipolar(sa), bitpack.pack_bipolar(sw)
        out = bitserial.bitserial_gemm(ap, wp, k=k, unipolar=False,
                                       schedule=bitserial.BitserialSchedule(8, 8))
        assert_array_equal(np.asarray(out), np.asarray(ref.bitserial_gemm_bipolar(ap, wp, k)))
