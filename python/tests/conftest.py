"""Make `import compile.*` work regardless of pytest invocation directory."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
