"""Pooling/residual kernels + the composed ResNet-18 graph vs oracles."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import network
from compile.kernels import pooling, ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


class TestMaxpool:
    @pytest.mark.parametrize("k,stride,pad", [(2, 2, 0), (3, 2, 1), (3, 1, 1)])
    def test_vs_oracle(self, k, stride, pad):
        x = rand((2, 8, 12, 12), 1)
        out = pooling.maxpool2d(x, k, stride, pad, bc=4)
        assert_allclose(out, ref.maxpool2d(x, k, stride, pad), rtol=0, atol=0)

    def test_resnet_stem_geometry(self):
        # 56 -> 28 with k=3, s=2, p=1 (the stem maxpool at 112-input scale)
        x = rand((1, 4, 56, 56), 2)
        out = pooling.maxpool2d(x, 3, 2, 1, bc=4)
        assert out.shape == (1, 4, 28, 28)

    def test_negative_inputs_pad_correctly(self):
        # all-negative input: -inf padding must not leak into outputs
        x = -np.abs(rand((1, 4, 6, 6), 3)) - 1.0
        out = np.asarray(pooling.maxpool2d(x, 3, 2, 1, bc=4))
        assert np.all(np.isfinite(out))
        assert_allclose(out, ref.maxpool2d(x, 3, 2, 1))


class TestGlobalAvgPool:
    def test_vs_oracle(self):
        x = rand((3, 16, 7, 7), 4)
        out = pooling.global_avgpool(x, bc=8)
        assert_allclose(out, ref.global_avgpool(x), rtol=1e-6, atol=1e-6)

    def test_constant_input(self):
        x = np.full((1, 8, 5, 5), 2.5, np.float32)
        out = np.asarray(pooling.global_avgpool(x, bc=8))
        assert_allclose(out, 2.5)


class TestResidual:
    def test_vs_oracle_relu(self):
        x, y = rand((2, 8, 6, 6), 5), rand((2, 8, 6, 6), 6)
        out = pooling.residual_add(x, y, relu=True, bc=4)
        assert_allclose(out, ref.residual_add(x, y, True))
        assert np.all(np.asarray(out) >= 0)

    def test_no_relu(self):
        x, y = rand((1, 4, 4, 4), 7), rand((1, 4, 4, 4), 8)
        out = pooling.residual_add(x, y, relu=False, bc=4)
        assert_allclose(out, x + y)


class TestResnet18Graph:
    def test_block_structure_matches_torchvision(self):
        blocks = network.resnet18_blocks()
        assert len(blocks) == 8
        assert blocks[0] == network.BlockSpec(64, 64, 1)
        assert blocks[2] == network.BlockSpec(64, 128, 2)
        assert [b.cout for b in blocks] == [64, 64, 128, 128, 256, 256, 512, 512]
        # downsamples exactly at the three stage transitions
        assert [b.has_downsample for b in blocks] == [
            False, False, True, False, True, False, True, False,
        ]

    def test_forward_matches_reference_small_input(self):
        # 32x32 input keeps interpret-mode runtime tractable while passing
        # through every block (final feature map 1x1)
        params = network.init_params(key=0, classes=10)
        x = rand((1, 3, 32, 32), 9) * 0.5
        logits = network.forward(x, params)
        expect = network.reference_forward(x, params)
        assert logits.shape == (1, 10)
        assert_allclose(np.asarray(logits), np.asarray(expect), rtol=1e-3, atol=1e-3)

    def test_forward_batch(self):
        params = network.init_params(key=1, classes=4)
        x = rand((2, 3, 32, 32), 10) * 0.5
        logits = np.asarray(network.forward(x, params))
        assert logits.shape == (2, 4)
        # batch elements are independent
        single = np.asarray(network.forward(x[:1], params))
        assert_allclose(logits[0], single[0], rtol=1e-4, atol=1e-4)
