# cachebound build entry points.
#
#   make artifacts   lower every operator variant to HLO text + manifest
#                    (Python/JAX runs ONLY here — never on the request path)
#   make build       release build of the Rust coordinator/CLI
#   make test        Rust test suite
#   make doc         rustdoc with warnings denied (CI parity)

PYTHON ?= python3
CARGO  ?= cargo
ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts artifacts-quick build test doc

artifacts:
	$(PYTHON) python/compile/aot.py --out-dir $(ARTIFACTS_DIR)

# tiny subset for smoke tests
artifacts-quick:
	$(PYTHON) python/compile/aot.py --out-dir $(ARTIFACTS_DIR) --quick

build:
	cd rust && $(CARGO) build --release

test:
	cd rust && $(CARGO) test -q

doc:
	cd rust && RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps
