//! bench_serve — throughput scaling of the sharded serving core.
//!
//! Serves the synthetic workload mix (`operators::workloads::serving_mix`,
//! native tiled GEMMs — real CPU work, no artifacts needed) through
//! `ShardedServer` at 1/2/4 workers and reports requests-per-second plus
//! the scaling factor over the single-worker baseline.  The acceptance
//! target (EXPERIMENTS.md §Serving): ≥ 2× at 4 workers on a ≥ 4-core host.
//! A second section isolates the LRU response cache's effect at a fixed
//! worker count.
//!
//! Run: `cargo bench --bench bench_serve`

use cachebound::coordinator::server::{
    ServeConfig, ServeOutcome, ShardedServer, SyntheticExecutor,
};
use cachebound::operators::workloads;
use cachebound::util::table::fmt_time;

const REQUESTS: usize = 480;
const SEED: u64 = 0xBEEF;
const RUNS: usize = 3;

fn serve_once(workers: usize, cache_entries: usize, stream: &[String]) -> ServeOutcome {
    let cfg = ServeConfig::new(workers).with_cache(cache_entries);
    ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
        .serve_stream(stream.iter().cloned())
}

/// Best-of-N throughput (req/s): serving runs are wall-clock experiments,
/// so the least-interfered run is the honest number.
fn best_rps(workers: usize, cache_entries: usize, stream: &[String]) -> (f64, ServeOutcome) {
    let mut best: Option<(f64, ServeOutcome)> = None;
    for _ in 0..RUNS {
        let out = serve_once(workers, cache_entries, stream);
        assert_eq!(
            out.metrics.completed, stream.len() as u64,
            "all requests must succeed: {:?}",
            out.responses.iter().find(|r| !r.ok)
        );
        let rps = out.metrics.throughput(out.wall_seconds);
        if best.as_ref().is_none_or(|(b, _)| rps > *b) {
            best = Some((rps, out));
        }
    }
    best.unwrap()
}

fn main() {
    println!("== bench_serve: sharded serving core ==\n");
    let stream = workloads::serving_requests(REQUESTS, SEED);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "{} requests over {} models, best of {RUNS} runs, {cores} cores\n",
        stream.len(),
        workloads::serving_mix().len()
    );

    // -- worker scaling, cache disabled (pure execution scaling) --
    let mut baseline = 0.0;
    let mut rps4 = 0.0;
    for workers in [1usize, 2, 4] {
        let (rps, out) = best_rps(workers, 0, &stream);
        if workers == 1 {
            baseline = rps;
        }
        if workers == 4 {
            rps4 = rps;
        }
        let p50 = out.metrics.latency_percentiles(&[50.0]).map_or(0.0, |p| p[0]);
        println!(
            "workers {workers}:  {rps:8.1} req/s   p50 {}   {:.2}x vs 1 worker   ({} shards, {} batches)",
            fmt_time(p50),
            rps / baseline,
            out.metrics.per_shard.len(),
            out.metrics.batches,
        );
    }
    let scaling = rps4 / baseline;
    println!(
        "\n4-worker scaling: {scaling:.2}x {}",
        if scaling >= 2.0 {
            "(meets the >= 2x acceptance target)"
        } else {
            "(below the 2x target - likely < 4 usable cores on this host)"
        }
    );

    // -- response-cache effect at 4 workers --
    println!("\n-- LRU response cache (4 workers) --");
    for cache in [0usize, 64] {
        let (rps, out) = best_rps(4, cache, &stream);
        println!(
            "cache {cache:>3} entries:  {rps:10.1} req/s   {} hits ({:.0}%)",
            out.metrics.cache_hits,
            out.metrics.cache_hit_rate() * 100.0
        );
    }
}
