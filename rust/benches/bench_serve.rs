//! bench_serve — throughput scaling of the sharded serving core.
//!
//! Serves the synthetic workload mix (`operators::workloads::serving_mix`,
//! native tiled GEMMs — real CPU work, no artifacts needed) through
//! `ShardedServer` at 1/2/4 workers and reports requests-per-second plus
//! the scaling factor over the single-worker baseline.  The acceptance
//! target (EXPERIMENTS.md §Serving): ≥ 2× at 4 workers on a ≥ 4-core host.
//! A second section isolates the LRU response cache's effect at a fixed
//! worker count; a third A/Bs hash vs cache-aware placement — on the
//! uniform mix (expected within ±5%) and on the adversarial two-artifact
//! co-run mix, where hashing co-locates two L2-hungry artifacts on one
//! worker and cache-aware placement must split them
//! (`coordinator::placement::adversarial_mix`).
//!
//! A fourth section runs the drifting-mix A/B: a stream that starts on the
//! uniform mix and drifts onto the adversarial pair, served (a) statically
//! hash-placed, (b) with a drain-time re-plan between the phases, and
//! (c) with live migration converging mid-stream — the
//! `--rebalance off|drain|live` spectrum.
//!
//! A fifth section measures throughput-at-SLO: seeded open-loop Poisson
//! arrivals walk a rate ladder upward under shed admission, and the last
//! rung where nothing is shed and the p99 end-to-end latency meets the
//! SLO is the max sustainable rate, per placement policy
//! (EXPERIMENTS.md §Throughput-at-SLO; the deterministic counterpart
//! lives in the sweep's `bench/sim/<cpu>/servslo/*` records).
//!
//! A sixth section covers the quantized serving tiers (DESIGN.md §Tiers):
//! per-size packing density of int8 twins vs their fp32 equivalents (the
//! cache-aware packer must fit strictly more quantized artifacts per
//! worker), interference-free worker counts per tier for the L2-heavy
//! tail, and a wall-clock fp32-only vs mixed-tier throughput A/B on the
//! same weighted stream (deterministic counterpart:
//! `bench/sim/<cpu>/servtier/*`).
//!
//! A seventh section A/Bs cold vs pre-warmed startup through the
//! persistent compiled-artifact cache (DESIGN.md §Artifact cache): the
//! same stream served twice against one cache root — the cold pass
//! compiles and stores every first-touch artifact, the warm pass loads
//! them all from disk with zero compiles (deterministic counterpart:
//! `bench/sim/<cpu>/servcache/*`).
//!
//! An eighth section A/Bs admission concurrency (DESIGN.md §Admission
//! concurrency): the same stream admitted by one thread vs four threads
//! hash-partitioned by artifact against lock-free route-table snapshots
//! (`serve --admission-threads`).  Wall-clock gains depend on host core
//! count and how hot the workers run, so the section asserts the
//! correctness contract — every request exactly one disposition, all
//! completed — and reports throughput informationally (deterministic
//! counterpart: `bench/sim/<cpu>/servadm/{1t,4t}`).
//!
//! Run: `cargo bench --bench bench_serve`

use std::collections::BTreeMap;
use std::sync::Arc;

use cachebound::analysis::InterferenceModel;
use cachebound::coordinator::placement::{adversarial_mix, plan as placement_plan};
use cachebound::coordinator::server::{
    AdmissionMode, PrepSource, ServeConfig, ServeOutcome, ShardedServer, SyntheticExecutor,
};
use cachebound::coordinator::{
    min_workers_interference_free, ArrivalConfig, PlacementPolicy, RebalanceMode,
};
use cachebound::hw::profile_by_name;
use cachebound::operators::workloads::{self, Tier};
use cachebound::telemetry::CacheProfile;
use cachebound::util::table::fmt_time;

const REQUESTS: usize = 480;
const SEED: u64 = 0xBEEF;
const RUNS: usize = 3;

fn serve_once(workers: usize, cache_entries: usize, stream: &[String]) -> ServeOutcome {
    let cfg = ServeConfig::new(workers).with_cache(cache_entries);
    ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
        .serve_stream(stream.iter().cloned())
}

/// One placement-A/B run: fixed worker count, no response cache (caching
/// would mask the execution-path difference the A/B is about).
fn serve_placed(
    workers: usize,
    stream: &[String],
    placement: PlacementPolicy,
    profiles: &Arc<BTreeMap<String, CacheProfile>>,
) -> ServeOutcome {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let cfg = ServeConfig::new(workers)
        .with_profiles(profiles.clone())
        .with_placement(placement)
        .with_cpu(cpu);
    ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
        .serve_stream(stream.iter().cloned())
}

/// Best-of-N placement run (same rationale as [`best_rps`]).
fn best_placed_rps(
    workers: usize,
    stream: &[String],
    placement: PlacementPolicy,
    profiles: &Arc<BTreeMap<String, CacheProfile>>,
) -> f64 {
    (0..RUNS)
        .map(|_| {
            let out = serve_placed(workers, stream, placement, profiles);
            assert_eq!(out.metrics.completed, stream.len() as u64);
            out.metrics.throughput(out.wall_seconds)
        })
        .fold(0.0, f64::max)
}

/// Best-of-N throughput (req/s): serving runs are wall-clock experiments,
/// so the least-interfered run is the honest number.
fn best_rps(workers: usize, cache_entries: usize, stream: &[String]) -> (f64, ServeOutcome) {
    let mut best: Option<(f64, ServeOutcome)> = None;
    for _ in 0..RUNS {
        let out = serve_once(workers, cache_entries, stream);
        assert_eq!(
            out.metrics.completed, stream.len() as u64,
            "all requests must succeed: {:?}",
            out.responses.iter().find(|r| !r.ok)
        );
        let rps = out.metrics.throughput(out.wall_seconds);
        if best.as_ref().is_none_or(|(b, _)| rps > *b) {
            best = Some((rps, out));
        }
    }
    best.unwrap()
}

fn main() {
    println!("== bench_serve: sharded serving core ==\n");
    let stream = workloads::serving_requests(REQUESTS, SEED);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "{} requests over {} models, best of {RUNS} runs, {cores} cores\n",
        stream.len(),
        workloads::serving_mix().len()
    );

    // -- worker scaling, cache disabled (pure execution scaling) --
    let mut baseline = 0.0;
    let mut rps4 = 0.0;
    for workers in [1usize, 2, 4] {
        let (rps, out) = best_rps(workers, 0, &stream);
        if workers == 1 {
            baseline = rps;
        }
        if workers == 4 {
            rps4 = rps;
        }
        let p50 = out.metrics.latency_percentiles(&[50.0]).map_or(0.0, |p| p[0]);
        println!(
            "workers {workers}:  {rps:8.1} req/s   p50 {}   {:.2}x vs 1 worker   ({} shards, {} batches)",
            fmt_time(p50),
            rps / baseline,
            out.metrics.per_shard.len(),
            out.metrics.batches,
        );
    }
    let scaling = rps4 / baseline;
    println!(
        "\n4-worker scaling: {scaling:.2}x {}",
        if scaling >= 2.0 {
            "(meets the >= 2x acceptance target)"
        } else {
            "(below the 2x target - likely < 4 usable cores on this host)"
        }
    );

    // -- response-cache effect at 4 workers --
    println!("\n-- LRU response cache (4 workers) --");
    for cache in [0usize, 64] {
        let (rps, out) = best_rps(4, cache, &stream);
        println!(
            "cache {cache:>3} entries:  {rps:10.1} req/s   {} hits ({:.0}%)",
            out.metrics.cache_hits,
            out.metrics.cache_hit_rate() * 100.0
        );
    }

    // -- placement A/B: hash vs cache-aware (2 workers, no cache) --
    let cpu = profile_by_name("a53").unwrap().cpu;
    println!("\n-- placement A/B: hash vs cache-aware (2 workers) --");
    println!("profiling the serving mix (telemetry traces)...");
    let mix_profiles = cachebound::telemetry::serving_mix_profiles(&cpu);
    let hash_rps = best_placed_rps(2, &stream, PlacementPolicy::Hash, &mix_profiles);
    let aware_rps = best_placed_rps(2, &stream, PlacementPolicy::CacheAware, &mix_profiles);
    println!(
        "uniform mix:      hash {hash_rps:8.1} req/s   cache-aware {aware_rps:8.1} req/s   \
         ({:+.1}% — expected within ±5%)",
        (aware_rps / hash_rps - 1.0) * 100.0
    );

    // -- open-loop: max sustainable rate at a p99 SLO (2 workers, shed) --
    //
    // The closed-loop sections measure capacity; this one measures what a
    // wall-clock arrival process can push through before queueing (not
    // the operators) dominates the tail.  A seeded Poisson rate ladder
    // walks upward; a rung is sustained when the admission layer sheds
    // nothing and the p99 end-to-end latency meets the SLO.
    const SLO_MS: f64 = 50.0;
    const OPEN_REQUESTS: usize = 240;
    println!(
        "\n-- open-loop: max sustainable rate at p99 <= {SLO_MS} ms (2 workers, shed admission) --"
    );
    let open_stream = workloads::serving_requests(OPEN_REQUESTS, SEED);
    for (label, placement) in
        [("hash", PlacementPolicy::Hash), ("cache-aware", PlacementPolicy::CacheAware)]
    {
        let mut sustained: Option<f64> = None;
        for rate in [200.0f64, 400.0, 800.0, 1600.0, 3200.0, 6400.0, 12800.0] {
            let schedule =
                ArrivalConfig::poisson(rate, OPEN_REQUESTS, SEED).schedule();
            let cfg = ServeConfig::new(2)
                .with_profiles(mix_profiles.clone())
                .with_cpu(profile_by_name("a53").unwrap().cpu)
                .with_placement(placement)
                .with_admission(AdmissionMode::Shed);
            let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
                .serve_open_loop(open_stream.iter().cloned(), &schedule);
            let m = &out.metrics;
            assert_eq!(m.completed + m.failed + m.shed, m.requests);
            let p99 =
                m.latency_percentiles(&[99.0]).map_or(f64::INFINITY, |p| p[0]);
            let meets = m.shed == 0 && p99 * 1e3 <= SLO_MS;
            println!(
                "{label:>11} @ {rate:7.0} req/s:  p99 {}   {} shed   max depth {}   {}",
                fmt_time(p99),
                m.shed,
                m.max_queue_depth(),
                if meets { "ok" } else { "over SLO" },
            );
            if meets {
                sustained = Some(rate);
            } else {
                break;
            }
        }
        match sustained {
            Some(rate) => println!("{label:>11}: sustains {rate:.0} req/s at the SLO\n"),
            None => println!("{label:>11}: no ladder rung meets the SLO on this host\n"),
        }
    }

    // -- quantized tiers: packing density + mixed-tier serving (2 workers) --
    //
    // The serving tiers exist because each lattice step shrinks the
    // working set (4 bytes -> 1 byte -> 2 bits per element), so the
    // cache-aware packer fits more artifacts per worker before the shared
    // L2 saturates.  The wall-clock A/B below serves the same weighted
    // stream twice: fp32-only, then with the L2-straddling tail (n >= 96)
    // downshifted to its int8 twin.  The deterministic counterpart lives
    // in the sweep's `bench/sim/<cpu>/servtier/*` records.
    println!("\n-- quantized tiers: packing density and mixed-tier serving (2 workers) --");
    println!("profiling the tiered serving mix (telemetry traces)...");
    let tier_model = InterferenceModel::new(&cpu);
    let tier_profiles = cachebound::telemetry::serving_tier_mix_profiles(&cpu);
    for item in workloads::serving_mix() {
        let twin = workloads::tier_artifact(Tier::Int8, item.n);
        let (Some(f), Some(q)) =
            (tier_profiles.get(&item.artifact), tier_profiles.get(&twin))
        else {
            continue; // the small sizes have no quantized twin in the menu
        };
        let (df, dq) = (tier_model.demand_bytes(f), tier_model.demand_bytes(q));
        let per_worker = |d: u64| (cpu.l2.size_bytes as u64 / d.max(1)).max(1);
        println!(
            "n{:>4}: fp32 demand {:>4} KiB ({:>2} per worker)   \
             int8 demand {:>4} KiB ({:>2} per worker)",
            item.n,
            df / 1024,
            per_worker(df),
            dq / 1024,
            per_worker(dq),
        );
        assert!(dq < df, "int8 twin of n{} must demand less L2 than fp32", item.n);
        assert!(
            per_worker(dq) > per_worker(df),
            "the packer must fit strictly more int8 n{} twins per worker",
            item.n
        );
    }
    let tail_set = |tier: Tier| -> BTreeMap<String, CacheProfile> {
        [64usize, 96, 128]
            .iter()
            .filter_map(|&n| {
                let name = workloads::tier_artifact(tier, n);
                tier_profiles.get(&name).map(|p| (name, p.clone()))
            })
            .collect()
    };
    println!(
        "interference-free workers for the L2-heavy tail (n64/96/128): \
         fp32 {}   int8 {}   bit-serial {}",
        min_workers_interference_free(&tier_model, &tail_set(Tier::F32), 0.05),
        min_workers_interference_free(&tier_model, &tail_set(Tier::Int8), 0.05),
        min_workers_interference_free(&tier_model, &tail_set(Tier::BitSerial), 0.05),
    );
    let mixed_stream: Vec<String> = stream
        .iter()
        .map(|a| match workloads::synthetic_tier(a) {
            Some((Tier::F32, n)) if n >= 96 => {
                workloads::degrade_artifact(a).expect("fp32 always downshifts")
            }
            _ => a.clone(),
        })
        .collect();
    let f32_rps = best_placed_rps(2, &stream, PlacementPolicy::CacheAware, &tier_profiles);
    let mixed_rps =
        best_placed_rps(2, &mixed_stream, PlacementPolicy::CacheAware, &tier_profiles);
    println!(
        "fp32-only:        {f32_rps:8.1} req/s   mixed-tier {mixed_rps:8.1} req/s   \
         ({:.2}x — the n>=96 tail served as int8 twins)",
        mixed_rps / f32_rps
    );

    // -- artifact cache: cold vs pre-warmed startup (2 workers) --
    //
    // The persistent compiled-artifact cache turns first-touch prepares
    // into disk loads.  Same stream, same cache root, two passes: the
    // cold pass compiles and stores, the warm pass must perform zero
    // compiles — every prep is a disk hit.
    println!("\n-- artifact cache: cold vs pre-warmed startup (2 workers) --");
    let cache_root = std::env::temp_dir().join("cachebound_bench_serve_cache");
    let _ = std::fs::remove_dir_all(&cache_root);
    let serve_cached = || {
        let cfg = ServeConfig::new(2).with_cache_dir(cache_root.clone());
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_stream(stream.iter().cloned());
        assert_eq!(out.metrics.completed, stream.len() as u64);
        let compiled = out
            .metrics
            .prep
            .iter()
            .filter(|p| p.source == PrepSource::Compiled)
            .count();
        let loaded = out.metrics.prep.len() - compiled;
        let prep_s: f64 = out.metrics.prep.iter().map(|p| p.seconds).sum();
        (out.metrics.throughput(out.wall_seconds), compiled, loaded, prep_s)
    };
    let (cold_rps, cold_compiled, cold_loaded, cold_prep) = serve_cached();
    let (warm_rps, warm_compiled, warm_loaded, warm_prep) = serve_cached();
    assert_eq!(cold_loaded, 0, "the first pass starts from an empty cache");
    assert_eq!(warm_compiled, 0, "the pre-warmed pass must perform zero compiles");
    assert_eq!(warm_loaded, cold_compiled, "every cold compile becomes a warm disk hit");
    println!(
        "cold start:       {cold_rps:8.1} req/s   ({cold_compiled} compiled, total prep {})",
        fmt_time(cold_prep)
    );
    println!(
        "pre-warmed start: {warm_rps:8.1} req/s   ({warm_loaded} disk-warm, total prep {} — \
         acceptance: zero compiles on the warm pass)",
        fmt_time(warm_prep)
    );
    let _ = std::fs::remove_dir_all(&cache_root);

    // -- admission concurrency: 1 thread vs 4 threads (2 workers) --
    //
    // The multi-admission path partitions the stream by artifact hash
    // across N admission threads that classify, route and enqueue
    // concurrently against epoch-versioned route snapshots.  Whether
    // that moves wall-clock throughput here depends on the host (the
    // synthetic workers are usually the bottleneck), so the assertion is
    // the correctness contract: identical disposition counts across
    // thread counts.  The deterministic rate-ceiling A/B lives in the
    // sweep's `bench/sim/<cpu>/servadm/{1t,4t}` records.
    println!("\n-- admission concurrency: 1 vs 4 admission threads (2 workers) --");
    let serve_admitted = |threads: usize| -> f64 {
        let mut best = 0.0f64;
        for _ in 0..RUNS {
            let cfg = ServeConfig::new(2).with_admission_threads(threads);
            let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
                .serve_stream(stream.iter().cloned());
            let m = &out.metrics;
            assert_eq!(m.requests, stream.len() as u64);
            assert_eq!(m.completed, stream.len() as u64);
            assert_eq!(m.completed + m.failed + m.shed, m.requests);
            best = best.max(m.throughput(out.wall_seconds));
        }
        best
    };
    let adm1 = serve_admitted(1);
    let adm4 = serve_admitted(4);
    println!(
        "1 admission thread:  {adm1:8.1} req/s\n\
         4 admission threads: {adm4:8.1} req/s   ({:.2}x — informational; \
         the deterministic ceiling A/B is the servadm gate family)",
        adm4 / adm1
    );

    // adversarial co-run mix: two artifacts that hash onto the same worker
    // and whose L2 demands sum past the A53's 512 KiB L2
    println!("profiling adversarial candidates (budgeted telemetry traces)...");
    let Some(adv) = adversarial_mix(&cpu, 2, 8) else {
        println!("adversarial mix: no qualifying candidate pair on this profile — skipped");
        return;
    };
    let model = InterferenceModel::new(&cpu);
    let refs: Vec<&CacheProfile> = adv.iter().map(|(_, p)| p).collect();
    let colocated = model.total_slowdown(&refs);
    println!(
        "adversarial pair: {} + {}  (demands {} + {} KiB vs {} KiB L2; \
         co-located predicted slowdown {:.3} vs {:.3} split)",
        adv[0].0,
        adv[1].0,
        model.demand_bytes(&adv[0].1) / 1024,
        model.demand_bytes(&adv[1].1) / 1024,
        cpu.l2.size_bytes / 1024,
        colocated,
        refs.len() as f64,
    );
    let adv_profiles: Arc<BTreeMap<String, CacheProfile>> =
        Arc::new(adv.iter().cloned().collect());
    let adv_stream: Vec<String> = (0..REQUESTS).map(|i| adv[i % 2].0.clone()).collect();
    let adv_hash = best_placed_rps(2, &adv_stream, PlacementPolicy::Hash, &adv_profiles);
    let adv_aware = best_placed_rps(2, &adv_stream, PlacementPolicy::CacheAware, &adv_profiles);
    println!(
        "adversarial mix:  hash {adv_hash:8.1} req/s   cache-aware {adv_aware:8.1} req/s   \
         ({:.2}x — hash serializes both on one worker, cache-aware splits them)",
        adv_aware / adv_hash
    );

    // -- drifting mix: static hash vs drain-rebalance vs live migration --
    //
    // Phase 1 is the uniform mix, phase 2 drifts onto the adversarial
    // pair.  A static hash server stays co-located through phase 2; a
    // drain-time rebalance only fixes the routing at the phase boundary
    // (and pays a full stop-the-world drain there); live migration
    // converges mid-phase while the stream keeps flowing.
    println!("\n-- drifting mix: static hash vs drain-rebalance vs live migration (2 workers) --");
    let mut all_profiles: BTreeMap<String, CacheProfile> =
        mix_profiles.as_ref().clone();
    all_profiles.extend(adv.iter().cloned());
    let all_profiles = Arc::new(all_profiles);
    let phase1: Vec<String> = stream[..REQUESTS / 2].to_vec();
    let phase2: Vec<String> =
        (0..REQUESTS / 2).map(|i| adv[i % 2].0.clone()).collect();
    let drift_stream: Vec<String> =
        phase1.iter().chain(&phase2).cloned().collect();

    let serve_drift = |rebalance: RebalanceMode| -> (f64, usize) {
        let mut best = 0.0f64;
        let mut migrations = 0usize;
        for _ in 0..RUNS {
            let cfg = ServeConfig::new(2)
                .with_profiles(all_profiles.clone())
                .with_cpu(profile_by_name("a53").unwrap().cpu)
                .with_rebalance(rebalance);
            let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
                .serve_stream(drift_stream.iter().cloned());
            assert_eq!(out.metrics.completed, drift_stream.len() as u64);
            best = best.max(out.metrics.throughput(out.wall_seconds));
            migrations = out.metrics.migrations.len();
        }
        (best, migrations)
    };

    // (a) static hash: no rebalancing at all
    let (static_rps, _) = serve_drift(RebalanceMode::Off);

    // (b) drain-rebalance: serve phase 1 hash-placed, drain, re-plan over
    // what was observed, then serve phase 2 under the new plan — both
    // walls count, the drain gap is this strategy's cost
    let mut drain_best = 0.0f64;
    for _ in 0..RUNS {
        let cfg1 = ServeConfig::new(2)
            .with_profiles(all_profiles.clone())
            .with_cpu(profile_by_name("a53").unwrap().cpu)
            .with_rebalance(RebalanceMode::Off);
        let out1 = ShardedServer::start(cfg1, |_w| Ok(SyntheticExecutor::new()))
            .serve_stream(phase1.iter().cloned());
        assert_eq!(out1.metrics.completed, phase1.len() as u64);
        // the drain-time re-plan over the artifacts phase 2 will serve
        let observed: BTreeMap<String, CacheProfile> = adv.iter().cloned().collect();
        let replanned = placement_plan(&model, &observed, 2);
        let cfg2 = ServeConfig::new(2)
            .with_profiles(all_profiles.clone())
            .with_cpu(profile_by_name("a53").unwrap().cpu)
            .with_plan(Arc::new(replanned))
            .with_rebalance(RebalanceMode::Off);
        let out2 = ShardedServer::start(cfg2, |_w| Ok(SyntheticExecutor::new()))
            .serve_stream(phase2.iter().cloned());
        assert_eq!(out2.metrics.completed, phase2.len() as u64);
        let rps = (out1.metrics.completed + out2.metrics.completed) as f64
            / (out1.wall_seconds + out2.wall_seconds);
        drain_best = drain_best.max(rps);
    }

    // (c) live: hash start, divergence-triggered migration mid-stream
    let (live_rps, live_migrations) = serve_drift(RebalanceMode::Live);

    println!(
        "static hash:      {static_rps:8.1} req/s   (pair stays co-located all of phase 2)"
    );
    println!(
        "drain-rebalance:  {drain_best:8.1} req/s   (re-plan applied only at the phase boundary)"
    );
    println!(
        "live migration:   {live_rps:8.1} req/s   ({live_migrations} migrations; \
         {:.2}x vs static, {:.2}x vs drain — acceptance: live >= drain)",
        live_rps / static_rps,
        live_rps / drain_best
    );
}
