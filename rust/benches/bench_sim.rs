//! bench_sim — performance of the framework's own hot paths: the cache
//! simulator replay rate, the analytic traffic model, the GBT cost model
//! and the end-to-end Fig-1 pipeline.  These are the L3 §Perf targets in
//! EXPERIMENTS.md (the coordinator must never be the bottleneck).
//!
//! Run: `cargo bench --bench bench_sim`

use cachebound::bench::native_line;
use cachebound::coordinator::pipeline::{Pipeline, PipelineConfig};
use cachebound::hw::profile_by_name;
use cachebound::operators::gemm::GemmSchedule;
use cachebound::sim::cache::{AccessKind, SetAssocCache};
use cachebound::sim::hierarchy::Hierarchy;
use cachebound::sim::trace;
use cachebound::sim::traffic::TrafficModel;
use cachebound::tuner::gbt::Gbt;
use cachebound::util::bench::{measure, report_line, BenchConfig};
use cachebound::util::rng::Xoshiro256;

fn main() {
    let cfg = BenchConfig::quick();
    println!("== bench_sim: framework hot paths ==\n");
    let cpu = profile_by_name("a53").unwrap().cpu;

    // raw cache access rate
    let mut cache = SetAssocCache::new(&cpu.l1);
    let mut rng = Xoshiro256::new(1);
    let addrs: Vec<u64> = (0..100_000).map(|_| rng.below(1 << 20)).collect();
    let m = measure(&cfg, || {
        let mut h = 0u64;
        for &a in &addrs {
            if cache.access(a, AccessKind::Read).hit {
                h += 1;
            }
        }
        h
    });
    println!(
        "{}   ({:.1} M accesses/s)",
        report_line("cache access x100k", &m, None),
        0.1 / m.seconds.median
    );

    // full-hierarchy GEMM trace replay (N=96: ~1M accesses)
    let m = measure(&cfg, || {
        let mut h = Hierarchy::new(&cpu);
        trace::replay_gemm(&mut h, 96, 96, 96, GemmSchedule::new(32, 32, 32, 4), 4);
        h.counts.accesses
    });
    let accesses = {
        let mut h = Hierarchy::new(&cpu);
        trace::replay_gemm(&mut h, 96, 96, 96, GemmSchedule::new(32, 32, 32, 4), 4);
        h.counts.accesses as f64
    };
    println!(
        "{}   ({:.1} M accesses/s)",
        report_line("gemm trace replay n96", &m, None),
        accesses / m.seconds.median / 1e6
    );

    // analytic traffic model (must be ~ns: it runs inside tuner loops)
    let tm = TrafficModel::new(&cpu);
    native_line("analytic traffic model", &cfg, None, || {
        tm.gemm(1024, 1024, 1024, GemmSchedule::new(64, 64, 64, 4), 4)
    });

    // full timing model
    native_line("simulate_gemm_time", &cfg, None, || {
        cachebound::sim::timing::simulate_gemm_time(
            &cpu,
            1024,
            1024,
            1024,
            GemmSchedule::new(64, 64, 64, 4),
            32,
        )
    });

    // GBT fit + rank (the tuner's per-batch cost)
    let mut rng = Xoshiro256::new(2);
    let xs: Vec<Vec<f64>> = (0..256).map(|_| (0..8).map(|_| rng.f64()).collect()).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x.iter().sum::<f64>() + rng.f64() * 0.1).collect();
    native_line("gbt fit 256x8 x40 trees", &cfg, None, || {
        Gbt::fit(&xs, &ys, 40, 3, 0.3)
    });
    let model = Gbt::fit(&xs, &ys, 40, 3, 0.3);
    let cands: Vec<usize> = (0..xs.len()).collect();
    native_line("gbt rank 256 candidates", &cfg, None, || {
        let mut r = Xoshiro256::new(3);
        model.rank(&cands, |i| xs[i].clone(), &mut r, 0.05)
    });

    // end-to-end fig1 pipeline (the report hot path)
    let e2e_cfg = BenchConfig {
        samples: 3,
        ..BenchConfig::quick()
    };
    native_line("fig1 end-to-end pipeline", &e2e_cfg, None, || {
        let mut p = Pipeline::new(PipelineConfig {
            n_workers: 2,
            tune_trials: 8,
            skip_native: true,
            native_max_n: 0,
        });
        cachebound::report::fig1(&mut p, "a53").unwrap().0.best_bound
    });
}
