//! bench_bitserial — regenerates Figs 4 & 5 (bit-serial GEMM performance
//! over size + eq. (5) required bandwidth) and measures the host-native
//! popcount GEMM including the runtime packing step.
//!
//! Run: `cargo bench --bench bench_bitserial`

use cachebound::bench::{bench_pipeline, native_line, quick_flag};
use cachebound::operators::bitserial;
use cachebound::operators::Tensor;
use cachebound::report;
use cachebound::util::bench::{measure, BenchConfig};

fn main() {
    let quick = quick_flag();
    println!("== bench_bitserial: Figs 4 & 5 ==\n");

    let mut pipeline = bench_pipeline(8);
    for profile in ["a53", "a72"] {
        let (f, csv4, csv5) = report::fig4_fig5(&mut pipeline, profile).unwrap();
        println!("-- {profile}: bit-serial GEMM GOP/s by (bits, N) — bipolar --");
        print!("{:>6}", "N\\bits");
        for b in [1, 2, 4, 8] {
            print!("{b:>10}");
        }
        println!();
        for &n in &[128usize, 512, 2048, 8192] {
            print!("{n:>6}");
            for b in [1usize, 2, 4, 8] {
                let g = f
                    .points
                    .iter()
                    .find(|(bb, uni, nn, _, _)| *bb == b && !*uni && *nn == n)
                    .map(|(_, _, _, g, _)| *g)
                    .unwrap_or(f64::NAN);
                print!("{g:>10.1}");
            }
            println!();
        }
        let max_bw = f.points.iter().map(|(.., bw)| *bw).fold(0.0, f64::max);
        println!(
            "  max required bandwidth {:.0} MiB/s vs L1 {:.0} MiB/s -> {}\n",
            max_bw / (1 << 20) as f64,
            f.l1_bw / (1 << 20) as f64,
            if max_bw < f.l1_bw { "NOT cache-bound (paper Fig 5)" } else { "cache-bound!" }
        );
        csv4.write(format!("results/bench_bitserial_fig4_{profile}.csv")).unwrap();
        csv5.write(format!("results/bench_bitserial_fig5_{profile}.csv")).unwrap();
    }

    // ablation: packing overhead (the paper's §VI open question — "the
    // overhead of bit packing and access to packed data").  Compare the
    // prepacked vs runtime-pack AOT artifacts through PJRT, and the native
    // operator with packing inside vs outside the timed region.
    println!("== ablation: activation-packing overhead ==");
    if let Ok(mut reg) = cachebound::runtime::Registry::open("artifacts") {
        let cfg = BenchConfig::quick();
        let pairs = [
            ("gemm_bs_uni_a2w2_n256_prepacked", "gemm_bs_uni_a2w2_n256_runtime_pack"),
        ];
        for (pre, rt) in pairs {
            if reg.manifest.by_name(pre).is_some() && reg.manifest.by_name(rt).is_some() {
                let mp = reg.measure(pre, &cfg).unwrap();
                let mr = reg.measure(rt, &cfg).unwrap();
                println!(
                    "  PJRT 2-bit n256: prepacked {:.3} ms vs runtime-pack {:.3} ms ({:+.1}% packing overhead)",
                    mp.seconds.median * 1e3,
                    mr.seconds.median * 1e3,
                    (mr.seconds.median / mp.seconds.median - 1.0) * 100.0
                );
            }
        }
    }
    {
        let cfg = BenchConfig::quick();
        let (n, bits) = (256usize, 2usize);
        let a = Tensor::rand_unipolar(&[n, n], bits as u32, 7);
        let w = Tensor::rand_unipolar(&[n, n], bits as u32, 8);
        let wp = bitserial::pack_unipolar(&w, bits);
        let ap_pre = bitserial::pack_unipolar(&a, bits);
        let m_pre = measure(&cfg, || bitserial::gemm_unipolar(&ap_pre, &wp));
        let m_rt = measure(&cfg, || {
            let ap = bitserial::pack_unipolar(&a, bits);
            bitserial::gemm_unipolar(&ap, &wp)
        });
        println!(
            "  native 2-bit n256: prepacked {:.3} ms vs runtime-pack {:.3} ms ({:+.1}% packing overhead)\n",
            m_pre.seconds.median * 1e3,
            m_rt.seconds.median * 1e3,
            (m_rt.seconds.median / m_pre.seconds.median - 1.0) * 100.0
        );
    }

    // host-native popcount GEMM incl. runtime activation packing
    println!("== host-native bit-serial GEMM (packing + popcount) ==");
    let cfg = BenchConfig::quick();
    let sizes: &[usize] = if quick { &[128] } else { &[128, 256] };
    for &n in sizes {
        for bits in [1usize, 2, 4] {
            let a = Tensor::rand_unipolar(&[n, n], bits as u32, 7);
            let w = Tensor::rand_unipolar(&[n, n], bits as u32, 8);
            let wp = bitserial::pack_unipolar(&w, bits); // weights pre-packed (§V-A)
            let macs = (n as f64).powi(3);
            native_line(&format!("bs uni {bits}b n{n} (pack+gemm)"), &cfg, Some(2.0 * macs), || {
                let ap = bitserial::pack_unipolar(&a, bits); // runtime packing
                bitserial::gemm_unipolar(&ap, &wp)
            });
        }
    }
}
