//! bench_qnn — regenerates Figs 6, 7 & 8 (quantized conv speedups over
//! float32, required bandwidth, absolute GFLOP/s) and measures host-native
//! int8 operators against their float32 counterparts.
//!
//! Run: `cargo bench --bench bench_qnn`

use cachebound::bench::{bench_pipeline, native_line, quick_flag};
use cachebound::operators::{conv, gemm, qnn, Tensor};
use cachebound::report;
use cachebound::util::bench::BenchConfig;

fn main() {
    let quick = quick_flag();
    println!("== bench_qnn: Figs 6, 7 & 8 ==\n");

    let mut pipeline = bench_pipeline(8);
    for profile in ["a53", "a72"] {
        let (f, csv6, csv7, csv8) = report::fig6_fig7_fig8(&mut pipeline, profile).unwrap();
        println!("-- {profile}: speedup over float32 (Fig 6) --");
        println!(
            "  {:<5} {:>6} {:>8} {:>8} {:>8} {:>8}",
            "layer", "qnn8", "bs1", "bs2", "bs4", "bs8"
        );
        for r in &f.rows {
            println!(
                "  {:<5} {:>6.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
                r.layer,
                r.speedup_qnn(),
                r.speedup_bits(1, true).unwrap_or(f64::NAN),
                r.speedup_bits(2, true).unwrap_or(f64::NAN),
                r.speedup_bits(4, true).unwrap_or(f64::NAN),
                r.speedup_bits(8, true).unwrap_or(f64::NAN),
            );
        }
        csv6.write(format!("results/bench_qnn_fig6_{profile}.csv")).unwrap();
        csv7.write(format!("results/bench_qnn_fig7_{profile}.csv")).unwrap();
        csv8.write(format!("results/bench_qnn_fig8_{profile}.csv")).unwrap();
        println!();
    }

    // host-native int8 vs f32
    println!("== host-native int8 vs float32 ==");
    let cfg = BenchConfig::quick();
    let n = if quick { 96 } else { 192 };
    let flops = 2.0 * (n as f64).powi(3);
    let af = Tensor::<f32>::rand_f32(&[n, n], 1);
    let bf = Tensor::<f32>::rand_f32(&[n, n], 2);
    native_line(&format!("f32 blocked gemm n{n}"), &cfg, Some(flops), || {
        gemm::blocked(&af, &bf)
    });
    let ai = Tensor::<i8>::rand_i8(&[n, n], 1);
    let bi = Tensor::<i8>::rand_i8(&[n, n], 2);
    native_line(&format!("i8  blocked gemm n{n}"), &cfg, Some(flops), || {
        qnn::gemm_blocked(&ai, &bi)
    });

    let (cin, cout, h) = (16usize, 16usize, 28usize);
    let xf = Tensor::<f32>::rand_f32(&[1, cin, h, h], 3);
    let wf = Tensor::<f32>::rand_f32(&[cout, cin, 3, 3], 4);
    let cmacs = (h * h * cin * cout * 9) as f64;
    native_line("f32 spatial conv 16x16x28", &cfg, Some(2.0 * cmacs), || {
        conv::spatial_pack(&xf, &wf, 1, 1, conv::ConvSchedule::default_tuned())
    });
    let xi = Tensor::<i8>::rand_i8(&[1, cin, h, h], 3);
    let wi = Tensor::<i8>::rand_i8(&[cout, cin, 3, 3], 4);
    native_line("i8  conv 16x16x28", &cfg, Some(2.0 * cmacs), || {
        qnn::conv2d(&xi, &wi, 1, 1)
    });
}
