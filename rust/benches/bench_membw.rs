//! bench_membw — regenerates Tables I & II: the bandwidth survey.
//!
//! Prints the calibrated ARM numbers (the paper's measurements) next to a
//! real RAMspeed-style sweep of this host, plus the host FMA peak vs the
//! eq. (1) prediction for the ARM parts.
//!
//! Run: `cargo bench --bench bench_membw`

use cachebound::bench::quick_flag;
use cachebound::hw::builtin_profiles;
use cachebound::membench;
use cachebound::report;

fn main() {
    let quick = quick_flag();
    println!("== bench_membw: Tables I & II ==\n");

    let host = if quick { None } else { Some(membench::bandwidth_sweep(&[])) };
    for profile in builtin_profiles() {
        let (t, csv) = report::bandwidth_table(&profile, host.as_deref());
        println!("{}", t.to_markdown());
        csv.write(format!("results/bench_membw_{}.csv", profile.cpu.name)).unwrap();
    }

    println!("== computational peak (paper §III-B1) ==");
    for profile in builtin_profiles() {
        let cpu = &profile.cpu;
        println!(
            "  {:<12} eq.(1) theoretical: {:5.1} GFLOP/s f32  ({:5.1} int8-OPs)",
            cpu.name,
            cpu.peak_flops(32) / 1e9,
            cpu.peak_flops(8) / 1e9
        );
    }
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let r = membench::measure_peak(threads, if quick { 0.2 } else { 1.0 });
    println!(
        "  {:<12} measured FMA peak:  {:5.1} GFLOP/s ({} threads)",
        "host", r.flops_per_sec / 1e9, threads
    );
}
