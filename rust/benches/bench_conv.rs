//! bench_conv — regenerates Figs 2 & 3 (ResNet-18 conv layer times and
//! GFLOP/s vs hardware bounds) plus host-native conv measurements.
//!
//! Run: `cargo bench --bench bench_conv`

use cachebound::bench::{bench_pipeline, native_line, quick_flag};
use cachebound::operators::conv::{self, ConvSchedule};
use cachebound::operators::workloads::layer_by_name;
use cachebound::operators::Tensor;
use cachebound::report;
use cachebound::util::bench::BenchConfig;

fn main() {
    let quick = quick_flag();
    println!("== bench_conv: Figs 2 & 3 ==\n");

    let mut pipeline = bench_pipeline(if quick { 8 } else { 32 });
    for profile in ["a53", "a72"] {
        let (f, csv) = report::fig2_fig3(&mut pipeline, profile).unwrap();
        println!("-- {profile}: layers sorted by simulated GFLOP/s (Fig 3 order) --");
        for (name, gf) in &f.sorted_perf {
            let i = f.layers.iter().position(|l| l == name).unwrap();
            let b = &f.bounds[i];
            println!(
                "  {name:<5} {gf:7.2} GFLOP/s   t={:9.3} ms  (L1 line {:7.3} ms, compute {:7.3} ms)",
                f.measured_s[i] * 1e3,
                b.l1_read_s * 1e3,
                b.compute_s * 1e3
            );
        }
        csv.write(format!("results/bench_conv_{profile}.csv")).unwrap();
        println!();
    }

    // host-native spatial-pack on a scaled-down C5-class layer
    println!("== host-native conv (spatial-pack vs im2col vs naive) ==");
    let cfg = BenchConfig::quick();
    let l = layer_by_name("C5").unwrap();
    let scale = if quick { 4 } else { 2 };
    let (cin, cout) = (l.cin / scale, l.cout / scale);
    let x = Tensor::rand_f32(&[1, cin, l.h, l.w], 1);
    let w = Tensor::rand_f32(&[cout, cin, l.k, l.k], 2);
    let macs = (l.ho() * l.wo() * cin * cout * l.k * l.k) as f64;
    native_line("spatial_pack C5/4", &cfg, Some(2.0 * macs), || {
        conv::spatial_pack(&x, &w, l.stride, l.pad, ConvSchedule::default_tuned())
    });
    native_line("im2col_conv  C5/4", &cfg, Some(2.0 * macs), || {
        conv::im2col_conv(&x, &w, l.stride, l.pad)
    });
    if quick {
        return;
    }
    native_line("naive_conv   C5/4", &cfg, Some(2.0 * macs), || {
        conv::naive(&x, &w, l.stride, l.pad)
    });
}
