//! bench_gemm — regenerates Tables IV & V (GEMM float32 GFLOP/s) plus the
//! native-operator host measurements and the PJRT artifact timings.
//!
//! Run: `cargo bench --bench bench_gemm`
//!
//! Output: one block per profile with the paper's five sizes and columns
//! (openBLAS-analog / naive / tuned / autotuned / theoretical peak), a
//! host-native section, and (if `make artifacts` ran) the artifact section.

use cachebound::bench::{bench_pipeline, native_line, quick_flag};
use cachebound::operators::gemm::{self, GemmSchedule};
use cachebound::operators::Tensor;
use cachebound::report;
use cachebound::runtime::Registry;
use cachebound::util::bench::{report_line, BenchConfig};

fn main() {
    let quick = quick_flag();
    println!("== bench_gemm: Tables IV & V ==\n");

    // --- simulated tables (the ARM substitution) ---------------------------
    let mut pipeline = bench_pipeline(if quick { 12 } else { 48 });
    let sizes: &[usize] = if quick { &[32, 128, 256] } else { &[32, 128, 256, 512, 1024] };
    for profile in ["a53", "a72"] {
        let (t, csv, _) = report::gemm_table(&mut pipeline, profile, sizes).unwrap();
        println!("{}", t.to_markdown());
        csv.write(format!("results/bench_gemm_table_{profile}.csv")).unwrap();
    }

    // --- host-native operators (real wallclock on this machine) ------------
    println!("== host-native GEMM (blocked vs tiled vs naive) ==");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let native_sizes: &[usize] = if quick { &[64, 128] } else { &[64, 128, 256] };
    for &n in native_sizes {
        let a = Tensor::rand_f32(&[n, n], 1);
        let b = Tensor::rand_f32(&[n, n], 2);
        let flops = 2.0 * (n as f64).powi(3);
        native_line(&format!("native blocked n{n}"), &cfg, Some(flops), || {
            gemm::blocked(&a, &b)
        });
        native_line(&format!("native tiled   n{n}"), &cfg, Some(flops), || {
            gemm::tiled(&a, &b, GemmSchedule::new(64, 64, 64, 4))
        });
        if n <= 128 {
            native_line(&format!("native naive   n{n}"), &cfg, Some(flops), || {
                gemm::naive(&a, &b)
            });
        }
    }

    // --- PJRT artifacts (the Pallas codegen path) ---------------------------
    println!("\n== PJRT artifacts (interpret-mode Pallas; structural timings) ==");
    match Registry::open("artifacts") {
        Ok(mut reg) => {
            for name in ["gemm_f32_tuned_n128", "gemm_f32_tuned_n256", "gemm_f32_naive_n128"] {
                if reg.manifest.by_name(name).is_none() {
                    continue;
                }
                match reg.measure(name, &BenchConfig::quick()) {
                    Ok(m) => {
                        let macs = reg.manifest.by_name(name).unwrap().macs as f64;
                        println!("{}", report_line(name, &m, Some(2.0 * macs)));
                    }
                    Err(e) => println!("{name}: error {e:#}"),
                }
            }
        }
        Err(e) => println!("(skipped: {e:#})"),
    }
}
