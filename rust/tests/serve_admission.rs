//! Admission-concurrency chaos harness (DESIGN.md §Admission concurrency).
//!
//! The lock-free admission path claims that N admission threads can
//! classify, route and enqueue against epoch-versioned route snapshots
//! while the coordinator migrates artifacts mid-stream — and that *any*
//! interleaving preserves the serving invariants: exactly one disposition
//! per request, per-artifact FIFO (each artifact has one submitting
//! thread), reconciling metrics, and bit-identical payloads against an
//! undisturbed single-threaded run.  This suite attacks the claim with a
//! deterministic chaos driver: seeded drifting request streams partitioned
//! across four admission threads, forced migration storms injected from
//! the coordinator thread at seeded points, and the automatic divergence
//! trigger running on top.
//!
//! Seeds: every chaos test runs once per seed in `ADMISSION_CHAOS_SEEDS`
//! (comma-separated, `0x` hex or decimal; default two seeds).  CI re-runs
//! the suite with a 4-seed matrix.

use std::collections::{BTreeMap, HashMap};
use std::thread;
use std::time::Duration;

use cachebound::coordinator::server::{
    AdmissionMode, Request, Response, ServeConfig, ServeOutcome, ShardedServer,
    SyntheticExecutor,
};
use cachebound::coordinator::RebalanceMode;
use cachebound::hw::profile_by_name;
use cachebound::operators::workloads;
use cachebound::telemetry::serving_mix_profiles;
use cachebound::util::rng::Xoshiro256;

/// Admission threads every chaos run partitions its stream across — the
/// `serve --admission-threads 4` configuration the CI matrix exercises.
const ADMISSION_THREADS: usize = 4;

/// The chaos seed matrix: `ADMISSION_CHAOS_SEEDS` (comma-separated,
/// decimal or `0x` hex), defaulting to two seeds so the suite is cheap in
/// a plain `cargo test` and broad in CI.
fn seeds() -> Vec<u64> {
    match std::env::var("ADMISSION_CHAOS_SEEDS") {
        Ok(raw) => raw
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.strip_prefix("0x")
                    .map(|h| u64::from_str_radix(h, 16))
                    .unwrap_or_else(|| s.parse())
                    .unwrap_or_else(|e| panic!("bad chaos seed '{s}': {e}"))
            })
            .collect(),
        Err(_) => vec![0xADA117, 0x5EED_50C5],
    }
}

/// A drifting request stream: three phases drawn from different sub-menus
/// of the serving mix, so the artifact population the admission threads
/// observe changes mid-stream (same shape as the migration chaos suite).
fn drifting_stream(n: usize, seed: u64) -> Vec<String> {
    let mix = workloads::serving_mix();
    let menu = |idx: &[usize], weight_seed: u64| -> Vec<(String, u32)> {
        idx.iter()
            .enumerate()
            .map(|(i, &m)| {
                (mix[m].artifact.clone(), 1 + ((weight_seed >> i) & 3) as u32)
            })
            .collect()
    };
    let phases: [Vec<(String, u32)>; 3] = [
        menu(&[0, 1, 2], seed),
        menu(&[2, 3, 4], seed >> 8),
        menu(&[0, 4], seed >> 16),
    ];
    let per_phase = n / 3;
    let mut out = Vec::with_capacity(n);
    for (i, m) in phases.iter().enumerate() {
        let want = if i == 2 { n - out.len() } else { per_phase };
        out.extend(workloads::bursty_requests(m, want, seed ^ (i as u64 + 1)));
    }
    out
}

fn assert_exactly_once(out: &ServeOutcome, n: usize) {
    let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(
        ids,
        (0..n as u64).collect::<Vec<_>>(),
        "dropped or duplicated dispositions"
    );
}

fn assert_per_artifact_fifo(responses: &[Response]) {
    let mut per_artifact: HashMap<&str, Vec<u64>> = HashMap::new();
    for r in responses {
        per_artifact.entry(r.artifact.as_str()).or_default().push(r.id);
    }
    for (artifact, ids) in per_artifact {
        assert!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "FIFO violated for {artifact}: {ids:?}"
        );
    }
}

/// Drive one stream through `ADMISSION_THREADS` admission handles under
/// `thread::scope` — the same artifact-hash partition `serve_concurrent`
/// uses (one submitter per artifact ⇒ per-artifact FIFO is preserved) —
/// while the calling closure keeps the coordinator duties on this thread.
/// Returns the finished outcome and whatever `coordinate_extra` counted.
fn drive_concurrent(
    mut srv: ShardedServer,
    stream: &[String],
    mut coordinate_extra: impl FnMut(&mut ShardedServer) -> usize,
) -> (ServeOutcome, usize) {
    let mut parts: Vec<Vec<(u64, String)>> =
        (0..ADMISSION_THREADS).map(|_| Vec::new()).collect();
    for (id, artifact) in stream.iter().enumerate() {
        let t = cachebound::coordinator::shard_for(artifact, ADMISSION_THREADS);
        parts[t].push((id as u64, artifact.clone()));
    }
    let handles: Vec<_> =
        (0..ADMISSION_THREADS).map(|_| srv.admission_handle()).collect();
    let mut extra = 0usize;
    let outcomes: Vec<_> = thread::scope(|s| {
        let joins: Vec<_> = parts
            .into_iter()
            .zip(handles)
            .map(|(part, mut handle)| {
                s.spawn(move || {
                    for (k, (id, artifact)) in part.into_iter().enumerate() {
                        // light pacing stretches the submission window so
                        // the coordinator's storm genuinely interleaves
                        // with live admission instead of racing a burst
                        if k % 8 == 0 {
                            thread::sleep(Duration::from_micros(200));
                        }
                        handle.submit(Request { id, artifact });
                    }
                    handle.into_outcome()
                })
            })
            .collect();
        // the coordinator loop: reap, rebalance, and storm (migrations
        // are single-writer operations and stay on this thread)
        loop {
            srv.coordinate();
            extra += coordinate_extra(&mut srv);
            if joins.iter().all(|j| j.is_finished()) {
                break;
            }
            thread::sleep(Duration::from_micros(100));
        }
        joins
            .into_iter()
            .map(|j| j.join().expect("admission thread panicked"))
            .collect()
    });
    for outcome in outcomes {
        srv.absorb(outcome);
    }
    (srv.finish(), extra)
}

/// The core chaos property: four admission threads racing a seeded
/// migration storm (forced moves of seen *and* unseen artifacts, plus the
/// automatic divergence trigger) keep every serving invariant, and every
/// payload stays bit-identical to an undisturbed single-threaded run.
#[test]
fn chaos_concurrent_admission_survives_migration_storms() {
    let mix = workloads::serving_mix();
    let profiles = serving_mix_profiles(&profile_by_name("a53").unwrap().cpu);
    for seed in seeds() {
        let mut rng = Xoshiro256::new(seed);
        let workers = 2 + rng.below(3) as usize; // 2..=4
        let n = 240;
        let stream = drifting_stream(n, seed);

        // the undisturbed baseline: same stream, one thread, no plans,
        // no migrations
        let baseline = ShardedServer::start(ServeConfig::new(workers), |_w| {
            Ok(SyntheticExecutor::new())
        })
        .serve_stream(stream.iter().cloned());
        assert_eq!(baseline.metrics.completed, n as u64, "seed {seed:#x}");

        // the chaos run: concurrent admission, live rebalancing, and a
        // forced-migration storm driven from the coordinator thread
        let mut cfg = ServeConfig::new(workers)
            .with_cache(1 + rng.below(8) as usize)
            .with_profiles(profiles.clone())
            .with_rebalance(RebalanceMode::Live)
            .with_admission_threads(ADMISSION_THREADS);
        cfg.rebalance_check_every = 16 + rng.below(32) as usize;
        let srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
        let mut storm_rng = Xoshiro256::new(seed ^ 0x5701_u64);
        let (out, forced) = drive_concurrent(srv, &stream, |srv| {
            // roughly every fourth coordinator pass, force a move of a
            // random mix artifact (often one no thread has admitted yet —
            // the uniform unseen-artifact protocol) to a random worker
            if storm_rng.below(4) == 0 {
                let victim = &mix[storm_rng.below(mix.len() as u64) as usize].artifact;
                let target = storm_rng.below(workers as u64) as usize;
                usize::from(srv.migrate(victim, target).is_some())
            } else {
                0
            }
        });

        assert_exactly_once(&out, n);
        assert_per_artifact_fifo(&out.responses);
        let m = &out.metrics;
        assert_eq!(m.requests, n as u64, "seed {seed:#x}");
        assert_eq!(m.completed + m.failed, m.requests, "seed {seed:#x}");
        assert_eq!(m.failed, 0, "seed {seed:#x}: {:?}",
            out.responses.iter().find(|r| !r.ok));
        // per-(shard, worker) rows still sum to the aggregate, across
        // every owner epoch the storm minted
        assert_eq!(
            m.per_shard.iter().map(|s| s.completed).sum::<u64>(),
            m.completed,
            "seed {seed:#x}: per-shard completed"
        );
        assert_eq!(
            m.per_shard.iter().map(|s| s.requests).sum::<u64>(),
            m.requests,
            "seed {seed:#x}: per-shard requests"
        );
        assert!(
            m.migrations.len() >= forced,
            "seed {seed:#x}: log must cover every forced move ({} < {forced})",
            m.migrations.len()
        );
        // an artifact migrates workers, never shards
        let mut artifact_shard: HashMap<&str, usize> = HashMap::new();
        for r in &out.responses {
            if let Some(prev) = artifact_shard.insert(r.artifact.as_str(), r.shard) {
                assert_eq!(prev, r.shard, "artifact {} changed shards", r.artifact);
            }
        }
        // the depth series stays chronological even though four threads
        // sampled it concurrently
        assert!(
            m.queue_depth.windows(2).all(|w| w[0].0 <= w[1].0),
            "seed {seed:#x}: depth samples out of order"
        );

        // purity across storms: executor state and cache entries moved,
        // never corrupted — every payload matches the undisturbed run
        let payload = |o: &ServeOutcome| -> BTreeMap<u64, f64> {
            o.responses.iter().map(|r| (r.id, r.payload.unwrap())).collect()
        };
        assert_eq!(
            payload(&out),
            payload(&baseline),
            "seed {seed:#x}: migrations must not change any payload"
        );
    }
}

/// Shed admission under concurrency: with a tiny in-flight limit some
/// requests shed at the front door, and every one of the N requests still
/// gets exactly one disposition — no lost, no duplicated, counts
/// reconciling across completed/failed/shed.
#[test]
fn concurrent_shed_admission_keeps_exactly_one_disposition() {
    for seed in seeds() {
        let n = 192;
        let stream = drifting_stream(n, seed);
        let mut cfg = ServeConfig::new(2)
            .with_admission(AdmissionMode::Shed)
            .with_admission_threads(ADMISSION_THREADS);
        cfg.admission_limit = 2; // shed aggressively
        let srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
        let (out, _) = drive_concurrent(srv, &stream, |_| 0);
        assert_exactly_once(&out, n);
        let m = &out.metrics;
        assert_eq!(m.requests, n as u64, "seed {seed:#x}");
        assert_eq!(
            m.completed + m.failed + m.shed,
            m.requests,
            "seed {seed:#x}: dispositions must partition the stream"
        );
        assert_eq!(m.failed, 0, "seed {seed:#x}: shed is not failure");
        // every latency percentile population covers every disposition
        assert_eq!(m.latency_seconds.len() as u64, m.requests, "seed {seed:#x}");
    }
}

/// The built-in concurrent drive (`serve_stream` with
/// `--admission-threads 4`) and the single-threaded drive serve the same
/// stream to the same completed payloads — admission concurrency changes
/// scheduling, never results.
#[test]
fn concurrent_drive_matches_single_threaded_payloads() {
    let seed = seeds()[0];
    let n = 128;
    let stream = drifting_stream(n, seed);
    let single = ShardedServer::start(ServeConfig::new(2), |_w| {
        Ok(SyntheticExecutor::new())
    })
    .serve_stream(stream.iter().cloned());
    let multi = ShardedServer::start(
        ServeConfig::new(2).with_admission_threads(ADMISSION_THREADS),
        |_w| Ok(SyntheticExecutor::new()),
    )
    .serve_stream(stream.iter().cloned());
    assert_exactly_once(&single, n);
    assert_exactly_once(&multi, n);
    assert_per_artifact_fifo(&multi.responses);
    assert_eq!(multi.metrics.completed, n as u64);
    let payload = |o: &ServeOutcome| -> BTreeMap<u64, f64> {
        o.responses.iter().map(|r| (r.id, r.payload.unwrap())).collect()
    };
    assert_eq!(payload(&multi), payload(&single));
}

/// The CLI surface: `cachebound serve --admission-threads 4` runs end to
/// end — alone and combined with live rebalancing — serving the full
/// stream and reporting the thread count in the summary line.
#[test]
fn cli_serve_admission_threads_round_trips() {
    use std::process::Command;

    let exe = env!("CARGO_BIN_EXE_cachebound");
    let out = Command::new(exe)
        .args([
            "serve",
            "--synthetic",
            "--workers",
            "2",
            "--requests",
            "64",
            "--admission-threads",
            "4",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "serve --admission-threads 4 must exit 0: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("admission none x4"), "{stdout}");
    assert!(stdout.contains("served 64/64"), "{stdout}");

    // combined with live rebalancing: the chaos configuration end to end
    let live = Command::new(exe)
        .args([
            "serve",
            "--synthetic",
            "--workers",
            "2",
            "--requests",
            "64",
            "--admission-threads",
            "4",
            "--rebalance",
            "live",
        ])
        .output()
        .unwrap();
    assert!(
        live.status.success(),
        "--admission-threads 4 --rebalance live must exit 0: {}",
        String::from_utf8_lossy(&live.stderr)
    );
    let stdout = String::from_utf8_lossy(&live.stdout);
    assert!(stdout.contains("served 64/64"), "{stdout}");
}
