//! Multi-worker serving invariants (DESIGN.md §Serving core).
//!
//! Everything here runs artifact-free through `SyntheticExecutor`, so the
//! suite exercises the real sharding/batching/caching machinery on every
//! host.  The invariants under test:
//!
//! 1. per-artifact FIFO completion order, with ≥ 4 workers;
//! 2. exactly one response per request (including failures);
//! 3. cache hits return bit-identical payloads with `exec_seconds == 0`;
//! 4. aggregate metrics totals equal request counts and per-shard sums;
//! 5. identical seeds reproduce identical payloads (deterministic stress).

use std::collections::{BTreeMap, HashMap};

use cachebound::coordinator::server::{
    Request, Response, ServeConfig, ServeOutcome, ShardedServer, SyntheticExecutor,
};
use cachebound::coordinator::RebalanceMode;
use cachebound::hw::profile_by_name;
use cachebound::operators::workloads;
use cachebound::telemetry::serving_mix_profiles;

fn serve(workers: usize, cache_entries: usize, stream: &[String]) -> ServeOutcome {
    let cfg = ServeConfig::new(workers).with_cache(cache_entries);
    ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
        .serve_stream(stream.iter().cloned())
}

/// Responses grouped per artifact, in the order they completed.
fn per_artifact_ids(responses: &[Response]) -> HashMap<&str, Vec<u64>> {
    let mut map: HashMap<&str, Vec<u64>> = HashMap::new();
    for r in responses {
        map.entry(r.artifact.as_str()).or_default().push(r.id);
    }
    map
}

#[test]
fn per_artifact_fifo_under_four_workers() {
    let stream = workloads::serving_requests(400, 0xF1F0);
    let out = serve(4, 0, &stream);
    assert_eq!(out.responses.len(), 400);
    assert!(out.responses.iter().all(|r| r.ok));
    // submission ids are monotone, so each artifact's completion-order id
    // sequence must be strictly increasing — FIFO per artifact even though
    // four workers completed them concurrently.
    for (artifact, ids) in per_artifact_ids(&out.responses) {
        assert!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "FIFO violated for {artifact}: {ids:?}"
        );
    }
}

#[test]
fn exactly_one_response_per_request() {
    let stream = workloads::serving_requests(250, 0x0E0E);
    let out = serve(4, 8, &stream);
    let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, (0..250).collect::<Vec<_>>(), "duplicated or dropped responses");
}

#[test]
fn cache_hit_returns_identical_payload_with_zero_exec() {
    // same artifact five times: first misses, the rest must hit
    let artifact = workloads::synthetic_artifact(64);
    let stream: Vec<String> = (0..5).map(|_| artifact.clone()).collect();
    let out = serve(2, 16, &stream);
    assert!(out.responses.iter().all(|r| r.ok));
    let by_id: BTreeMap<u64, &Response> =
        out.responses.iter().map(|r| (r.id, r)).collect();
    let first = by_id[&0];
    assert!(!first.cached, "first request cannot hit");
    assert!(first.exec_seconds > 0.0);
    let payload = first.payload.expect("payload");
    for id in 1..5u64 {
        let r = by_id[&id];
        assert!(r.cached, "request {id} should be a cache hit");
        assert_eq!(r.exec_seconds, 0.0, "cache hit must report zero exec time");
        assert_eq!(r.payload, Some(payload), "cache hit payload must be identical");
    }
    assert_eq!(out.metrics.cache_hits, 4);
    assert_eq!(out.metrics.completed, 5);
}

#[test]
fn cache_disabled_never_hits() {
    let artifact = workloads::synthetic_artifact(48);
    let stream: Vec<String> = (0..6).map(|_| artifact.clone()).collect();
    let out = serve(2, 0, &stream);
    assert!(out.responses.iter().all(|r| r.ok && !r.cached));
    assert_eq!(out.metrics.cache_hits, 0);
    // still pure: payloads identical even when recomputed every time
    let p0 = out.responses[0].payload.unwrap();
    assert!(out.responses.iter().all(|r| r.payload == Some(p0)));
}

#[test]
fn metrics_totals_equal_request_counts_and_shard_sums() {
    let mut stream = workloads::serving_requests(300, 0x717A);
    // sprinkle in some failures
    for i in (0..300).step_by(50) {
        stream[i] = "not_a_real_artifact".to_string();
    }
    let out = serve(4, 32, &stream);
    let m = &out.metrics;
    assert_eq!(m.requests, 300);
    assert_eq!(m.completed + m.failed, m.requests);
    assert_eq!(m.failed, 6);
    assert_eq!(out.responses.len(), 300);

    assert_eq!(m.rejected, 0, "no catalog attached, nothing rejected at admission");

    // per-shard rollup must sum to the aggregate
    let shard_requests: u64 = m.per_shard.iter().map(|s| s.requests).sum();
    let shard_completed: u64 = m.per_shard.iter().map(|s| s.completed).sum();
    let shard_failed: u64 = m.per_shard.iter().map(|s| s.failed).sum();
    let shard_hits: u64 = m.per_shard.iter().map(|s| s.cache_hits).sum();
    let shard_batches: u64 = m.per_shard.iter().map(|s| s.batches).sum();
    let shard_latencies: u64 = m.per_shard.iter().map(|s| s.latency.count()).sum();
    assert_eq!(shard_requests, m.requests);
    assert_eq!(shard_completed, m.completed);
    assert_eq!(shard_failed, m.failed);
    assert_eq!(shard_hits, m.cache_hits);
    assert_eq!(shard_batches, m.batches);
    assert_eq!(shard_latencies, m.completed, "histograms record completed requests");

    // each shard is owned by exactly one worker, and an artifact never
    // appears on two shards
    let mut artifact_shard: HashMap<&str, usize> = HashMap::new();
    for r in &out.responses {
        if let Some(prev) = artifact_shard.insert(r.artifact.as_str(), r.shard) {
            assert_eq!(prev, r.shard, "artifact {} migrated shards", r.artifact);
        }
    }
}

#[test]
fn rejected_at_admission_with_catalog_semantics() {
    // without a catalog the unknown name reaches a worker and fails there;
    // either way: one response, counted in failed
    let stream = vec![
        workloads::synthetic_artifact(32),
        "bogus".to_string(),
        workloads::synthetic_artifact(32),
    ];
    let out = serve(3, 4, &stream);
    assert_eq!(out.responses.len(), 3);
    assert_eq!(out.metrics.completed, 2);
    assert_eq!(out.metrics.failed, 1);
    let bad = out.responses.iter().find(|r| !r.ok).unwrap();
    assert_eq!(bad.artifact, "bogus");
    assert!(bad.error.is_some());
}

#[test]
fn catalog_rejects_at_admission_and_metrics_reconcile() {
    use std::sync::Arc;

    use cachebound::runtime::{ArtifactSpec, Manifest};
    use cachebound::util::json::Value;

    let known = workloads::synthetic_artifact(32);
    // minimal in-memory catalog: one known artifact, nothing on disk
    let manifest = Manifest {
        dir: "unused".into(),
        artifacts: vec![ArtifactSpec {
            name: known.clone(),
            file: "unused.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
            kind: "gemm".into(),
            macs: 0,
            meta: Value::Obj(Default::default()),
        }],
        resnet_macs: vec![],
    };
    let cfg = ServeConfig::new(2).with_cache(4).with_catalog(Arc::new(manifest));
    let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
    for (id, artifact) in
        [known.clone(), "unknown_model".to_string(), known.clone()].into_iter().enumerate()
    {
        srv.submit(Request { id: id as u64, artifact });
    }
    let out = srv.finish();
    let m = &out.metrics;
    assert_eq!(out.responses.len(), 3, "rejections still produce their one response");
    assert_eq!(m.requests, 3);
    assert_eq!(m.rejected, 1);
    assert_eq!(m.completed, 2);
    assert_eq!(m.failed, 1);
    let rej = out.responses.iter().find(|r| !r.ok).unwrap();
    assert_eq!(rej.artifact, "unknown_model");
    assert!(rej.error.as_deref().unwrap().contains("admission"));
    // rejected requests never reach a worker: per-shard sums cover exactly
    // the admitted requests
    let shard_requests: u64 = m.per_shard.iter().map(|s| s.requests).sum();
    assert_eq!(shard_requests, m.requests - m.rejected);
}

#[test]
fn live_rebalance_2000_request_stress() {
    // 2000 requests across a drifting mix with live rebalancing on and one
    // guaranteed forced move injected mid-stream: per-shard histograms and
    // the (shard, worker) rows — including the extra owner-epoch rows
    // migrations mint — must still reconcile to the global totals, and the
    // payloads must match an undisturbed baseline.
    let phase1 = workloads::serving_requests(1000, 0x5EED);
    // drift: the tail of the stream skews onto the two heaviest artifacts
    let heavy_menu: Vec<(String, u32)> = [(96usize, 3u32), (128, 1)]
        .iter()
        .map(|&(n, w)| (workloads::synthetic_artifact(n), w))
        .collect();
    let phase2 = workloads::bursty_requests(&heavy_menu, 1000, 0xD81F7);
    let stream: Vec<String> = phase1.iter().chain(&phase2).cloned().collect();

    let baseline = serve(4, 2, &stream);
    assert_eq!(baseline.metrics.completed, 2000);

    let cfg = ServeConfig::new(4)
        .with_cache(2)
        .with_profiles(serving_mix_profiles(&profile_by_name("a53").unwrap().cpu))
        .with_rebalance(RebalanceMode::Live);
    let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
    for (id, artifact) in stream.iter().enumerate() {
        if id == 1200 {
            // a forced move on top of whatever the divergence check did:
            // rotating off the current owner guarantees the log is non-empty
            let victim = workloads::synthetic_artifact(96);
            let here = srv.route_of(&victim).expect("phase 2 serves n96");
            srv.migrate(&victim, (here + 1) % 4).expect("a real move");
        }
        srv.submit(Request { id: id as u64, artifact: artifact.clone() });
    }
    let out = srv.finish();
    let m = &out.metrics;

    assert_eq!(out.responses.len(), 2000);
    assert!(out.responses.iter().all(|r| r.ok));
    assert_eq!(m.completed, 2000);
    assert!(!m.migrations.is_empty(), "the forced move must be logged");

    // exactly-once + FIFO across every migration
    let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, (0..2000).collect::<Vec<_>>());
    for (artifact, ids) in per_artifact_ids(&out.responses) {
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "FIFO violated for {artifact}");
    }

    // (shard, worker) reconciliation after artifacts moved
    assert_eq!(m.per_shard.iter().map(|s| s.requests).sum::<u64>(), m.requests);
    assert_eq!(m.per_shard.iter().map(|s| s.completed).sum::<u64>(), m.completed);
    assert_eq!(m.per_shard.iter().map(|s| s.failed).sum::<u64>(), 0);
    assert_eq!(m.per_shard.iter().map(|s| s.cache_hits).sum::<u64>(), m.cache_hits);
    assert_eq!(m.per_shard.iter().map(|s| s.batches).sum::<u64>(), m.batches);
    assert_eq!(
        m.per_shard.iter().map(|s| s.latency.count()).sum::<u64>(),
        m.completed,
        "histograms record completed requests across owner epochs"
    );
    // the forced move is in the log with its quiesce accounting intact
    // (the deterministic two-epoch row split is pinned by the controlled
    // `forced_migration_reroutes_and_logs` unit test, where no automatic
    // re-migration can interleave)
    let forced: Vec<_> = m.migrations.iter().filter(|r| r.forced).collect();
    assert_eq!(forced.len(), 1);
    assert_eq!(forced[0].artifact, workloads::synthetic_artifact(96));
    assert_ne!(forced[0].from_worker, forced[0].to_worker);

    // purity: migrations must not change a single payload
    let payloads = |o: &ServeOutcome| -> BTreeMap<u64, f64> {
        o.responses.iter().map(|r| (r.id, r.payload.unwrap())).collect()
    };
    assert_eq!(payloads(&out), payloads(&baseline));
}

#[test]
fn deterministic_seed_stress() {
    // 2000 requests, 4 workers, deliberately tiny cache to force eviction
    // churn; two runs with the same seed must agree on every payload, and
    // a single-worker run must agree with the multi-worker runs.
    let stream = workloads::serving_requests(2000, 0x5EED);
    let a = serve(4, 2, &stream);
    let b = serve(4, 2, &stream);
    let c = serve(1, 2, &stream);
    for out in [&a, &b, &c] {
        assert_eq!(out.responses.len(), 2000);
        assert!(out.responses.iter().all(|r| r.ok));
        assert_eq!(out.metrics.completed, 2000);
    }
    let payloads = |o: &ServeOutcome| -> BTreeMap<u64, f64> {
        o.responses.iter().map(|r| (r.id, r.payload.unwrap())).collect()
    };
    let (pa, pb, pc) = (payloads(&a), payloads(&b), payloads(&c));
    assert_eq!(pa, pb, "same seed, same worker count must reproduce payloads");
    assert_eq!(pa, pc, "worker count must not change payloads");
    // FIFO also holds at stress volume
    for (artifact, ids) in per_artifact_ids(&a.responses) {
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "FIFO violated for {artifact}");
    }
}
