//! Artifact runtime tests — require `make artifacts` to have run.
//!
//! Loads every HLO artifact through PJRT, checks the cross-language
//! checksums, and cross-validates artifact outputs against the native
//! operators on identical protocol inputs.  These are the tests proving
//! all three layers compose: Pallas kernel → JAX graph → HLO text →
//! PJRT executable → rust.

use cachebound::operators::gemm;
use cachebound::operators::Tensor;
use cachebound::runtime::Registry;
use cachebound::util::bench::BenchConfig;

fn registry() -> Option<Registry> {
    match Registry::open("artifacts") {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping artifact tests: {e:#}");
            None
        }
    }
}

#[test]
fn every_artifact_validates() {
    let Some(mut reg) = registry() else { return };
    let names = reg.names(None);
    assert!(names.len() >= 40, "expected the full catalog, got {}", names.len());
    let mut failures = Vec::new();
    for name in &names {
        match reg.validate(name) {
            Ok(v) if v.passed => {}
            Ok(v) => failures.push(format!("{name}: checksum mismatch {:?}", v.details)),
            Err(e) => failures.push(format!("{name}: {e:#}")),
        }
    }
    assert!(failures.is_empty(), "{} failures:\n{}", failures.len(), failures.join("\n"));
}

#[test]
fn gemm_artifact_matches_native_operator_elementwise() {
    // The Pallas-tiled GEMM artifact and the native rust GEMM must produce
    // the same numbers from the same SplitMix64 inputs.
    let Some(mut reg) = registry() else { return };
    let name = "gemm_f32_tuned_n128";
    let spec = reg.manifest.by_name(name).expect("artifact present").clone();
    let n = 128usize;

    let out = reg.run_protocol(name).unwrap();
    let artifact_result = out.outputs[0].to_vec::<f32>().unwrap();

    let a = Tensor::<f32>::rand_f32(&[n, n], spec.inputs[0].seed);
    let b = Tensor::<f32>::rand_f32(&[n, n], spec.inputs[1].seed);
    let native = gemm::blocked(&a, &b);

    let mut max_err = 0.0f32;
    for (x, y) in artifact_result.iter().zip(&native.data) {
        max_err = max_err.max((x - y).abs());
    }
    assert!(max_err < 1e-2, "artifact vs native max err {max_err}");
}

#[test]
fn qnn_artifact_is_bit_exact_with_native_int8() {
    let Some(mut reg) = registry() else { return };
    let name = "gemm_qnn8_n128";
    let Some(spec) = reg.manifest.by_name(name).cloned() else {
        eprintln!("skipping: {name} not in catalog");
        return;
    };
    let n = 128usize;
    let out = reg.run_protocol(name).unwrap();
    let artifact_result = out.outputs[0].to_vec::<i32>().unwrap();

    let a = Tensor::<i8>::rand_i8(&[n, n], spec.inputs[0].seed);
    let b = Tensor::<i8>::rand_i8(&[n, n], spec.inputs[1].seed);
    let native = cachebound::operators::qnn::gemm_blocked(&a, &b);
    assert_eq!(artifact_result, native.data, "int8 GEMM must be bit-exact");
}

#[test]
fn bitserial_artifact_matches_native_popcount_gemm() {
    let Some(mut reg) = registry() else { return };
    let name = "gemm_bs_uni_a2w2_n256_prepacked";
    let Some(spec) = reg.manifest.by_name(name).cloned() else {
        eprintln!("skipping: {name} not in catalog");
        return;
    };
    let out = reg.run_protocol(name).unwrap();
    let artifact_result = out.outputs[0].to_vec::<i32>().unwrap();

    // reconstruct the packed operands and run the native bit-serial GEMM
    let (bits, n, kw) = (2usize, 256usize, 8usize);
    let mk = |seed: u64| {
        let t = Tensor::<u32>::rand_u32(&[bits, n, kw], seed);
        cachebound::operators::bitserial::Packed {
            bits,
            rows: n,
            kw,
            k: kw * 32,
            data: t.data,
        }
    };
    let ap = mk(spec.inputs[0].seed);
    let wp = mk(spec.inputs[1].seed);
    let native = cachebound::operators::bitserial::gemm_unipolar(&ap, &wp);
    assert_eq!(artifact_result, native.data, "bit-serial GEMM must be bit-exact");
}

#[test]
fn whole_network_artifact_runs_and_is_finite() {
    // The composed ResNet-18 graph (stem + 8 residual blocks + head, every
    // conv the spatial-pack Pallas kernel) must execute through PJRT and
    // produce finite logits of the right shape.
    let Some(mut reg) = registry() else { return };
    let name = "resnet18_full_i32";
    let Some(spec) = reg.manifest.by_name(name).cloned() else {
        eprintln!("skipping: {name} absent");
        return;
    };
    assert_eq!(spec.kind, "network");
    let out = reg.run_protocol(name).unwrap();
    let logits = out.outputs[0].to_vec::<f32>().unwrap();
    assert_eq!(logits.len(), 10, "1x10 logits");
    assert!(logits.iter().all(|x| x.is_finite()));
    // checksum already covered by every_artifact_validates; spot-check here
    let sum: f64 = logits.iter().map(|&x| x as f64).sum();
    let expect = spec.outputs[0].checksum;
    assert!(
        (sum - expect).abs() / expect.abs().max(1.0) < 1e-3,
        "network checksum {sum} vs {expect}"
    );
}

#[test]
fn artifact_timing_is_measurable() {
    let Some(mut reg) = registry() else { return };
    let m = reg.measure("gemm_f32_tuned_n128", &BenchConfig::quick()).unwrap();
    assert!(m.seconds.median > 0.0);
    assert!(m.total_iters > 0);
}

#[test]
fn schedule_variants_all_compute_the_same_product() {
    // All AOT schedule variants of the same problem must agree: real
    // codegen diversity, identical numerics (checksums are per-variant
    // but inputs share seeds per artifact, so compare via validate()).
    let Some(mut reg) = registry() else { return };
    let variants = reg.names(Some("gemm_variant"));
    if variants.is_empty() {
        eprintln!("skipping: no variant artifacts");
        return;
    }
    for name in &variants {
        let v = reg.validate(name).unwrap();
        assert!(v.passed, "{name} failed: {:?}", v.details);
    }
}

#[test]
fn conv_artifact_matches_native_spatial_pack() {
    let Some(mut reg) = registry() else { return };
    let name = "conv_f32_c11";
    let Some(spec) = reg.manifest.by_name(name).cloned() else {
        eprintln!("skipping: {name} absent");
        return;
    };
    let out = reg.run_protocol(name).unwrap();
    let artifact_result = out.outputs[0].to_vec::<f32>().unwrap();

    let l = cachebound::operators::workloads::layer_by_name("C11").unwrap();
    let x = Tensor::<f32>::rand_f32(&[1, l.cin, l.h, l.w], spec.inputs[0].seed);
    let w = Tensor::<f32>::rand_f32(&[l.cout, l.cin, l.k, l.k], spec.inputs[1].seed);
    let native = cachebound::operators::conv::spatial_pack(
        &x,
        &w,
        l.stride,
        l.pad,
        cachebound::operators::conv::ConvSchedule::default_tuned(),
    );
    assert_eq!(artifact_result.len(), native.data.len());
    let mut max_err = 0.0f32;
    for (a, b) in artifact_result.iter().zip(&native.data) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 5e-2, "conv artifact vs native max err {max_err}");
}
