//! Property-based tests over randomized inputs.
//!
//! The environment is offline (no `proptest` crate), so this file carries a
//! small self-contained harness: a seeded Xoshiro generator drives N random
//! cases per property, and failures print the offending case for replay.

use cachebound::coordinator::jobs::{Job, JobSpec};
use cachebound::coordinator::loadgen::{observed_rate, ArrivalConfig};
use cachebound::coordinator::pool::WorkerPool;
use cachebound::coordinator::server::{
    AdmissionMode, Request, ServeConfig, ShardedServer, SyntheticExecutor, TierPolicy,
};
use cachebound::coordinator::{shard_for, RebalanceMode, RouteWriter};
use cachebound::hw::profile_by_name;
use cachebound::operators::bitserial;
use cachebound::operators::conv::{self, ConvSchedule};
use cachebound::operators::gemm::{self, GemmSchedule};
use cachebound::operators::tensor::max_abs_diff;
use cachebound::operators::workloads;
use cachebound::operators::Tensor;
use cachebound::sim::cache::{AccessKind, SetAssocCache};
use cachebound::telemetry::{MissRatioCurve, Operand, ReuseAnalyzer};
use cachebound::util::json;
use cachebound::util::rng::Xoshiro256;

/// Run `cases` random trials of `prop`, printing the case seed on failure.
fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Xoshiro256)) {
    for case in 0..cases {
        let seed = 0xFEED_0000 + case as u64;
        let mut rng = Xoshiro256::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

// ---------------------------------------------------------------------------
// Cache simulator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_cache_conservation_and_bounds() {
    // hits + misses == accesses; evictions < misses; hit after touch.
    forall("cache_conservation", 25, |rng| {
        let spec = cachebound::hw::CacheLevelSpec {
            size_bytes: 1024 << rng.below(3),
            line_bytes: 32 << rng.below(2),
            associativity: 1 + rng.below(4) as usize,
            read_bw: 1.0,
            write_bw: 1.0,
            latency_cycles: 1,
        };
        // sets must be a power of two: size/(line*assoc)
        if !(spec.size_bytes / (spec.line_bytes * spec.associativity)).is_power_of_two() {
            return;
        }
        let mut c = SetAssocCache::new(&spec);
        let accesses = 500 + rng.below(500);
        for _ in 0..accesses {
            let addr = rng.below(1 << 16);
            let kind = if rng.below(4) == 0 { AccessKind::Write } else { AccessKind::Read };
            c.access(addr, kind);
        }
        assert_eq!(c.stats.accesses(), accesses);
        assert!(c.stats.evictions <= c.stats.misses());
        assert!(c.stats.writebacks <= c.stats.evictions);
        // immediate re-touch of the last address must hit
        let addr = 4096;
        c.access(addr, AccessKind::Read);
        assert!(c.access(addr, AccessKind::Read).hit);
    });
}

#[test]
fn prop_cache_larger_is_never_worse() {
    // For the same trace, doubling capacity (same line/assoc structure)
    // cannot increase misses (LRU inclusion property for same-assoc).
    forall("cache_monotone_capacity", 15, |rng| {
        let line = 64;
        let small = cachebound::hw::CacheLevelSpec {
            size_bytes: 4096,
            line_bytes: line,
            associativity: 4096 / line, // fully associative -> LRU stack property
            read_bw: 1.0,
            write_bw: 1.0,
            latency_cycles: 1,
        };
        let big = cachebound::hw::CacheLevelSpec {
            size_bytes: 8192,
            associativity: 8192 / line,
            ..small
        };
        let mut cs = SetAssocCache::new(&small);
        let mut cb = SetAssocCache::new(&big);
        for _ in 0..2000 {
            let addr = rng.below(1 << 14);
            cs.access(addr, AccessKind::Read);
            cb.access(addr, AccessKind::Read);
        }
        assert!(cb.stats.misses() <= cs.stats.misses());
    });
}

// ---------------------------------------------------------------------------
// Operator equivalences
// ---------------------------------------------------------------------------

#[test]
fn prop_tiled_gemm_equals_naive() {
    forall("tiled_gemm", 20, |rng| {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(40) as usize;
        let n = 1 + rng.below(40) as usize;
        let a = Tensor::rand_f32(&[m, k], rng.next_u64());
        let b = Tensor::rand_f32(&[k, n], rng.next_u64());
        let s = GemmSchedule::new(
            1 << rng.below(6),
            1 << rng.below(6),
            1 << rng.below(6),
            1 + rng.below(8) as usize,
        );
        let c0 = gemm::naive(&a, &b);
        let c1 = gemm::tiled(&a, &b, s);
        assert!(max_abs_diff(&c0, &c1) < 1e-3, "m={m} k={k} n={n} {s:?}");
    });
}

#[test]
fn prop_spatial_pack_equals_naive_conv() {
    forall("spatial_pack", 15, |rng| {
        let cin = 1 + rng.below(6) as usize;
        let cout = 1 + rng.below(8) as usize;
        let h = 4 + rng.below(12) as usize;
        let k = *rng.choose(&[1usize, 3]);
        let stride = 1 + rng.below(2) as usize;
        let pad = rng.below(k as u64 + 1) as usize;
        if h + 2 * pad < k {
            return;
        }
        let x = Tensor::rand_f32(&[1, cin, h, h], rng.next_u64());
        let w = Tensor::rand_f32(&[cout, cin, k, k], rng.next_u64());
        let s = ConvSchedule::new(1 + rng.below(8) as usize, 1 + rng.below(4) as usize);
        let c0 = conv::naive(&x, &w, stride, pad);
        let c1 = conv::spatial_pack(&x, &w, stride, pad, s);
        assert!(
            max_abs_diff(&c0, &c1) < 1e-3,
            "cin={cin} cout={cout} h={h} k={k} s={stride} p={pad} {s:?}"
        );
    });
}

#[test]
fn prop_bitserial_pack_roundtrip_and_gemm() {
    forall("bitserial", 20, |rng| {
        let bits = 1 + rng.below(8) as usize;
        let rows = 1 + rng.below(8) as usize;
        let kw = 1 + rng.below(4) as usize;
        let k = kw * 32;
        let v = Tensor::rand_unipolar(&[rows, k], bits as u32, rng.next_u64());
        let p = bitserial::pack_unipolar(&v, bits);
        assert_eq!(bitserial::unpack_unipolar(&p), v);

        // gemm against i64 reference
        let w = Tensor::rand_unipolar(&[rows, k], bits as u32, rng.next_u64());
        let wp = bitserial::pack_unipolar(&w, bits);
        let out = bitserial::gemm_unipolar(&p, &wp);
        for i in 0..rows {
            for j in 0..rows {
                let mut acc = 0i64;
                for t in 0..k {
                    acc += v.data[i * k + t] as i64 * w.data[j * k + t] as i64;
                }
                assert_eq!(out.data[i * rows + j] as i64, acc);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Coordinator invariants (routing, batching, state)
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_completes_every_job_exactly_once() {
    forall("pool_exactly_once", 8, |rng| {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let n_jobs = 1 + rng.below(24);
        let jobs: Vec<Job> = (0..n_jobs)
            .map(|id| Job {
                id,
                spec: if rng.below(5) == 0 {
                    // leader-only jobs without a registry must fail but
                    // still complete exactly once
                    JobSpec::ArtifactValidate { name: format!("missing-{id}") }
                } else {
                    JobSpec::SimGemm {
                        cpu: cpu.clone(),
                        n: 32 << rng.below(3),
                        schedule: GemmSchedule::new(
                            8 << rng.below(4),
                            8 << rng.below(4),
                            8 << rng.below(4),
                            1 + rng.below(4) as usize,
                        ),
                        elem_bits: 32,
                    }
                },
            })
            .collect();
        let leader_ids: Vec<u64> =
            jobs.iter().filter(|j| j.spec.leader_only()).map(|j| j.id).collect();
        let pool = WorkerPool::new(1 + rng.below(4) as usize);
        let done = pool.run(jobs, None);
        assert_eq!(done.len(), n_jobs as usize);
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n_jobs).collect::<Vec<_>>());
        // routing invariant: leader-only jobs executed on the leader
        for c in &done {
            if leader_ids.contains(&c.id) {
                assert_eq!(c.executed_on, "leader");
            } else {
                assert!(c.executed_on.starts_with("worker-"));
            }
        }
    });
}

#[test]
fn prop_result_store_ingest_is_keyed_correctly() {
    forall("store_keys", 10, |rng| {
        let cpu = profile_by_name("a72").unwrap().cpu;
        let n_jobs = 1 + rng.below(16);
        let jobs: Vec<Job> = (0..n_jobs)
            .map(|id| Job {
                id,
                spec: JobSpec::SimGemm {
                    cpu: cpu.clone(),
                    n: 16 * (1 + id as usize), // unique n per job -> unique key
                    schedule: GemmSchedule::new(64, 64, 64, 4),
                    elem_bits: 32,
                },
            })
            .collect();
        let keys: Vec<String> = jobs.iter().map(|j| j.spec.key()).collect();
        let pool = WorkerPool::new(2);
        let done = pool.run(jobs, None);
        let mut store = cachebound::coordinator::ResultStore::new();
        store.ingest(&done);
        assert_eq!(store.len(), n_jobs as usize);
        for key in keys {
            assert!(store.seconds(&key).is_some(), "missing {key}");
        }
    });
}

// ---------------------------------------------------------------------------
// Serving invariants under arbitrary migration schedules
// ---------------------------------------------------------------------------

#[test]
fn prop_serve_fifo_and_exactly_once_under_arbitrary_migrations() {
    // Arbitrary request streams (including unknown artifacts, which fail
    // on a worker) interleaved with arbitrary forced-migration schedules,
    // with live rebalancing randomly on or off: per-artifact FIFO and
    // exactly-one-response must hold regardless.
    let mix = workloads::serving_mix();
    let profiles =
        cachebound::telemetry::serving_mix_profiles(&profile_by_name("a53").unwrap().cpu);
    forall("serve_migration_schedules", 6, |rng| {
        let workers = 1 + rng.below(4) as usize;
        let live = rng.below(2) == 0;
        let n = 60 + rng.below(60) as usize;
        let mut cfg = ServeConfig::new(workers).with_cache(rng.below(6) as usize);
        if live {
            cfg = cfg
                .with_profiles(profiles.clone())
                .with_rebalance(RebalanceMode::Live);
            cfg.rebalance_check_every = 8 + rng.below(24) as usize;
        }
        let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
        let mut expect_failures = 0u64;
        for id in 0..n as u64 {
            // ~1/12 of the schedule is a forced migration of a random
            // artifact (possibly unseen, possibly a no-op move)
            if rng.below(12) == 0 {
                let artifact = &mix[rng.below(mix.len() as u64) as usize].artifact;
                let target = rng.below(workers as u64) as usize;
                let _ = srv.migrate(artifact, target);
            }
            let artifact = if rng.below(16) == 0 {
                expect_failures += 1;
                "prop_bogus_artifact".to_string()
            } else {
                mix[rng.below(mix.len() as u64) as usize].artifact.clone()
            };
            srv.submit(Request { id, artifact });
        }
        let out = srv.finish();
        // exactly one response per request
        let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        // per-artifact FIFO (failures included: they answer in order too)
        let mut per_artifact: std::collections::HashMap<&str, Vec<u64>> =
            std::collections::HashMap::new();
        for r in &out.responses {
            per_artifact.entry(r.artifact.as_str()).or_default().push(r.id);
        }
        for (artifact, ids) in per_artifact {
            assert!(
                ids.windows(2).all(|w| w[0] < w[1]),
                "FIFO violated for {artifact}: {ids:?}"
            );
        }
        // totals reconcile
        let m = &out.metrics;
        assert_eq!(m.requests, n as u64);
        assert_eq!(m.completed + m.failed, m.requests);
        assert_eq!(m.failed, expect_failures);
        assert_eq!(
            m.per_shard.iter().map(|s| s.requests).sum::<u64>(),
            m.requests
        );
        assert_eq!(
            m.per_shard.iter().map(|s| s.latency.count()).sum::<u64>(),
            m.completed
        );
    });
}

#[test]
fn prop_arrival_schedules_deterministic_sorted_and_rate_conserving() {
    // The open-loop contract (DESIGN.md §Admission): the same config
    // yields the identical schedule bit for bit, offsets are sorted and
    // non-negative, the stream has exactly `n` arrivals — and a pure
    // Poisson draw conserves the configured rate (thinning at amplitude 0
    // accepts every candidate, so the mean gap is exactly 1/rate).
    forall("arrival_schedules", 10, |rng| {
        let rate = 50.0 * (1.0 + rng.below(100) as f64);
        let n = 256 + rng.below(256) as usize;
        let seed = rng.below(u64::MAX);
        let mut cfg = ArrivalConfig::poisson(rate, n, seed);
        if rng.below(2) == 0 {
            cfg = cfg.with_diurnal(
                rng.below(100) as f64 / 100.0,
                0.001 * (1.0 + rng.below(1000) as f64),
            );
        }
        if rng.below(2) == 0 {
            cfg = cfg.with_flash(
                1 + rng.below(3) as usize,
                1.0 + rng.below(8) as f64,
                n as f64 / rate / 16.0,
            );
        }
        let s = cfg.schedule();
        assert_eq!(s, cfg.schedule(), "same config must replay bit-identically");
        assert_eq!(s.len(), n);
        assert!(s[0] >= 0.0 && s.iter().all(|t| t.is_finite()));
        assert!(s.windows(2).all(|w| w[0] <= w[1]), "offsets must be sorted");
        // rate conservation on the unmodulated process (modulated draws
        // legitimately run above base rate, bounded by the peak envelope)
        let flat = ArrivalConfig::poisson(rate, n, seed).schedule();
        let observed = observed_rate(&flat);
        assert!(
            (observed - rate).abs() / rate < 0.5,
            "observed {observed} req/s vs configured {rate} over {n} arrivals"
        );
        assert!(
            observed_rate(&s) <= cfg.peak_rate() * 1.5,
            "modulated rate must stay near the thinning envelope"
        );
    });
}

#[test]
fn prop_admission_dispositions_reconcile() {
    // Arbitrary streams (including unknown artifacts) under arbitrary
    // admission modes and in-flight limits: every submitted request gets
    // exactly one disposition, served + failed + shed covers the stream,
    // degraded requests are a subset of the served, and every
    // disposition leaves a latency sample.
    let mix = workloads::serving_mix();
    forall("admission_reconciliation", 6, |rng| {
        let workers = 1 + rng.below(3) as usize;
        let mode = *rng.choose(&[
            AdmissionMode::None,
            AdmissionMode::Shed,
            AdmissionMode::Degrade,
        ]);
        let n = 40 + rng.below(60) as usize;
        let cfg = ServeConfig::new(workers)
            .with_admission(mode)
            .with_admission_limit(1 + rng.below(8) as usize);
        let stream: Vec<String> = (0..n)
            .map(|_| {
                if rng.below(16) == 0 {
                    "prop_bogus_artifact".to_string()
                } else {
                    mix[rng.below(mix.len() as u64) as usize].artifact.clone()
                }
            })
            .collect();
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_stream(stream.into_iter());
        let m = &out.metrics;
        assert_eq!(m.requests, n as u64);
        assert_eq!(
            m.completed + m.failed + m.shed,
            m.requests,
            "mode {mode:?}: served + failed + shed must cover every request"
        );
        assert!(m.degraded <= m.completed, "degraded requests are served");
        if mode == AdmissionMode::None {
            assert_eq!(m.shed, 0, "no admission, no sheds");
            assert_eq!(m.degraded, 0);
        }
        assert_eq!(
            m.latency_seconds.len(),
            m.requests as usize,
            "every disposition must leave a latency sample"
        );
        let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "exactly one disposition");
    });
}

#[test]
fn prop_tier_downshift_dispositions_reconcile() {
    // The tier generalization of the admission property: arbitrary
    // streams over the full precision-tier menu, under either tier
    // policy and arbitrary in-flight limits, still give every request
    // exactly one disposition — and every cross-tier downshift is one
    // lattice step at the same GEMM size.
    let mix = workloads::serving_mix_tiered();
    forall("tier_downshift_reconciliation", 6, |rng| {
        let workers = 1 + rng.below(3) as usize;
        let policy = *rng.choose(&[TierPolicy::Pinned, TierPolicy::DownshiftOnPressure]);
        let n = 40 + rng.below(60) as usize;
        let cfg = ServeConfig::new(workers)
            .with_admission(AdmissionMode::Degrade)
            .with_admission_limit(1 + rng.below(4) as usize)
            .with_tier_policy(policy);
        let stream: Vec<String> = (0..n)
            .map(|_| mix[rng.below(mix.len() as u64) as usize].artifact.clone())
            .collect();
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_stream(stream.into_iter());
        let m = &out.metrics;
        assert_eq!(m.requests, n as u64);
        assert_eq!(
            m.completed + m.failed + m.shed,
            m.requests,
            "policy {policy:?}: served + failed + shed must cover every request"
        );
        assert_eq!(m.failed, 0, "policy {policy:?}: the tiered menu never fails");
        assert!(m.degraded <= m.completed, "degraded requests are served");
        assert_eq!(
            m.latency_seconds.len(),
            m.requests as usize,
            "every disposition must leave a latency sample"
        );
        let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "exactly one disposition");
        for r in out.responses.iter().filter(|r| r.degraded_from.is_some()) {
            let (from_tier, from_n) =
                workloads::synthetic_tier(r.degraded_from.as_deref().unwrap()).unwrap();
            let (to_tier, to_n) = workloads::synthetic_tier(&r.artifact).unwrap();
            match policy {
                TierPolicy::Pinned => {
                    assert_eq!(to_tier, from_tier, "pinned must not cross tiers: {r:?}");
                    assert!(to_n < from_n, "pinned degrade shrinks the shape: {r:?}");
                }
                TierPolicy::DownshiftOnPressure => {
                    assert_eq!(to_n, from_n, "downshift keeps the shape: {r:?}");
                    assert_eq!(
                        Some(to_tier),
                        from_tier.next_down(),
                        "downshift is one lattice step: {r:?}"
                    );
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Route-table invariants (epoch-versioned snapshots, coordinator::routing)
// ---------------------------------------------------------------------------

#[test]
fn prop_route_epochs_monotone_and_pinned_views_immutable() {
    // Random pin schedules against a shadow model: epochs are strictly
    // monotone, a snapshot pinned across any number of publishes resolves
    // every artifact exactly as it did at pin time, and a fresh snapshot
    // always agrees with the model (pins beat the hash fallback).
    forall("route_snapshots", 12, |rng| {
        let workers = 1 + rng.below(4) as usize;
        let n_shards = 2 << rng.below(4);
        let mut w = RouteWriter::new(workers, n_shards, None);
        let reader = w.reader();
        let artifacts: Vec<String> =
            (0..1 + rng.below(8)).map(|i| format!("prop_route_{i}")).collect();
        // epoch 0, no pins: the deterministic hash routes everything
        for a in &artifacts {
            assert_eq!(w.current().worker_for(a), shard_for(a, n_shards) % workers);
        }
        let mut model = std::collections::BTreeMap::new();
        let mut last_epoch = 0u64;
        for _ in 0..5 + rng.below(20) {
            let stale = reader.pin();
            let at_pin: Vec<usize> = artifacts.iter().map(|a| stale.worker_for(a)).collect();
            let victim = &artifacts[rng.below(artifacts.len() as u64) as usize];
            let target = rng.below(workers as u64) as usize;
            let epoch = w.pin_route(victim, target);
            assert!(epoch > last_epoch, "epochs must be strictly monotone");
            last_epoch = epoch;
            model.insert(victim.clone(), target);
            // the pinned view is frozen: the publish must not leak into it
            for (a, &before) in artifacts.iter().zip(&at_pin) {
                assert_eq!(stale.worker_for(a), before, "pinned view moved for {a}");
            }
            drop(stale);
            let fresh = reader.pin();
            assert_eq!(fresh.epoch(), epoch, "a fresh pin sees the latest publish");
            for a in &artifacts {
                let want =
                    model.get(a).copied().unwrap_or(shard_for(a, n_shards) % workers);
                assert_eq!(fresh.worker_for(a), want, "{a} disagrees with the model");
            }
        }
    });
}

#[test]
fn prop_route_swaps_atomic_under_concurrent_readers() {
    // The writer always publishes the pair ("pair_a", "pair_b") to one
    // worker in a single epoch; hammering readers must never observe them
    // split (a torn swap) or an epoch running backwards, for random worker
    // counts, reader counts and fence cadences.
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    forall("route_atomic_swaps", 4, |rng| {
        let workers = 2 + rng.below(3) as usize;
        let mut w = RouteWriter::new(workers, 8, None);
        let publishes = 100 + rng.below(200);
        let fence_every = 4 << rng.below(3);
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..2 + rng.below(3) as usize)
            .map(|_| {
                let r = w.reader();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut last_epoch = 0u64;
                    while !stop.load(Ordering::SeqCst) {
                        let snap = r.pin();
                        assert_eq!(
                            snap.worker_for("pair_a"),
                            snap.worker_for("pair_b"),
                            "partial swap at epoch {}",
                            snap.epoch()
                        );
                        assert!(snap.epoch() >= last_epoch, "epochs ran backwards");
                        last_epoch = snap.epoch();
                    }
                })
            })
            .collect();
        for k in 0..publishes {
            let target = rng.below(workers as u64) as usize;
            let epoch = w.publish(|pins| {
                pins.insert("pair_a".into(), target);
                pins.insert("pair_b".into(), target);
            });
            if k % fence_every == 0 {
                w.wait_for_readers(epoch);
            }
        }
        stop.store(true, Ordering::SeqCst);
        for h in readers {
            h.join().unwrap();
        }
    });
}

#[test]
fn prop_placement_plans_deterministic_for_equal_inputs() {
    use cachebound::analysis::{InterferenceModel, TraceMeta};
    use cachebound::coordinator::placement::plan;
    use cachebound::operators::workloads::BenchWorkload;
    use cachebound::telemetry::CacheProfile;
    use std::collections::BTreeMap;

    // random profile populations: re-planning the identical input must be
    // bit-identical (the property live rebalancing's convergence rests
    // on), complete, and in worker range
    let cpu = profile_by_name("a53").unwrap().cpu;
    let model = InterferenceModel::new(&cpu);
    forall("placement_determinism", 12, |rng| {
        let n_profiles = 1 + rng.below(8) as usize;
        let profiles: BTreeMap<String, CacheProfile> = (0..n_profiles)
            .map(|i| {
                let knee = 16 * 1024 * (1 + rng.below(24));
                let peak = 0.5 + rng.below(50) as f64 / 100.0;
                let accesses = 100_000 + rng.below(1_000_000);
                let name = format!("prop_artifact_{i}");
                let profile = CacheProfile {
                    artifact: name.clone(),
                    accesses,
                    l1_hit_rate: 0.0,
                    l2_hit_rate: peak,
                    working_set_bytes: knee,
                    footprint_bytes: knee + rng.below(knee),
                    predicted_class: "RAM-read".into(),
                    solo_time_s: 0.0,
                    workload: Some(BenchWorkload::Gemm { n: 64 }),
                    meta: Some(TraceMeta {
                        traced_accesses: accesses,
                        traced_bytes: accesses * 4,
                        traced_write_accesses: 0,
                        scale: 1.0,
                    }),
                    mrc_points: vec![(64, 0.0), (knee, peak)],
                    knees: vec![],
                };
                (name, profile)
            })
            .collect();
        let workers = 1 + rng.below(4) as usize;
        let first = plan(&model, &profiles, workers);
        for _ in 0..3 {
            assert_eq!(plan(&model, &profiles, workers), first, "plan must be deterministic");
        }
        assert_eq!(first.assignments.len(), n_profiles, "every artifact assigned");
        assert!(first.assignments.values().all(|&w| w < workers));
        let planned: usize = first.plan.iter().map(|w| w.artifacts.len()).sum();
        assert_eq!(planned, n_profiles, "assigned exactly once");
        assert!(first.total_slowdown >= n_profiles as f64 - 1e-9);
    });
}

// ---------------------------------------------------------------------------
// JSON round-trip over random documents
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut Xoshiro256, depth: usize) -> json::Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.below(2) == 0),
            2 => {
                // numbers the writer preserves exactly: moderate integers
                // and dyadic fractions
                let int = rng.below(1 << 40) as f64 - (1u64 << 39) as f64;
                let frac = rng.below(16) as f64 / 16.0;
                json::Value::Num(int + frac)
            }
            3 => {
                let len = rng.below(12) as usize;
                let s: String = (0..len)
                    .map(|_| char::from_u32(32 + rng.below(90) as u32).unwrap())
                    .collect();
                json::Value::Str(s)
            }
            4 => {
                let len = rng.below(4) as usize;
                json::Value::Arr((0..len).map(|_| random_value(rng, depth - 1)).collect())
            }
            _ => {
                let len = rng.below(4) as usize;
                json::Value::Obj(
                    (0..len)
                        .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    forall("json_roundtrip", 50, |rng| {
        let v = random_value(rng, 3);
        let text = json::to_string_pretty(&v);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("{e}: {text}"));
        assert_eq!(back, v, "text: {text}");
    });
}

// ---------------------------------------------------------------------------
// Timing-model sanity over random shapes
// ---------------------------------------------------------------------------

#[test]
fn prop_simulated_time_positive_and_monotone_in_work() {
    forall("timing_monotone", 20, |rng| {
        let cpu = profile_by_name(*rng.choose(&["a53", "a72"])).unwrap().cpu;
        let n = 32 << rng.below(4);
        let s = GemmSchedule::new(
            8 << rng.below(4),
            8 << rng.below(4),
            8 << rng.below(4),
            1 + rng.below(8) as usize,
        );
        let t1 = cachebound::sim::timing::simulate_gemm_time(&cpu, n, n, n, s, 32).total_s;
        let t2 =
            cachebound::sim::timing::simulate_gemm_time(&cpu, 2 * n, 2 * n, 2 * n, s, 32).total_s;
        assert!(t1 > 0.0 && t2.is_finite());
        assert!(t2 > t1, "8x work must take longer: {t1} vs {t2} (n={n}, {s:?})");
    });
}

// ---------------------------------------------------------------------------
// Set-aware reuse-distance invariants
// ---------------------------------------------------------------------------

/// Feed `a` a random access mix: mostly uniform lines, with occasional
/// power-of-two strided runs (the aliasing pattern that makes per-set and
/// fully-associative views diverge the most).
fn random_line_trace(a: &mut ReuseAnalyzer, rng: &mut Xoshiro256) {
    let bursts = 50 + rng.below(100);
    for _ in 0..bursts {
        if rng.below(4) == 0 {
            let stride = 1u64 << rng.below(7);
            let base = rng.below(64);
            for i in 0..8u64 {
                a.touch((base + i * stride) * 64, Operand::A);
            }
        } else {
            a.touch(rng.below(256) * 64, Operand::A);
        }
    }
}

#[test]
fn prop_per_set_histograms_conserve_mass_and_dominate_fully_assoc() {
    // The per-set refinement is an exact repartition of the same access
    // stream: total and cold mass match the fully-associative histogram,
    // and because a within-set distance only counts *same-set* intervening
    // lines (a subset of all intervening lines), the per-set view hits at
    // least as often at every depth up to the bounded stack.
    forall("set_hist_conservation", 20, |rng| {
        let sets = 1usize << rng.below(5); // 1..16 sets
        let mut a = ReuseAnalyzer::with_sets(64, sets);
        random_line_trace(&mut a, rng);
        let fa = a.combined();
        let sh = a.set_histograms().unwrap();
        assert_eq!(sh.total(), fa.total(), "mass conservation ({sets} sets)");
        assert_eq!(sh.cold(), fa.cold(), "cold conservation ({sets} sets)");
        for d in [1usize, 2, 4, 8, 16, 32] {
            assert!(
                sh.hits_within_ways(d) >= fa.hits_within(d),
                "{sets} sets, depth {d}: per-set {} < fully-assoc {}",
                sh.hits_within_ways(d),
                fa.hits_within(d)
            );
        }
    });
}

#[test]
fn prop_set_aware_hits_equal_lru_simulation_exactly() {
    // Each set of a W-way true-LRU cache is an independent W-line LRU over
    // its sub-stream, so per-set Mattson is *exact*: hit counts must equal
    // the simulator's, access for access, at any geometry.  The Smith
    // fallback (no per-set data) must stay conservative: never above the
    // fully-associative estimate.
    forall("set_aware_vs_sim", 20, |rng| {
        let line = 64usize;
        let ways = 1usize << rng.below(3); // 1, 2, 4
        let sets = 1usize << (2 + rng.below(4)); // 4..32
        let spec = cachebound::hw::CacheLevelSpec {
            size_bytes: sets * ways * line,
            line_bytes: line,
            associativity: ways,
            read_bw: 1.0,
            write_bw: 1.0,
            latency_cycles: 1,
        };
        let mut c = SetAssocCache::new(&spec);
        let mut a = ReuseAnalyzer::with_sets(line, sets);
        let accesses = 400 + rng.below(400);
        for _ in 0..accesses {
            let addr = rng.below(1 << 14);
            let kind = if rng.below(4) == 0 { AccessKind::Write } else { AccessKind::Read };
            c.access(addr, kind);
            a.touch(addr, Operand::A);
        }
        let sh = a.set_histograms().unwrap();
        assert_eq!(
            sh.hits_within_ways(ways),
            c.stats.hits(),
            "{sets} sets x {ways} ways: per-set Mattson must equal true-LRU simulation"
        );
        assert_eq!(sh.total(), c.stats.accesses());

        // Smith fallback: a curve with no per-set data scored against a
        // real CPU must discount, never inflate, the fully-assoc rate.
        let cpu = profile_by_name(*rng.choose(&["a53", "a72"])).unwrap().cpu;
        let mrc = MissRatioCurve::new(a.combined(), line);
        let p = mrc.predict_set_aware(&cpu);
        assert!(
            p.rates.l1_hit_rate <= p.fa_l1_hit_rate + 1e-12,
            "Smith fallback above fully-assoc: {} vs {}",
            p.rates.l1_hit_rate,
            p.fa_l1_hit_rate
        );
        assert!(p.conflict_pp >= -1e-9, "fallback conflict gap must be non-negative");
    });
}
