//! End-to-end pipeline smoke: the full experiment grid on tiny budgets,
//! including PJRT artifact jobs when `artifacts/` exists.

use cachebound::coordinator::pipeline::{Pipeline, PipelineConfig};
use cachebound::coordinator::{Job, JobSpec};
use cachebound::runtime::Registry;

fn tiny_config() -> PipelineConfig {
    PipelineConfig {
        n_workers: 2,
        tune_trials: 6,
        skip_native: true,
        native_max_n: 0,
    }
}

#[test]
fn full_report_surface_runs_end_to_end() {
    let mut p = Pipeline::new(tiny_config());
    // every report entry point, in one pipeline, sharing the store
    let (f1, _) = cachebound::report::fig1(&mut p, "a53").unwrap();
    assert_eq!(f1.best_bound, "L1-read");
    let (f23, _) = cachebound::report::fig2_fig3(&mut p, "a53").unwrap();
    assert_eq!(f23.layers.len(), 10);
    let (f45, _, _) = cachebound::report::fig4_fig5(&mut p, "a53").unwrap();
    assert!(!f45.points.is_empty());
    let (f678, ..) = cachebound::report::fig6_fig7_fig8(&mut p, "a53").unwrap();
    assert_eq!(f678.rows.len(), 10);
    let (f9, _) = cachebound::report::fig9(&mut p, "a53").unwrap();
    assert_eq!(f9.sizes.len(), f9.tuned_gflops.len());
    // the store accumulated everything without key collisions breaking it
    assert!(p.store.len() > 100, "store has {} entries", p.store.len());
}

#[test]
fn mixed_leader_worker_batch_with_registry() {
    let Ok(reg) = Registry::open("artifacts") else {
        eprintln!("skipping: no artifacts/");
        return;
    };
    let mut p = Pipeline::new(tiny_config()).with_registry(reg);
    let cpu = cachebound::hw::profile_by_name("a53").unwrap().cpu;
    // interleave sim jobs (workers) and artifact jobs (leader)
    let mut jobs = Vec::new();
    for (i, n) in [64usize, 128].iter().enumerate() {
        jobs.push(Job {
            id: i as u64,
            spec: JobSpec::SimGemm {
                cpu: cpu.clone(),
                n: *n,
                schedule: cachebound::operators::gemm::GemmSchedule::new(64, 64, 64, 4),
                elem_bits: 32,
            },
        });
    }
    jobs.push(Job {
        id: 10,
        spec: JobSpec::ArtifactValidate { name: "gemm_f32_tuned_n32".into() },
    });
    jobs.push(Job {
        id: 11,
        spec: JobSpec::ArtifactMeasure { name: "gemm_f32_tuned_n32".into() },
    });
    let completed = p.pool.run(jobs, p.registry.as_mut());
    assert_eq!(completed.len(), 4);
    for c in &completed {
        assert!(!c.output.is_failure(), "{}: {:?}", c.key, c.output);
    }
}

#[test]
fn results_persist_and_reload() {
    let mut p = Pipeline::new(tiny_config());
    p.gemm_table("a72", &[64]).unwrap();
    let path = std::env::temp_dir().join("cachebound_e2e_store.json");
    p.store.save(&path).unwrap();
    let loaded = cachebound::coordinator::ResultStore::load(&path).unwrap();
    assert_eq!(loaded.len(), p.store.len());
    let _ = std::fs::remove_file(&path);
}
