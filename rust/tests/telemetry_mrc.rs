//! Acceptance tests for the cache-telemetry subsystem: MRC predictions
//! versus full `sim::Hierarchy` simulation on the paper's Tables IV/V
//! GEMM grid, trace coverage of every operator family, and the
//! `cachebound trace` CLI's JSON contract.
//!
//! Both sides of every comparison come from the *same* traced replay: the
//! replay runs through the set-associative hierarchy with a reuse-distance
//! sink attached, so "simulated" is the set-associative LRU ground truth
//! and "predicted" is the Mattson stack-property estimate from the same
//! access stream.  Row budgets keep the replays cheap; the loop nests are
//! periodic along their outer dimension, so the truncated trace carries
//! the full shape's reuse structure.

use std::fs;
use std::process::Command;

use cachebound::hw::profile_by_name;
use cachebound::operators::workloads::{BenchWorkload, ConvLayer, GEMM_TABLE_SIZES};
use cachebound::sim::hierarchy::Hierarchy;
use cachebound::sim::trace::{replay_gemm, replay_gemm_traced};
use cachebound::telemetry::{
    trace_workload, NullSink, ReuseAnalyzer, TraceBudget, TraceReport,
};
use cachebound::util::json;

/// Row budget per grid size: enough outer iterations to cover the tile
/// reuse pattern, small enough that the debug-mode suite stays fast.
fn rows_for(n: usize) -> usize {
    if n >= 512 {
        32
    } else {
        64
    }
}

fn traced_grid_reports() -> &'static Vec<(usize, TraceReport)> {
    static REPORTS: std::sync::OnceLock<Vec<(usize, TraceReport)>> = std::sync::OnceLock::new();
    REPORTS.get_or_init(|| {
        let cpu = profile_by_name("a53").unwrap().cpu;
        GEMM_TABLE_SIZES
            .iter()
            .map(|&n| {
                let r = trace_workload(
                    &cpu,
                    &BenchWorkload::Gemm { n },
                    TraceBudget::new(rows_for(n)),
                );
                (n, r)
            })
            .collect()
    })
}

/// Acceptance: MRC-predicted L1/L2 hit rates within 2 percentage points of
/// the full set-associative simulation on every Tables IV/V GEMM shape.
#[test]
fn mrc_hit_rates_match_full_simulation_on_tables_iv_v_grid() {
    for (n, r) in traced_grid_reports() {
        assert!(
            r.l1_err_pp() <= 2.0,
            "n={n}: L1 hit-rate error {:.3} p.p. (mrc {:.4} vs sim {:.4})",
            r.l1_err_pp(),
            r.prediction.rates.l1_hit_rate,
            r.sim_l1_hit_rate,
        );
        assert!(
            r.l2_err_pp() <= 2.0,
            "n={n}: L2 hit-rate error {:.3} p.p. (mrc {:.4} vs sim {:.4})",
            r.l2_err_pp(),
            r.prediction.rates.l2_hit_rate,
            r.sim_l2_hit_rate,
        );
    }
}

/// Acceptance: the MRC-derived boundness class agrees with
/// `analysis::classify` (applied through the shared roofline path) on the
/// Tables IV/V grid.
#[test]
fn mrc_boundness_class_agrees_with_classify_on_grid() {
    for (n, r) in traced_grid_reports() {
        assert!(
            r.classes_agree(),
            "n={n}: predicted {} vs simulated {} (pred {:?})",
            r.predicted_class,
            r.sim_class,
            r.prediction.time,
        );
        // sanity: the grid's verdicts come from the paper's vocabulary
        assert!(
            ["compute", "L1-read", "L2-read", "RAM-read", "overhead"]
                .contains(&r.predicted_class.as_str()),
            "n={n}: unexpected class {}",
            r.predicted_class
        );
    }
}

/// Acceptance: one shape of each operator family traces and emits valid
/// JSON with reuse histograms, the MRC and a predicted class.
#[test]
fn every_family_emits_valid_trace_json() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let tiny = ConvLayer {
        name: "tiny",
        b: 1,
        cin: 8,
        cout: 16,
        h: 12,
        w: 12,
        k: 3,
        stride: 1,
        pad: 1,
    };
    let grid = [
        BenchWorkload::Gemm { n: 48 },
        BenchWorkload::Conv { layer: tiny },
        BenchWorkload::QnnConv { layer: tiny },
        BenchWorkload::Bitserial { n: 64, bits: 2 },
    ];
    for w in &grid {
        let r = trace_workload(&cpu, w, TraceBudget::default());
        let text = json::to_string_pretty(&r.to_json());
        let v = json::parse(&text).unwrap_or_else(|e| panic!("{}: bad JSON: {e}", r.key()));
        assert_eq!(v.req("family").unwrap().as_str().unwrap(), w.family());
        assert!(!v.req("operands").unwrap().as_arr().unwrap().is_empty());
        assert!(!v.req("mrc").unwrap().as_arr().unwrap().is_empty());
        let predicted = v.req("predicted").unwrap();
        assert!(predicted.req("class").unwrap().as_str().is_ok());
        assert!(predicted.req("l1_hit_rate").unwrap().as_f64().unwrap() >= 0.0);
    }
}

/// Acceptance: the `NullSink` path leaves the simulator bit-identical —
/// traced and untraced replays of the same workload produce the same
/// per-level counts and cache stats.
#[test]
fn null_sink_replay_is_bit_identical_to_untraced() {
    let cpu = profile_by_name("a72").unwrap().cpu;
    let s = cachebound::operators::gemm::GemmSchedule::default_tuned();
    let mut plain = Hierarchy::new(&cpu);
    replay_gemm(&mut plain, 48, 96, 96, s, 4);
    let mut traced = Hierarchy::new(&cpu);
    replay_gemm_traced(&mut traced, 48, 96, 96, s, 4, &mut NullSink);
    assert_eq!(plain.counts, traced.counts);
    assert_eq!(plain.l1.stats, traced.l1.stats);
    assert_eq!(plain.l2.stats, traced.l2.stats);
}

/// The analyzer's accounting is closed: per-operand histogram mass equals
/// hierarchy accesses, and the combined histogram equals the operand sum.
#[test]
fn analyzer_accounting_is_closed_over_a_real_trace() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let mut h = Hierarchy::new(&cpu);
    let mut analyzer = ReuseAnalyzer::new(cpu.l1.line_bytes);
    let s = cachebound::operators::gemm::GemmSchedule::default_tuned();
    replay_gemm_traced(&mut h, 32, 128, 128, s, 4, &mut analyzer);
    assert_eq!(analyzer.accesses(), h.counts.accesses);
    assert_eq!(analyzer.combined().total(), h.counts.accesses);
    // the L1 miss count is the fully-associative view; it must sit close
    // to the set-associative truth (this is the essence of the MRC bet)
    let mrc_misses = h.counts.accesses
        - analyzer
            .combined()
            .hits_within(cpu.l1.size_bytes / cpu.l1.line_bytes);
    let sim_misses = h.l1.stats.misses();
    let diff = mrc_misses.abs_diff(sim_misses) as f64 / h.counts.accesses as f64;
    assert!(diff < 0.02, "miss-count gap {:.3} of accesses", diff);
}

/// Acceptance (CLI): `cachebound trace` runs for every family and the
/// `--json` artifact parses with the documented fields.
#[test]
fn trace_cli_emits_valid_json_for_every_family() {
    let exe = env!("CARGO_BIN_EXE_cachebound");
    let dir = std::env::temp_dir().join("cachebound_trace_cli_test");
    fs::create_dir_all(&dir).unwrap();
    let cases: [(&str, &[&str]); 4] = [
        ("gemm", &["--n", "48", "--rows", "16"]),
        ("conv", &["--layer", "C2", "--rows", "2"]),
        ("qnn", &["--layer", "C4", "--rows", "8"]),
        ("bitserial", &["--n", "64", "--bits", "1", "--rows", "16"]),
    ];
    for (family, extra) in cases {
        let path = dir.join(format!("{family}.json"));
        let out = Command::new(exe)
            .arg("trace")
            .arg(family)
            .args(extra)
            .args(["--json", path.to_str().unwrap()])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "trace {family} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = fs::read_to_string(&path).unwrap();
        let v = json::parse(&text).unwrap();
        assert_eq!(v.req("family").unwrap().as_str().unwrap(), family);
        assert!(v.req("predicted").unwrap().req("class").is_ok());
        assert!(v.req("simulated").unwrap().req("l1_hit_rate").is_ok());
        assert!(!v.req("mrc").unwrap().as_arr().unwrap().is_empty());
    }
    let _ = fs::remove_dir_all(&dir);
}
