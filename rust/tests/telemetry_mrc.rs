//! Acceptance tests for the cache-telemetry subsystem: MRC predictions
//! versus full `sim::Hierarchy` simulation on the paper's Tables IV/V
//! GEMM grid — on **both** boards, the A53's 4-way L1 and the A72's
//! 2-way L1 — plus adversarial power-of-two-stride workloads where the
//! fully-associative Mattson curve is demonstrably wrong and only the
//! set-aware model tracks the simulator, trace coverage of every operator
//! family, and the `cachebound trace` CLI's JSON contract.
//!
//! Both sides of every comparison come from the *same* traced replay: the
//! replay runs through the set-associative hierarchy with a reuse-distance
//! sink attached, so "simulated" is the set-associative LRU ground truth
//! and "predicted" is the Mattson stack-property estimate from the same
//! access stream.  Row budgets keep the replays cheap; the loop nests are
//! periodic along their outer dimension, so the truncated trace carries
//! the full shape's reuse structure.

use std::fs;
use std::process::Command;

use cachebound::hw::profile_by_name;
use cachebound::operators::workloads::{BenchWorkload, ConvLayer, GEMM_TABLE_SIZES};
use cachebound::sim::hierarchy::Hierarchy;
use cachebound::sim::trace::{replay_gemm, replay_gemm_traced, replay_strided};
use cachebound::telemetry::{
    trace_workload, MissRatioCurve, NullSink, ReuseAnalyzer, TraceBudget, TraceReport,
};
use cachebound::util::json;

/// Row budget per grid size: enough outer iterations to cover the tile
/// reuse pattern, small enough that the debug-mode suite stays fast.
fn rows_for(n: usize) -> usize {
    if n >= 512 {
        32
    } else {
        64
    }
}

/// The hardware grid: both boards the paper measures.  The A53's 4-way L1
/// is the friendly case; the A72's 2-way L1 is the one that *needs* the
/// set-aware model — half the ways means conflict misses bite at half the
/// per-set depth.
const GRID_PROFILES: [&str; 2] = ["a53", "a72"];

fn traced_grid_reports() -> &'static Vec<(&'static str, usize, TraceReport)> {
    static REPORTS: std::sync::OnceLock<Vec<(&'static str, usize, TraceReport)>> =
        std::sync::OnceLock::new();
    REPORTS.get_or_init(|| {
        let mut out = Vec::new();
        for profile in GRID_PROFILES {
            let cpu = profile_by_name(profile).unwrap().cpu;
            for &n in GEMM_TABLE_SIZES {
                let r = trace_workload(
                    &cpu,
                    &BenchWorkload::Gemm { n },
                    TraceBudget::new(rows_for(n)),
                );
                out.push((profile, n, r));
            }
        }
        out
    })
}

/// Acceptance: set-aware MRC-predicted L1/L2 hit rates within 2 percentage
/// points of the full set-associative simulation on every Tables IV/V GEMM
/// shape, on both the A53 (4-way L1) and the A72 (2-way L1).
#[test]
fn mrc_hit_rates_match_full_simulation_on_tables_iv_v_grid() {
    for (profile, n, r) in traced_grid_reports() {
        assert!(
            r.l1_err_pp() <= 2.0,
            "{profile} n={n}: L1 hit-rate error {:.3} p.p. (mrc {:.4} vs sim {:.4})",
            r.l1_err_pp(),
            r.prediction.rates.l1_hit_rate,
            r.sim_l1_hit_rate,
        );
        assert!(
            r.l2_err_pp() <= 2.0,
            "{profile} n={n}: L2 hit-rate error {:.3} p.p. (mrc {:.4} vs sim {:.4})",
            r.l2_err_pp(),
            r.prediction.rates.l2_hit_rate,
            r.sim_l2_hit_rate,
        );
    }
}

/// Acceptance: the MRC-derived boundness class agrees with
/// `analysis::classify` (applied through the shared roofline path) on the
/// Tables IV/V grid, on both boards.
#[test]
fn mrc_boundness_class_agrees_with_classify_on_grid() {
    for (profile, n, r) in traced_grid_reports() {
        assert!(
            r.classes_agree(),
            "{profile} n={n}: predicted {} vs simulated {} (pred {:?})",
            r.predicted_class,
            r.sim_class,
            r.prediction.time,
        );
        // sanity: the grid's verdicts come from the paper's vocabulary
        assert!(
            ["compute", "L1-read", "L2-read", "RAM-read", "overhead"]
                .contains(&r.predicted_class.as_str()),
            "{profile} n={n}: unexpected class {}",
            r.predicted_class
        );
    }
}

/// One adversarial strided replay: `lines` lines `stride_bytes` apart,
/// swept `rounds` times, through the named profile's hierarchy with a
/// per-set reuse sink attached.  Returns `(fully_assoc_l1, set_aware_l1,
/// sim_l1, conflict_pp)` — all from the same access stream.
fn strided_case(profile: &str, stride_bytes: u64, lines: usize, rounds: usize) -> (f64, f64, f64, f64) {
    let cpu = profile_by_name(profile).unwrap().cpu;
    let mut h = Hierarchy::new(&cpu);
    let mut analyzer = ReuseAnalyzer::with_sets(cpu.l1.line_bytes, cpu.l1.sets());
    replay_strided(&mut h, stride_bytes, lines, rounds, &mut analyzer);
    let sets = analyzer.take_set_histograms().expect("with_sets tracks per-set stacks");
    let mrc = MissRatioCurve::with_sets(analyzer.combined(), cpu.l1.line_bytes, sets);
    let p = mrc.predict_set_aware(&cpu);
    (p.fa_l1_hit_rate, p.rates.l1_hit_rate, h.l1.stats.hit_rate(), p.conflict_pp)
}

/// Shared assertion: the fully-associative curve must be demonstrably
/// wrong (> 2 p.p. off the simulator) while the set-aware prediction stays
/// within the grid tolerance, and the gap is surfaced as `conflict_pp`.
fn assert_conflict_case(name: &str, fa: f64, sa: f64, sim: f64, conflict_pp: f64) {
    let fa_err = (fa - sim).abs() * 100.0;
    let sa_err = (sa - sim).abs() * 100.0;
    assert!(
        fa_err > 2.0,
        "{name}: fully-assoc is not adversarial here (err {fa_err:.2} p.p., fa {fa:.4} vs sim {sim:.4})"
    );
    assert!(
        sa_err <= 2.0,
        "{name}: set-aware error {sa_err:.2} p.p. (sa {sa:.4} vs sim {sim:.4})"
    );
    assert!(
        conflict_pp > 2.0,
        "{name}: conflict gap {conflict_pp:.2} p.p. should expose the mispricing"
    );
}

/// Adversarial: on the A72 a 16 KiB stride aliases every line to set 0,
/// so 8 lines thrash the 2-way set — the simulator misses every warm
/// access while the fully-associative curve (8 lines ≪ 512-line L1)
/// predicts near-perfect hits.  The set-aware model must side with the
/// simulator.
#[test]
fn a72_single_set_stride_defeats_fully_assoc_model() {
    // stride 16384 B = 256 lines; set = (i·256) & 255 = 0 for every i
    let (fa, sa, sim, pp) = strided_case("a72", 16384, 8, 32);
    assert_conflict_case("a72 stride 16KiB x8", fa, sa, sim, pp);
    assert!(sim < 0.01, "8 lines cycling one 2-way set never hit (sim {sim:.4})");
}

/// Adversarial: 8 KiB stride on the A72 folds 16 lines onto two sets
/// (8 per 2-way set) — same thrash, spread across sets.
#[test]
fn a72_two_set_stride_defeats_fully_assoc_model() {
    // stride 8192 B = 128 lines; sets alternate {0, 128}
    let (fa, sa, sim, pp) = strided_case("a72", 8192, 16, 32);
    assert_conflict_case("a72 stride 8KiB x16", fa, sa, sim, pp);
}

/// Adversarial: 4 KiB stride on the A72 folds 16 lines onto four sets
/// (4 per 2-way set); within-set distance 3 >= 2 ways still misses.
#[test]
fn a72_four_set_stride_defeats_fully_assoc_model() {
    // stride 4096 B = 64 lines; sets cycle {0, 64, 128, 192}
    let (fa, sa, sim, pp) = strided_case("a72", 4096, 16, 32);
    assert_conflict_case("a72 stride 4KiB x16", fa, sa, sim, pp);
}

/// Adversarial (A53 leg): 4 KiB stride aliases every line to set 0 of the
/// 64-set L1; 8 lines overwhelm even 4 ways.  Conflict modelling is not an
/// A72-only concern — the A53 just needs deeper aliasing to expose it.
#[test]
fn a53_single_set_stride_defeats_fully_assoc_model() {
    // stride 4096 B = 64 lines; set = (i·64) & 63 = 0 for every i
    let (fa, sa, sim, pp) = strided_case("a53", 4096, 8, 32);
    assert_conflict_case("a53 stride 4KiB x8", fa, sa, sim, pp);
}

/// Regression (the 64-cubed knife edge): the B panel's reuse distance
/// (~267 lines) sits just past the A53's 256-line L1, so the
/// fully-associative curve is forced to round the whole panel one way or
/// the other.  The per-set model is exact for the simulated LRU, so it
/// must (a) stay within the grid tolerance and (b) never be further from
/// the simulator than the fully-associative estimate.
#[test]
fn gemm64_knife_edge_set_aware_tracks_simulator() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 64 }, TraceBudget::new(64));
    let sim = r.sim_l1_hit_rate;
    let sa_err = (r.prediction.rates.l1_hit_rate - sim).abs() * 100.0;
    let fa_err = (r.prediction.fa_l1_hit_rate - sim).abs() * 100.0;
    assert!(
        sa_err <= 2.0,
        "knife edge: set-aware L1 error {sa_err:.3} p.p. (sa {:.4} vs sim {sim:.4})",
        r.prediction.rates.l1_hit_rate
    );
    assert!(
        sa_err <= fa_err + 1e-9,
        "knife edge: set-aware ({sa_err:.3} p.p.) must not be further from the \
         simulator than fully-assoc ({fa_err:.3} p.p.)"
    );
    // the surfaced gap is exactly the (signed) fa-vs-sa difference
    let expected_pp = (r.prediction.fa_l1_hit_rate - r.prediction.rates.l1_hit_rate) * 100.0;
    assert!((r.conflict_pp() - expected_pp).abs() < 1e-9);
}

/// Acceptance: one shape of each operator family traces and emits valid
/// JSON with reuse histograms, the MRC and a predicted class.
#[test]
fn every_family_emits_valid_trace_json() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let tiny = ConvLayer {
        name: "tiny",
        b: 1,
        cin: 8,
        cout: 16,
        h: 12,
        w: 12,
        k: 3,
        stride: 1,
        pad: 1,
    };
    let grid = [
        BenchWorkload::Gemm { n: 48 },
        BenchWorkload::Conv { layer: tiny },
        BenchWorkload::QnnConv { layer: tiny },
        BenchWorkload::Bitserial { n: 64, bits: 2 },
    ];
    for w in &grid {
        let r = trace_workload(&cpu, w, TraceBudget::default());
        let text = json::to_string_pretty(&r.to_json());
        let v = json::parse(&text).unwrap_or_else(|e| panic!("{}: bad JSON: {e}", r.key()));
        assert_eq!(v.req("family").unwrap().as_str().unwrap(), w.family());
        assert!(!v.req("operands").unwrap().as_arr().unwrap().is_empty());
        assert!(!v.req("mrc").unwrap().as_arr().unwrap().is_empty());
        let predicted = v.req("predicted").unwrap();
        assert!(predicted.req("class").unwrap().as_str().is_ok());
        assert!(predicted.req("l1_hit_rate").unwrap().as_f64().unwrap() >= 0.0);
        // the conflict-miss fields: the surfaced gap must reconcile with
        // the fully-associative and set-aware rates it is defined from
        let fa = predicted.req("fa_l1_hit_rate").unwrap().as_f64().unwrap();
        let sa = predicted.req("l1_hit_rate").unwrap().as_f64().unwrap();
        let pp = predicted.req("conflict_pp").unwrap().as_f64().unwrap();
        assert!(
            (pp - (fa - sa) * 100.0).abs() < 1e-9,
            "{}: conflict_pp {pp} vs fa {fa} / sa {sa}",
            r.key()
        );
    }
}

/// Acceptance: the `NullSink` path leaves the simulator bit-identical —
/// traced and untraced replays of the same workload produce the same
/// per-level counts and cache stats.
#[test]
fn null_sink_replay_is_bit_identical_to_untraced() {
    let cpu = profile_by_name("a72").unwrap().cpu;
    let s = cachebound::operators::gemm::GemmSchedule::default_tuned();
    let mut plain = Hierarchy::new(&cpu);
    replay_gemm(&mut plain, 48, 96, 96, s, 4);
    let mut traced = Hierarchy::new(&cpu);
    replay_gemm_traced(&mut traced, 48, 96, 96, s, 4, &mut NullSink);
    assert_eq!(plain.counts, traced.counts);
    assert_eq!(plain.l1.stats, traced.l1.stats);
    assert_eq!(plain.l2.stats, traced.l2.stats);
}

/// The analyzer's accounting is closed: per-operand histogram mass equals
/// hierarchy accesses, and the combined histogram equals the operand sum.
#[test]
fn analyzer_accounting_is_closed_over_a_real_trace() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let mut h = Hierarchy::new(&cpu);
    let mut analyzer = ReuseAnalyzer::new(cpu.l1.line_bytes);
    let s = cachebound::operators::gemm::GemmSchedule::default_tuned();
    replay_gemm_traced(&mut h, 32, 128, 128, s, 4, &mut analyzer);
    assert_eq!(analyzer.accesses(), h.counts.accesses);
    assert_eq!(analyzer.combined().total(), h.counts.accesses);
    // the L1 miss count is the fully-associative view; it must sit close
    // to the set-associative truth (this is the essence of the MRC bet)
    let mrc_misses = h.counts.accesses
        - analyzer
            .combined()
            .hits_within(cpu.l1.size_bytes / cpu.l1.line_bytes);
    let sim_misses = h.l1.stats.misses();
    let diff = mrc_misses.abs_diff(sim_misses) as f64 / h.counts.accesses as f64;
    assert!(diff < 0.02, "miss-count gap {:.3} of accesses", diff);
}

/// Acceptance (CLI): `cachebound trace` runs for every family and the
/// `--json` artifact parses with the documented fields.
#[test]
fn trace_cli_emits_valid_json_for_every_family() {
    let exe = env!("CARGO_BIN_EXE_cachebound");
    let dir = std::env::temp_dir().join("cachebound_trace_cli_test");
    fs::create_dir_all(&dir).unwrap();
    let cases: [(&str, &[&str]); 4] = [
        ("gemm", &["--n", "48", "--rows", "16"]),
        ("conv", &["--layer", "C2", "--rows", "2"]),
        ("qnn", &["--layer", "C4", "--rows", "8"]),
        ("bitserial", &["--n", "64", "--bits", "1", "--rows", "16"]),
    ];
    for (family, extra) in cases {
        let path = dir.join(format!("{family}.json"));
        let out = Command::new(exe)
            .arg("trace")
            .arg(family)
            .args(extra)
            .args(["--json", path.to_str().unwrap()])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "trace {family} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = fs::read_to_string(&path).unwrap();
        let v = json::parse(&text).unwrap();
        assert_eq!(v.req("family").unwrap().as_str().unwrap(), family);
        assert!(v.req("predicted").unwrap().req("class").is_ok());
        assert!(v.req("predicted").unwrap().req("conflict_pp").unwrap().as_f64().is_ok());
        assert!(v.req("predicted").unwrap().req("fa_l1_hit_rate").unwrap().as_f64().is_ok());
        assert!(v.req("simulated").unwrap().req("l1_hit_rate").is_ok());
        assert!(!v.req("mrc").unwrap().as_arr().unwrap().is_empty());
    }
    let _ = fs::remove_dir_all(&dir);
}
