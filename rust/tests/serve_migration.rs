//! Migration chaos harness (DESIGN.md §Migration).
//!
//! The live-migration protocol claims three invariants survive *any*
//! interleaving of requests and migrations: per-artifact FIFO, exactly
//! one response per request, and metrics that reconcile across every
//! `(shard, worker)` owner epoch.  This suite attacks the claim with a
//! deterministic chaos driver: seeded drifting request streams,
//! forced migrations injected at seeded points, and the automatic
//! divergence trigger running on top.
//!
//! Seeds: every chaos test runs once per seed in
//! `MIGRATION_CHAOS_SEEDS` (comma-separated, `0x` hex or decimal;
//! default two seeds).  CI re-runs the suite with a 4-seed matrix.
//!
//! The convergence test pins the acceptance criterion: the adversarial
//! co-run mix (`syn_gemm_n160` + `syn_gemm_n192`) started under *hash*
//! placement converges to the cache-aware greedy plan mid-stream, with
//! both workers busy afterwards.  (The throughput side of the criterion
//! — live ≥ drain-time rebalance — is measured by the drifting-mix
//! section of `benches/bench_serve.rs`; wall-clock assertions do not
//! belong in a correctness suite.)

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use cachebound::analysis::InterferenceModel;
use cachebound::coordinator::placement::{adversarial_mix, plan};
use cachebound::coordinator::server::{
    Request, Response, ServeConfig, ServeOutcome, ShardedServer, SyntheticExecutor,
};
use cachebound::coordinator::RebalanceMode;
use cachebound::hw::profile_by_name;
use cachebound::operators::workloads;
use cachebound::telemetry::{serving_mix_profiles, CacheProfile};
use cachebound::util::rng::Xoshiro256;

/// The chaos seed matrix: `MIGRATION_CHAOS_SEEDS` (comma-separated,
/// decimal or `0x` hex), defaulting to two seeds so the suite is cheap in
/// a plain `cargo test` and broad in CI.
fn seeds() -> Vec<u64> {
    match std::env::var("MIGRATION_CHAOS_SEEDS") {
        Ok(raw) => raw
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.strip_prefix("0x")
                    .map(|h| u64::from_str_radix(h, 16))
                    .unwrap_or_else(|| s.parse())
                    .unwrap_or_else(|e| panic!("bad chaos seed '{s}': {e}"))
            })
            .collect(),
        Err(_) => vec![0xC0FFEE, 0x5EED_CAB5],
    }
}

/// A drifting request stream: three phases drawn from different sub-menus
/// of the serving mix, so the artifact population the server observes
/// changes mid-stream (what the live divergence check exists to chase).
fn drifting_stream(n: usize, seed: u64) -> Vec<String> {
    let mix = workloads::serving_mix();
    let menu = |idx: &[usize], weight_seed: u64| -> Vec<(String, u32)> {
        idx.iter()
            .enumerate()
            .map(|(i, &m)| {
                (mix[m].artifact.clone(), 1 + ((weight_seed >> i) & 3) as u32)
            })
            .collect()
    };
    let phases: [Vec<(String, u32)>; 3] = [
        menu(&[0, 1, 2], seed),
        menu(&[2, 3, 4], seed >> 8),
        menu(&[0, 4], seed >> 16),
    ];
    let per_phase = n / 3;
    let mut out = Vec::with_capacity(n);
    for (i, m) in phases.iter().enumerate() {
        let want = if i == 2 { n - out.len() } else { per_phase };
        out.extend(workloads::bursty_requests(m, want, seed ^ (i as u64 + 1)));
    }
    out
}

fn assert_exactly_once(out: &ServeOutcome, n: usize) {
    let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(
        ids,
        (0..n as u64).collect::<Vec<_>>(),
        "dropped or duplicated responses"
    );
}

fn assert_per_artifact_fifo(responses: &[Response]) {
    let mut per_artifact: HashMap<&str, Vec<u64>> = HashMap::new();
    for r in responses {
        per_artifact.entry(r.artifact.as_str()).or_default().push(r.id);
    }
    for (artifact, ids) in per_artifact {
        assert!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "FIFO violated for {artifact}: {ids:?}"
        );
    }
}

/// Aggregate totals must equal the sums over every `(shard, worker)` row —
/// including the extra rows migrations mint when a shard changes owners.
fn assert_metrics_reconcile(out: &ServeOutcome, n: usize) {
    let m = &out.metrics;
    assert_eq!(m.requests, n as u64);
    assert_eq!(m.completed + m.failed, m.requests);
    let sums: [u64; 5] = [
        m.per_shard.iter().map(|s| s.requests).sum(),
        m.per_shard.iter().map(|s| s.completed).sum(),
        m.per_shard.iter().map(|s| s.failed).sum(),
        m.per_shard.iter().map(|s| s.cache_hits).sum(),
        m.per_shard.iter().map(|s| s.latency.count()).sum(),
    ];
    assert_eq!(sums[0], m.requests - m.rejected, "per-shard requests");
    assert_eq!(sums[1], m.completed, "per-shard completed");
    assert_eq!(sums[2], m.failed - m.rejected, "per-shard failed");
    assert_eq!(sums[3], m.cache_hits, "per-shard cache hits");
    assert_eq!(sums[4], m.completed, "histograms record completed requests");
    // an artifact migrates workers, never shards
    let mut artifact_shard: HashMap<&str, usize> = HashMap::new();
    for r in &out.responses {
        if let Some(prev) = artifact_shard.insert(r.artifact.as_str(), r.shard) {
            assert_eq!(prev, r.shard, "artifact {} changed shards", r.artifact);
        }
    }
    // the migration log itself is well-formed: automatic moves always
    // relocate and carry the divergence that triggered them; forced moves
    // (including route pins, where from == to) log a zero divergence
    for rec in &m.migrations {
        assert!((0.0..=1.0).contains(&rec.divergence), "{rec:?}");
        if rec.forced {
            assert_eq!(rec.divergence, 0.0, "{rec:?}");
        } else {
            assert_ne!(rec.from_worker, rec.to_worker, "{rec:?}");
            assert!(rec.divergence > 0.0, "{rec:?}");
        }
    }
}

/// The core chaos property: under seeded mix drift, forced migrations at
/// seeded points *and* the automatic divergence trigger, every serving
/// invariant holds and the payloads are bit-identical to an undisturbed
/// baseline run.
#[test]
fn chaos_migrations_preserve_serving_invariants() {
    let mix = workloads::serving_mix();
    let profiles = serving_mix_profiles(&profile_by_name("a53").unwrap().cpu);
    for seed in seeds() {
        let mut rng = Xoshiro256::new(seed);
        let workers = 2 + rng.below(3) as usize; // 2..=4
        let n = 240;
        let stream = drifting_stream(n, seed);

        // the undisturbed baseline: same stream, no plans, no migrations
        let baseline = ShardedServer::start(ServeConfig::new(workers), |_w| {
            Ok(SyntheticExecutor::new())
        })
        .serve_stream(stream.iter().cloned());
        assert_eq!(baseline.metrics.completed, n as u64, "seed {seed:#x}");

        // the chaos run: live rebalancing plus forced moves at seeded points
        let mut cfg = ServeConfig::new(workers)
            .with_cache(1 + rng.below(8) as usize)
            .with_profiles(profiles.clone())
            .with_rebalance(RebalanceMode::Live);
        cfg.rebalance_check_every = 16 + rng.below(32) as usize;
        let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
        let mut forced = 0usize;
        for (id, artifact) in stream.iter().enumerate() {
            if rng.below(16) == 0 {
                let victim = &mix[rng.below(mix.len() as u64) as usize].artifact;
                let target = rng.below(workers as u64) as usize;
                forced += usize::from(srv.migrate(victim, target).is_some());
            }
            srv.submit(Request { id: id as u64, artifact: artifact.clone() });
        }
        let out = srv.finish();

        assert_exactly_once(&out, n);
        assert_per_artifact_fifo(&out.responses);
        assert_metrics_reconcile(&out, n);
        assert_eq!(out.metrics.failed, 0, "seed {seed:#x}: {:?}",
            out.responses.iter().find(|r| !r.ok));
        assert!(
            out.metrics.migrations.len() >= forced,
            "seed {seed:#x}: log must cover every forced move ({} < {forced})",
            out.metrics.migrations.len()
        );

        // purity across migrations: executor state and cache entries moved,
        // never corrupted — every payload matches the undisturbed run
        let payload = |o: &ServeOutcome| -> BTreeMap<u64, f64> {
            o.responses.iter().map(|r| (r.id, r.payload.unwrap())).collect()
        };
        assert_eq!(
            payload(&out),
            payload(&baseline),
            "seed {seed:#x}: migrations must not change any payload"
        );
    }
}

/// The acceptance criterion: the adversarial pair, started under hash
/// placement, converges to the cache-aware greedy plan mid-stream — the
/// routing after convergence *is* the plan's assignment, both workers end
/// up busy, and the run has no residual rebalance suggestion.
#[test]
fn hash_start_converges_to_cache_aware_plan_mid_stream() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let adv = adversarial_mix(&cpu, 2, 8).expect("qualifying pair on the A53");
    let profiles: BTreeMap<String, CacheProfile> = adv.iter().cloned().collect();
    let expected = plan(&InterferenceModel::new(&cpu), &profiles, 2);
    assert_ne!(
        expected.worker_for(&adv[0].0),
        expected.worker_for(&adv[1].0),
        "the greedy plan splits the pair"
    );

    let mut cfg = ServeConfig::new(2)
        .with_profiles(Arc::new(profiles))
        .with_cpu(cpu)
        .with_rebalance(RebalanceMode::Live);
    cfg.rebalance_check_every = 8;
    let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
    assert!(srv.placement().is_none(), "hash start: no upfront plan");
    let n = 48usize;
    for id in 0..n as u64 {
        let artifact = adv[id as usize % 2].0.clone();
        srv.submit(Request { id, artifact });
    }
    // convergence: the live routing now equals the greedy assignment
    assert!(!srv.migrations().is_empty(), "the divergence check must have fired");
    for (name, _) in &adv {
        assert_eq!(
            srv.route_of(name),
            expected.worker_for(name),
            "{name} must be routed by the converged plan"
        );
    }
    let first_move = srv.migrations()[0].at_request;
    assert!(
        first_move < n as u64,
        "migration happened mid-stream, not at drain ({first_move})"
    );

    let out = srv.finish();
    assert_exactly_once(&out, n);
    assert_per_artifact_fifo(&out.responses);
    assert_metrics_reconcile(&out, n);
    assert_eq!(out.metrics.failed, 0);
    // both workers busy after the split
    let busy = out
        .metrics
        .worker_pressure
        .iter()
        .filter(|p| p.artifacts > 0)
        .count();
    assert_eq!(busy, 2, "{:?}", out.metrics.worker_pressure);
    // post-migration prediction agrees with observation (the stale
    // predicted_bytes regression) and nothing is left to suggest
    for row in &out.metrics.worker_pressure {
        assert_eq!(row.predicted_bytes, row.resident_bytes, "worker {}", row.worker);
    }
    assert!(out.rebalanced.is_none(), "converged run suggests nothing");
}

/// Forced migrations with the response cache on: the cache entry moves
/// with the artifact and keeps serving bit-identical hits on the target,
/// under repeated ping-pong moves.
#[test]
fn migrated_state_survives_ping_pong_moves() {
    let artifact = workloads::synthetic_artifact(96);
    let mut srv = ShardedServer::start(
        ServeConfig::new(2).with_cache(4),
        |_w| Ok(SyntheticExecutor::new()),
    );
    let mut id = 0u64;
    let mut submit_burst = |srv: &mut ShardedServer, k: u64| {
        for _ in 0..k {
            srv.submit(Request { id, artifact: artifact.clone() });
            id += 1;
        }
    };
    submit_burst(&mut srv, 3);
    for _ in 0..4 {
        let here = srv.route_of(&artifact).unwrap();
        let rec = srv.migrate(&artifact, 1 - here).expect("a real move");
        assert_eq!(rec.to_worker, 1 - here);
        assert!(rec.cache_moved, "{rec:?}");
        submit_burst(&mut srv, 3);
    }
    let n = id as usize;
    let out = srv.finish();
    assert_exactly_once(&out, n);
    assert_per_artifact_fifo(&out.responses);
    assert!(out.responses.iter().all(|r| r.ok));
    assert_eq!(out.metrics.migrations.len(), 4);
    // one cold execution, everything after hits a (possibly migrated) entry
    let p0 = out.responses.iter().find(|r| r.id == 0).unwrap().payload.unwrap();
    for r in &out.responses {
        assert_eq!(r.payload, Some(p0), "bit-identical across every move");
        if r.id > 0 {
            assert!(r.cached, "request {} should hit the moved entry", r.id);
            assert_eq!(r.exec_seconds, 0.0);
        }
    }
    assert_eq!(out.metrics.cache_hits, n as u64 - 1);
}

/// The CLI surface: `cachebound serve --rebalance live` runs end to end
/// and reports its mode; an unknown mode is rejected loudly.
#[test]
fn cli_serve_rebalance_flag_round_trips() {
    use std::process::Command;

    let exe = env!("CARGO_BIN_EXE_cachebound");
    let out = Command::new(exe)
        .args([
            "serve",
            "--synthetic",
            "--workers",
            "2",
            "--requests",
            "64",
            "--rebalance",
            "live",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "serve --rebalance live must exit 0: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("rebalance live"), "{stdout}");
    assert!(stdout.contains("served 64/64"), "{stdout}");

    let bad = Command::new(exe)
        .args(["serve", "--synthetic", "--requests", "4", "--rebalance", "sometimes"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("rebalance"));
}
