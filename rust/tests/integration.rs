//! Cross-module integration tests.
//!
//! The heavyweight invariants that tie the substrates together:
//!
//! * the analytic traffic model agrees with the trace-driven cache
//!   simulator on sizes where exact replay is feasible;
//! * the manifest's workload grid matches the rust-side Table III;
//! * all native operator variants agree with each other;
//! * the full analysis chain reproduces the paper's qualitative results.

use cachebound::analysis::bounds::gemm_bounds;
use cachebound::analysis::classify::{classify, BoundClass};
use cachebound::coordinator::pipeline::{Pipeline, PipelineConfig};
use cachebound::hw::{profile_by_name, MemLevel};
use cachebound::operators::conv::{self, ConvSchedule};
use cachebound::operators::gemm::{self, GemmSchedule};
use cachebound::operators::tensor::max_abs_diff;
use cachebound::operators::workloads;
use cachebound::operators::Tensor;
use cachebound::sim::hierarchy::Hierarchy;
use cachebound::sim::trace;
use cachebound::sim::traffic::TrafficModel;

fn quick_pipeline() -> Pipeline {
    Pipeline::new(PipelineConfig {
        n_workers: 2,
        tune_trials: 8,
        skip_native: true,
        native_max_n: 0,
    })
}

// ---------------------------------------------------------------------------
// Traffic model vs trace simulator
// ---------------------------------------------------------------------------

#[test]
fn analytic_l1_traffic_matches_trace_for_gemm() {
    // The L1 element-byte count is exact arithmetic in both — must agree
    // to within the model's ceil() rounding.
    let cpu = profile_by_name("a53").unwrap().cpu;
    let tm = TrafficModel::new(&cpu);
    for (n, s) in [
        (64usize, GemmSchedule::new(16, 16, 16, 1)),
        (96, GemmSchedule::new(32, 32, 32, 4)),
        (128, GemmSchedule::naive()),
    ] {
        let mut h = Hierarchy::new(&cpu);
        trace::replay_gemm(&mut h, n, n, n, s, 4);
        let t = tm.gemm(n, n, n, s, 4);
        let measured = h.counts.l1_bytes as f64;
        let ratio = t.l1_bytes / measured;
        assert!(
            (0.95..=1.05).contains(&ratio),
            "n={n} {s:?}: model {} vs trace {measured} (ratio {ratio})",
            t.l1_bytes
        );
    }
}

#[test]
fn analytic_l2_traffic_tracks_trace_within_2x() {
    // Line-granular lower-level traffic involves replacement detail the
    // analytic model abstracts; requiring agreement within a small factor
    // on both sides of the tile-fit boundary keeps the model honest.
    let cpu = profile_by_name("a53").unwrap().cpu;
    let tm = TrafficModel::new(&cpu);
    for (n, s) in [
        (128usize, GemmSchedule::new(16, 64, 16, 4)), // fits L1
        (128, GemmSchedule::naive()),                 // tiny tiles
    ] {
        let mut h = Hierarchy::new(&cpu);
        trace::replay_gemm(&mut h, n, n, n, s, 4);
        let t = tm.gemm(n, n, n, s, 4);
        let measured = (h.counts.l2_bytes + h.counts.wb_l2_bytes) as f64;
        let ratio = t.l2_bytes / measured;
        assert!(
            (0.3..=3.0).contains(&ratio),
            "n={n} {s:?}: model {:.3e} vs trace {measured:.3e} (ratio {ratio:.2})",
            t.l2_bytes
        );
    }
}

#[test]
fn trace_sim_and_model_agree_on_schedule_ordering() {
    // Whatever the absolute numbers, both must order schedules the same
    // way — that ordering is what the tuner consumes.
    let cpu = profile_by_name("a72").unwrap().cpu;
    let tm = TrafficModel::new(&cpu);
    let n = 128;
    let schedules = [
        GemmSchedule::naive(),
        GemmSchedule::new(16, 64, 16, 4),
        GemmSchedule::new(64, 64, 64, 4),
    ];
    let mut trace_l2 = Vec::new();
    let mut model_l2 = Vec::new();
    for s in schedules {
        let mut h = Hierarchy::new(&cpu);
        trace::replay_gemm(&mut h, n, n, n, s, 4);
        trace_l2.push(h.counts.l2_bytes as f64);
        model_l2.push(tm.gemm(n, n, n, s, 4).l2_bytes);
    }
    // The robust, tuner-relevant claim: both agree the naive schedule
    // produces the most lower-level traffic.  (The relative order of two
    // good L1-fitting schedules is within both models' noise band.)
    let worst_trace = trace_l2
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let worst_model = model_l2
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(worst_trace, 0, "trace must rank naive worst: {trace_l2:?}");
    assert_eq!(worst_model, 0, "model must rank naive worst: {model_l2:?}");
}

// ---------------------------------------------------------------------------
// Workload grid consistency (python <-> rust)
// ---------------------------------------------------------------------------

#[test]
fn manifest_workloads_match_rust_table_iii() {
    // Needs `make artifacts`; skip silently if absent so `cargo test`
    // works on a fresh clone (runtime_artifacts.rs covers the strict path).
    let Ok(m) = cachebound::runtime::Manifest::load("artifacts") else {
        eprintln!("skipping: no artifacts/");
        return;
    };
    let layers = workloads::resnet18_layers();
    assert_eq!(m.resnet_macs.len(), layers.len());
    for ((name, macs), l) in m.resnet_macs.iter().zip(&layers) {
        assert_eq!(name, l.name);
        assert_eq!(*macs, l.macs(), "layer {name}");
    }
}

// ---------------------------------------------------------------------------
// Native operator cross-validation
// ---------------------------------------------------------------------------

#[test]
fn all_gemm_variants_agree_on_realistic_sizes() {
    for n in [96usize, 160] {
        let a = Tensor::rand_f32(&[n, n], n as u64);
        let b = Tensor::rand_f32(&[n, n], n as u64 + 1);
        let c_naive = gemm::naive(&a, &b);
        let c_tiled = gemm::tiled(&a, &b, GemmSchedule::new(48, 32, 16, 4));
        let c_blocked = gemm::blocked(&a, &b);
        assert!(max_abs_diff(&c_naive, &c_tiled) < 1e-3);
        assert!(max_abs_diff(&c_naive, &c_blocked) < 1e-3);
    }
}

#[test]
fn conv_variants_agree_on_resnet_geometry_class() {
    // scaled-down C3-class layer (3x3 stride 2) and C4-class (1x1 stride 2)
    for (k, stride, pad) in [(3usize, 2usize, 1usize), (1, 2, 0), (3, 1, 1)] {
        let x = Tensor::rand_f32(&[1, 16, 28, 28], 5);
        let w = Tensor::rand_f32(&[32, 16, k, k], 6);
        let direct = conv::naive(&x, &w, stride, pad);
        let sp = conv::spatial_pack(&x, &w, stride, pad, ConvSchedule::new(8, 4));
        let im = conv::im2col_conv(&x, &w, stride, pad);
        assert!(max_abs_diff(&direct, &sp) < 1e-3, "k={k} s={stride}");
        assert!(max_abs_diff(&direct, &im) < 1e-3, "k={k} s={stride}");
    }
}

// ---------------------------------------------------------------------------
// End-to-end analysis chain (the paper's headline claims)
// ---------------------------------------------------------------------------

#[test]
fn paper_claim_gemm_is_l1_bound_on_both_parts() {
    for profile in ["a53", "a72"] {
        let mut p = quick_pipeline();
        let (f, _) = cachebound::report::fig1(&mut p, profile).unwrap();
        assert_eq!(f.best_bound, "L1-read", "profile {profile}");
    }
}

#[test]
fn paper_claim_quantized_not_cache_bound() {
    let cpu = profile_by_name("a72").unwrap().cpu;
    let mut p = quick_pipeline();
    let (f, _, _) = cachebound::report::fig4_fig5(&mut p, "a72").unwrap();
    let l1 = cpu.read_bw_bytes(MemLevel::L1);
    assert!(f.points.iter().all(|(.., bw)| *bw < l1 * 1.05));
}

#[test]
fn paper_claim_speedup_ordering_1bit_beats_8bit_beats_f32() {
    let mut p = quick_pipeline();
    let (f, ..) = cachebound::report::fig6_fig7_fig8(&mut p, "a72").unwrap();
    for r in &f.rows {
        let s1 = r.speedup_bits(1, true).unwrap();
        assert!(s1 > 1.0, "{}: 1-bit speedup {s1} must beat f32", r.layer);
        assert!(r.speedup_qnn() > 1.0, "{}: qnn8 {}", r.layer, r.speedup_qnn());
    }
}

#[test]
fn classification_of_simulated_tuned_gemm_is_l1() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    for n in [256usize, 512, 1024] {
        let tb = cachebound::sim::timing::simulate_gemm_time(
            &cpu,
            n,
            n,
            n,
            GemmSchedule::new(64, 64, 64, 4),
            32,
        );
        let b = gemm_bounds(&cpu, n);
        let class = classify(tb.total_s, &b, 2.0);
        assert_eq!(class, BoundClass::CacheRead(MemLevel::L1), "n={n}");
    }
}

#[test]
fn tuned_beats_naive_by_paper_magnitude() {
    // Table IV: tuned/naive ratio is ~3.5x at N=128 rising to ~9x at 1024.
    let cpu = profile_by_name("a53").unwrap().cpu;
    for (n, min_ratio) in [(128usize, 2.0), (1024, 4.0)] {
        let naive =
            cachebound::sim::timing::simulate_gemm_time(&cpu, n, n, n, GemmSchedule::naive(), 32);
        let tuned = cachebound::sim::timing::simulate_gemm_time(
            &cpu,
            n,
            n,
            n,
            GemmSchedule::new(64, 64, 64, 4),
            32,
        );
        let ratio = naive.total_s / tuned.total_s;
        assert!(ratio > min_ratio, "n={n}: ratio {ratio:.2}");
    }
}
