//! Tier-downshift chaos harness (DESIGN.md §Tiers, §Admission).
//!
//! `TierPolicy::DownshiftOnPressure` claims the open-loop invariants of
//! the overload suite survive *precision* degradation exactly as they
//! survive shape degradation: every submitted request gets exactly one
//! disposition (served, shed, or degraded — never silently dropped),
//! every downshift is one step down the tier lattice at the *same* GEMM
//! size, the bit-serial floor sheds instead of inventing a lower tier,
//! and per-artifact FIFO holds among the served responses.  This suite
//! attacks those claims with seeded overload schedules driven wall-clock
//! through `serve_open_loop`, composed with forced live migrations
//! mid-downshift.
//!
//! Seeds: every chaos test runs once per seed in `TIER_CHAOS_SEEDS`
//! (comma-separated, `0x` hex or decimal; default two seeds).  CI
//! re-runs the suite with a 4-seed matrix.
//!
//! The artifacts are the large synthetic GEMMs (n96/n128 across all
//! three tiers, ms-scale native execution on any host), so a µs-scale
//! arrival schedule is overload by construction — the assertions compare
//! dispositions and lattice steps, not wall-clock figures.

use std::collections::HashMap;
use std::time::Instant;

use cachebound::coordinator::server::{
    AdmissionMode, Request, Response, ServeConfig, ServeOutcome, ShardedServer,
    SyntheticExecutor, TierPolicy,
};
use cachebound::coordinator::ArrivalConfig;
use cachebound::operators::workloads::{self, Tier};
use cachebound::util::rng::Xoshiro256;

/// The chaos seed matrix: `TIER_CHAOS_SEEDS` (comma-separated, decimal
/// or `0x` hex), defaulting to two seeds so the suite is cheap in a
/// plain `cargo test` and broad in CI.
fn seeds() -> Vec<u64> {
    match std::env::var("TIER_CHAOS_SEEDS") {
        Ok(raw) => raw
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.strip_prefix("0x")
                    .map(|h| u64::from_str_radix(h, 16))
                    .unwrap_or_else(|| s.parse())
                    .unwrap_or_else(|e| panic!("bad chaos seed '{s}': {e}"))
            })
            .collect(),
        Err(_) => vec![0x7135, 0xD0E5],
    }
}

/// An overload stream over the big end of the tiered menu: the n96/n128
/// fp32 artifacts and their int8 twins, drawn seeded.
fn tiered_overload_stream(n: usize, seed: u64) -> Vec<String> {
    let menu = [
        workloads::tier_artifact(Tier::F32, 96),
        workloads::tier_artifact(Tier::F32, 128),
        workloads::tier_artifact(Tier::Int8, 96),
        workloads::tier_artifact(Tier::Int8, 128),
    ];
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| menu[rng.below(4) as usize].clone()).collect()
}

/// A schedule far past capacity: base Poisson at `rate` req/s with a
/// seeded flash crowd on top.
fn overload_schedule(rate: f64, n: usize, seed: u64) -> Vec<f64> {
    ArrivalConfig::poisson(rate, n, seed)
        .with_flash(1, 3.0, 0.002)
        .schedule()
}

/// Every submitted request got exactly one disposition, and every
/// disposition left a latency sample — the "never silent" invariant.
fn assert_dispositions_reconcile(out: &ServeOutcome, n: usize, seed: u64) {
    let m = &out.metrics;
    assert_eq!(m.requests, n as u64, "seed {seed:#x}");
    assert_eq!(
        m.completed + m.failed + m.shed,
        m.requests,
        "seed {seed:#x}: served + failed + shed must cover every request"
    );
    assert!(m.degraded <= m.completed, "seed {seed:#x}: degraded requests are served");
    assert_eq!(
        m.latency_seconds.len(),
        m.requests as usize,
        "seed {seed:#x}: every disposition must leave a latency sample"
    );
    let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(
        ids,
        (0..n as u64).collect::<Vec<_>>(),
        "seed {seed:#x}: dropped or duplicated responses"
    );
}

/// Every degraded response took exactly one step down the tier lattice
/// at an unchanged GEMM size — the downshift analogue of the overload
/// suite's shape check.
fn assert_downshifts_walk_the_lattice(responses: &[Response], seed: u64) {
    for r in responses.iter().filter(|r| r.degraded_from.is_some()) {
        assert!(r.ok, "seed {seed:#x}: degraded requests are served: {r:?}");
        let from = r.degraded_from.as_deref().unwrap();
        let (from_tier, from_n) =
            workloads::synthetic_tier(from).unwrap_or_else(|| panic!("seed {seed:#x}: {r:?}"));
        let (to_tier, to_n) = workloads::synthetic_tier(&r.artifact)
            .unwrap_or_else(|| panic!("seed {seed:#x}: {r:?}"));
        assert_eq!(to_n, from_n, "seed {seed:#x}: downshift must keep the shape: {r:?}");
        assert_eq!(
            Some(to_tier),
            from_tier.next_down(),
            "seed {seed:#x}: downshift must be one lattice step: {r:?}"
        );
    }
}

/// Per-artifact FIFO among the *served* responses (sheds are emitted at
/// the front door and do not join any queue).
fn assert_served_fifo(responses: &[Response], seed: u64) {
    let mut per_artifact: HashMap<&str, Vec<u64>> = HashMap::new();
    for r in responses.iter().filter(|r| r.ok) {
        per_artifact.entry(r.artifact.as_str()).or_default().push(r.id);
    }
    for (artifact, ids) in per_artifact {
        assert!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "seed {seed:#x}: FIFO violated for {artifact}: {ids:?}"
        );
    }
}

/// The core property: under `Degrade` + `DownshiftOnPressure`, a seeded
/// flash-crowd schedule far past capacity downshifts visibly, every
/// downshift is one lattice step at the same shape, and every request
/// reconciles to exactly one disposition.
#[test]
fn downshift_preserves_dispositions_under_seeded_overload() {
    for seed in seeds() {
        let n = 160;
        let stream = tiered_overload_stream(n, seed);
        let schedule = overload_schedule(200_000.0, n, seed);

        let cfg = ServeConfig::new(2)
            .with_admission(AdmissionMode::Degrade)
            .with_admission_limit(4)
            .with_tier_policy(TierPolicy::DownshiftOnPressure);
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_open_loop(stream.into_iter(), &schedule);

        assert_dispositions_reconcile(&out, n, seed);
        assert_downshifts_walk_the_lattice(&out.responses, seed);
        assert_served_fifo(&out.responses, seed);
        let m = &out.metrics;
        assert_eq!(m.failed, 0, "seed {seed:#x}: downshifts are not failures");
        assert!(
            m.degraded > 0,
            "seed {seed:#x}: a 200k req/s burst into ms-scale service must downshift"
        );
    }
}

/// The lattice floor: an all-bit-serial overload has nowhere lower to
/// go, so `Degrade` must shed loudly — never fabricate a tier below
/// bit-serial, never drop silently.
#[test]
fn bitserial_floor_sheds_instead_of_downshifting() {
    for seed in seeds() {
        let n = 120;
        let menu =
            [workloads::tier_artifact(Tier::BitSerial, 96), workloads::tier_artifact(Tier::BitSerial, 128)];
        let mut rng = Xoshiro256::new(seed);
        let stream: Vec<String> =
            (0..n).map(|_| menu[rng.below(2) as usize].clone()).collect();
        let schedule = overload_schedule(200_000.0, n, seed);

        let cfg = ServeConfig::new(2)
            .with_admission(AdmissionMode::Degrade)
            .with_admission_limit(4)
            .with_tier_policy(TierPolicy::DownshiftOnPressure);
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_open_loop(stream.into_iter(), &schedule);

        assert_dispositions_reconcile(&out, n, seed);
        let m = &out.metrics;
        assert_eq!(m.degraded, 0, "seed {seed:#x}: bit-serial has no lower tier");
        assert_eq!(m.failed, 0, "seed {seed:#x}: floor sheds are not failures");
        assert!(
            m.shed > 0,
            "seed {seed:#x}: overload at the lattice floor must shed visibly"
        );
    }
}

/// Downshift composed with forced live migration: seeded moves injected
/// *during* a downshifting episode must not break any disposition,
/// lattice, or FIFO invariant (the pacing loop reproduces
/// `serve_open_loop` by hand because migration needs `&mut` access
/// between submissions).
#[test]
fn forced_migrations_during_downshift_preserve_invariants() {
    for seed in seeds() {
        let mut rng = Xoshiro256::new(seed);
        let n = 160;
        let stream = tiered_overload_stream(n, seed);
        let schedule = overload_schedule(20_000.0, n, seed);
        let victims = [
            workloads::tier_artifact(Tier::F32, 128),
            workloads::tier_artifact(Tier::Int8, 128),
        ];

        let cfg = ServeConfig::new(2)
            .with_admission(AdmissionMode::Degrade)
            .with_admission_limit(4)
            .with_tier_policy(TierPolicy::DownshiftOnPressure);
        let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
        let mut forced = 0usize;
        let t0 = Instant::now();
        for (id, (artifact, at)) in stream.into_iter().zip(&schedule).enumerate() {
            while t0.elapsed().as_secs_f64() < *at {
                std::hint::spin_loop();
            }
            if rng.below(16) == 0 {
                let victim = &victims[rng.below(2) as usize];
                let target = rng.below(2) as usize;
                forced += usize::from(srv.migrate(victim, target).is_some());
            }
            srv.submit(Request { id: id as u64, artifact });
        }
        let out = srv.finish();

        assert_dispositions_reconcile(&out, n, seed);
        assert_downshifts_walk_the_lattice(&out.responses, seed);
        assert_served_fifo(&out.responses, seed);
        assert_eq!(out.metrics.failed, 0, "seed {seed:#x}");
        assert!(
            out.metrics.migrations.len() >= forced,
            "seed {seed:#x}: log must cover every forced move ({} < {forced})",
            out.metrics.migrations.len()
        );
    }
}

/// The pinned-policy control: the same tiered overload under the default
/// `TierPolicy::Pinned` never crosses tiers — every degradation shrinks
/// the shape inside its own tier, so the two degrade axes stay disjoint.
#[test]
fn pinned_policy_keeps_every_tier_in_place() {
    for seed in seeds() {
        let n = 120;
        let stream = tiered_overload_stream(n, seed);
        let schedule = overload_schedule(200_000.0, n, seed);

        let cfg = ServeConfig::new(2)
            .with_admission(AdmissionMode::Degrade)
            .with_admission_limit(4); // TierPolicy::Pinned default
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_open_loop(stream.into_iter(), &schedule);

        assert_dispositions_reconcile(&out, n, seed);
        for r in out.responses.iter().filter(|r| r.degraded_from.is_some()) {
            let from = r.degraded_from.as_deref().unwrap();
            let (from_tier, from_n) = workloads::synthetic_tier(from).unwrap();
            let (to_tier, to_n) = workloads::synthetic_tier(&r.artifact).unwrap();
            assert_eq!(to_tier, from_tier, "seed {seed:#x}: pinned must not cross tiers: {r:?}");
            assert!(to_n < from_n, "seed {seed:#x}: pinned degrade shrinks the shape: {r:?}");
        }
    }
}

/// The CLI surface: `cachebound serve --tiers --tier-policy downshift`
/// runs the tiered menu end to end and reports its tier policy; an
/// unknown policy is rejected loudly.
#[test]
fn cli_serve_tier_flags_round_trip() {
    use std::process::Command;

    let exe = env!("CARGO_BIN_EXE_cachebound");
    let out = Command::new(exe)
        .args([
            "serve",
            "--synthetic",
            "--workers",
            "2",
            "--requests",
            "48",
            "--tiers",
            "--tier-policy",
            "downshift",
            "--arrival-rate",
            "400",
            "--admission",
            "degrade",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "tiered serve must exit 0 (downshifts are not failures): {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("tier policy downshift"), "{stdout}");

    let bad = Command::new(exe)
        .args(["serve", "--synthetic", "--requests", "4", "--tier-policy", "sideways"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("tier policy"));
}
