//! End-to-end tests of the persistent compiled-artifact cache through the
//! real binary: `cache warmup` fills the store, a `serve --cache-dir` run
//! against it performs zero compiles, `cache prune` enforces a byte
//! budget, and `cache doctor` reports counts consistent with all of it.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::Command;

fn temp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cachebound_serve_cache_{name}"));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn run(args: &[&str], cache_dir: &Path) -> String {
    let exe = env!("CARGO_BIN_EXE_cachebound");
    let out = Command::new(exe).args(args).arg(cache_dir).output().unwrap();
    assert!(
        out.status.success(),
        "`cachebound {}` failed:\n{}{}",
        args.join(" "),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// The integer immediately following `prefix` on the first line that
/// contains it.
fn count_after(stdout: &str, prefix: &str) -> u64 {
    let line = stdout
        .lines()
        .find(|l| l.contains(prefix))
        .unwrap_or_else(|| panic!("no line contains {prefix:?} in:\n{stdout}"));
    let rest = &line[line.find(prefix).unwrap() + prefix.len()..];
    rest.split_whitespace()
        .next()
        .and_then(|w| w.parse().ok())
        .unwrap_or_else(|| panic!("no integer after {prefix:?} in {line:?}"))
}

/// The integer immediately preceding `suffix` on the first line that
/// contains it (e.g. `count_before(doc, " entries,")` on the doctor line
/// "cache <root>: 5 entries, 396593 bytes resident, 0 quarantined").
fn count_before(stdout: &str, suffix: &str) -> u64 {
    let line = stdout
        .lines()
        .find(|l| l.contains(suffix))
        .unwrap_or_else(|| panic!("no line contains {suffix:?} in:\n{stdout}"));
    let head = &line[..line.find(suffix).unwrap()];
    head.split_whitespace()
        .last()
        .and_then(|w| w.parse().ok())
        .unwrap_or_else(|| panic!("no integer before {suffix:?} in {line:?}"))
}

/// The tentpole acceptance path: a cold serve compiles and stores, the
/// second start against the warm cache performs zero compiles — every
/// first-touch prep is a disk hit.
#[test]
fn second_serve_start_performs_zero_compiles() {
    let cache = temp_root("zero_compiles");
    let serve = [
        "serve",
        "--synthetic",
        "--workers",
        "2",
        "--requests",
        "96",
        "--cache-dir",
    ];
    let cold = run(&serve, &cache);
    let cold_compiled = count_after(&cold, "artifact prep: compiled ");
    let cold_loaded = count_after(&cold, "loaded ");
    assert!(cold_compiled > 0, "cold start must compile:\n{cold}");
    assert_eq!(cold_loaded, 0, "nothing to load on a cold start:\n{cold}");

    let warm = run(&serve, &cache);
    let warm_compiled = count_after(&warm, "artifact prep: compiled ");
    let warm_loaded = count_after(&warm, "loaded ");
    assert_eq!(warm_compiled, 0, "warm start must not compile:\n{warm}");
    assert_eq!(
        warm_loaded, cold_compiled,
        "same seed, same artifacts — every cold compile is a warm load:\n{warm}"
    );
    assert!(warm.contains("disk-warmed"), "per-artifact prep lines:\n{warm}");

    // doctor agrees: one resident entry per cold compile, and the warm
    // run's loads registered as lifetime hits
    let doc = run(&["cache", "doctor", "--cache-dir"], &cache);
    assert_eq!(
        count_before(&doc, " entries,"),
        cold_compiled,
        "one cache entry per compiled artifact:\n{doc}"
    );
    assert!(
        count_before(&doc, " hits /") >= warm_loaded,
        "warm loads are lifetime hits:\n{doc}"
    );
    let _ = fs::remove_dir_all(&cache);
}

/// `cache warmup --synthetic` pre-fills the store so even the *first*
/// serve start is warm, and a repeated warmup is a no-op.
#[test]
fn warmup_makes_the_first_serve_start_warm() {
    let cache = temp_root("warmup");
    let wu = run(&["cache", "warmup", "--synthetic", "--cache-dir"], &cache);
    let stored = count_after(&wu, "warmup (synthetic native-GEMM mix): ");
    assert_eq!(stored, 5, "the f32 serving mix has five artifacts:\n{wu}");

    let again = run(&["cache", "warmup", "--synthetic", "--cache-dir"], &cache);
    assert_eq!(
        count_after(&again, "warmup (synthetic native-GEMM mix): "),
        0,
        "second warmup stores nothing:\n{again}"
    );
    assert_eq!(count_after(&again, "stored, "), 5, "all five already warm:\n{again}");

    let serve = run(
        &[
            "serve",
            "--synthetic",
            "--workers",
            "2",
            "--requests",
            "64",
            "--cache-dir",
        ],
        &cache,
    );
    assert_eq!(
        count_after(&serve, "artifact prep: compiled "),
        0,
        "warmed cache makes the first start compile-free:\n{serve}"
    );
    let _ = fs::remove_dir_all(&cache);
}

/// `cache prune --max-bytes` deterministically enforces the budget:
/// dry-run lists victims without deleting, the real run evicts
/// least-recently-used entries down to the budget, and doctor reflects
/// the post-prune state.
#[test]
fn prune_enforces_the_byte_budget_and_doctor_agrees() {
    let cache = temp_root("prune");
    run(&["cache", "warmup", "--synthetic", "--cache-dir"], &cache);

    let resident =
        |out: &str| -> u64 { count_before(out, " bytes resident") };
    let before = resident(&run(&["cache", "doctor", "--cache-dir"], &cache));
    // five f32 payloads (three n² tensors each, n up to 128) comfortably
    // exceed the budget, while the largest single payload fits under it
    let budget = "250000";
    assert!(before > 250_000, "mix payload exceeds the budget ({before} bytes)");

    // dry run: victims listed, nothing deleted
    let dry = run(
        &["cache", "prune", "--max-bytes", budget, "--dry-run", "--cache-dir"],
        &cache,
    );
    assert!(dry.contains("would evict"), "{dry}");
    assert!(dry.contains("(dry run)"), "{dry}");
    assert_eq!(
        resident(&run(&["cache", "doctor", "--cache-dir"], &cache)),
        before,
        "dry run must not delete"
    );

    // the real prune: budget enforced, doctor consistent
    let pruned = run(&["cache", "prune", "--max-bytes", budget, "--cache-dir"], &cache);
    assert!(pruned.contains("evicted"), "{pruned}");
    let after = resident(&run(&["cache", "doctor", "--cache-dir"], &cache));
    assert!(after <= 250_000, "budget enforced: {after} bytes resident");
    assert!(after > 0, "the most recently stored payload fits the budget");

    // pruning is deterministic: the same budget again evicts nothing
    let again = run(&["cache", "prune", "--max-bytes", budget, "--cache-dir"], &cache);
    assert!(again.contains("0 victim(s)"), "already under budget:\n{again}");
    let _ = fs::remove_dir_all(&cache);
}
