//! End-to-end tests of the roofline bench harness: a real (quick,
//! synthetic) sweep through the multi-worker coordinator, the BENCH.json
//! schema roundtrip, and the `cachebound bench compare` regression gate —
//! including the process exit code CI relies on.

use std::fs;
use std::path::PathBuf;
use std::process::Command;

use cachebound::bench::{compare, run_sweep, BenchReport, SweepConfig, DEFAULT_THRESHOLD_PCT};
use cachebound::coordinator::pipeline::{Pipeline, PipelineConfig};

fn quick_pipeline() -> Pipeline {
    Pipeline::new(PipelineConfig {
        n_workers: 2,
        tune_trials: 4,
        skip_native: true,
        native_max_n: 0,
    })
}

fn quick_report() -> BenchReport {
    let cfg = SweepConfig {
        profiles: vec!["a53".into(), "a72".into()],
        ..SweepConfig::new(true, true)
    };
    run_sweep(&mut quick_pipeline(), &cfg).unwrap()
}

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cachebound_bench_gate_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn sweep_roundtrips_through_bench_json() {
    let report = quick_report();
    assert!(!report.records.is_empty());
    assert_eq!(report.hw.len(), 2);
    // every record scored: positive time, a class, bound lines ordered
    for r in &report.records {
        assert!(r.measured_s > 0.0, "{}", r.key);
        assert!(!r.class.is_empty(), "{}", r.key);
        assert!(r.l1_read_s < r.l2_read_s && r.l2_read_s < r.ram_read_s, "{}", r.key);
        // serving records (servedrift: MRC-predicted per-request times;
        // servslo/servtier/servadm: 1/max-sustainable-rate; servcache:
        // total startup time) are not bound-line measurements — the ≤105%
        // clamp only applies to the operator grid
        if r.family != "servedrift"
            && r.family != "servslo"
            && r.family != "servtier"
            && r.family != "servcache"
            && r.family != "servadm"
        {
            assert!(
                r.pct_of_bound > 0.0 && r.pct_of_bound <= 105.0,
                "{}: {}",
                r.key,
                r.pct_of_bound
            );
        }
    }
    // the serving records ride in the same report (both profiles swept;
    // only the A53 pair qualifies)
    assert_eq!(
        report.records.iter().filter(|r| r.family == "servedrift").count(),
        2
    );
    assert_eq!(
        report.records.iter().filter(|r| r.family == "servslo").count(),
        2
    );
    // the quantized-tier A/B qualifies on both profiles: two legs each
    assert_eq!(
        report.records.iter().filter(|r| r.family == "servtier").count(),
        4
    );
    // so does the cold-vs-warm artifact-cache A/B
    assert_eq!(
        report.records.iter().filter(|r| r.family == "servcache").count(),
        4
    );
    // and the admission-concurrency A/B (1t vs 4t, both profiles)
    assert_eq!(
        report.records.iter().filter(|r| r.family == "servadm").count(),
        4
    );
    let dir = temp_dir("roundtrip");
    let path = dir.join("BENCH.json");
    report.save(&path).unwrap();
    let loaded = BenchReport::load(&path).unwrap();
    assert_eq!(report, loaded, "save/load must be lossless");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn injected_2x_slowdown_fails_compare() {
    let base = quick_report();
    let mut slow = base.clone();
    for r in &mut slow.records {
        if r.family == "gemm" {
            r.measured_s *= 2.0;
        }
    }
    let rep = compare(&base, &slow, DEFAULT_THRESHOLD_PCT);
    assert!(!rep.passed());
    assert_eq!(
        rep.regressions.len(),
        base.records.iter().filter(|r| r.family == "gemm").count()
    );
    // untouched families did not move
    assert!(rep.regressions.iter().all(|d| d.key.contains("/gemm/")));
}

/// The contract the `bench-smoke` CI job gates on: the real binary exits 0
/// on a clean comparison and non-zero on an injected regression.
#[test]
fn cli_compare_exit_codes() {
    let base = quick_report();
    let mut slow = base.clone();
    slow.records[0].measured_s *= 2.0;

    let dir = temp_dir("cli");
    let base_path = dir.join("base.json");
    let slow_path = dir.join("slow.json");
    base.save(&base_path).unwrap();
    slow.save(&slow_path).unwrap();

    let exe = env!("CARGO_BIN_EXE_cachebound");
    let ok = Command::new(exe)
        .args(["bench", "compare"])
        .arg(&base_path)
        .arg(&base_path)
        .output()
        .unwrap();
    assert!(
        ok.status.success(),
        "identical reports must pass: {}",
        String::from_utf8_lossy(&ok.stderr)
    );

    let bad = Command::new(exe)
        .args(["bench", "compare"])
        .arg(&base_path)
        .arg(&slow_path)
        .output()
        .unwrap();
    assert!(!bad.status.success(), "2x slowdown must exit non-zero");
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("regressed"),
        "stderr: {}",
        String::from_utf8_lossy(&bad.stderr)
    );

    // a generous threshold waves the same slowdown through
    let waved = Command::new(exe)
        .args(["bench", "compare"])
        .arg(&base_path)
        .arg(&slow_path)
        .args(["--threshold", "150"])
        .output()
        .unwrap();
    assert!(waved.status.success());
    let _ = fs::remove_dir_all(&dir);
}

/// The committed CI baseline must always be loadable by the current schema.
#[test]
fn committed_baseline_parses() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../bench/baseline.json");
    let baseline = BenchReport::load(path).unwrap();
    // comparing any run against the committed baseline must never fail the
    // gate spuriously (empty or matching grids both pass)
    let rep = compare(&baseline, &quick_report(), DEFAULT_THRESHOLD_PCT);
    assert!(rep.passed(), "{}", rep.render());
}
