//! End-to-end tests of cache-aware placement (PR 4 acceptance criteria):
//! the adversarial two-artifact mix is split across workers while hash
//! placement co-locates it, solo interference predictions agree exactly
//! with `analysis::predict`, and `cachebound serve --placement cache-aware`
//! runs the synthetic mix end to end through the real binary.

use std::collections::BTreeMap;
use std::process::Command;
use std::sync::{Arc, OnceLock};

use cachebound::analysis::InterferenceModel;
use cachebound::coordinator::placement::{adversarial_mix, plan};
use cachebound::coordinator::server::{
    Request, ServeConfig, ShardedServer, SyntheticExecutor,
};
use cachebound::coordinator::{shard_for, PlacementPolicy};
use cachebound::hw::profile_by_name;
use cachebound::telemetry::{serving_mix_profiles, CacheProfile};

/// The adversarial pair is traced once per test binary (replays are the
/// slow part of these tests).
fn adversarial() -> &'static Vec<(String, CacheProfile)> {
    static ADV: OnceLock<Vec<(String, CacheProfile)>> = OnceLock::new();
    ADV.get_or_init(|| {
        let cpu = profile_by_name("a53").unwrap().cpu;
        adversarial_mix(&cpu, 2, 8).expect("qualifying pair on the A53")
    })
}

fn mix_profiles() -> Arc<BTreeMap<String, CacheProfile>> {
    serving_mix_profiles(&profile_by_name("a53").unwrap().cpu)
}

/// The adversarial pair is real on the A53: hash co-locates it, demands
/// straddle the L2, and the greedy plan splits it — while on the uniform
/// serving mix the plan covers every artifact with finite cost.
#[test]
fn adversarial_mix_splits_but_uniform_mix_stays_covered() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let model = InterferenceModel::new(&cpu);

    let adv = adversarial();
    let (na, pa) = &adv[0];
    let (nb, pb) = &adv[1];
    assert_eq!(
        shard_for(na, 8) % 2,
        shard_for(nb, 8) % 2,
        "hash must co-locate the adversarial pair"
    );
    let l2 = cpu.l2.size_bytes as u64;
    assert!(model.demand_bytes(pa) + model.demand_bytes(pb) > l2);

    let adv_map: BTreeMap<String, CacheProfile> =
        adv.iter().cloned().collect();
    let placement = plan(&model, &adv_map, 2);
    assert_ne!(placement.worker_for(na), placement.worker_for(nb), "{placement:?}");
    // split predicted cost is within noise of interference-free...
    assert!(placement.total_slowdown < 2.0 + 1e-6, "{}", placement.total_slowdown);
    // ...and never worse than forcing both onto one worker
    assert!(placement.total_slowdown <= model.total_slowdown(&[pa, pb]) + 1e-12);

    let profiles = mix_profiles();
    let uniform = plan(&model, &profiles, 2);
    assert_eq!(uniform.assignments.len(), profiles.len());
    assert!(uniform.total_slowdown.is_finite());
    assert!(uniform.total_slowdown >= profiles.len() as f64 - 1e-9);
}

/// Serving the adversarial stream through real servers: hash leaves one
/// worker idle (both artifacts on one), cache-aware uses both.
#[test]
fn adversarial_stream_uses_both_workers_only_under_cache_aware() {
    let cpu = profile_by_name("a53").unwrap().cpu;
    let adv = adversarial();
    let profiles: Arc<BTreeMap<String, CacheProfile>> =
        Arc::new(adv.iter().cloned().collect());
    let stream: Vec<String> = (0..24).map(|i| adv[i % 2].0.clone()).collect();

    let workers_used = |placement: PlacementPolicy| -> usize {
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_profiles(profiles.clone())
                .with_placement(placement)
                .with_cpu(cpu.clone()),
            |_w| Ok(SyntheticExecutor::new()),
        );
        for (id, artifact) in stream.iter().enumerate() {
            srv.submit(Request { id: id as u64, artifact: artifact.clone() });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.completed, stream.len() as u64);
        out.metrics
            .worker_pressure
            .iter()
            .filter(|p| p.artifacts > 0)
            .count()
    };

    assert_eq!(workers_used(PlacementPolicy::Hash), 1, "hash co-locates the pair");
    assert_eq!(workers_used(PlacementPolicy::CacheAware), 2, "the plan splits it");
}

/// The acceptance criterion's CLI path: `cachebound serve --synthetic
/// --placement cache-aware` runs the synthetic mix end to end and prints
/// the plan plus predicted-vs-observed pressure.
#[test]
fn cli_serve_cache_aware_runs_end_to_end() {
    let exe = env!("CARGO_BIN_EXE_cachebound");
    let out = Command::new(exe)
        .args([
            "serve",
            "--synthetic",
            "--workers",
            "2",
            "--requests",
            "48",
            "--placement",
            "cache-aware",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "serve must exit 0: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("cache-aware placement"), "{stdout}");
    assert!(stdout.contains("Cache-aware placement plan"), "{stdout}");
    assert!(stdout.contains("predicted"), "{stdout}");
    assert!(stdout.contains("served 48/48"), "{stdout}");

    // an unknown policy is rejected loudly
    let bad = Command::new(exe)
        .args(["serve", "--synthetic", "--requests", "4", "--placement", "nope"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("placement"));
}
