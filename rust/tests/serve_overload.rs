//! Overload chaos harness (DESIGN.md §Admission).
//!
//! The open-loop serving layer claims three invariants survive *any*
//! seeded overload episode: every submitted request gets exactly one
//! disposition (served, shed, or degraded — never silently dropped),
//! the admission layer bounds the queue (`Shed`) or visibly fails to
//! (`None`), and per-artifact FIFO holds among the served requests.
//! This suite attacks the claim with seeded arrival schedules — Poisson
//! base rates far past capacity, flash crowds injected at seeded points
//! — driven wall-clock through `serve_open_loop`, composed with forced
//! live migrations mid-overload.
//!
//! Seeds: every chaos test runs once per seed in
//! `OVERLOAD_CHAOS_SEEDS` (comma-separated, `0x` hex or decimal;
//! default two seeds).  CI re-runs the suite with a 4-seed matrix.
//!
//! The artifacts are the large synthetic GEMMs (n96/n128, ms-scale
//! native execution on any host), so a µs-scale arrival schedule is
//! overload by construction — the assertions hold on fast and slow
//! hosts alike because they compare dispositions and depth bounds, not
//! wall-clock figures.

use std::collections::HashMap;
use std::time::Instant;

use cachebound::coordinator::server::{
    AdmissionMode, Request, Response, ServeConfig, ServeOutcome, ShardedServer,
    SyntheticExecutor,
};
use cachebound::coordinator::ArrivalConfig;
use cachebound::operators::workloads;
use cachebound::util::rng::Xoshiro256;

/// The chaos seed matrix: `OVERLOAD_CHAOS_SEEDS` (comma-separated,
/// decimal or `0x` hex), defaulting to two seeds so the suite is cheap
/// in a plain `cargo test` and broad in CI.
fn seeds() -> Vec<u64> {
    match std::env::var("OVERLOAD_CHAOS_SEEDS") {
        Ok(raw) => raw
            .split(',')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.strip_prefix("0x")
                    .map(|h| u64::from_str_radix(h, 16))
                    .unwrap_or_else(|| s.parse())
                    .unwrap_or_else(|e| panic!("bad chaos seed '{s}': {e}"))
            })
            .collect(),
        Err(_) => vec![0xF00D, 0xBEEF42],
    }
}

/// An overload stream: the two largest synthetic GEMMs, alternating —
/// ms-scale service times against the µs-scale arrival schedules below.
fn overload_stream(n: usize, seed: u64) -> Vec<String> {
    let pair = [workloads::synthetic_artifact(96), workloads::synthetic_artifact(128)];
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| pair[rng.below(2) as usize].clone()).collect()
}

/// A schedule far past capacity: base Poisson at `rate` req/s with a
/// seeded flash crowd on top.
fn overload_schedule(rate: f64, n: usize, seed: u64) -> Vec<f64> {
    ArrivalConfig::poisson(rate, n, seed)
        .with_flash(1, 3.0, 0.002)
        .schedule()
}

/// Every submitted request got exactly one disposition, and every
/// disposition left a latency sample — the "never silent" invariant.
fn assert_dispositions_reconcile(out: &ServeOutcome, n: usize, seed: u64) {
    let m = &out.metrics;
    assert_eq!(m.requests, n as u64, "seed {seed:#x}");
    assert_eq!(
        m.completed + m.failed + m.shed,
        m.requests,
        "seed {seed:#x}: served + failed + shed must cover every request"
    );
    assert!(m.degraded <= m.completed, "seed {seed:#x}: degraded requests are served");
    assert_eq!(
        m.latency_seconds.len(),
        m.requests as usize,
        "seed {seed:#x}: every disposition must leave a latency sample"
    );
    let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(
        ids,
        (0..n as u64).collect::<Vec<_>>(),
        "seed {seed:#x}: dropped or duplicated responses"
    );
}

/// Per-artifact FIFO among the *served* responses (sheds are emitted at
/// the front door and do not join any queue).
fn assert_served_fifo(responses: &[Response], seed: u64) {
    let mut per_artifact: HashMap<&str, Vec<u64>> = HashMap::new();
    for r in responses.iter().filter(|r| r.ok) {
        per_artifact.entry(r.artifact.as_str()).or_default().push(r.id);
    }
    for (artifact, ids) in per_artifact {
        assert!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "seed {seed:#x}: FIFO violated for {artifact}: {ids:?}"
        );
    }
}

/// The core overload property: under `Shed`, a seeded flash-crowd
/// schedule far past capacity sheds visibly, keeps the in-flight queue
/// within `workers x limit`, and every disposition reconciles.
#[test]
fn shed_bounds_the_queue_under_seeded_overload() {
    for seed in seeds() {
        let mut rng = Xoshiro256::new(seed);
        let workers = 2usize;
        let limit = 4 + rng.below(5) as usize; // 4..=8
        let n = 240;
        let stream = overload_stream(n, seed);
        let schedule = overload_schedule(200_000.0, n, seed);

        let cfg = ServeConfig::new(workers)
            .with_admission(AdmissionMode::Shed)
            .with_admission_limit(limit);
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_open_loop(stream.into_iter(), &schedule);

        assert_dispositions_reconcile(&out, n, seed);
        assert_served_fifo(&out.responses, seed);
        let m = &out.metrics;
        assert_eq!(m.failed, 0, "seed {seed:#x}: sheds are not failures");
        assert!(
            m.shed > 0,
            "seed {seed:#x}: a 200k req/s burst into ms-scale service must shed"
        );
        assert!(
            m.max_queue_depth() <= (workers * limit) as u64,
            "seed {seed:#x}: depth {} exceeds the admission bound {}",
            m.max_queue_depth(),
            workers * limit
        );
        // shed responses are loud: not ok, flagged, and say why
        for r in out.responses.iter().filter(|r| r.shed) {
            assert!(!r.ok, "seed {seed:#x}: {r:?}");
            assert!(
                r.error.as_deref().is_some_and(|e| e.contains("shed")),
                "seed {seed:#x}: {r:?}"
            );
            assert!(r.latency_seconds >= 0.0, "seed {seed:#x}: {r:?}");
        }
    }
}

/// The control experiment: the same overload with admission off serves
/// everything eventually — and the queue-depth series records the
/// unbounded growth the admission layer exists to prevent.
#[test]
fn none_mode_records_unbounded_queue_growth() {
    for seed in seeds() {
        let workers = 2usize;
        let limit = 8usize; // the bound the Shed run would have enforced
        let n = 240;
        let stream = overload_stream(n, seed);
        let schedule = overload_schedule(200_000.0, n, seed);

        let cfg = ServeConfig::new(workers); // AdmissionMode::None default
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_open_loop(stream.into_iter(), &schedule);

        assert_dispositions_reconcile(&out, n, seed);
        let m = &out.metrics;
        assert_eq!(m.completed, n as u64, "seed {seed:#x}: nothing is refused");
        assert_eq!(m.shed, 0, "seed {seed:#x}");
        assert!(
            m.max_queue_depth() > (4 * workers * limit) as u64,
            "seed {seed:#x}: open-loop overload without admission must pile up \
             far past the Shed bound (depth {})",
            m.max_queue_depth()
        );
    }
}

/// `Degrade` under the same overload: excess requests are served as the
/// next-smaller GEMM variant instead of dropped — every degraded
/// response is an *ok* response that names its original artifact.
#[test]
fn degrade_serves_smaller_variants_under_overload() {
    for seed in seeds() {
        let n = 160;
        // all-n128 stream so every degradation is the n128 -> n96 step
        let big = workloads::synthetic_artifact(128);
        let stream: Vec<String> = (0..n).map(|_| big.clone()).collect();
        let schedule = overload_schedule(200_000.0, n, seed);

        let cfg = ServeConfig::new(2)
            .with_admission(AdmissionMode::Degrade)
            .with_admission_limit(4);
        let out = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()))
            .serve_open_loop(stream.into_iter(), &schedule);

        assert_dispositions_reconcile(&out, n, seed);
        let m = &out.metrics;
        assert_eq!(m.failed, 0, "seed {seed:#x}");
        assert!(
            m.degraded > 0,
            "seed {seed:#x}: overload past the limit must degrade something"
        );
        for r in out.responses.iter().filter(|r| r.degraded_from.is_some()) {
            assert!(r.ok, "seed {seed:#x}: degraded requests are served: {r:?}");
            assert_eq!(r.degraded_from.as_deref(), Some(big.as_str()), "seed {seed:#x}");
            assert_eq!(r.artifact, workloads::synthetic_artifact(96), "seed {seed:#x}");
        }
    }
}

/// Overload composed with live migration: forced moves injected at
/// seeded points *during* a shedding episode must not break any
/// disposition or FIFO invariant (the pacing loop reproduces
/// `serve_open_loop` by hand because migration needs `&mut` access
/// between submissions).
#[test]
fn forced_migrations_during_overload_preserve_invariants() {
    for seed in seeds() {
        let mut rng = Xoshiro256::new(seed);
        let n = 160;
        let stream = overload_stream(n, seed);
        let schedule = overload_schedule(20_000.0, n, seed);
        let pair = [workloads::synthetic_artifact(96), workloads::synthetic_artifact(128)];

        let cfg = ServeConfig::new(2)
            .with_admission(AdmissionMode::Shed)
            .with_admission_limit(4);
        let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
        let mut forced = 0usize;
        let t0 = Instant::now();
        for (id, (artifact, at)) in stream.into_iter().zip(&schedule).enumerate() {
            while t0.elapsed().as_secs_f64() < *at {
                std::hint::spin_loop();
            }
            if rng.below(16) == 0 {
                let victim = &pair[rng.below(2) as usize];
                let target = rng.below(2) as usize;
                forced += usize::from(srv.migrate(victim, target).is_some());
            }
            srv.submit(Request { id: id as u64, artifact });
        }
        let out = srv.finish();

        assert_dispositions_reconcile(&out, n, seed);
        assert_served_fifo(&out.responses, seed);
        assert_eq!(out.metrics.failed, 0, "seed {seed:#x}");
        assert!(
            out.metrics.migrations.len() >= forced,
            "seed {seed:#x}: log must cover every forced move ({} < {forced})",
            out.metrics.migrations.len()
        );
    }
}

/// The CLI surface: `cachebound serve --arrival-rate ... --admission
/// shed` runs open-loop end to end, reports its admission mode and an
/// SLO verdict; an unknown admission mode is rejected loudly.
#[test]
fn cli_serve_open_loop_flags_round_trip() {
    use std::process::Command;

    let exe = env!("CARGO_BIN_EXE_cachebound");
    let out = Command::new(exe)
        .args([
            "serve",
            "--synthetic",
            "--workers",
            "2",
            "--requests",
            "64",
            "--arrival-rate",
            "400",
            "--slo-ms",
            "50",
            "--admission",
            "shed",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "open-loop serve must exit 0 (sheds are not failures): {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("admission shed"), "{stdout}");
    assert!(stdout.contains("SLO:"), "{stdout}");

    let bad = Command::new(exe)
        .args(["serve", "--synthetic", "--requests", "4", "--admission", "maybe"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("admission"));
}
