//! `cachebound` — CLI for the cache-boundness reproduction.
//!
//! One subcommand per paper artifact plus utilities:
//!
//! ```text
//! cachebound profiles                     list hardware profiles
//! cachebound membench [--quick]           host bandwidth sweep (Tables I/II analog)
//! cachebound peak [--threads N]           host FMA peak (eq. 1 verification)
//! cachebound table1|table2 [--host]       bandwidth tables (calibrated [+ host])
//! cachebound table4|table5                GEMM performance tables
//! cachebound fig1..fig9 [--profile P]     figure data series (CSV under results/)
//! cachebound validate                     run every AOT artifact through PJRT
//! cachebound bench [--quick] [--synthetic] [--telemetry]   roofline sweep -> BENCH.json
//! cachebound bench compare a.json b.json  perf-regression gate (CI)
//! cachebound trace <family> [flags] [--json PATH]   reuse histograms + MRC + prediction
//! cachebound figmrc [--profile P] [--n N] miss-ratio-curve figure (CSV)
//! cachebound serve --workers N [--placement cache-aware] [--arrival-rate RPS --admission shed]
//!                                         sharded multi-worker serving (open-loop + admission)
//! cachebound cache warmup|doctor|prune [--cache-dir DIR]   persistent compiled-artifact cache
//! cachebound tune --n N [--profile P] [--tuner gbt|random] [--trials T]
//! cachebound report-all [--out DIR]       everything: tables, figures, CSVs
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use cachebound::bench::{self, BenchReport};
use cachebound::coordinator::pipeline::{Pipeline, PipelineConfig};
use cachebound::coordinator::server::{
    AdmissionMode, BatchPolicy, Executor, PjrtExecutor, PrepSource, ServeConfig, ShardedServer,
    SyntheticExecutor, TierPolicy,
};
use cachebound::coordinator::{ArrivalConfig, PlacementPolicy, RebalanceMode};
use cachebound::hw::{builtin_profiles, profile_by_name};
use cachebound::membench;
use cachebound::operators::workloads::{self, BenchWorkload};
use cachebound::report;
use cachebound::runtime::{ArtifactCache, Manifest, Registry};
use cachebound::telemetry::{self, TraceBudget};
use cachebound::tuner;
use cachebound::util::table::{fmt_gflops, fmt_mibs, fmt_time, Align, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal `--flag value` / `--flag=value` / `--flag` parser; non-flag
/// tokens (that are not a flag's value) are collected as positionals.
struct Opts {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Opts {
    fn parse(args: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(name) = args[i].strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else {
                    let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                        i += 1;
                        args[i].clone()
                    } else {
                        "true".to_string()
                    };
                    flags.insert(name.to_string(), val);
                }
            } else {
                positional.push(args[i].clone());
            }
            i += 1;
        }
        Opts { flags, positional }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn profile(&self, default: &str) -> String {
        self.get("profile").unwrap_or(default).to_string()
    }
}

fn pipeline_from(opts: &Opts) -> Result<Pipeline> {
    let mut cfg = PipelineConfig {
        skip_native: opts.has("skip-native"),
        ..PipelineConfig::default()
    };
    cfg.tune_trials = opts.usize("trials", cfg.tune_trials)?;
    let mut p = Pipeline::new(cfg);
    if !opts.has("no-artifacts") {
        if let Ok(reg) = Registry::open(artifacts_dir(opts)) {
            p = p.with_registry(reg);
        }
    }
    Ok(p)
}

fn artifacts_dir(opts: &Opts) -> String {
    opts.get("artifacts").unwrap_or("artifacts").to_string()
}

fn results_dir(opts: &Opts) -> String {
    opts.get("out").unwrap_or("results").to_string()
}

fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let opts = Opts::parse(&args[1.min(args.len())..]);
    match cmd {
        "profiles" => cmd_profiles(),
        "membench" => cmd_membench(&opts),
        "peak" => cmd_peak(&opts),
        "table1" => cmd_bandwidth_table(&opts, "a53"),
        "table2" => cmd_bandwidth_table(&opts, "a72"),
        "table4" => cmd_gemm_table(&opts, "a53"),
        "table5" => cmd_gemm_table(&opts, "a72"),
        "fig1" => cmd_fig1(&opts),
        "fig2" | "fig3" => cmd_fig23(&opts),
        "fig4" | "fig5" => cmd_fig45(&opts),
        "fig6" | "fig7" | "fig8" => cmd_fig678(&opts),
        "fig9" => cmd_fig9(&opts),
        "validate" => cmd_validate(&opts),
        "bench" => cmd_bench(&args[1..]),
        "trace" => cmd_trace(&opts),
        "figmrc" => cmd_figmrc(&opts),
        "serve" => cmd_serve(&opts),
        "cache" => cmd_cache(&args[1..]),
        "tune" => cmd_tune(&opts),
        "report-all" => cmd_report_all(&opts),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' — try `cachebound help`"),
    }
}

const HELP: &str = "cachebound — reproduction of 'Understanding Cache Boundness of ML Operators on ARM Processors'

commands:
  profiles                    list hardware profiles (Cortex-A53, Cortex-A72)
  membench [--quick]          host bandwidth sweep (RAMspeed analog)
  peak [--threads N]          host FMA peak benchmark (arm-peak analog)
  table1|table2 [--host]      Tables I/II: memory bandwidths
  table4|table5 [--trials T]  Tables IV/V: GEMM float32 GFLOP/s
  fig1 [--profile P]          time-vs-size + hardware bounds (GEMM)
  fig2|fig3 [--profile P]     ResNet-18 conv times / sorted GFLOP/s
  fig4|fig5 [--profile P]     bit-serial GEMM perf / required bandwidth
  fig6|fig7|fig8 [--profile P] quantized conv speedups / bw / GFLOP/s
  fig9 [--profile P]          GEMM GFLOP/s over size (tuned/naive/blas)
  validate [--artifacts DIR]  execute every AOT artifact via PJRT, check checksums
  bench [--quick] [--synthetic] [--profile P] [--out FILE] [--telemetry]
                              roofline sweep of the GEMM/conv/qnn/bit-serial
                              grid; classifies each run against the hardware
                              bound lines and writes BENCH.json
                              (--synthetic = deterministic simulator timing,
                              the CI mode; default = host wallclock;
                              --telemetry = attach per-run reuse/MRC
                              sections, schema v2)
  bench compare BASE.json NEW.json [--threshold PCT]
                              diff two BENCH.json files; exit non-zero when
                              any workload slowed by more than PCT (def. 10)
  trace gemm|conv|qnn|bitserial [--n N] [--layer C2] [--bits B]
        [--profile P] [--rows R] [--json PATH]
                              traced replay through the cache hierarchy:
                              per-operand reuse-distance histograms, the
                              miss-ratio curve + working-set knees, and
                              MRC-predicted vs fully-simulated hit rates
                              and boundness class
  figmrc [--profile P] [--n N] miss-ratio-curve figure data (CSV) for a
                              tuned GEMM, L1/L2 capacities marked
  serve [--workers N] [--cache-entries K] [--requests R] [--seed S]
        [--max-batch B] [--shards M] [--synthetic] [--cache-dir DIR]
        [--placement hash|cache-aware] [--rebalance off|drain|live]
        [--arrival-rate RPS] [--slo-ms MS] [--admission none|shed|degrade]
        [--admission-limit L] [--admission-threads N]
        [--tiers] [--tier-policy pinned|downshift]
                              sharded multi-worker serving over AOT artifacts
                              (falls back to the synthetic native-GEMM mix
                              when artifacts/ is absent or --synthetic is set;
                              synthetic mode attaches telemetry cache profiles
                              and reports per-worker working-set pressure;
                              --placement cache-aware packs artifacts onto
                              workers by predicted co-run slowdown on the
                              shared L2 instead of hashing; --rebalance live
                              migrates artifacts mid-stream when observed
                              pressure diverges from the plan — quiesce,
                              state handoff, atomic route swap — and prints
                              the migration log; drain (default) only
                              suggests a re-plan at exit;
                              --arrival-rate paces submission open-loop on a
                              seeded Poisson schedule instead of closed-loop,
                              reporting p99/p99.9 against --slo-ms (def. 50);
                              --admission shed rejects new work at a
                              per-worker in-flight limit (L, def. 64, halved
                              when the worker's resident set overflows L2),
                              degrade reroutes to a smaller GEMM variant;
                              --admission-threads N > 1 partitions the stream
                              by artifact hash across N admission threads that
                              classify, route and enqueue concurrently against
                              lock-free route-table snapshots (migrations keep
                              their fenced atomic swap);
                              --tiers serves the full precision-tier menu —
                              fp32 + int8 + packed bit-serial twins — so the
                              cache-aware packer can exploit the smaller
                              quantized working sets; --tier-policy downshift
                              makes degrade step down the precision lattice
                              (fp32 -> int8 -> bit-serial) at the same shape
                              instead of shrinking N;
                              --cache-dir attaches the persistent compiled-
                              artifact cache: workers load compiled artifacts
                              from disk instead of compiling, store fresh
                              compiles back, and the summary reports the
                              per-artifact compile/load times)
  cache warmup [--synthetic] [--tiers] [--artifacts DIR] [--cache-dir DIR]
  cache doctor [--cache-dir DIR]
  cache prune --max-bytes B [--dry-run] [--cache-dir DIR]
                              persistent compiled-artifact cache (digest-keyed
                              disk store under --cache-dir, default
                              .cachebound-cache): warmup pre-compiles the
                              serving mix — AOT artifacts when a manifest is
                              present, the synthetic native-GEMM mix otherwise
                              (--tiers adds the int8/bit-serial twins) — so
                              the next `serve --cache-dir` start performs zero
                              compiles; doctor prints resident entries/bytes,
                              lifetime hit/miss counters, and per-tier usage;
                              prune evicts least-recently-used entries until
                              resident bytes fit --max-bytes (--dry-run lists
                              the victims without deleting anything)
  tune --n N [--profile P] [--tuner gbt|random] [--trials T]
  report-all [--out DIR]      regenerate every table & figure, write CSVs

common flags: --profile a53|a72  --out DIR  --artifacts DIR  --skip-native";

fn cmd_profiles() -> Result<()> {
    for p in builtin_profiles() {
        let c = &p.cpu;
        println!(
            "{:<12} {}  {:.1} GHz x{}  SIMD {}b  L1 {}KB  L2 {}KB  peak(f32) {} GFLOP/s  [{}]",
            c.name,
            c.soc,
            c.frequency_hz / 1e9,
            c.cores,
            c.simd_bits,
            c.l1.size_bytes / 1024,
            c.l2.size_bytes / 1024,
            fmt_gflops(c.peak_flops(32)),
            p.provenance,
        );
    }
    Ok(())
}

fn cmd_membench(opts: &Opts) -> Result<()> {
    println!("host bandwidth sweep (RAMspeed analog; paper §III-B2)...");
    let extra: Vec<usize> = if opts.has("quick") {
        vec![]
    } else {
        vec![64 << 10, 1 << 20, 4 << 20]
    };
    let pts = membench::bandwidth_sweep(&extra);
    println!("{:>12} {:>14} {:>14}", "block", "read MiB/s", "write MiB/s");
    for p in &pts {
        println!(
            "{:>12} {:>14} {:>14}",
            format!("{} KB", p.block_bytes / 1024),
            fmt_mibs(p.read_bw),
            fmt_mibs(p.write_bw)
        );
    }
    Ok(())
}

fn cmd_peak(opts: &Opts) -> Result<()> {
    let threads = opts.usize(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    println!("host FMA peak ({threads} threads; paper §III-B1 arm-peak analog)...");
    let r = membench::measure_peak(threads, 1.0);
    println!("measured: {} GFLOP/s over {:.2}s", fmt_gflops(r.flops_per_sec), r.seconds);
    Ok(())
}

fn cmd_bandwidth_table(opts: &Opts, profile: &str) -> Result<()> {
    let p = profile_by_name(profile)?;
    let host = if opts.has("host") {
        Some(membench::bandwidth_sweep(&[]))
    } else {
        None
    };
    let (t, csv) = report::bandwidth_table(&p, host.as_deref());
    println!("{}", t.to_markdown());
    let path = format!("{}/table_{}_bandwidth.csv", results_dir(opts), p.cpu.name);
    csv.write(&path)?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_gemm_table(opts: &Opts, profile: &str) -> Result<()> {
    let mut pipeline = pipeline_from(opts)?;
    let sizes = [32, 128, 256, 512, 1024];
    let (t, csv, _) = report::gemm_table(&mut pipeline, profile, &sizes)?;
    println!("{}", t.to_markdown());
    let path = format!("{}/table_gemm_{}.csv", results_dir(opts), profile);
    csv.write(&path)?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_fig1(opts: &Opts) -> Result<()> {
    let profile = opts.profile("a53");
    let mut pipeline = pipeline_from(opts)?;
    let (f, csv) = report::fig1(&mut pipeline, &profile)?;
    let path = format!("{}/fig1_{}.csv", results_dir(opts), profile);
    csv.write(&path)?;
    println!("Fig 1 ({profile}): tuned GEMM best explained by **{}** bound", f.best_bound);
    println!("(paper: {})", report::paper::expectations::FIG1);
    println!("wrote {path}");
    Ok(())
}

fn cmd_fig23(opts: &Opts) -> Result<()> {
    let profile = opts.profile("a53");
    let mut pipeline = pipeline_from(opts)?;
    let (f, csv) = report::fig2_fig3(&mut pipeline, &profile)?;
    let path = format!("{}/fig2_fig3_{}.csv", results_dir(opts), profile);
    csv.write(&path)?;
    println!("Fig 3 ({profile}) — layers by GFLOP/s (desc):");
    for (name, gf) in &f.sorted_perf {
        println!("  {name:<5} {gf:7.2} GFLOP/s");
    }
    println!("(paper: {})", report::paper::expectations::FIG3);
    println!("wrote {path}");
    Ok(())
}

fn cmd_fig45(opts: &Opts) -> Result<()> {
    let profile = opts.profile("a72");
    let mut pipeline = pipeline_from(opts)?;
    let (f, csv4, csv5) = report::fig4_fig5(&mut pipeline, &profile)?;
    let p4 = format!("{}/fig4_{}.csv", results_dir(opts), profile);
    let p5 = format!("{}/fig5_{}.csv", results_dir(opts), profile);
    csv4.write(&p4)?;
    csv5.write(&p5)?;
    let below = f.points.iter().filter(|(.., bw)| *bw < f.l1_bw).count();
    println!(
        "Fig 4/5 ({profile}): {} points; {}/{} required-bw points below the L1 line",
        f.points.len(),
        below,
        f.points.len()
    );
    println!("(paper: {})", report::paper::expectations::FIG5);
    println!("wrote {p4}\nwrote {p5}");
    Ok(())
}

fn cmd_fig678(opts: &Opts) -> Result<()> {
    let profile = opts.profile("a72");
    let mut pipeline = pipeline_from(opts)?;
    let (f, csv6, csv7, csv8) = report::fig6_fig7_fig8(&mut pipeline, &profile)?;
    let p6 = format!("{}/fig6_{}.csv", results_dir(opts), profile);
    let p7 = format!("{}/fig7_{}.csv", results_dir(opts), profile);
    let p8 = format!("{}/fig8_{}.csv", results_dir(opts), profile);
    csv6.write(&p6)?;
    csv7.write(&p7)?;
    csv8.write(&p8)?;
    println!("Fig 6 ({profile}) — speedup over float32:");
    println!("  {:<5} {:>6} {:>8} {:>8} {:>8} {:>8}", "layer", "qnn8", "bs1", "bs2", "bs4", "bs8");
    for r in &f.rows {
        println!(
            "  {:<5} {:>6.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            r.layer,
            r.speedup_qnn(),
            r.speedup_bits(1, true).unwrap_or(f64::NAN),
            r.speedup_bits(2, true).unwrap_or(f64::NAN),
            r.speedup_bits(4, true).unwrap_or(f64::NAN),
            r.speedup_bits(8, true).unwrap_or(f64::NAN),
        );
    }
    println!("(paper: {})", report::paper::expectations::FIG6);
    println!("wrote {p6}\nwrote {p7}\nwrote {p8}");
    Ok(())
}

fn cmd_fig9(opts: &Opts) -> Result<()> {
    let profile = opts.profile("a72");
    let mut pipeline = pipeline_from(opts)?;
    let (f, csv) = report::fig9(&mut pipeline, &profile)?;
    let path = format!("{}/fig9_{}.csv", results_dir(opts), profile);
    csv.write(&path)?;
    println!(
        "Fig 9 ({profile}): tuned tops out at {:.2} GFLOP/s vs theoretical {:.1}",
        f.tuned_gflops.iter().cloned().fold(0.0, f64::max),
        f.peak_gflops
    );
    println!("wrote {path}");
    Ok(())
}

fn cmd_validate(opts: &Opts) -> Result<()> {
    let mut pipeline = pipeline_from(opts)?;
    if pipeline.registry.is_none() {
        bail!("artifacts not found — run `make artifacts` first");
    }
    let results = pipeline.validate_artifacts()?;
    let mut failed = 0;
    for (name, passed) in &results {
        println!("{} {}", if *passed { "PASS" } else { "FAIL" }, name);
        if !passed {
            failed += 1;
        }
    }
    println!("{}/{} artifacts validated", results.len() - failed, results.len());
    if failed > 0 {
        bail!("{failed} artifacts failed validation");
    }
    Ok(())
}

/// `cachebound bench [...]` / `cachebound bench compare a.json b.json`.
fn cmd_bench(args: &[String]) -> Result<()> {
    if args.first().map(String::as_str) == Some("compare") {
        return cmd_bench_compare(&args[1..]);
    }
    let opts = Opts::parse(args);
    let quick = opts.has("quick");
    let synthetic = opts.has("synthetic");
    let out = opts.get("out").unwrap_or("BENCH.json").to_string();
    let mut cfg = bench::SweepConfig::new(quick, synthetic);
    if let Some(p) = opts.get("profile") {
        cfg.profiles = vec![p.to_string()];
    }
    cfg.telemetry = opts.has("telemetry");
    cfg.trace_rows = opts.usize("trace-rows", cfg.trace_rows)?;
    println!(
        "roofline bench: {} mode, {} grid, profiles {:?}{} ...",
        if synthetic { "simulator" } else { "host-native" },
        if quick { "quick" } else { "full" },
        cfg.profiles,
        if cfg.telemetry { ", +telemetry" } else { "" }
    );
    // the sweep needs no artifacts: simulator or native loop nests only
    let mut pipeline = Pipeline::new(PipelineConfig {
        skip_native: true,
        ..PipelineConfig::default()
    });
    let report = bench::run_sweep(&mut pipeline, &cfg)?;

    let mut table = Table::new(
        "Roofline bench — measured vs hardware bounds",
        &["workload", "profile", "time", "GFLOP/s", "class", "% of bound", "% of paper"],
    )
    .align(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Left,
        Align::Right,
        Align::Right,
    ]);
    for r in &report.records {
        table.row(vec![
            format!("{}/{}", r.family, r.shape),
            r.profile.clone(),
            fmt_time(r.measured_s),
            format!("{:.2}", r.gflops),
            r.class.clone(),
            format!("{:.0}%", r.pct_of_bound),
            r.pct_of_paper.map_or_else(|| "-".into(), |p| format!("{p:.0}%")),
        ]);
    }
    println!("{}", table.to_markdown());
    let cache_bound = report
        .records
        .iter()
        .filter(|r| r.class.contains("-read"))
        .count();
    println!(
        "{}/{} workloads classified cache-read bound (paper: GEMM/conv track the L1 line)",
        cache_bound,
        report.records.len()
    );
    if cfg.telemetry {
        let with: Vec<_> = report
            .records
            .iter()
            .filter_map(|r| r.telemetry.as_ref())
            .collect();
        let agree = with
            .iter()
            .filter(|t| t.predicted_class == t.sim_class)
            .count();
        let mean_err: f64 = with
            .iter()
            .map(|t| (t.mrc_l1_hit_rate - t.sim_l1_hit_rate).abs() * 100.0)
            .sum::<f64>()
            / with.len().max(1) as f64;
        println!(
            "telemetry: {}/{} MRC-predicted classes agree with full simulation, \
             mean |L1 hit-rate error| {:.2} p.p.",
            agree,
            with.len(),
            mean_err
        );
    }
    report.save(&out)?;
    println!("wrote {out} ({} records, schema v{})", report.records.len(), report.version);
    Ok(())
}

/// `cachebound bench compare <baseline.json> <new.json> [--threshold PCT]`.
fn cmd_bench_compare(args: &[String]) -> Result<()> {
    let opts = Opts::parse(args);
    let threshold = match opts.get("threshold") {
        Some(v) => v.parse::<f64>()?,
        None => bench::DEFAULT_THRESHOLD_PCT,
    };
    if !threshold.is_finite() || threshold < 0.0 {
        bail!("--threshold must be a percentage >= 0, got {threshold}");
    }
    let [base_path, new_path] = opts.positional.as_slice() else {
        bail!("usage: cachebound bench compare <baseline.json> <new.json> [--threshold PCT]");
    };
    let base = BenchReport::load(base_path)?;
    let new = BenchReport::load(new_path)?;
    let rep = bench::compare(&base, &new, threshold);
    print!("{}", rep.render());
    if !rep.passed() {
        bail!(
            "{} workload(s) regressed more than {threshold}% vs {base_path}",
            rep.regressions.len()
        );
    }
    Ok(())
}

/// `cachebound trace <gemm|conv|qnn|bitserial> [...]`.
fn cmd_trace(opts: &Opts) -> Result<()> {
    let family = opts
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("usage: cachebound trace <gemm|conv|qnn|bitserial> [flags]"))?;
    let layer_of = |name: &str| {
        workloads::layer_by_name(name)
            .ok_or_else(|| anyhow!("unknown Table III layer '{name}' (C2..C11)"))
    };
    let workload = match family {
        "gemm" => BenchWorkload::Gemm { n: opts.usize("n", 256)? },
        "conv" => BenchWorkload::Conv { layer: layer_of(opts.get("layer").unwrap_or("C2"))? },
        "qnn" => BenchWorkload::QnnConv { layer: layer_of(opts.get("layer").unwrap_or("C2"))? },
        "bitserial" => BenchWorkload::Bitserial {
            n: opts.usize("n", 256)?,
            bits: opts.usize("bits", 2)?,
        },
        other => bail!("unknown operator family '{other}' (gemm|conv|qnn|bitserial)"),
    };
    let profile = opts.profile("a53");
    let cpu = profile_by_name(&profile)?.cpu;
    let budget = TraceBudget::new(opts.usize("rows", TraceBudget::default().max_rows)?);
    println!(
        "tracing {} on {} (row budget {}, schedule: tuned defaults)...",
        workload.key_part(),
        cpu.name,
        budget.max_rows
    );
    let r = telemetry::trace_workload(&cpu, &workload, budget);

    println!(
        "\n{} accesses over {} distinct lines (scale x{:.1} to full shape)",
        r.accesses, r.lines_touched, r.scale
    );
    let mut t = Table::new(
        "Per-operand reuse distances (lines)",
        &["operand", "accesses", "cold", "p50"],
    )
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for o in &r.operands {
        t.row(vec![
            o.operand.clone(),
            o.accesses.to_string(),
            o.cold.to_string(),
            o.p50_lines.map_or_else(|| "-".into(), |d| d.to_string()),
        ]);
    }
    println!("{}", t.to_markdown());

    let mut t = Table::new(
        "Miss-ratio curve (working-set knees marked *)",
        &["capacity", "predicted hit rate", ""],
    )
    .align(&[Align::Right, Align::Right, Align::Left]);
    let knee_caps: Vec<u64> = r.knees.iter().map(|k| k.capacity_bytes).collect();
    for &(bytes, rate) in &r.mrc_points {
        let mut marks = String::new();
        if knee_caps.contains(&bytes) {
            marks.push('*');
        }
        if bytes == cpu.l1.size_bytes as u64 {
            marks.push_str(" <- L1");
        }
        if bytes == cpu.l2.size_bytes as u64 {
            marks.push_str(" <- L2");
        }
        t.row(vec![
            format!("{} KiB", bytes / 1024),
            format!("{:.2}%", rate * 100.0),
            marks,
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "working set (98% of peak hit rate): {} KiB",
        r.working_set_bytes / 1024
    );

    println!("\npredicted vs simulated ({}):", cpu.name);
    println!(
        "  L1 hit rate  {:.2}% (mrc) vs {:.2}% (sim)  [{:+.2} p.p.]",
        r.prediction.rates.l1_hit_rate * 100.0,
        r.sim_l1_hit_rate * 100.0,
        (r.prediction.rates.l1_hit_rate - r.sim_l1_hit_rate) * 100.0,
    );
    println!(
        "  L2 hit rate  {:.2}% (mrc) vs {:.2}% (sim)  [{:+.2} p.p.]",
        r.prediction.rates.l2_hit_rate * 100.0,
        r.sim_l2_hit_rate * 100.0,
        (r.prediction.rates.l2_hit_rate - r.sim_l2_hit_rate) * 100.0,
    );
    println!(
        "  conflict     {:+.2} p.p. (fully-assoc L1 {:.2}% vs set-aware {:.2}%)",
        r.prediction.conflict_pp,
        r.prediction.fa_l1_hit_rate * 100.0,
        r.prediction.rates.l1_hit_rate * 100.0,
    );
    println!(
        "  time         {} (mrc) vs {} (sim)",
        fmt_time(r.prediction.time.total_s),
        fmt_time(r.sim_time_s)
    );
    println!(
        "  class        {} (mrc) vs {} (sim) -> {}",
        r.predicted_class,
        r.sim_class,
        if r.classes_agree() { "agree" } else { "DISAGREE" }
    );

    if let Some(path) = opts.get("json") {
        let text = cachebound::util::json::to_string_pretty(&r.to_json());
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, text)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `cachebound figmrc [--profile P] [--n N]`.
fn cmd_figmrc(opts: &Opts) -> Result<()> {
    let profile = opts.profile("a53");
    let n = opts.usize("n", 256)?;
    let (f, csv) = report::fig_mrc(&profile, n)?;
    let path = format!("{}/figmrc_{}_n{}.csv", results_dir(opts), profile, n);
    csv.write(&path)?;
    println!(
        "MRC ({profile}, {}): L1 {:.1}% / L2 {:.1}% predicted hit rates, \
         working set {} KiB, class {} (mrc) vs {} (sim)",
        f.workload,
        f.l1_hit_rate * 100.0,
        f.l2_hit_rate * 100.0,
        f.working_set_bytes / 1024,
        f.predicted_class,
        f.sim_class,
    );
    println!("wrote {path}");
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<()> {
    let workers = opts.usize("workers", 4)?;
    let n_requests = opts.usize("requests", 256)?;
    let seed = opts.usize("seed", 0xD15C)? as u64;
    let placement = match opts.get("placement") {
        Some(v) => PlacementPolicy::parse(v)?,
        None => PlacementPolicy::Hash,
    };
    let rebalance = match opts.get("rebalance") {
        Some(v) => RebalanceMode::parse(v)?,
        None => RebalanceMode::Drain,
    };
    let admission = match opts.get("admission") {
        Some(v) => AdmissionMode::parse(v)?,
        None => AdmissionMode::None,
    };
    let tiers = opts.has("tiers");
    let tier_policy = match opts.get("tier-policy") {
        Some(v) => TierPolicy::parse(v)?,
        None => TierPolicy::Pinned,
    };
    // 0 = closed-loop (submit as fast as the server accepts); positive =
    // open-loop wall-clock pacing on a seeded Poisson schedule
    let arrival_rate: f64 = match opts.get("arrival-rate") {
        Some(v) => {
            let r: f64 = v.parse()?;
            if !(r > 0.0) {
                bail!("--arrival-rate must be a positive req/s figure, got {v}");
            }
            r
        }
        None => 0.0,
    };
    let slo_ms: f64 = match opts.get("slo-ms") {
        Some(v) => v.parse()?,
        None => 50.0,
    };
    let mut cfg = ServeConfig::new(workers).with_cache(opts.usize("cache-entries", 64)?);
    cfg.batch = BatchPolicy { max_batch: opts.usize("max-batch", 8)? };
    cfg.shards = opts.usize("shards", 0)?;
    cfg.placement = placement;
    cfg.rebalance = rebalance;
    cfg.admission = admission;
    cfg.admission_limit = opts.usize("admission-limit", cfg.admission_limit)?;
    let admission_threads = opts.usize("admission-threads", 1)?;
    cfg = cfg.with_admission_threads(admission_threads);
    cfg.tier_policy = tier_policy;
    if let Some(dir) = opts.get("cache-dir") {
        cfg = cfg.with_cache_dir(dir);
    }

    // Fall back to the synthetic mix only when artifacts are genuinely
    // absent; a present-but-broken manifest is a hard error, not a silent
    // change of what gets measured.
    let manifest = if opts.has("synthetic") {
        None
    } else {
        let dir = artifacts_dir(opts);
        if std::path::Path::new(&dir).join("manifest.json").exists() {
            Some(Arc::new(Manifest::load(&dir)?))
        } else {
            println!("note: no {dir}/manifest.json — serving the synthetic native-GEMM mix");
            None
        }
    };
    let (outcome, mode) = match manifest {
        Some(m) => {
            let menu: Vec<(String, u32)> =
                m.artifacts.iter().map(|a| (a.name.clone(), 1)).collect();
            if menu.is_empty() {
                bail!("manifest has no artifacts — run `make artifacts`");
            }
            if placement == PlacementPolicy::CacheAware {
                println!(
                    "note: AOT artifacts carry no cache profiles — \
                     cache-aware placement falls back to hash"
                );
            }
            if rebalance == RebalanceMode::Live {
                println!(
                    "note: AOT artifacts carry no cache profiles — \
                     live rebalancing has no divergence signal to act on"
                );
            }
            if tiers {
                println!(
                    "note: AOT artifacts have no precision-tier twins — \
                     --tiers applies to the synthetic mix only"
                );
            }
            let stream = workloads::bursty_requests(&menu, n_requests, seed);
            cfg.catalog = Some(m.clone());
            let exec_manifest = m.clone();
            let srv = ShardedServer::start(cfg, move |_w| {
                PjrtExecutor::with_manifest(exec_manifest.clone())
            });
            let out = if arrival_rate > 0.0 {
                let schedule =
                    ArrivalConfig::poisson(arrival_rate, n_requests, seed).schedule();
                srv.serve_open_loop(stream, &schedule)
            } else {
                srv.serve_stream(stream)
            };
            (out, "pjrt artifacts")
        }
        None => {
            // telemetry cache profiles for the synthetic mix: traced once
            // per artifact (and cached per profile), so serve metrics can
            // report per-worker working-set pressure against the calibrated
            // part — and, under --placement cache-aware, feed the greedy
            // co-run planner
            let cpu = profile_by_name(&opts.profile("a53"))?.cpu;
            cfg.profiles = Some(if tiers {
                telemetry::serving_tier_mix_profiles(&cpu)
            } else {
                telemetry::serving_mix_profiles(&cpu)
            });
            cfg.cpu = Some(cpu);
            let stream = if tiers {
                workloads::serving_requests_tiered(n_requests, seed)
            } else {
                workloads::serving_requests(n_requests, seed)
            };
            let srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
            if let Some(plan) = srv.placement() {
                let mut t = Table::new(
                    "Cache-aware placement plan (greedy co-run packing)",
                    &["worker", "artifacts", "resident", "slowdown"],
                )
                .align(&[Align::Right, Align::Left, Align::Right, Align::Right]);
                for w in &plan.plan {
                    t.row(vec![
                        w.worker.to_string(),
                        w.artifacts.join(", "),
                        format!("{} KiB", w.resident_bytes / 1024),
                        format!("{:.3}", w.slowdown),
                    ]);
                }
                println!("{}", t.to_markdown());
            }
            let out = if arrival_rate > 0.0 {
                let schedule =
                    ArrivalConfig::poisson(arrival_rate, n_requests, seed).schedule();
                srv.serve_open_loop(stream, &schedule)
            } else {
                srv.serve_stream(stream)
            };
            (out, "synthetic native-GEMM mix")
        }
    };

    let m = &outcome.metrics;
    println!(
        "served {}/{} requests in {:.2}s -> {:.1} req/s  \
         ({workers} workers, {mode}, {} placement, rebalance {}, admission {} x{}, \
         tier policy {})",
        m.completed,
        m.requests,
        outcome.wall_seconds,
        m.throughput(outcome.wall_seconds),
        placement.name(),
        rebalance.name(),
        admission.name(),
        admission_threads.max(1),
        tier_policy.name(),
    );
    println!(
        "batches {}  cache hits {} ({:.0}%)  failed {} (of which {} rejected at catalog)  \
         shed {}  degraded {}  max queue depth {}",
        m.batches,
        m.cache_hits,
        m.cache_hit_rate() * 100.0,
        m.failed,
        m.rejected,
        m.shed,
        m.degraded,
        m.max_queue_depth(),
    );
    if let Some(p) = m.latency_percentiles(&[50.0, 95.0, 99.0, 99.9, 100.0]) {
        println!(
            "latency p50 {}  p95 {}  p99 {}  p99.9 {}  max {}",
            fmt_time(p[0]),
            fmt_time(p[1]),
            fmt_time(p[2]),
            fmt_time(p[3]),
            fmt_time(p[4]),
        );
        if arrival_rate > 0.0 {
            // the open-loop verdict: did this arrival rate meet the SLO?
            let p99_ms = p[2] * 1e3;
            println!(
                "SLO: p99 {:.3} ms vs {:.1} ms target at {:.0} req/s offered — {}",
                p99_ms,
                slo_ms,
                arrival_rate,
                if m.shed == 0 && p99_ms <= slo_ms { "met" } else { "MISSED" },
            );
        }
    }
    // The cold-vs-warm story in one place: every first-touch artifact prep,
    // with whether it was compiled from scratch or loaded from the
    // persistent artifact cache, and what each cost.
    if !m.prep.is_empty() {
        for p in &m.prep {
            println!(
                "prep: worker {} {} {} in {}",
                p.worker,
                if p.source == PrepSource::Compiled { "compiled" } else { "disk-warmed" },
                p.artifact,
                fmt_time(p.seconds),
            );
        }
        let compiled = m.prep.iter().filter(|p| p.source == PrepSource::Compiled).count();
        println!(
            "artifact prep: compiled {} artifact(s), loaded {} from cache",
            compiled,
            m.prep.len() - compiled,
        );
    }

    let mut table = Table::new(
        "Per-shard serving metrics",
        &["shard", "worker", "requests", "hits", "p50", "p99"],
    )
    .align(&[
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for s in &m.per_shard {
        table.row(vec![
            s.shard.to_string(),
            s.worker.to_string(),
            s.requests.to_string(),
            s.cache_hits.to_string(),
            fmt_time(s.latency.percentile(50.0)),
            fmt_time(s.latency.percentile(99.0)),
        ]);
    }
    println!("{}", table.to_markdown());
    if !m.worker_pressure.is_empty() {
        let cpu = profile_by_name(&opts.profile("a53"))?.cpu;
        let mut t = Table::new(
            "Per-worker cache working-set pressure (telemetry profiles)",
            &["worker", "artifacts", "profiled", "resident", "predicted", "vs L1", "vs L2"],
        )
        .align(&[
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
        for p in &m.worker_pressure {
            t.row(vec![
                p.worker.to_string(),
                p.artifacts.to_string(),
                p.profiled.to_string(),
                format!("{} KiB", p.resident_bytes / 1024),
                if placement == PlacementPolicy::CacheAware {
                    format!("{} KiB", p.predicted_bytes / 1024)
                } else {
                    "-".into()
                },
                format!("{:.1}x", p.resident_bytes as f64 / cpu.l1.size_bytes as f64),
                format!("{:.2}x", p.resident_bytes as f64 / cpu.l2.size_bytes as f64),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if !m.migrations.is_empty() {
        let mut t = Table::new(
            "Live migrations (quiesce → state handoff → route swap)",
            &["at-req", "artifact", "move", "drained", "cache", "state", "divergence", "trigger"],
        )
        .align(&[
            Align::Right,
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
        for rec in &m.migrations {
            t.row(vec![
                rec.at_request.to_string(),
                rec.artifact.clone(),
                format!("{}→{}", rec.from_worker, rec.to_worker),
                rec.drained.to_string(),
                if rec.cache_moved { "moved" } else { "-" }.to_string(),
                if rec.state_moved { "moved" } else { "recompile" }.to_string(),
                format!("{:.2}", rec.divergence),
                if rec.forced { "forced" } else { "divergence" }.to_string(),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if let Some(re) = &outcome.rebalanced {
        println!(
            "note: observed pressure diverged from the plan — suggested rebalance \
             (predicted total slowdown {:.3}):",
            re.total_slowdown
        );
        for w in &re.plan {
            println!("  worker {}: {}", w.worker, w.artifacts.join(", "));
        }
    }
    if m.failed > 0 {
        // surface the root cause, not just the count (sheds are a
        // deliberate admission disposition, not failures — skip them)
        if let Some(r) = outcome.responses.iter().find(|r| !r.ok && !r.shed) {
            eprintln!(
                "first failure ({}): {}",
                r.artifact,
                r.error.as_deref().unwrap_or("unknown error")
            );
        }
        bail!("{} requests failed", m.failed);
    }
    Ok(())
}

/// `cachebound cache warmup|doctor|prune` — operate the persistent
/// compiled-artifact cache (DESIGN.md §Artifact cache) outside a serve run.
fn cmd_cache(args: &[String]) -> Result<()> {
    let sub = args.first().map(String::as_str).unwrap_or("doctor");
    let opts = Opts::parse(&args[1.min(args.len())..]);
    let root = opts.get("cache-dir").unwrap_or(".cachebound-cache").to_string();
    let mut cache = ArtifactCache::open(&root)?;
    match sub {
        "warmup" => cmd_cache_warmup(&opts, &mut cache),
        "doctor" => cmd_cache_doctor(&cache),
        "prune" => cmd_cache_prune(&opts, &mut cache),
        other => bail!("unknown cache subcommand '{other}' — try warmup|doctor|prune"),
    }
}

/// Pre-compile the serving mix into the cache so the next `serve
/// --cache-dir` start (or a live-migration pre-warm) performs zero
/// compiles.  Artifact source resolution mirrors `serve`: AOT artifacts
/// when a manifest is present, the synthetic native-GEMM mix otherwise.
fn cmd_cache_warmup(opts: &Opts, cache: &mut ArtifactCache) -> Result<()> {
    let manifest = if opts.has("synthetic") {
        None
    } else {
        let dir = artifacts_dir(opts);
        if std::path::Path::new(&dir).join("manifest.json").exists() {
            Some(Arc::new(Manifest::load(&dir)?))
        } else {
            println!("note: no {dir}/manifest.json — warming the synthetic native-GEMM mix");
            None
        }
    };
    let (mut executor, names, mode): (Box<dyn Executor>, Vec<String>, &str) = match manifest {
        Some(m) => {
            let names: Vec<String> = m.artifacts.iter().map(|a| a.name.clone()).collect();
            if names.is_empty() {
                bail!("manifest has no artifacts — run `make artifacts`");
            }
            (Box::new(PjrtExecutor::with_manifest(m)?), names, "pjrt artifacts")
        }
        None => {
            let mix = if opts.has("tiers") {
                workloads::serving_mix_tiered()
            } else {
                workloads::serving_mix()
            };
            let names = mix.into_iter().map(|it| it.artifact).collect();
            (Box::new(SyntheticExecutor::new()), names, "synthetic native-GEMM mix")
        }
    };
    let (mut stored, mut warm, mut skipped) = (0usize, 0usize, 0usize);
    for name in &names {
        let Some(digest) = executor.artifact_digest(name) else {
            println!("  {name}: no digest — not cacheable, skipped");
            skipped += 1;
            continue;
        };
        if cache.contains(&digest) {
            println!("  {name}: already warm ({digest})");
            warm += 1;
            continue;
        }
        let t0 = std::time::Instant::now();
        executor.prepare(name)?;
        let Some(bytes) = executor.store_compiled(name) else {
            println!("  {name}: compiled but exports no payload — skipped");
            skipped += 1;
            continue;
        };
        let tier = workloads::synthetic_tier(name).map(|(t, _)| t.name()).unwrap_or("pjrt");
        cache.store(&digest, name, tier, &bytes)?;
        println!(
            "  {name}: compiled + stored {} bytes in {} ({digest})",
            bytes.len(),
            fmt_time(t0.elapsed().as_secs_f64()),
        );
        stored += 1;
    }
    println!(
        "warmup ({mode}): {stored} stored, {warm} already warm, {skipped} skipped — \
         {} entries / {} bytes at {}",
        cache.len(),
        cache.total_bytes(),
        cache.root().display(),
    );
    Ok(())
}

/// Print the cache health report: residency, lifetime counters, per-tier
/// usage.  Read-only — doctor never mutates the store.
fn cmd_cache_doctor(cache: &ArtifactCache) -> Result<()> {
    let d = cache.doctor();
    println!(
        "cache {}: {} entries, {} bytes resident, {} quarantined",
        d.root.display(),
        d.entries,
        d.total_bytes,
        d.quarantined,
    );
    println!(
        "lifetime: {} hits / {} misses / {} stores / {} corrupt — \
         {} bytes read / {} bytes written",
        d.stats.hits,
        d.stats.misses,
        d.stats.stores,
        d.stats.corrupt,
        d.stats.bytes_read,
        d.stats.bytes_written,
    );
    if !d.per_tier.is_empty() {
        let mut t = Table::new("Cache usage by precision tier", &["tier", "entries", "bytes"])
            .align(&[Align::Left, Align::Right, Align::Right]);
        for (tier, u) in &d.per_tier {
            t.row(vec![tier.clone(), u.entries.to_string(), u.bytes.to_string()]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

/// Evict least-recently-used entries until resident bytes fit the budget.
fn cmd_cache_prune(opts: &Opts, cache: &mut ArtifactCache) -> Result<()> {
    let max_bytes: u64 = match opts.get("max-bytes") {
        Some(v) => v.parse()?,
        None => bail!("cache prune needs --max-bytes BYTES (add --dry-run to preview)"),
    };
    let r = cache.prune(max_bytes, opts.has("dry-run"));
    for (digest, artifact, bytes) in &r.evicted {
        println!(
            "  {} {artifact}: {bytes} bytes ({digest})",
            if r.dry_run { "would evict" } else { "evicted" },
        );
    }
    println!(
        "prune to {max_bytes} bytes{}: {} -> {} resident bytes, {} victim(s)",
        if r.dry_run { " (dry run)" } else { "" },
        r.bytes_before,
        r.bytes_after,
        r.evicted.len(),
    );
    Ok(())
}

fn cmd_tune(opts: &Opts) -> Result<()> {
    let profile = opts.profile("a53");
    let n = opts.usize("n", 256)?;
    let trials = opts.usize("trials", 64)?;
    let kind = match opts.get("tuner").unwrap_or("gbt") {
        "gbt" | "xgb" => tuner::TunerKind::Gbt,
        "random" => tuner::TunerKind::Random,
        other => return Err(anyhow!("unknown tuner '{other}'")),
    };
    let cpu = profile_by_name(&profile)?.cpu;
    let space = tuner::GemmSpace::new(&cpu, n, n, n);
    let mut target = tuner::SimGemmTarget::square(&cpu, n);
    println!(
        "tuning GEMM N={n} on {} ({:?}, {} trials, space {})...",
        cpu.name,
        kind,
        trials,
        tuner::SearchSpace::len(&space)
    );
    let res = tuner::tune(&tuner::Tuner::new(kind, trials), &space, &mut target)?;
    let gflops = 2.0 * (n as f64).powi(3) / res.best_seconds / 1e9;
    println!(
        "best: {:?} -> {:.3} ms ({} GFLOP/s)",
        res.best_config,
        res.best_seconds * 1e3,
        fmt_gflops(gflops * 1e9)
    );
    Ok(())
}

fn cmd_report_all(opts: &Opts) -> Result<()> {
    let out = results_dir(opts);
    println!("regenerating every table and figure into {out}/ ...\n");
    for profile in ["a53", "a72"] {
        cmd_bandwidth_table(opts, profile)?;
        cmd_gemm_table(opts, profile)?;
    }
    for (f, p) in [
        (cmd_fig1 as fn(&Opts) -> Result<()>, "fig1"),
        (cmd_fig23, "fig2/3"),
        (cmd_fig45, "fig4/5"),
        (cmd_fig678, "fig6/7/8"),
        (cmd_fig9, "fig9"),
        (cmd_figmrc, "figmrc"),
    ] {
        println!("--- {p} ---");
        f(opts)?;
    }
    println!("\nreport-all complete; CSVs in {out}/");
    Ok(())
}
