//! # cachebound
//!
//! A full reproduction of *"Understanding Cache Boundness of ML Operators
//! on ARM Processors"* (Klein, Gratl, Mücke, Fröning — CS.AR 2021) as a
//! three-layer Rust + JAX + Pallas framework:
//!
//! * **L3 (this crate)** — the measurement-and-analysis coordinator: hardware
//!   models, a cache-hierarchy simulator, native operators, an AutoTVM-style
//!   auto-tuner, the cache-bound analytical model, report generators
//!   that regenerate every table and figure of the paper, a sharded
//!   multi-worker serving core (`coordinator::server`) that keeps each
//!   artifact's executable cache-resident on exactly one worker, and a
//!   roofline benchmark harness (`bench`) that sweeps the operator grid,
//!   classifies every run against the hardware bound lines, and emits the
//!   machine-readable `BENCH.json` the CI perf-regression gate diffs, and
//!   a cache-telemetry subsystem (`telemetry`) that turns one traced
//!   replay into reuse-distance profiles, miss-ratio curves and
//!   boundness *predictions* for arbitrary cache sizes
//!   (`cachebound trace`).
//! * **L2 (`python/compile/model.py`)** — JAX single-operator networks,
//!   lowered ahead-of-time to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels (tiled GEMM,
//!   spatial-pack conv, bit-packing, bit-serial GEMM, QNN int8).
//!
//! Python runs only at build time (`make artifacts`); the `runtime` module
//! loads the artifacts through PJRT and executes them from Rust.
//!
//! See the repository `README.md` for the quickstart and CLI reference,
//! `DESIGN.md` for the experiment index (which module reproduces which
//! paper table/figure), the serving-core design and the placement model,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod hw;
pub mod membench;
pub mod operators;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod tuner;
pub mod util;
