//! Markdown/ASCII table rendering for the paper's tables and figure data.
//!
//! Every `cachebound figN`/`tableN` command prints one of these and writes
//! the same rows as CSV via `util::csv`.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    /// Left-aligned column.
    Left,
    /// Right-aligned column.
    Right,
}

/// A simple table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Per-column alignment (defaults to right).
    pub aligns: Vec<Align>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given title and headers, right-aligned.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Override the per-column alignment.
    pub fn align(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append one row (width-checked against the header).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let widths = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push('|');
        for (h, w) in self.headers.iter().zip(&widths) {
            out.push_str(&format!(" {h:<w$} |"));
        }
        out.push_str("\n|");
        for (a, w) in self.aligns.iter().zip(&widths) {
            match a {
                Align::Left => out.push_str(&format!("{:-<w$}--|", ":", w = w)),
                Align::Right => out.push_str(&format!("-{:->w$}:|", "-", w = w)),
            }
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for ((c, w), a) in row.iter().zip(&widths).zip(&self.aligns) {
                match a {
                    Align::Left => out.push_str(&format!(" {c:<w$} |")),
                    Align::Right => out.push_str(&format!(" {c:>w$} |")),
                }
            }
            out.push('\n');
        }
        out
    }

    fn widths(&self) -> Vec<usize> {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        widths
    }
}

/// Format seconds with an adaptive unit (the paper's plots span ns…s).
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format a rate in GFLOP/s with paper-style precision.
pub fn fmt_gflops(flops_per_sec: f64) -> String {
    format!("{:.2}", flops_per_sec / 1e9)
}

/// Format bandwidth in MiB/s (the unit of paper Tables I & II).
pub fn fmt_mibs(bytes_per_sec: f64) -> String {
    format!("{:.0}", bytes_per_sec / (1024.0 * 1024.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]).align(&[Align::Left, Align::Right]);
        t.row(vec!["alpha".into(), "1.5".into()]);
        t.row(vec!["b".into(), "22".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| alpha |   1.5 |")); // value col right-aligned to width 5
        assert!(md.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn time_units() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 us");
        assert_eq!(fmt_time(3.2e-9), "3.2 ns");
    }

    #[test]
    fn bandwidth_matches_paper_units() {
        // Table I: 14363 MiB/s L1 read on A53
        let bw = 14363.0 * 1024.0 * 1024.0;
        assert_eq!(fmt_mibs(bw), "14363");
    }
}
