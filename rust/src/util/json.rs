//! Minimal JSON: a recursive-descent parser and a pretty writer.
//!
//! Used for `artifacts/manifest.json` (written by the AOT compiler),
//! hardware-profile files under `rust/profiles/`, and all result emission
//! under `results/`.  Supports the full JSON grammar except `\u` surrogate
//! pairs (the manifest is plain ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Numbers are kept as f64 (the manifest only uses ints that
/// fit exactly) with an `as_u64`/`as_i64` view for counts.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, as f64.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (sorted keys — deterministic output).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field lookup (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access that errors with the path on miss.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// The value as f64, or an error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The value as an exact u64, or an error.
    pub fn as_u64(&self) -> Result<u64> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > 2f64.powi(53) {
            bail!("expected unsigned integer, got {x}");
        }
        Ok(x as u64)
    }

    /// The value as an exact usize, or an error.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// The value as a string slice, or an error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The value as a bool, or an error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// The value as an array slice, or an error.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// The value as an object map, or an error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Serialize with 1-space indentation (mirrors `json.dumps(..., indent=1)`).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, 0);
    out
}

fn write_value(out: &mut String, v: &Value, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(xs) => {
            if xs.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                indent(out, depth + 1);
                write_value(out, x, depth + 1);
            }
            out.push('\n');
            indent(out, depth);
            out.push(']');
        }
        Value::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                indent(out, depth + 1);
                write_string(out, k);
                out.push_str(": ");
                write_value(out, x, depth + 1);
            }
            out.push('\n');
            indent(out, depth);
            out.push('}');
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push(' ');
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for emitting results.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Shorthand: a number value.
pub fn num(x: f64) -> Value {
    Value::Num(x)
}

/// Shorthand: a string value.
pub fn s(x: impl Into<String>) -> Value {
    Value::Str(x.into())
}

/// Shorthand: an array value.
pub fn arr(xs: Vec<Value>) -> Value {
    Value::Arr(xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
 "version": 1,
 "artifacts": [
  {"name": "gemm_f32_tuned_n128", "inputs": [{"shape": [128, 128], "dtype": "f32", "seed": 3237998592}],
   "outputs": [{"checksum": -143.25, "exact": false}]}
 ],
 "empty_arr": [], "empty_obj": {}, "null": null, "t": true, "f": false
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.req("version").unwrap().as_u64().unwrap(), 1);
        let arts = v.req("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].req("name").unwrap().as_str().unwrap(), "gemm_f32_tuned_n128");
        let seed = arts[0].req("inputs").unwrap().as_arr().unwrap()[0]
            .req("seed").unwrap().as_u64().unwrap();
        assert_eq!(seed, 3_237_998_592);
        // round trip
        let text2 = to_string_pretty(&v);
        assert_eq!(parse(&text2).unwrap(), v);
    }

    #[test]
    fn parses_negative_and_float() {
        let v = parse("[-1.5e3, 0.25, -7]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -1500.0);
        assert_eq!(a[1].as_f64().unwrap(), 0.25);
        assert_eq!(a[2].as_f64().unwrap(), -7.0);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
        let s2 = to_string_pretty(&v);
        assert_eq!(parse(&s2).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn as_u64_rejects_fractions() {
        assert!(parse("1.5").unwrap().as_u64().is_err());
        assert!(parse("-2").unwrap().as_u64().is_err());
        assert_eq!(parse("42").unwrap().as_u64().unwrap(), 42);
    }
}
