//! Tiny CSV writer — result series under `results/` are CSV so they can be
//! re-plotted with any external tool (the repo has no plotting deps).

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

/// A CSV document under construction.
#[derive(Clone, Debug, Default)]
pub struct Csv {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    /// Empty document with the given header row.
    pub fn new(headers: &[&str]) -> Self {
        Csv {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (width-checked against the header).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "csv row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render to CSV text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&escape_row(&self.headers));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&escape_row(row));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())
            .with_context(|| format!("writing csv {}", path.display()))
    }

    /// Data-row count.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows exist.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

fn escape_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["1".into(), "plain".into()]);
        c.row(vec!["2".into(), "has,comma".into()]);
        c.row(vec!["3".into(), "has\"quote".into()]);
        let s = c.to_string();
        assert_eq!(
            s,
            "a,b\n1,plain\n2,\"has,comma\"\n3,\"has\"\"quote\"\n"
        );
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cachebound_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::new(&["x"]);
        c.row(vec!["42".into()]);
        c.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n42\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
