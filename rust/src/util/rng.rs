//! SplitMix64 + xoshiro256** PRNGs.
//!
//! `SplitMix64` doubles as the **cross-language input protocol**: the AOT
//! compiler (`python/compile/aot.py`) generates every artifact input as
//! `mix(seed + (i+1)*GOLDEN)` and records output checksums in the manifest;
//! `runtime::inputs` regenerates bit-identical tensors here.  Do not change
//! the constants without changing both sides.

/// The golden-ratio increment of SplitMix64.
pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Finalizer of SplitMix64: a single avalanche of the state.
#[inline]
pub fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Element `i` (0-based) of the SplitMix64 stream for `seed` — matches
/// `aot.splitmix64_stream(seed, n)[i]` exactly.
#[inline]
pub fn stream_at(seed: u64, i: u64) -> u64 {
    mix(seed.wrapping_add(GOLDEN.wrapping_mul(i + 1)))
}

/// Sequential SplitMix64 generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next value of the stream.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        mix(self.state)
    }
}

/// xoshiro256** — the workhorse RNG for tuning, workload generation and
/// property tests (better equidistribution than SplitMix64 for long runs).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    /// Next value of the stream.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// tuning/test purposes; n is always tiny here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)`.
    #[inline]
    pub fn f32_sym(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_vectors() {
        // Same vectors asserted in python/tests/test_model_aot.py — the
        // cross-language contract.
        assert_eq!(stream_at(0, 0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(stream_at(0, 1), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(stream_at(0, 2), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn sequential_matches_indexed() {
        let mut sm = SplitMix64::new(12345);
        for i in 0..64 {
            assert_eq!(sm.next_u64(), stream_at(12345, i));
        }
    }

    #[test]
    fn xoshiro_is_deterministic_and_not_constant() {
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        assert!(xs.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(11);
        let mut v: Vec<u32> = (0..32).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
    }
}
