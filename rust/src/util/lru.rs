//! A small least-recently-used cache.
//!
//! Used by the sharded serving core (`coordinator::server`) as the
//! response cache for repeated pure requests: artifacts are shape-static
//! and executed on fixed protocol inputs, so a response payload is a pure
//! function of the artifact name and can be replayed without touching the
//! executor.  Capacities are tiny (tens to hundreds of entries), so
//! eviction does a linear minimum-stamp scan instead of maintaining an
//! intrusive list — simpler, and never on a hot path.

use std::collections::HashMap;
use std::hash::Hash;

/// LRU cache with a fixed capacity.  A capacity of 0 disables the cache
/// entirely (`get` always misses, `put` is a no-op).
#[derive(Clone, Debug)]
pub struct LruCache<K: Eq + Hash + Clone, V> {
    capacity: usize,
    /// value + last-touch stamp.
    map: HashMap<K, (V, u64)>,
    clock: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// Cache holding up to `capacity` entries (0 disables it).
    pub fn new(capacity: usize) -> Self {
        LruCache {
            capacity,
            map: HashMap::with_capacity(capacity.min(1024)),
            clock: 0,
        }
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(key).map(|(v, stamp)| {
            *stamp = clock;
            &*v
        })
    }

    /// Check membership without refreshing recency.
    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Remove `key`, returning its value if it was resident.  The serving
    /// core uses this to hand an artifact's response-cache entry to its new
    /// worker during a live migration — the entry *moves*, it is never
    /// duplicated.
    pub fn remove(&mut self, key: &K) -> Option<V> {
        self.map.remove(key).map(|(v, _)| v)
    }

    /// Insert `key -> value`, evicting the least-recently-used entry if the
    /// cache is full.  Returns the evicted key, if any.
    pub fn put(&mut self, key: K, value: V) -> Option<K> {
        if self.capacity == 0 {
            return None;
        }
        self.clock += 1;
        let fresh = !self.map.contains_key(&key);
        self.map.insert(key, (value, self.clock));
        if fresh && self.map.len() > self.capacity {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            self.map.remove(&victim);
            return Some(victim);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_and_miss() {
        let mut c = LruCache::new(2);
        assert!(c.get(&"a").is_none());
        c.put("a", 1);
        assert_eq!(c.get(&"a"), Some(&1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        // touch "a" so "b" becomes LRU
        assert_eq!(c.get(&"a"), Some(&1));
        let evicted = c.put("c", 3);
        assert_eq!(evicted, Some("b"));
        assert!(c.get(&"b").is_none());
        assert_eq!(c.get(&"a"), Some(&1));
        assert_eq!(c.get(&"c"), Some(&3));
    }

    #[test]
    fn reinsert_updates_without_evicting() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        assert_eq!(c.put("a", 10), None);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&"a"), Some(&10));
    }

    #[test]
    fn remove_takes_the_entry_out() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        assert_eq!(c.remove(&"a"), Some(1));
        assert_eq!(c.remove(&"a"), None, "an entry moves at most once");
        assert!(!c.contains(&"a"));
        assert_eq!(c.len(), 1);
        // the freed slot is reusable without evicting the survivor
        c.put("c", 3);
        assert!(c.contains(&"b") && c.contains(&"c"));
    }

    #[test]
    fn remove_then_reinsert_accounts_capacity_once() {
        let mut c = LruCache::new(2);
        c.put("a", 1);
        c.put("b", 2);
        assert_eq!(c.remove(&"a"), Some(1));
        // reinserting the removed key occupies one slot, not two: the
        // cache is exactly full again and the next fresh insert evicts
        // exactly one entry
        assert_eq!(c.put("a", 10), None);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&"a"), Some(&10), "reinsert carries the new value");
        let evicted = c.put("c", 3);
        assert_eq!(evicted, Some("b"), "the untouched survivor is the LRU victim");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn remove_of_missing_key_is_a_clean_miss() {
        let mut c: LruCache<&str, i32> = LruCache::new(2);
        assert_eq!(c.remove(&"ghost"), None, "empty cache");
        c.put("a", 1);
        assert_eq!(c.remove(&"ghost"), None, "never-inserted key");
        assert_eq!(c.len(), 1, "a miss must not disturb residents");
        assert_eq!(c.get(&"a"), Some(&1));
    }

    #[test]
    fn eviction_order_skips_removed_entries() {
        let mut c = LruCache::new(3);
        c.put("a", 1);
        c.put("b", 2);
        c.put("c", 3);
        // "a" is the LRU — but removing it must hand eviction pressure to
        // the next-oldest survivor, not dangle on the departed key
        assert_eq!(c.remove(&"a"), Some(1));
        assert_eq!(c.put("d", 4), None, "removal freed a slot");
        let evicted = c.put("e", 5);
        assert_eq!(evicted, Some("b"), "oldest *surviving* entry is the victim");
        assert!(c.contains(&"c") && c.contains(&"d") && c.contains(&"e"));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = LruCache::new(0);
        assert_eq!(c.put("a", 1), None);
        assert!(c.get(&"a").is_none());
        assert!(c.is_empty());
    }
}
