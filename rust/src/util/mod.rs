//! Dependency-free substrates: RNG, JSON, stats, tables, CSV, timing.
//!
//! The build environment is fully offline, so the framework ships its own
//! minimal versions of what would normally be `rand`, `serde_json`,
//! `criterion` and friends.  Each submodule is small, tested, and used
//! across the coordinator, tuner, simulator and report layers.

pub mod bench;
pub mod csv;
pub mod json;
pub mod lru;
pub mod rng;
pub mod stats;
pub mod table;
