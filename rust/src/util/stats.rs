//! Summary statistics for measurement runs.
//!
//! The paper reports single best-effort numbers; we keep full sample sets
//! and report median + MAD (robust to scheduler noise on a shared host),
//! plus min/mean/max for the bench harness output.

/// Summary of a set of timing samples (seconds or any unit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median.
    pub median: f64,
    /// Median absolute deviation (scaled by 1.4826 for normal consistency).
    pub mad: f64,
    /// Sample standard deviation.
    pub stddev: f64,
}

impl Summary {
    /// Summarize a non-empty sample set.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile_sorted(&sorted, 50.0);
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0) * 1.4826;
        Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            median,
            mad,
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.  `p` is clamped
/// to `[0, 100]`: any `p > 100` used to compute `hi > len - 1` and index
/// past the end of the slice (a panic), and `p < 0` only behaved by the
/// accident of saturating float→int casts.  Both now pin to the boundary
/// samples (pinned by `percentile_out_of_range_clamps`).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Pearson correlation of two equal-length series — used by the analysis
/// layer to quantify "execution time strongly correlates with the L1 cache
/// boundary" (paper §IV-B) instead of eyeballing the log-log plot.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Ordinary least squares in log-log space: returns (slope, intercept, r).
/// Fig 1 is a log-log plot; time ~ c·N^slope, so slope≈3 for cubic scaling.
pub fn loglog_fit(ns: &[f64], ts: &[f64]) -> (f64, f64, f64) {
    let xs: Vec<f64> = ns.iter().map(|x| x.ln()).collect();
    let ys: Vec<f64> = ts.iter().map(|x| x.ln()).collect();
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(&ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = num / den;
    let intercept = my - slope * mx;
    (slope, intercept, pearson(&xs, &ys))
}

/// Geometric mean — used for speedup aggregation across layers (Fig 6).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.median, 3.0);
        assert!((s.mean - 22.0).abs() < 1e-12);
        // median is robust to the outlier
        assert!(s.median < s.mean);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn percentile_single_sample_is_that_sample_for_any_p() {
        let v = [42.0];
        for p in [-5.0, 0.0, 50.0, 99.9, 100.0, 250.0] {
            assert_eq!(percentile_sorted(&v, p), 42.0);
        }
    }

    #[test]
    fn percentile_two_sample_interpolation_is_linear() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 25.0), 2.5);
        assert_eq!(percentile_sorted(&v, 75.0), 7.5);
        assert!((percentile_sorted(&v, 99.9) - 9.99).abs() < 1e-12);
    }

    #[test]
    fn percentile_p999_sits_between_p99_and_max() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let p99 = percentile_sorted(&v, 99.0);
        let p999 = percentile_sorted(&v, 99.9);
        let max = percentile_sorted(&v, 100.0);
        assert!(p99 < p999 && p999 < max, "{p99} {p999} {max}");
        assert_eq!(max, 999.0);
    }

    #[test]
    fn percentile_out_of_range_clamps() {
        // regression: p > 100 indexed past the end of the slice
        let v = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&v, 150.0), 3.0);
        assert_eq!(percentile_sorted(&v, 100.0 + 1e-9), 3.0);
        assert_eq!(percentile_sorted(&v, -10.0), 1.0);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_recovers_cubic() {
        let ns = [32.0, 64.0, 128.0, 256.0, 512.0];
        let ts: Vec<f64> = ns.iter().map(|n| 2e-9 * n * n * n).collect();
        let (slope, _, r) = loglog_fit(&ns, &ts);
        assert!((slope - 3.0).abs() < 1e-9, "slope {slope}");
        assert!((r - 1.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_of_constants() {
        assert!((geomean(&[4.0, 4.0, 4.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
