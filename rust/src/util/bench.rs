//! Measurement harness — the criterion stand-in used by `cargo bench`
//! targets and by the tuner's measurement loop.
//!
//! Protocol (mirrors AutoTVM's measure step): warm up until the operator is
//! in steady state, then collect `samples` timed runs of `iters_per_sample`
//! iterations each and summarize.  `iters_per_sample` auto-calibrates so one
//! sample lasts ≳ `target_sample_time`, keeping timer overhead negligible
//! for microsecond-scale operators (the paper's small-matrix regime).

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Configuration for one measurement.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup time before samples are recorded.
    pub warmup: Duration,
    /// Samples per measurement.
    pub samples: usize,
    /// Per-sample duration the iteration count is tuned to.
    pub target_sample_time: Duration,
    /// Hard cap on total time spent in one `measure` call.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(100),
            samples: 15,
            target_sample_time: Duration::from_millis(20),
            max_total: Duration::from_secs(10),
        }
    }
}

impl BenchConfig {
    /// A faster profile for tuner inner loops (hundreds of configs).
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(10),
            samples: 5,
            target_sample_time: Duration::from_millis(5),
            max_total: Duration::from_secs(2),
        }
    }
}

/// Result of one measurement: per-iteration seconds.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Per-iteration timing summary.
    pub seconds: Summary,
    /// Iterations folded into each sample.
    pub iters_per_sample: u64,
    /// Total iterations across all samples.
    pub total_iters: u64,
}

impl Measurement {
    /// Throughput in FLOP/s given the per-iteration FLOP count (2·MACs).
    pub fn flops(&self, flop_per_iter: f64) -> f64 {
        flop_per_iter / self.seconds.median
    }
}

/// Measure a closure.  The closure should perform one full operator run and
/// return a value that depends on the computation (to defeat DCE); we fold
/// it into a black-box sink.
pub fn measure<T, F: FnMut() -> T>(cfg: &BenchConfig, mut f: F) -> Measurement {
    let started = Instant::now();

    // Warmup + calibration of iters_per_sample.
    let mut one = Duration::ZERO;
    let mut warm_iters = 0u64;
    while started.elapsed() < cfg.warmup || warm_iters < 2 {
        let t0 = Instant::now();
        sink(f());
        one = t0.elapsed();
        warm_iters += 1;
        if started.elapsed() > cfg.max_total / 4 {
            break;
        }
    }
    let iters = if one >= cfg.target_sample_time {
        1
    } else {
        let est = (cfg.target_sample_time.as_secs_f64() / one.as_secs_f64().max(1e-9))
            .ceil() as u64;
        est.clamp(1, 1 << 22)
    };

    let mut samples = Vec::with_capacity(cfg.samples);
    let mut total_iters = 0u64;
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            sink(f());
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        samples.push(dt);
        total_iters += iters;
        if started.elapsed() > cfg.max_total {
            break;
        }
    }
    Measurement {
        seconds: Summary::of(&samples),
        iters_per_sample: iters,
        total_iters,
    }
}

/// Opaque sink: prevents the optimizer from deleting the measured work.
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One bench-report line in the style `name  median  (min … max)  unit/s`.
pub fn report_line(name: &str, m: &Measurement, flop_per_iter: Option<f64>) -> String {
    let s = &m.seconds;
    let mut line = format!(
        "{name:<44} {:>12}  ({} … {})",
        super::table::fmt_time(s.median),
        super::table::fmt_time(s.min),
        super::table::fmt_time(s.max),
    );
    if let Some(fl) = flop_per_iter {
        line.push_str(&format!("  {:>9} GFLOP/s", super::table::fmt_gflops(fl / s.median)));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            samples: 3,
            target_sample_time: Duration::from_micros(200),
            max_total: Duration::from_secs(1),
        };
        let mut acc = 0u64;
        let m = measure(&cfg, || {
            acc = acc.wrapping_add(1);
            std::hint::black_box(acc)
        });
        assert!(m.seconds.median > 0.0);
        assert!(m.total_iters > 0);
    }

    #[test]
    fn flops_inverse_to_time() {
        let m = Measurement {
            seconds: Summary::of(&[0.5, 0.5, 0.5]),
            iters_per_sample: 1,
            total_iters: 3,
        };
        assert!((m.flops(1e9) - 2e9).abs() < 1.0);
    }

    #[test]
    fn report_line_contains_name_and_rate() {
        let m = Measurement {
            seconds: Summary::of(&[1e-3]),
            iters_per_sample: 1,
            total_iters: 1,
        };
        let line = report_line("gemm_n128", &m, Some(2.0 * 128f64.powi(3)));
        assert!(line.contains("gemm_n128"));
        assert!(line.contains("GFLOP/s"));
    }
}
