//! PJRT runtime: load AOT artifacts (HLO text) and execute them from Rust.
//!
//! This is the deployment half of the three-layer architecture: python/jax
//! lowered every operator variant to `artifacts/*.hlo.txt` at build time
//! (`make artifacts`); this module compiles them on the PJRT CPU client and
//! runs them on the request path with **no python anywhere**.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (inputs, seeds,
//!   expected output checksums, workload metadata).
//! * [`artifact_cache`] — the persistent compiled-artifact store: a
//!   digest-keyed, self-verifying disk cache that lets server restarts,
//!   `cachebound cache warmup` and live-migration targets *load*
//!   compiled artifacts instead of recompiling them.
//! * [`inputs`] — regenerates each artifact's inputs bit-identically from
//!   the SplitMix64 protocol shared with `aot.py`.
//! * [`client`] — the `xla`-crate wrapper: HLO text → `XlaComputation` →
//!   compiled executable → timed execution.
//! * [`registry`] — an executable cache keyed by artifact name, compiling
//!   lazily and exposing checksum validation + timing entry points.
//!
//! Threading contract: the manifest is plain data and is shared across
//! threads as `Arc<Manifest>` (`Registry::with_manifest`); the PJRT client
//! and everything compiled through it are **not** `Send` and must be
//! created on the thread that uses them — the sharded server
//! (`coordinator::server`) builds one `Registry` inside each worker thread
//! for exactly this reason.

pub mod artifact_cache;
pub mod client;
pub mod inputs;
pub mod manifest;
pub mod registry;

pub use artifact_cache::{ArtifactCache, CacheStats, DoctorReport, PruneReport};
pub use client::{RunOutput, Runtime};
pub use manifest::{ArtifactSpec, InputSpec, Manifest, OutputSpec};
pub use registry::Registry;
