//! The PJRT execution wrapper.
//!
//! Owns one `PjRtClient` (CPU) and compiles HLO-text artifacts into loaded
//! executables.  HLO *text* is the interchange format: jax ≥ 0.5 emits
//! protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `python/compile/aot.py`).

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Result of one artifact execution.
pub struct RunOutput {
    /// Flattened outputs (the AOT side lowers with `return_tuple=True`,
    /// so a single result tuple is decomposed here).
    pub outputs: Vec<Literal>,
    /// Wall time of the `execute` call (host→device transfers included,
    /// like the paper's TVM operator timings which include input copies).
    pub seconds: f64,
}

/// A PJRT CPU runtime.
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    /// Create the CPU client (one per process is plenty).
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO text file into a loaded executable.
    pub fn compile_hlo_file(&self, path: impl AsRef<Path>) -> Result<PjRtLoadedExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Execute with literal inputs, unwrap the result tuple, time the call.
    pub fn run(&self, exe: &PjRtLoadedExecutable, inputs: &[Literal]) -> Result<RunOutput> {
        let t0 = Instant::now();
        let result = exe.execute::<Literal>(inputs)?;
        let buffers = &result[0];
        let mut outputs = Vec::with_capacity(buffers.len());
        for buf in buffers {
            outputs.push(buf.to_literal_sync()?);
        }
        let seconds = t0.elapsed().as_secs_f64();
        // return_tuple=True wraps everything in a 1-tuple
        if outputs.len() == 1 {
            if let Ok(parts) = outputs.pop().unwrap().to_tuple() {
                outputs = parts;
            }
        }
        Ok(RunOutput { outputs, seconds })
    }

    /// Execute `iters` times for timing (first call excluded by the
    /// caller's warmup); returns per-iteration seconds.
    pub fn time(
        &self,
        exe: &PjRtLoadedExecutable,
        inputs: &[Literal],
        iters: usize,
    ) -> Result<f64> {
        let t0 = Instant::now();
        for _ in 0..iters.max(1) {
            let result = exe.execute::<Literal>(inputs)?;
            std::hint::black_box(&result);
        }
        Ok(t0.elapsed().as_secs_f64() / iters.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full round-trip tests live in rust/tests/runtime_artifacts.rs (they
    // need `make artifacts`); here we only exercise client creation and a
    // tiny inline HLO module.
    const TINY_HLO: &str = r#"
HloModule tiny.1

ENTRY main.4 {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  add = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(add)
}
"#;

    #[test]
    fn client_and_inline_hlo_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.platform(), "cpu");
        let dir = std::env::temp_dir().join("cachebound_client_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.hlo.txt");
        std::fs::write(&path, TINY_HLO).unwrap();
        let exe = rt.compile_hlo_file(&path).unwrap();
        let x = Literal::vec1(&[1f32, 2., 3., 4.]);
        let y = Literal::vec1(&[10f32, 20., 30., 40.]);
        let out = rt.run(&exe, &[x, y]).unwrap();
        assert_eq!(out.outputs.len(), 1);
        assert_eq!(out.outputs[0].to_vec::<f32>().unwrap(), vec![11f32, 22., 33., 44.]);
        assert!(out.seconds > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
