//! `artifacts/manifest.json` parsing.
//!
//! The manifest is written by `python/compile/aot.py` and is the single
//! contract between the build path and the runtime: file names, input
//! shapes/dtypes/seeds, output checksums (the cross-language numerics
//! test), and the workload grid (which the integration tests cross-check
//! against `operators::workloads`).

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

/// One input tensor: shape, dtype spec ("f32" | "i8" | "u32" | "i32u<bits>"),
/// and the SplitMix64 seed for regeneration.
#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Dtype spec ("f32" | "i8" | "u32" | "i32u<bits>").
    pub dtype: String,
    /// SplitMix64 seed regenerating the tensor bit-exactly.
    pub seed: u64,
}

impl InputSpec {
    /// Element count (shape product).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One expected output: shape, numpy dtype name, checksum + exactness.
#[derive(Clone, Debug, PartialEq)]
pub struct OutputSpec {
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Numpy dtype name.
    pub dtype: String,
    /// Expected output checksum.
    pub checksum: f64,
    /// Whether the checksum must match bit-exactly.
    pub exact: bool,
}

/// One lowered operator variant.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Artifact name (the serving/validation identity).
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Protocol inputs (regenerated from seeds).
    pub inputs: Vec<InputSpec>,
    /// Expected outputs with checksums.
    pub outputs: Vec<OutputSpec>,
    /// "gemm" | "conv" | "qnn_gemm" | "bitserial_gemm" | ...
    pub kind: String,
    /// MACs of the underlying workload (paper accounting).
    pub macs: u64,
    /// Raw metadata object for kind-specific fields (n, layer, bits, block).
    pub meta: Value,
}

impl ArtifactSpec {
    /// Logical FLOPs (2·MACs).
    pub fn flops(&self) -> f64 {
        2.0 * self.macs as f64
    }

    /// Kind-specific metadata accessors.
    pub fn meta_u64(&self, key: &str) -> Option<u64> {
        self.meta.get(key).and_then(|v| v.as_u64().ok())
    }

    /// String-valued kind-specific metadata accessor.
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str().ok())
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Every lowered operator variant.
    pub artifacts: Vec<ArtifactSpec>,
    /// (name, macs) pairs of the ResNet-18 workload grid for cross-checks.
    pub resnet_macs: Vec<(String, u64)>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let mut artifacts = Vec::new();
        for a in v.req("artifacts")?.as_arr()? {
            let mut inputs = Vec::new();
            for i in a.req("inputs")?.as_arr()? {
                inputs.push(InputSpec {
                    shape: i
                        .req("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<_>>()?,
                    dtype: i.req("dtype")?.as_str()?.to_string(),
                    seed: i.req("seed")?.as_u64()?,
                });
            }
            let mut outputs = Vec::new();
            if let Some(outs) = a.get("outputs") {
                for o in outs.as_arr()? {
                    outputs.push(OutputSpec {
                        shape: o
                            .req("shape")?
                            .as_arr()?
                            .iter()
                            .map(|x| x.as_usize())
                            .collect::<Result<_>>()?,
                        dtype: o.req("dtype")?.as_str()?.to_string(),
                        checksum: o.req("checksum")?.as_f64()?,
                        exact: o.req("exact")?.as_bool()?,
                    });
                }
            }
            let meta = a.req("meta")?.clone();
            artifacts.push(ArtifactSpec {
                name: a.req("name")?.as_str()?.to_string(),
                file: a.req("file")?.as_str()?.to_string(),
                inputs,
                outputs,
                kind: meta.req("kind")?.as_str()?.to_string(),
                macs: meta.req("macs")?.as_u64()?,
                meta,
            });
        }

        let mut resnet_macs = Vec::new();
        if let Some(w) = v.get("workloads") {
            if let Some(layers) = w.get("resnet18_layers") {
                for l in layers.as_arr()? {
                    resnet_macs.push((
                        l.req("name")?.as_str()?.to_string(),
                        l.req("macs")?.as_u64()?,
                    ));
                }
            }
        }

        Ok(Manifest { dir, artifacts, resnet_macs })
    }

    /// Look up an artifact by name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts of a given kind.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, a: &ArtifactSpec) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tiny_manifest(dir: &Path) {
        fs::create_dir_all(dir).unwrap();
        fs::write(
            dir.join("manifest.json"),
            r#"{
 "version": 1,
 "workloads": {"resnet18_layers": [{"name": "C2", "macs": 124010496}]},
 "artifacts": [
  {"name": "gemm_f32_tuned_n32", "file": "gemm_f32_tuned_n32.hlo.txt",
   "inputs": [{"shape": [32, 32], "dtype": "f32", "seed": 99}],
   "outputs": [{"shape": [32, 32], "dtype": "float32", "checksum": 1.5, "exact": false}],
   "meta": {"kind": "gemm", "macs": 32768, "n": 32}}
 ]
}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("cachebound_manifest_test");
        write_tiny_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.by_name("gemm_f32_tuned_n32").unwrap();
        assert_eq!(a.kind, "gemm");
        assert_eq!(a.macs, 32_768);
        assert_eq!(a.inputs[0].shape, vec![32, 32]);
        assert_eq!(a.inputs[0].seed, 99);
        assert_eq!(a.outputs[0].checksum, 1.5);
        assert_eq!(a.meta_u64("n"), Some(32));
        assert_eq!(m.resnet_macs[0], ("C2".to_string(), 124_010_496));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
