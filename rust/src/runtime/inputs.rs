//! Deterministic artifact-input regeneration (the rust half of the
//! SplitMix64 protocol defined in `python/compile/aot.py::gen_input`).

use anyhow::{bail, Result};
use xla::{ElementType, Literal};

use crate::util::rng::stream_at;

use super::manifest::InputSpec;

/// Materialize one input tensor as an XLA literal, bit-identical to what
/// the AOT compiler used when recording the output checksums.
pub fn generate_literal(spec: &InputSpec) -> Result<Literal> {
    let n = spec.elements();
    match parse_dtype(&spec.dtype)? {
        Dtype::F32 => {
            let data: Vec<f32> = (0..n as u64)
                .map(|i| {
                    let z = stream_at(spec.seed, i);
                    (((z >> 40) as f64 / (1u64 << 24) as f64) * 2.0 - 1.0) as f32
                })
                .collect();
            literal_from(ElementType::F32, &spec.shape, bytes_of(&data))
        }
        Dtype::I8 => {
            let data: Vec<i8> = (0..n as u64)
                .map(|i| (((stream_at(spec.seed, i) >> 40) % 15) as i64 - 7) as i8)
                .collect();
            literal_from(ElementType::S8, &spec.shape, bytes_of(&data))
        }
        Dtype::U32 => {
            let data: Vec<u32> = (0..n as u64)
                .map(|i| (stream_at(spec.seed, i) >> 32) as u32)
                .collect();
            literal_from(ElementType::U32, &spec.shape, bytes_of(&data))
        }
        Dtype::I32Unipolar(bits) => {
            let data: Vec<i32> = (0..n as u64)
                .map(|i| ((stream_at(spec.seed, i) >> 40) % (1u64 << bits)) as i32)
                .collect();
            literal_from(ElementType::S32, &spec.shape, bytes_of(&data))
        }
    }
}

/// Checksum of a result literal — must use f64 accumulation in the same
/// element order as `aot.checksum` (row-major flat sum; addition is
/// reassociated there too, so float sums agree to ~1e-3 relative).
pub fn literal_checksum(lit: &Literal) -> Result<f64> {
    let shape = lit.shape()?;
    let prim = lit.element_type()?;
    Ok(match prim {
        ElementType::F32 => lit.to_vec::<f32>()?.iter().map(|&x| x as f64).sum(),
        ElementType::S32 => lit.to_vec::<i32>()?.iter().map(|&x| x as f64).sum(),
        ElementType::S8 => lit.to_vec::<i8>()?.iter().map(|&x| x as f64).sum(),
        ElementType::U32 => lit.to_vec::<u32>()?.iter().map(|&x| x as f64).sum(),
        other => bail!("unsupported output element type {other:?} (shape {shape:?})"),
    })
}

enum Dtype {
    F32,
    I8,
    U32,
    I32Unipolar(u32),
}

fn parse_dtype(d: &str) -> Result<Dtype> {
    if d == "f32" {
        Ok(Dtype::F32)
    } else if d == "i8" {
        Ok(Dtype::I8)
    } else if d == "u32" {
        Ok(Dtype::U32)
    } else if let Some(bits) = d.strip_prefix("i32u") {
        Ok(Dtype::I32Unipolar(bits.parse()?))
    } else {
        bail!("unknown dtype spec '{d}'")
    }
}

fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

fn literal_from(ty: ElementType, shape: &[usize], bytes: &[u8]) -> Result<Literal> {
    Ok(Literal::create_from_shape_and_untyped_data(ty, shape, bytes)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: &str, seed: u64) -> InputSpec {
        InputSpec {
            shape: shape.to_vec(),
            dtype: dtype.into(),
            seed,
        }
    }

    #[test]
    fn f32_literal_matches_tensor_fill() {
        let s = spec(&[8, 8], "f32", 42);
        let lit = generate_literal(&s).unwrap();
        let v = lit.to_vec::<f32>().unwrap();
        let t = crate::operators::Tensor::<f32>::rand_f32(&[8, 8], 42);
        assert_eq!(v, t.data);
    }

    #[test]
    fn i8_and_u32_and_unipolar() {
        let lit = generate_literal(&spec(&[100], "i8", 7)).unwrap();
        let v = lit.to_vec::<i8>().unwrap();
        assert!(v.iter().all(|&x| (-7..=7).contains(&x)));

        let lit = generate_literal(&spec(&[100], "u32", 7)).unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap().len(), 100);

        let lit = generate_literal(&spec(&[100], "i32u3", 7)).unwrap();
        let v = lit.to_vec::<i32>().unwrap();
        assert!(v.iter().all(|&x| (0..8).contains(&x)));
    }

    #[test]
    fn checksum_of_known_literal() {
        let lit = Literal::vec1(&[1.5f32, 2.5, -1.0]);
        assert_eq!(literal_checksum(&lit).unwrap(), 3.0);
    }

    #[test]
    fn rejects_unknown_dtype() {
        assert!(generate_literal(&spec(&[2], "f64", 0)).is_err());
    }
}
