//! Persistent compiled-artifact cache: a digest-keyed disk store.
//!
//! Regenerating compiled artifacts is the expensive part of deployment —
//! the paper's tuned schedules exist *because* compilation per
//! shape/schedule is costly, and the `Tier` lattice multiplies the
//! artifact space further.  This module makes that work durable: an
//! executor's compiled form (materialized synthetic inputs, or the HLO
//! program text behind a PJRT executable) is stored on disk under a
//! stable content digest, so server restarts, `cache warmup` runs and
//! live-migration targets *load* instead of compiling.
//!
//! Design (exercised by the `cachebound cache warmup|doctor|prune` CLI
//! and the serving stack via `ServeConfig::cache_dir`):
//!
//! * **digest keys** — [`digest_hex`] hashes the artifact's identity
//!   tuple (name, tier, shape/manifest descriptor, toolchain/CPU tag)
//!   with FNV-1a; any change to the inputs produces a new key, which *is*
//!   the invalidation rule.
//! * **self-verifying payloads** — each object file carries a 16-hex-char
//!   FNV-1a digest of its body as a header; [`ArtifactCache::load`]
//!   re-verifies on every read, and a mismatch quarantines the file and
//!   reports a miss instead of serving corrupt bytes.
//! * **atomic persistence** — objects and the index are written to a
//!   temp file and `rename`d into place, so a crashed writer can leave a
//!   stale temp file but never a torn object.
//! * **deterministic prune** — [`ArtifactCache::prune`] evicts by
//!   (logical last-use clock, digest) ascending until the byte budget
//!   holds; a logical clock (not wall time) keeps the order reproducible.
//!
//! Several workers may share one cache root: object files are
//! digest-named and self-verifying, so concurrent stores of the same
//! content are idempotent; the index is advisory metadata reconciled
//! against the objects directory on open (last writer wins).

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, arr, num, obj, s, Value};

/// Tag mixed into every digest so payloads from a different build of this
/// crate never collide with the current one (the toolchain half of the
/// invalidation rule; the CPU profile half is the caller's job).
pub const TOOLCHAIN_TAG: &str = concat!("cachebound-", env!("CARGO_PKG_VERSION"));

/// 64-bit FNV-1a over `bytes` — tiny, dependency-free, and stable across
/// platforms; collision resistance at cache scale (tens of artifacts) is
/// ample, and payloads are re-verified on load anyway.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable content digest over an identity tuple: the parts are joined
/// with an unambiguous separator and FNV-hashed to 16 lowercase hex
/// chars.  Digests are strings end to end (JSON numbers are f64 and
/// cannot carry a full u64).
pub fn digest_hex(parts: &[&str]) -> String {
    let joined = parts.join("\u{1f}");
    format!("{:016x}", fnv1a64(joined.as_bytes()))
}

/// Hit/miss/byte accounting, cumulative across sessions (persisted in the
/// index so `cache doctor` reports lifetime counters).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Loads that returned a verified payload.
    pub hits: u64,
    /// Loads that found nothing (corrupt entries count here too).
    pub misses: u64,
    /// Payloads written.
    pub stores: u64,
    /// Payloads that failed digest re-verification and were quarantined.
    pub corrupt: u64,
    /// Payload bytes returned by hits.
    pub bytes_read: u64,
    /// Payload bytes written by stores.
    pub bytes_written: u64,
}

/// One resident cache entry (index metadata; the payload lives in
/// `objects/<digest>.bin`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheEntry {
    /// Content digest — the key and the object file stem.
    pub digest: String,
    /// Artifact the payload belongs to (display/debug metadata).
    pub artifact: String,
    /// Precision-tier label ("f32" | "int8" | "bitserial" | "pjrt" | "?").
    pub tier: String,
    /// Payload body bytes (header excluded).
    pub bytes: u64,
    /// Logical last-use stamp (monotone per cache; drives LRU prune).
    pub last_used: u64,
}

/// What [`ArtifactCache::prune`] did (or would do, under `--dry-run`).
#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    /// Resident payload bytes before pruning.
    pub bytes_before: u64,
    /// Resident payload bytes after (equals `bytes_before` on a dry run
    /// that found victims — the report lists them, the disk keeps them).
    pub bytes_after: u64,
    /// `(digest, artifact, bytes)` of each victim, in eviction order.
    pub evicted: Vec<(String, String, u64)>,
    /// True when nothing was deleted (dry run).
    pub dry_run: bool,
}

/// Per-tier usage row of a [`DoctorReport`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierUsage {
    /// Entries of this tier.
    pub entries: u64,
    /// Payload bytes of this tier.
    pub bytes: u64,
}

/// Everything `cachebound cache doctor` prints.
#[derive(Clone, Debug)]
pub struct DoctorReport {
    /// Cache root directory.
    pub root: PathBuf,
    /// Resident entries.
    pub entries: u64,
    /// Resident payload bytes.
    pub total_bytes: u64,
    /// Quarantined object files (failed digest re-verification).
    pub quarantined: u64,
    /// Lifetime hit/miss/byte counters.
    pub stats: CacheStats,
    /// Usage by precision-tier label.
    pub per_tier: BTreeMap<String, TierUsage>,
}

/// The disk-backed, digest-keyed artifact cache (module docs).
#[derive(Debug)]
pub struct ArtifactCache {
    root: PathBuf,
    index: BTreeMap<String, CacheEntry>,
    clock: u64,
    stats: CacheStats,
}

/// Payload header: 16 ASCII hex chars of the body's FNV-1a digest.
const HEADER_LEN: usize = 16;

impl ArtifactCache {
    /// Open (creating if needed) the cache rooted at `root`, loading the
    /// persisted index and reconciling it against the objects directory:
    /// indexed entries whose object vanished are dropped; unindexed
    /// objects are adopted with placeholder metadata (they stay loadable
    /// — payloads are self-verifying).
    pub fn open(root: impl AsRef<Path>) -> Result<ArtifactCache> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(root.join("objects"))
            .with_context(|| format!("creating cache root {}", root.display()))?;
        fs::create_dir_all(root.join("quarantine"))?;
        let mut cache = ArtifactCache {
            root,
            index: BTreeMap::new(),
            clock: 0,
            stats: CacheStats::default(),
        };
        cache.load_index();
        cache.reconcile()?;
        Ok(cache)
    }

    /// Cache root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when no entry is resident.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Resident payload bytes (headers excluded).
    pub fn total_bytes(&self) -> u64 {
        self.index.values().map(|e| e.bytes).sum()
    }

    /// Is a payload resident under `digest`?  (No recency touch, no IO.)
    pub fn contains(&self, digest: &str) -> bool {
        self.index.contains_key(digest)
    }

    /// Lifetime hit/miss/byte counters.
    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    fn object_path(&self, digest: &str) -> PathBuf {
        self.root.join("objects").join(format!("{digest}.bin"))
    }

    /// Load the payload stored under `digest`, re-verifying its content
    /// hash.  A missing entry is a miss; a corrupt one is quarantined
    /// (moved to `quarantine/`, dropped from the index) and reported as a
    /// miss — the caller compiles fresh and may re-store.
    pub fn load(&mut self, digest: &str) -> Option<Vec<u8>> {
        if !self.index.contains_key(digest) && !self.adopt_from_disk(digest) {
            self.stats.misses += 1;
            return None;
        }
        let path = self.object_path(digest);
        let raw = match fs::read(&path) {
            Ok(raw) if raw.len() >= HEADER_LEN => raw,
            _ => {
                // vanished or truncated below even a header: quarantine
                // whatever is left and miss
                self.quarantine(digest);
                return None;
            }
        };
        let (header, body) = raw.split_at(HEADER_LEN);
        let expect = String::from_utf8_lossy(header).to_string();
        let actual = format!("{:016x}", fnv1a64(body));
        if expect != actual {
            self.quarantine(digest);
            return None;
        }
        self.clock += 1;
        let clock = self.clock;
        let entry = self.index.get_mut(digest).expect("checked above");
        entry.last_used = clock;
        self.stats.hits += 1;
        self.stats.bytes_read += body.len() as u64;
        self.persist_index();
        Some(body.to_vec())
    }

    /// A sibling cache instance sharing this root (another worker, or a
    /// `cache warmup` run) may have stored `digest` after our `open`:
    /// probe the objects directory and adopt the entry if the file is
    /// there.  This is what lets a live-migration target pre-warm from an
    /// object its source worker wrote moments ago.  Metadata is the same
    /// placeholder `reconcile` uses; the payload stays self-verifying.
    fn adopt_from_disk(&mut self, digest: &str) -> bool {
        match fs::metadata(self.object_path(digest)) {
            Ok(m) => {
                self.index.insert(
                    digest.to_string(),
                    CacheEntry {
                        digest: digest.to_string(),
                        artifact: "(unindexed)".to_string(),
                        tier: "?".to_string(),
                        bytes: m.len().saturating_sub(HEADER_LEN as u64),
                        last_used: self.clock,
                    },
                );
                true
            }
            Err(_) => false,
        }
    }

    /// Move `digest`'s object into `quarantine/` and forget it,
    /// accounting the event as corruption *and* a miss.
    fn quarantine(&mut self, digest: &str) {
        let from = self.object_path(digest);
        let to = self.root.join("quarantine").join(format!("{digest}.bin"));
        let _ = fs::rename(&from, &to); // best effort; removal also suffices
        if !to.exists() {
            let _ = fs::remove_file(&from);
        }
        self.index.remove(digest);
        self.stats.corrupt += 1;
        self.stats.misses += 1;
        self.persist_index();
    }

    /// Store `body` under `digest` with write-then-rename atomicity.
    /// Re-storing an existing digest is idempotent (same content ⇒ same
    /// digest ⇒ same bytes).
    pub fn store(&mut self, digest: &str, artifact: &str, tier: &str, body: &[u8]) -> Result<()> {
        let path = self.object_path(digest);
        let tmp = self.root.join("objects").join(format!(".tmp-{digest}"));
        let mut raw = Vec::with_capacity(HEADER_LEN + body.len());
        raw.extend_from_slice(format!("{:016x}", fnv1a64(body)).as_bytes());
        raw.extend_from_slice(body);
        fs::write(&tmp, &raw).with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &path).with_context(|| format!("renaming into {}", path.display()))?;
        self.clock += 1;
        self.index.insert(
            digest.to_string(),
            CacheEntry {
                digest: digest.to_string(),
                artifact: artifact.to_string(),
                tier: tier.to_string(),
                bytes: body.len() as u64,
                last_used: self.clock,
            },
        );
        self.stats.stores += 1;
        self.stats.bytes_written += body.len() as u64;
        self.persist_index();
        Ok(())
    }

    /// Evict least-recently-used entries (ties broken by digest, so the
    /// order — and therefore the surviving set — is deterministic) until
    /// resident payload bytes fit `max_bytes`.  `dry_run` reports the
    /// victims without deleting anything.
    pub fn prune(&mut self, max_bytes: u64, dry_run: bool) -> PruneReport {
        let bytes_before = self.total_bytes();
        let mut order: Vec<(u64, String, String, u64)> = self
            .index
            .values()
            .map(|e| (e.last_used, e.digest.clone(), e.artifact.clone(), e.bytes))
            .collect();
        order.sort();
        let mut remaining = bytes_before;
        let mut evicted = Vec::new();
        for (_, digest, artifact, bytes) in order {
            if remaining <= max_bytes {
                break;
            }
            remaining -= bytes;
            evicted.push((digest, artifact, bytes));
        }
        if !dry_run {
            for (digest, _, _) in &evicted {
                let _ = fs::remove_file(self.object_path(digest));
                self.index.remove(digest);
            }
            self.persist_index();
        }
        PruneReport {
            bytes_before,
            bytes_after: if dry_run { bytes_before } else { remaining },
            evicted,
            dry_run,
        }
    }

    /// Usage snapshot for `cachebound cache doctor`.
    pub fn doctor(&self) -> DoctorReport {
        let mut per_tier: BTreeMap<String, TierUsage> = BTreeMap::new();
        for e in self.index.values() {
            let row = per_tier.entry(e.tier.clone()).or_default();
            row.entries += 1;
            row.bytes += e.bytes;
        }
        let quarantined = fs::read_dir(self.root.join("quarantine"))
            .map(|d| d.filter_map(|e| e.ok()).count() as u64)
            .unwrap_or(0);
        DoctorReport {
            root: self.root.clone(),
            entries: self.index.len() as u64,
            total_bytes: self.total_bytes(),
            quarantined,
            stats: self.stats,
            per_tier,
        }
    }

    /// Entries in digest order (stable iteration for reports/tests).
    pub fn entries(&self) -> impl Iterator<Item = &CacheEntry> {
        self.index.values()
    }

    // -- index persistence ------------------------------------------------

    fn index_path(&self) -> PathBuf {
        self.root.join("index.json")
    }

    fn load_index(&mut self) {
        let Ok(text) = fs::read_to_string(self.index_path()) else { return };
        let Ok(v) = json::parse(&text) else { return };
        self.clock = v.get("clock").and_then(|x| x.as_u64().ok()).unwrap_or(0);
        if let Some(st) = v.get("stats") {
            let f = |k: &str| st.get(k).and_then(|x| x.as_u64().ok()).unwrap_or(0);
            self.stats = CacheStats {
                hits: f("hits"),
                misses: f("misses"),
                stores: f("stores"),
                corrupt: f("corrupt"),
                bytes_read: f("bytes_read"),
                bytes_written: f("bytes_written"),
            };
        }
        let Some(Ok(entries)) = v.get("entries").map(|e| e.as_arr()) else { return };
        for e in entries {
            let (Some(digest), Some(artifact), Some(tier)) = (
                e.get("digest").and_then(|x| x.as_str().ok()),
                e.get("artifact").and_then(|x| x.as_str().ok()),
                e.get("tier").and_then(|x| x.as_str().ok()),
            ) else {
                continue;
            };
            self.index.insert(
                digest.to_string(),
                CacheEntry {
                    digest: digest.to_string(),
                    artifact: artifact.to_string(),
                    tier: tier.to_string(),
                    bytes: e.get("bytes").and_then(|x| x.as_u64().ok()).unwrap_or(0),
                    last_used: e.get("last_used").and_then(|x| x.as_u64().ok()).unwrap_or(0),
                },
            );
        }
    }

    /// Drop indexed entries whose object vanished; adopt unindexed
    /// objects with placeholder metadata.
    fn reconcile(&mut self) -> Result<()> {
        let stale: Vec<String> = self
            .index
            .keys()
            .filter(|d| !self.object_path(d).exists())
            .cloned()
            .collect();
        for d in stale {
            self.index.remove(&d);
        }
        for entry in fs::read_dir(self.root.join("objects"))? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            let Some(digest) = name.strip_suffix(".bin") else { continue };
            if self.index.contains_key(digest) {
                continue;
            }
            let bytes = entry.metadata().map(|m| m.len()).unwrap_or(0);
            self.index.insert(
                digest.to_string(),
                CacheEntry {
                    digest: digest.to_string(),
                    artifact: "(unindexed)".to_string(),
                    tier: "?".to_string(),
                    bytes: bytes.saturating_sub(HEADER_LEN as u64),
                    last_used: 0,
                },
            );
        }
        Ok(())
    }

    /// Atomically persist the index (advisory metadata; benign to lose —
    /// `reconcile` rebuilds residency from the objects directory).
    fn persist_index(&self) {
        let entries: Vec<Value> = self
            .index
            .values()
            .map(|e| {
                obj(vec![
                    ("digest", s(e.digest.clone())),
                    ("artifact", s(e.artifact.clone())),
                    ("tier", s(e.tier.clone())),
                    ("bytes", num(e.bytes as f64)),
                    ("last_used", num(e.last_used as f64)),
                ])
            })
            .collect();
        let v = obj(vec![
            ("version", num(1.0)),
            ("clock", num(self.clock as f64)),
            (
                "stats",
                obj(vec![
                    ("hits", num(self.stats.hits as f64)),
                    ("misses", num(self.stats.misses as f64)),
                    ("stores", num(self.stats.stores as f64)),
                    ("corrupt", num(self.stats.corrupt as f64)),
                    ("bytes_read", num(self.stats.bytes_read as f64)),
                    ("bytes_written", num(self.stats.bytes_written as f64)),
                ]),
            ),
            ("entries", arr(entries)),
        ]);
        let tmp = self.root.join(".index.tmp");
        if fs::write(&tmp, json::to_string_pretty(&v)).is_ok() {
            let _ = fs::rename(&tmp, self.index_path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cachebound_artifact_cache_{name}"));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn digest_is_stable_and_separator_safe() {
        let d = digest_hex(&["a", "b"]);
        assert_eq!(d.len(), 16);
        assert_eq!(d, digest_hex(&["a", "b"]), "pure function");
        // the separator keeps ("ab","") distinct from ("a","b")
        assert_ne!(digest_hex(&["ab", ""]), digest_hex(&["a", "b"]));
        assert_ne!(digest_hex(&["a"]), digest_hex(&["a", ""]));
    }

    #[test]
    fn store_load_round_trip_with_accounting() {
        let root = temp_root("roundtrip");
        let mut c = ArtifactCache::open(&root).unwrap();
        let d = digest_hex(&["syn", "gemm", "32"]);
        assert_eq!(c.load(&d), None, "cold cache misses");
        c.store(&d, "syn_gemm_n32", "f32", b"payload-bytes").unwrap();
        assert!(c.contains(&d));
        assert_eq!(c.load(&d).as_deref(), Some(b"payload-bytes".as_ref()));
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.stores), (1, 1, 1));
        assert_eq!(st.bytes_written, 13);
        assert_eq!(st.bytes_read, 13);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn index_and_stats_survive_reopen() {
        let root = temp_root("reopen");
        {
            let mut c = ArtifactCache::open(&root).unwrap();
            let d = digest_hex(&["x"]);
            c.store(&d, "x", "f32", b"abc").unwrap();
            assert!(c.load(&d).is_some());
        }
        let mut c = ArtifactCache::open(&root).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c.total_bytes(), 3);
        assert_eq!(c.stats().hits, 1, "counters are lifetime, not session");
        let d = digest_hex(&["x"]);
        assert_eq!(c.load(&d).as_deref(), Some(b"abc".as_ref()), "warm across restart");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_payload_is_quarantined_and_misses() {
        let root = temp_root("corrupt");
        let mut c = ArtifactCache::open(&root).unwrap();
        let d = digest_hex(&["victim"]);
        c.store(&d, "victim", "int8", b"good-bytes").unwrap();
        // flip a body byte on disk behind the cache's back
        let path = root.join("objects").join(format!("{d}.bin"));
        let mut raw = fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xFF;
        fs::write(&path, &raw).unwrap();
        assert_eq!(c.load(&d), None, "corruption is a miss, not bad bytes");
        assert!(!c.contains(&d));
        assert_eq!(c.stats().corrupt, 1);
        assert_eq!(c.stats().misses, 1);
        assert!(
            root.join("quarantine").join(format!("{d}.bin")).exists(),
            "corrupt object moved aside for diagnosis"
        );
        // doctor sees the quarantine row
        assert_eq!(c.doctor().quarantined, 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn prune_enforces_budget_deterministically_in_lru_order() {
        let root = temp_root("prune");
        let mut c = ArtifactCache::open(&root).unwrap();
        for name in ["a", "b", "c"] {
            c.store(&digest_hex(&[name]), name, "f32", &[0u8; 100]).unwrap();
        }
        // touch "a" so "b" is the coldest entry
        assert!(c.load(&digest_hex(&["a"])).is_some());
        // dry run: reports victims, deletes nothing
        let dry = c.prune(150, true);
        assert!(dry.dry_run);
        assert_eq!(dry.evicted.len(), 2);
        assert_eq!(dry.evicted[0].1, "b", "LRU first");
        assert_eq!(c.len(), 3, "dry run keeps everything");
        // real prune: same victims, enforced budget
        let rep = c.prune(150, false);
        assert_eq!(
            rep.evicted.iter().map(|e| e.1.as_str()).collect::<Vec<_>>(),
            dry.evicted.iter().map(|e| e.1.as_str()).collect::<Vec<_>>(),
            "dry run predicted the real eviction order"
        );
        assert_eq!(rep.bytes_before, 300);
        assert_eq!(rep.bytes_after, 100);
        assert_eq!(c.len(), 1);
        assert!(c.contains(&digest_hex(&["a"])), "the touched entry survives");
        assert!(!root.join("objects").join(format!("{}.bin", digest_hex(&["b"]))).exists());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn doctor_breaks_usage_down_by_tier() {
        let root = temp_root("doctor");
        let mut c = ArtifactCache::open(&root).unwrap();
        c.store(&digest_hex(&["f1"]), "f1", "f32", &[0u8; 10]).unwrap();
        c.store(&digest_hex(&["f2"]), "f2", "f32", &[0u8; 20]).unwrap();
        c.store(&digest_hex(&["q1"]), "q1", "int8", &[0u8; 5]).unwrap();
        let rep = c.doctor();
        assert_eq!(rep.entries, 3);
        assert_eq!(rep.total_bytes, 35);
        assert_eq!(rep.per_tier["f32"], TierUsage { entries: 2, bytes: 30 });
        assert_eq!(rep.per_tier["int8"], TierUsage { entries: 1, bytes: 5 });
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn unindexed_objects_are_adopted_on_open() {
        let root = temp_root("adopt");
        {
            let mut c = ArtifactCache::open(&root).unwrap();
            c.store(&digest_hex(&["orphan"]), "orphan", "f32", b"body").unwrap();
        }
        // lose the index; the object must still be loadable
        fs::remove_file(root.join("index.json")).unwrap();
        let mut c = ArtifactCache::open(&root).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(
            c.load(&digest_hex(&["orphan"])).as_deref(),
            Some(b"body".as_ref()),
            "self-verifying payloads survive index loss"
        );
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn sibling_store_is_visible_without_reopen() {
        // Two instances share one root (the per-worker topology of the
        // sharded server): a store through one must be loadable through
        // the other without reopening — the migration pre-warm path.
        let root = temp_root("sibling");
        let mut a = ArtifactCache::open(&root).unwrap();
        let mut b = ArtifactCache::open(&root).unwrap();
        let d = digest_hex(&["shared"]);
        a.store(&d, "shared", "f32", b"late-arrival").unwrap();
        assert_eq!(
            b.load(&d).as_deref(),
            Some(b"late-arrival".as_ref()),
            "adopt-from-disk sees objects stored after open"
        );
        assert_eq!(b.stats().hits, 1);
        let _ = fs::remove_dir_all(&root);
    }
}
