//! Executable registry: lazy compile-once cache over the manifest.
//!
//! The coordinator asks the registry to validate or time artifacts by
//! name; compiled executables and generated inputs are cached so sweeps
//! over the same artifact (tuning, benches) pay compilation exactly once.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtLoadedExecutable};

use crate::util::bench::{BenchConfig, Measurement};
use crate::util::stats::Summary;

use super::client::Runtime;
use super::inputs::{generate_literal, literal_checksum};
use super::manifest::{ArtifactSpec, Manifest};

/// Outcome of validating one artifact against its manifest checksums.
#[derive(Clone, Debug)]
pub struct Validation {
    /// Artifact that was validated.
    pub name: String,
    /// All outputs matched their checksums.
    pub passed: bool,
    /// (expected, actual, relative error) per output.
    pub details: Vec<(f64, f64, f64)>,
}

/// Loaded-artifact registry: compiles HLO through PJRT on demand and
/// caches executables + protocol inputs per artifact.
pub struct Registry {
    /// The parsed manifest.  Held through `Arc` so a serving front-end and
    /// many per-worker registries can share one parse: the manifest is
    /// plain data and thread-safe, while the PJRT client, executables and
    /// input literals below are **not** `Send` and stay confined to the
    /// thread that built this `Registry`.
    pub manifest: Arc<Manifest>,
    runtime: Runtime,
    executables: HashMap<String, PjRtLoadedExecutable>,
    input_cache: HashMap<String, Vec<Literal>>,
}

impl Registry {
    /// Open `<artifacts_dir>/manifest.json` and build a registry.
    pub fn open(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::with_manifest(Arc::new(Manifest::load(artifacts_dir)?))
    }

    /// Build a registry around a manifest parsed elsewhere — the sharing
    /// path for multi-worker serving: parse once on the admission thread,
    /// hand each worker an `Arc`, and let every worker create its own PJRT
    /// client where it lives.
    pub fn with_manifest(manifest: Arc<Manifest>) -> Result<Self> {
        Ok(Registry {
            manifest,
            runtime: Runtime::cpu()?,
            executables: HashMap::new(),
            input_cache: HashMap::new(),
        })
    }

    /// The underlying PJRT runtime handle.
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// A thread-safe handle to the manifest (see the field docs).
    pub fn shared_manifest(&self) -> Arc<Manifest> {
        self.manifest.clone()
    }

    fn spec(&self, name: &str) -> Result<ArtifactSpec> {
        self.manifest
            .by_name(name)
            .cloned()
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Compile (or fetch cached) an executable.
    pub fn executable(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let spec = self.spec(name)?;
            let exe = self
                .runtime
                .compile_hlo_file(self.manifest.hlo_path(&spec))
                .with_context(|| format!("compiling artifact {name}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Is `name`'s executable already compiled in this registry?
    pub fn is_compiled(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// The bytes of `name`'s HLO program text — the payload the artifact
    /// cache persists for PJRT artifacts (the `xla` crate exposes no
    /// serialized-executable form, so the program text is the portable
    /// compiled form we can store and reload).
    pub fn hlo_bytes(&self, name: &str) -> Result<Vec<u8>> {
        let spec = self.spec(name)?;
        std::fs::read(self.manifest.hlo_path(&spec))
            .with_context(|| format!("reading HLO text of {name}"))
    }

    /// Compile `name` from HLO program text handed in as bytes (an
    /// artifact-cache payload) instead of the manifest's file path.  The
    /// bytes are staged to a temp file because the PJRT wrapper parses
    /// HLO from a file.  On success the executable is cached exactly as
    /// if [`Registry::executable`] had compiled it.
    pub fn install_hlo_text(&mut self, name: &str, bytes: &[u8]) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let staged = std::env::temp_dir().join(format!("cachebound-warm-{name}.hlo.txt"));
        std::fs::write(&staged, bytes)
            .with_context(|| format!("staging warm HLO for {name}"))?;
        let exe = self
            .runtime
            .compile_hlo_file(&staged)
            .with_context(|| format!("compiling warm artifact {name}"))?;
        let _ = std::fs::remove_file(&staged);
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Generate (or fetch cached) the protocol inputs for an artifact.
    pub fn inputs(&mut self, name: &str) -> Result<&[Literal]> {
        if !self.input_cache.contains_key(name) {
            let spec = self.spec(name)?;
            let lits = spec
                .inputs
                .iter()
                .map(generate_literal)
                .collect::<Result<Vec<_>>>()?;
            self.input_cache.insert(name.to_string(), lits);
        }
        Ok(&self.input_cache[name])
    }

    /// Execute once and compare output checksums with the manifest
    /// (exact for integer outputs, 1e-3 relative for floats — different
    /// XLA builds on the two sides).
    pub fn validate(&mut self, name: &str) -> Result<Validation> {
        let spec = self.spec(name)?;
        self.executable(name)?;
        self.inputs(name)?;
        let exe = &self.executables[name];
        let inputs = &self.input_cache[name];
        let out = self.runtime.run(exe, inputs)?;
        if out.outputs.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: output arity {} != manifest {}",
                out.outputs.len(),
                spec.outputs.len()
            ));
        }
        let mut details = Vec::new();
        let mut passed = true;
        for (lit, expect) in out.outputs.iter().zip(&spec.outputs) {
            let actual = literal_checksum(lit)?;
            let denom = expect.checksum.abs().max(1.0);
            let rel = (actual - expect.checksum).abs() / denom;
            let ok = if expect.exact { actual == expect.checksum } else { rel < 1e-3 };
            passed &= ok;
            details.push((expect.checksum, actual, rel));
        }
        Ok(Validation {
            name: name.to_string(),
            passed,
            details,
        })
    }

    /// Execute an artifact once on its protocol inputs.
    pub fn run_protocol(&mut self, name: &str) -> Result<super::client::RunOutput> {
        self.executable(name)?;
        self.inputs(name)?;
        let exe = &self.executables[name];
        let inputs = &self.input_cache[name];
        self.runtime.run(exe, inputs)
    }

    /// Time an artifact with the bench harness protocol.
    pub fn measure(&mut self, name: &str, cfg: &BenchConfig) -> Result<Measurement> {
        self.executable(name)?;
        self.inputs(name)?;
        let exe = &self.executables[name];
        let inputs = &self.input_cache[name];
        // warmup
        let _ = self.runtime.run(exe, inputs)?;
        let one = self.runtime.time(exe, inputs, 1)?;
        let iters = ((cfg.target_sample_time.as_secs_f64() / one.max(1e-9)).ceil() as usize)
            .clamp(1, 1 << 16);
        let mut samples = Vec::with_capacity(cfg.samples);
        for _ in 0..cfg.samples {
            samples.push(self.runtime.time(exe, inputs, iters)?);
        }
        Ok(Measurement {
            seconds: Summary::of(&samples),
            iters_per_sample: iters as u64,
            total_iters: (iters * cfg.samples) as u64,
        })
    }

    /// Names of all artifacts, optionally filtered by kind.
    pub fn names(&self, kind: Option<&str>) -> Vec<String> {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| kind.is_none_or(|k| a.kind == k))
            .map(|a| a.name.clone())
            .collect()
    }
}
