//! Inference serving: admission queue → artifact shards → worker pool.
//!
//! The deployment face of the L3 coordinator, in two tiers:
//!
//! * [`Server`] — the original single-threaded leader loop (request queue →
//!   compile-once batcher → PJRT execution).  Kept as the reference
//!   implementation and the baseline that `bench_serve` scales against.
//! * [`ShardedServer`] — the multi-worker serving core.  A front-end
//!   admission queue hashes each request's artifact name to one of
//!   `n_shards` queues ([`super::shard::shard_for`]); each worker owns the
//!   disjoint set of shards `{s : s mod workers == w}`, so an artifact's
//!   compiled executable, protocol inputs and response-cache entry live on
//!   exactly one worker.  Workers batch consecutive same-artifact requests
//!   (the compile-once batching axis that matters for shape-static XLA
//!   executables), consult a per-worker LRU response cache for repeated
//!   pure requests, and record per-shard latency histograms that roll up
//!   into the aggregate [`Metrics`].
//!
//! Execution is abstracted behind [`Executor`] so the core is testable and
//! benchmarkable without AOT artifacts: [`PjrtExecutor`] serves compiled
//! HLO through the PJRT registry (constructed *inside* each worker thread —
//! the PJRT client is not `Send`, only the parsed manifest is shared, via
//! `Arc`), while [`SyntheticExecutor`] serves native tiled-GEMM workloads
//! from `operators::workloads::serving_mix`.
//!
//! Invariants (tested in `rust/tests/serve_multiworker.rs`):
//!
//! * **per-artifact FIFO** — an artifact maps to one shard queue on one
//!   (consistently chosen) worker, and each shard queue is drained
//!   front-to-back, so responses for any given artifact are emitted in
//!   admission order even with many workers and no global lock.  Under
//!   hash placement a shard has exactly one owning worker; a cache-aware
//!   plan may split a shard's artifacts across workers, in which case the
//!   per-shard rollup keeps one [`ShardMetrics`] row per (shard, worker);
//! * **exactly one response per request** — every admitted request is
//!   answered (success, failure, or cache hit), and rejected requests are
//!   answered at the front door;
//! * **metrics totals** — `completed + failed == requests` in the
//!   aggregate [`Metrics`], and the per-[`ShardMetrics`] sums equal the
//!   aggregate minus admission-rejected requests (`Metrics::rejected`),
//!   which never reach a shard;
//! * **cache purity** — a cache hit returns a payload bit-identical to the
//!   original execution, with `exec_seconds == 0` and `cached == true`.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::analysis::InterferenceModel;
use crate::hw::{profile_by_name, CpuSpec};
use crate::operators::gemm::{self, GemmSchedule};
use crate::operators::workloads;
use crate::operators::Tensor;
use crate::runtime::inputs::literal_checksum;
use crate::runtime::{Manifest, Registry};
use crate::telemetry::CacheProfile;
use crate::util::lru::LruCache;
use crate::util::stats::{percentile_sorted, Summary};

use super::placement::{self, Placement, PlacementPolicy};
use super::shard::{shard_for, ShardMetrics};

/// One inference request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    /// Caller-chosen request id, echoed in the response.
    pub id: u64,
    /// Artifact name to execute (the "model variant" being served).
    pub artifact: String,
}

/// One completed response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Id of the request this response answers.
    pub id: u64,
    /// Artifact that was executed.
    pub artifact: String,
    /// Execution wall time (excludes queueing; 0 for cache hits).
    pub exec_seconds: f64,
    /// Total latency including queue wait.
    pub latency_seconds: f64,
    /// Did execution succeed?
    pub ok: bool,
    /// Failure description when `ok` is false.
    pub error: Option<String>,
    /// Output checksum — the response payload.  Artifacts are pure
    /// functions of their protocol inputs, so this is identical across
    /// repeated requests (and bit-identical on cache hits).
    pub payload: Option<f64>,
    /// Served from the LRU response cache.
    pub cached: bool,
    /// Shard that owned the request (0 for the single-threaded [`Server`]).
    pub shard: usize,
}

/// Aggregate serving metrics.
///
/// For the sharded server, totals equal the sums over `per_shard` (tested);
/// the single-threaded [`Server`] leaves `per_shard` empty.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Requests admitted (including rejected ones).
    pub requests: u64,
    /// Successfully answered requests.
    pub completed: u64,
    /// Failed requests (rejections included).
    pub failed: u64,
    /// Executor batches formed.
    pub batches: u64,
    /// Responses served from the response cache (subset of `completed`).
    pub cache_hits: u64,
    /// Requests rejected at admission (unknown artifact under a catalog) —
    /// a subset of `failed` that reaches no shard, so per-shard sums cover
    /// `requests - rejected`.
    pub rejected: u64,
    /// Per-response execution times.
    pub exec_seconds: Vec<f64>,
    /// Per-response end-to-end latencies.
    pub latency_seconds: Vec<f64>,
    /// Per-shard rollup (sharded server only): one row per
    /// (shard, worker) pair — a single row per shard under hash placement,
    /// possibly several when a cache-aware plan splits a shard's artifacts.
    pub per_shard: Vec<ShardMetrics>,
    /// Per-worker working-set-pressure estimates (populated only when the
    /// server was started with per-artifact [`CacheProfile`]s).
    pub worker_pressure: Vec<WorkerPressure>,
}

/// Cache working-set pressure of one worker: how many bytes of cache its
/// resident artifact set wants, from the telemetry subsystem's
/// per-artifact profiles.  The shard→worker affinity makes this a
/// per-worker property: an artifact's executable *and* its cache working
/// set live on exactly one worker.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerPressure {
    /// Worker index this row describes.
    pub worker: usize,
    /// Distinct artifacts routed to this worker.
    pub artifacts: u64,
    /// Of those, how many had a profile attached.
    pub profiled: u64,
    /// Σ `working_set_bytes` over the profiled artifacts — compare against
    /// the part's L1/L2 sizes to see whether the worker's mix is
    /// cache-resident.
    pub resident_bytes: u64,
    /// What the cache-aware placement plan *predicted* this worker would
    /// hold (0 under hash placement).  The gap between this and
    /// `resident_bytes` is what drives [`super::placement::Placement::rebalance`].
    pub predicted_bytes: u64,
}

impl Metrics {
    /// Summary of execution times (None when empty).
    pub fn exec_summary(&self) -> Option<Summary> {
        (!self.exec_seconds.is_empty()).then(|| Summary::of(&self.exec_seconds))
    }

    /// Summary of end-to-end latencies (None when empty).
    pub fn latency_summary(&self) -> Option<Summary> {
        (!self.latency_seconds.is_empty()).then(|| Summary::of(&self.latency_seconds))
    }

    /// Completed requests per second of wall time.
    pub fn throughput(&self, wall_seconds: f64) -> f64 {
        self.completed as f64 / wall_seconds.max(1e-12)
    }

    /// Cache hits / completed (0 when nothing completed).
    pub fn cache_hit_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.completed as f64
        }
    }

    /// End-to-end latency percentiles (`ps` in `[0, 100]`; 100 = max),
    /// sorting the sample set once for any number of percentiles.  `None`
    /// when nothing completed.  The single rollup used by the CLI, the
    /// `ServeMix` job and the serving bench.
    pub fn latency_percentiles(&self, ps: &[f64]) -> Option<Vec<f64>> {
        if self.latency_seconds.is_empty() {
            return None;
        }
        let mut sorted = self.latency_seconds.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(ps.iter().map(|&p| percentile_sorted(&sorted, p)).collect())
    }
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max consecutive same-artifact requests grouped into one batch.
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8 }
    }
}

// ---------------------------------------------------------------------------
// Executors
// ---------------------------------------------------------------------------

/// Result of one artifact execution.
#[derive(Clone, Copy, Debug)]
pub struct Exec {
    /// Execution wall time, seconds.
    pub seconds: f64,
    /// Output checksum (the pure-function response payload).
    pub payload: f64,
}

/// Execution backend of the serving core.
///
/// An executor is created *inside* its worker thread (see
/// [`ShardedServer::start`]) so implementations holding non-`Send` state —
/// the PJRT client above all — work unchanged.
pub trait Executor {
    /// One-time per-batch warmup: compile the executable, materialize
    /// inputs.  Paid before the batch's first execution so `execute` times
    /// exclude cold-start cost.
    fn prepare(&mut self, artifact: &str) -> Result<()>;

    /// Execute `artifact` once on its protocol inputs.
    fn execute(&mut self, artifact: &str) -> Result<Exec>;
}

/// PJRT-backed executor: serves compiled HLO artifacts via [`Registry`].
pub struct PjrtExecutor {
    registry: Registry,
}

impl PjrtExecutor {
    /// Executor over `<artifacts_dir>/manifest.json`.
    pub fn open(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(PjrtExecutor { registry: Registry::open(artifacts_dir)? })
    }

    /// Build from a manifest already parsed by the admission front-end —
    /// the thread-safe handle sharing path: `Arc<Manifest>` crosses threads,
    /// the PJRT client is created fresh per worker.
    pub fn with_manifest(manifest: Arc<Manifest>) -> Result<Self> {
        Ok(PjrtExecutor { registry: Registry::with_manifest(manifest)? })
    }
}

impl Executor for PjrtExecutor {
    fn prepare(&mut self, artifact: &str) -> Result<()> {
        self.registry.executable(artifact)?;
        self.registry.inputs(artifact)?;
        Ok(())
    }

    fn execute(&mut self, artifact: &str) -> Result<Exec> {
        let out = self.registry.run_protocol(artifact)?;
        let mut payload = 0.0;
        for lit in &out.outputs {
            payload += literal_checksum(lit)?;
        }
        Ok(Exec { seconds: out.seconds, payload })
    }
}

/// Artifact-free executor: serves the synthetic tiled-GEMM workloads named
/// by [`workloads::synthetic_artifact`].  Inputs are generated
/// deterministically per artifact (the compile-once analog: first request
/// pays materialization), so payloads are bit-identical across runs,
/// workers and worker counts — which is what the determinism and cache
/// tests assert.
pub struct SyntheticExecutor {
    schedule: GemmSchedule,
    inputs: HashMap<String, (Tensor<f32>, Tensor<f32>)>,
}

impl SyntheticExecutor {
    /// Executor with empty input caches.
    pub fn new() -> Self {
        SyntheticExecutor {
            schedule: GemmSchedule::new(32, 32, 32, 4),
            inputs: HashMap::new(),
        }
    }
}

impl Default for SyntheticExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for SyntheticExecutor {
    fn prepare(&mut self, artifact: &str) -> Result<()> {
        let n = workloads::synthetic_gemm_n(artifact)
            .ok_or_else(|| anyhow!("'{artifact}' is not a synthetic serving artifact"))?;
        if !self.inputs.contains_key(artifact) {
            let a = Tensor::rand_f32(&[n, n], 0xA0 + n as u64);
            let b = Tensor::rand_f32(&[n, n], 0xB0 + n as u64);
            self.inputs.insert(artifact.to_string(), (a, b));
        }
        Ok(())
    }

    fn execute(&mut self, artifact: &str) -> Result<Exec> {
        self.prepare(artifact)?;
        let (a, b) = &self.inputs[artifact];
        let t0 = Instant::now();
        let c = gemm::tiled(a, b, self.schedule);
        let seconds = t0.elapsed().as_secs_f64();
        let payload = c.data.iter().map(|x| *x as f64).sum();
        Ok(Exec { seconds, payload })
    }
}

// ---------------------------------------------------------------------------
// Single-threaded reference server
// ---------------------------------------------------------------------------

/// The original server: single-threaded leader loop over a PJRT registry.
///
/// Still the right tool when the PJRT client must stay on the leader and
/// worker parallelism is unwanted; [`ShardedServer`] is the scaling path.
pub struct Server {
    registry: Registry,
    policy: BatchPolicy,
    queue: VecDeque<(Request, Instant)>,
    /// Aggregate metrics of everything served so far.
    pub metrics: Metrics,
}

impl Server {
    /// Server over an opened registry.
    pub fn new(registry: Registry, policy: BatchPolicy) -> Self {
        Server {
            registry,
            policy,
            queue: VecDeque::new(),
            metrics: Metrics::default(),
        }
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests += 1;
        self.queue.push_back((req, Instant::now()));
    }

    /// Drain the queue, batching same-artifact runs; returns responses in
    /// completion order (FIFO except for batch grouping).
    pub fn drain(&mut self) -> Vec<Response> {
        let mut responses = Vec::with_capacity(self.queue.len());
        while let Some((head, t_enq)) = self.queue.pop_front() {
            // group consecutive same-artifact requests
            let mut batch = vec![(head, t_enq)];
            while batch.len() < self.policy.max_batch {
                match self.queue.front() {
                    Some((next, _)) if next.artifact == batch[0].0.artifact => {
                        batch.push(self.queue.pop_front().unwrap());
                    }
                    _ => break,
                }
            }
            self.metrics.batches += 1;
            // ensure compiled + inputs ready (first call pays compilation —
            // the server's warmup; excluded from exec time via pre-touch)
            let artifact = batch[0].0.artifact.clone();
            let prep: Result<()> = (|| {
                self.registry.executable(&artifact)?;
                self.registry.inputs(&artifact)?;
                Ok(())
            })();
            for (req, enq) in batch {
                match &prep {
                    Ok(()) => match self.registry.run_protocol(&req.artifact) {
                        Ok(out) => {
                            self.metrics.completed += 1;
                            self.metrics.exec_seconds.push(out.seconds);
                            let latency = enq.elapsed().as_secs_f64();
                            self.metrics.latency_seconds.push(latency);
                            responses.push(Response {
                                id: req.id,
                                artifact: req.artifact,
                                exec_seconds: out.seconds,
                                latency_seconds: latency,
                                ok: true,
                                error: None,
                                payload: None,
                                cached: false,
                                shard: 0,
                            });
                        }
                        Err(e) => responses.push(self.fail(req, enq, e.to_string())),
                    },
                    Err(e) => {
                        let msg = e.to_string();
                        responses.push(self.fail(req, enq, msg));
                    }
                }
            }
        }
        responses
    }

    fn fail(&mut self, req: Request, enq: Instant, error: String) -> Response {
        self.metrics.failed += 1;
        Response {
            id: req.id,
            artifact: req.artifact,
            exec_seconds: 0.0,
            latency_seconds: enq.elapsed().as_secs_f64(),
            ok: false,
            error: Some(error),
            payload: None,
            cached: false,
            shard: 0,
        }
    }

    /// Requests still queued.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

// ---------------------------------------------------------------------------
// Sharded multi-worker server
// ---------------------------------------------------------------------------

/// Configuration of the sharded serving core.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads.  Each owns the shards `{s : s mod workers == w}`.
    pub workers: usize,
    /// Shard count; 0 means auto (`4 × workers`).  More shards than workers
    /// smooths load imbalance without breaking artifact affinity.
    pub shards: usize,
    /// Per-worker LRU response-cache entries; 0 disables caching.
    pub cache_entries: usize,
    /// Batching policy (max consecutive same-artifact runs).
    pub batch: BatchPolicy,
    /// Admission-time catalog: requests whose artifact is not in the
    /// manifest are rejected at the front door without touching a worker.
    /// Shared with `PjrtExecutor` workers via `Arc` — the one registry
    /// handle that *is* thread-safe.
    pub catalog: Option<Arc<Manifest>>,
    /// Per-artifact cache profiles (telemetry subsystem).  When present,
    /// [`Metrics::worker_pressure`] reports each worker's resident
    /// working-set estimate, and [`PlacementPolicy::CacheAware`] has the
    /// data it needs to plan.
    pub profiles: Option<Arc<BTreeMap<String, CacheProfile>>>,
    /// How artifacts map to workers: the hash baseline, or a greedy
    /// cache-aware plan over `profiles` (`super::placement`).
    pub placement: PlacementPolicy,
    /// CPU profile pricing the cache-aware plan (None defaults to the
    /// Cortex-A53, the part the synthetic serving mix is calibrated
    /// against).
    pub cpu: Option<CpuSpec>,
    /// Observed-vs-predicted pressure divergence (fraction, `[0, 1]`)
    /// beyond which [`ShardedServer::finish`] computes a rebalanced
    /// placement ([`ServeOutcome::rebalanced`]).
    pub rebalance_threshold: f64,
}

impl ServeConfig {
    /// Config for `workers` worker threads with every option at its
    /// baseline (auto shards, no cache, hash placement).
    pub fn new(workers: usize) -> Self {
        ServeConfig {
            workers: workers.max(1),
            shards: 0,
            cache_entries: 0,
            batch: BatchPolicy::default(),
            catalog: None,
            profiles: None,
            placement: PlacementPolicy::default(),
            cpu: None,
            rebalance_threshold: 0.25,
        }
    }

    /// Enable the per-worker LRU response cache with `entries` entries.
    pub fn with_cache(mut self, entries: usize) -> Self {
        self.cache_entries = entries;
        self
    }

    /// Attach the admission-time artifact catalog.
    pub fn with_catalog(mut self, catalog: Arc<Manifest>) -> Self {
        self.catalog = Some(catalog);
        self
    }

    /// Attach per-artifact cache profiles (enables pressure reporting and
    /// cache-aware placement).
    pub fn with_profiles(mut self, profiles: Arc<BTreeMap<String, CacheProfile>>) -> Self {
        self.profiles = Some(profiles);
        self
    }

    /// Select the placement policy.
    pub fn with_placement(mut self, placement: PlacementPolicy) -> Self {
        self.placement = placement;
        self
    }

    /// Price the cache-aware plan against `cpu` instead of the default
    /// Cortex-A53.
    pub fn with_cpu(mut self, cpu: CpuSpec) -> Self {
        self.cpu = Some(cpu);
        self
    }

    fn n_shards(&self) -> usize {
        if self.shards == 0 {
            self.workers * 4
        } else {
            self.shards.max(self.workers)
        }
    }
}

struct Envelope {
    req: Request,
    enqueued: Instant,
    shard: usize,
}

/// Everything a finished serving run produced.
#[derive(Debug)]
pub struct ServeOutcome {
    /// Responses in completion order (per-artifact subsequences are in
    /// admission order — the FIFO invariant).
    pub responses: Vec<Response>,
    /// Aggregate serving metrics (per-shard and per-worker rollups inside).
    pub metrics: Metrics,
    /// Wall time from server start to drain completion.
    pub wall_seconds: f64,
    /// Set when a cache-aware run's observed per-worker pressure diverged
    /// from the plan beyond `ServeConfig::rebalance_threshold`: the
    /// re-planned placement over the artifacts actually served — the
    /// server's feedback hook ([`super::placement::Placement::rebalance`]).
    pub rebalanced: Option<Placement>,
}

/// The sharded multi-worker serving core.  See the module docs for the
/// design and invariants.
pub struct ShardedServer {
    n_shards: usize,
    workers: usize,
    catalog: Option<Arc<Manifest>>,
    profiles: Option<Arc<BTreeMap<String, CacheProfile>>>,
    /// The cache-aware plan, when the config asked for one and profiles
    /// were available; None under hash placement.
    placement: Option<Arc<Placement>>,
    /// CPU the plan was priced against (also used by the rebalance hook).
    cpu: CpuSpec,
    rebalance_threshold: f64,
    senders: Vec<mpsc::Sender<Envelope>>,
    resp_rx: mpsc::Receiver<Response>,
    handles: Vec<thread::JoinHandle<Vec<ShardMetrics>>>,
    admitted: u64,
    rejected: Vec<Response>,
    /// Distinct artifacts admitted per worker (working-set accounting).
    worker_artifacts: Vec<BTreeSet<String>>,
    started: Instant,
}

impl ShardedServer {
    /// Spawn the worker pool.  `factory` runs once *inside* each worker
    /// thread to build that worker's executor (PJRT clients are not `Send`,
    /// so they must be born where they live); a factory error fails that
    /// worker's requests cleanly instead of panicking.
    pub fn start<E, F>(config: ServeConfig, factory: F) -> Self
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let n_shards = config.n_shards();
        let workers = config.workers;
        let cpu = config
            .cpu
            .clone()
            .unwrap_or_else(|| profile_by_name("a53").expect("builtin profile").cpu);
        // The cache-aware plan needs profiles; without them the policy
        // silently degrades to hash (the CLI surfaces a note).
        let placement_plan = match (config.placement, &config.profiles) {
            (PlacementPolicy::CacheAware, Some(profiles)) => Some(Arc::new(placement::plan(
                &InterferenceModel::new(&cpu),
                profiles,
                workers,
            ))),
            _ => None,
        };
        let factory = Arc::new(factory);
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::channel::<Envelope>();
            senders.push(tx);
            let resp_tx = resp_tx.clone();
            let factory = factory.clone();
            let batch = config.batch;
            let cache_entries = config.cache_entries;
            let handle = thread::Builder::new()
                .name(format!("serve-worker-{w}"))
                .spawn(move || worker_loop(w, rx, resp_tx, (*factory)(w), batch, cache_entries))
                .expect("spawn serve worker");
            handles.push(handle);
        }
        ShardedServer {
            n_shards,
            workers,
            catalog: config.catalog,
            profiles: config.profiles,
            placement: placement_plan,
            cpu,
            rebalance_threshold: config.rebalance_threshold,
            senders,
            resp_rx,
            handles,
            admitted: 0,
            rejected: Vec::new(),
            worker_artifacts: vec![BTreeSet::new(); workers],
            started: Instant::now(),
        }
    }

    /// The cache-aware plan this server routes by (None under hash
    /// placement or when no profiles were attached).
    pub fn placement(&self) -> Option<&Placement> {
        self.placement.as_deref()
    }

    /// Shard count of this server.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Worker-thread count of this server.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Shard a request and hand it to the owning worker.  Unknown artifacts
    /// (when a catalog is attached) are rejected here, producing their one
    /// response without any worker round-trip.
    pub fn submit(&mut self, req: Request) {
        if let Some(cat) = &self.catalog {
            if cat.by_name(&req.artifact).is_none() {
                self.rejected.push(Response {
                    id: req.id,
                    artifact: req.artifact,
                    exec_seconds: 0.0,
                    latency_seconds: 0.0,
                    ok: false,
                    error: Some("artifact not in manifest (rejected at admission)".into()),
                    payload: None,
                    cached: false,
                    shard: 0,
                });
                return;
            }
        }
        let shard = shard_for(&req.artifact, self.n_shards);
        // The plan overrides the shard→worker hash for artifacts it covers;
        // per-artifact FIFO survives because an artifact still maps to one
        // shard queue on one (consistently chosen) worker.
        let worker = self
            .placement
            .as_ref()
            .and_then(|p| p.worker_for(&req.artifact))
            .unwrap_or(shard % self.workers);
        self.admitted += 1;
        if !self.worker_artifacts[worker].contains(&req.artifact) {
            self.worker_artifacts[worker].insert(req.artifact.clone());
        }
        self.senders[worker]
            .send(Envelope { req, enqueued: Instant::now(), shard })
            .expect("serve worker alive");
    }

    /// Submit an entire request stream (ids assigned in stream order) and
    /// drain to completion — the synchronous drive shared by the CLI, the
    /// `ServeMix` job, the invariant tests and `bench_serve`.
    pub fn serve_stream<I>(mut self, stream: I) -> ServeOutcome
    where
        I: IntoIterator<Item = String>,
    {
        for (id, artifact) in stream.into_iter().enumerate() {
            self.submit(Request { id: id as u64, artifact });
        }
        self.finish()
    }

    /// Collect any responses already available, without blocking.
    pub fn poll_responses(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        while let Ok(r) = self.resp_rx.try_recv() {
            out.push(r);
        }
        out
    }

    /// Close admission, drain every in-flight request, join the workers and
    /// roll per-shard metrics up into the aggregate [`Metrics`].
    pub fn finish(self) -> ServeOutcome {
        let ShardedServer {
            senders,
            resp_rx,
            handles,
            admitted,
            rejected,
            started,
            profiles,
            placement,
            cpu,
            rebalance_threshold,
            worker_artifacts,
            ..
        } = self;
        drop(senders); // workers drain their queues and exit
        let mut responses: Vec<Response> = resp_rx.iter().collect();
        // Keyed by (shard, worker), not shard alone: a cache-aware plan may
        // route two same-shard artifacts to different workers, and folding
        // those rows together would misattribute the owning worker.  Under
        // hash placement a shard has exactly one owner, so the keys — and
        // the rollup — are identical to the shard-only version.
        let mut per_shard: BTreeMap<(usize, usize), ShardMetrics> = BTreeMap::new();
        for h in handles {
            for sm in h.join().expect("serve worker panicked") {
                per_shard
                    .entry((sm.shard, sm.worker))
                    .and_modify(|acc| acc.merge(&sm))
                    .or_insert(sm);
            }
        }
        let wall_seconds = started.elapsed().as_secs_f64();

        let mut metrics = Metrics {
            requests: admitted + rejected.len() as u64,
            ..Metrics::default()
        };
        for r in &responses {
            if r.ok {
                metrics.completed += 1;
                metrics.exec_seconds.push(r.exec_seconds);
                metrics.latency_seconds.push(r.latency_seconds);
                if r.cached {
                    metrics.cache_hits += 1;
                }
            } else {
                metrics.failed += 1;
            }
        }
        metrics.failed += rejected.len() as u64;
        metrics.rejected = rejected.len() as u64;
        metrics.batches = per_shard.values().map(|s| s.batches).sum();
        metrics.per_shard = per_shard.into_values().collect();
        if let Some(profiles) = &profiles {
            metrics.worker_pressure = worker_artifacts
                .iter()
                .enumerate()
                .map(|(worker, artifacts)| {
                    let mut p = WorkerPressure {
                        worker,
                        artifacts: artifacts.len() as u64,
                        predicted_bytes: placement
                            .as_ref()
                            .map_or(0, |pl| pl.predicted_bytes(worker)),
                        ..WorkerPressure::default()
                    };
                    for a in artifacts {
                        if let Some(profile) = profiles.get(a) {
                            p.profiled += 1;
                            p.resident_bytes += profile.working_set_bytes;
                        }
                    }
                    p
                })
                .collect();
        }
        // The rebalance hook: when the plan's predicted pressure diverged
        // from what this run actually put on each worker, re-plan over the
        // artifacts that were really served.
        let rebalanced = match (&placement, &profiles) {
            (Some(plan), Some(profiles)) if !metrics.worker_pressure.is_empty() => {
                let observed: BTreeMap<String, CacheProfile> = worker_artifacts
                    .iter()
                    .flatten()
                    .filter_map(|a| profiles.get(a).map(|p| (a.clone(), p.clone())))
                    .collect();
                plan.rebalance(
                    &InterferenceModel::new(&cpu),
                    &observed,
                    &metrics.worker_pressure,
                    rebalance_threshold,
                )
            }
            _ => None,
        };
        responses.extend(rejected);
        ServeOutcome { responses, metrics, wall_seconds, rebalanced }
    }
}

/// One worker: drains its envelope channel into per-shard FIFO queues and
/// serves them batch-by-batch, oldest shard head first.
fn worker_loop<E: Executor>(
    worker: usize,
    rx: mpsc::Receiver<Envelope>,
    resp_tx: mpsc::Sender<Response>,
    executor: Result<E>,
    batch_policy: BatchPolicy,
    cache_entries: usize,
) -> Vec<ShardMetrics> {
    let mut executor = executor;
    let mut queues: BTreeMap<usize, VecDeque<Envelope>> = BTreeMap::new();
    let mut metrics: BTreeMap<usize, ShardMetrics> = BTreeMap::new();
    let mut cache: LruCache<String, f64> = LruCache::new(cache_entries);
    let mut open = true;

    loop {
        let queued = queues.values().map(|q| q.len()).sum::<usize>();
        if queued == 0 {
            if !open {
                break;
            }
            // idle: block for the next request (or channel close)
            match rx.recv() {
                Ok(env) => queues.entry(env.shard).or_default().push_back(env),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // soak up whatever else has arrived, without blocking
        while open {
            match rx.try_recv() {
                Ok(env) => queues.entry(env.shard).or_default().push_back(env),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // serve one batch from the shard whose head request is oldest
        let Some(shard) = queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q.front().unwrap().enqueued)
            .map(|(s, _)| *s)
        else {
            continue;
        };
        let queue = queues.get_mut(&shard).unwrap();
        let mut batch = vec![queue.pop_front().unwrap()];
        while batch.len() < batch_policy.max_batch {
            match queue.front() {
                Some(next) if next.req.artifact == batch[0].req.artifact => {
                    batch.push(queue.pop_front().unwrap());
                }
                _ => break,
            }
        }

        let artifact = batch[0].req.artifact.clone();
        let sm = metrics
            .entry(shard)
            .or_insert_with(|| ShardMetrics::new(shard, worker));
        sm.batches += 1;
        sm.requests += batch.len() as u64;

        // skip executor warmup when the whole batch will hit the cache
        let prep = if cache.contains(&artifact) {
            Ok(())
        } else {
            match &mut executor {
                Ok(ex) => ex.prepare(&artifact),
                Err(e) => Err(anyhow!("executor unavailable: {e:#}")),
            }
        };

        for env in batch {
            let latency = env.enqueued.elapsed().as_secs_f64();
            if let Some(&payload) = cache.get(&env.req.artifact) {
                sm.completed += 1;
                sm.cache_hits += 1;
                sm.latency.record(latency);
                let _ = resp_tx.send(Response {
                    id: env.req.id,
                    artifact: env.req.artifact,
                    exec_seconds: 0.0,
                    latency_seconds: latency,
                    ok: true,
                    error: None,
                    payload: Some(payload),
                    cached: true,
                    shard,
                });
                continue;
            }
            let result = match (&mut executor, &prep) {
                (Ok(ex), Ok(())) => ex.execute(&env.req.artifact),
                (_, Err(e)) => Err(anyhow!("{e:#}")),
                (Err(e), _) => Err(anyhow!("executor unavailable: {e:#}")),
            };
            match result {
                Ok(exec) => {
                    cache.put(env.req.artifact.clone(), exec.payload);
                    let latency = env.enqueued.elapsed().as_secs_f64();
                    sm.completed += 1;
                    sm.latency.record(latency);
                    let _ = resp_tx.send(Response {
                        id: env.req.id,
                        artifact: env.req.artifact,
                        exec_seconds: exec.seconds,
                        latency_seconds: latency,
                        ok: true,
                        error: None,
                        payload: Some(exec.payload),
                        cached: false,
                        shard,
                    });
                }
                Err(e) => {
                    sm.failed += 1;
                    let _ = resp_tx.send(Response {
                        id: env.req.id,
                        artifact: env.req.artifact,
                        exec_seconds: 0.0,
                        latency_seconds: env.enqueued.elapsed().as_secs_f64(),
                        ok: false,
                        error: Some(e.to_string()),
                        payload: None,
                        cached: false,
                        shard,
                    });
                }
            }
        }
    }
    metrics.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Option<Registry> {
        Registry::open("artifacts").ok()
    }

    #[test]
    fn serves_requests_fifo_with_batching() {
        let Some(reg) = registry() else {
            eprintln!("skipping: no artifacts/");
            return;
        };
        let mut srv = Server::new(reg, BatchPolicy { max_batch: 4 });
        // interleaved artifacts: a a b a -> batches [a,a], [b], [a];
        // only *consecutive* same-artifact requests group, so completion
        // order stays strictly FIFO.
        for (id, art) in [
            (0u64, "gemm_f32_tuned_n32"),
            (1, "gemm_f32_tuned_n32"),
            (2, "gemm_f32_naive_n32"),
            (3, "gemm_f32_tuned_n32"),
        ] {
            srv.submit(Request { id, artifact: art.into() });
        }
        let resp = srv.drain();
        assert_eq!(resp.len(), 4);
        assert!(resp.iter().all(|r| r.ok), "{resp:?}");
        assert_eq!(resp.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(srv.metrics.batches, 3);
        assert_eq!(srv.metrics.completed, 4);
        assert_eq!(srv.queue_len(), 0);
    }

    #[test]
    fn unknown_artifact_fails_cleanly() {
        let Some(reg) = registry() else { return };
        let mut srv = Server::new(reg, BatchPolicy::default());
        srv.submit(Request { id: 9, artifact: "no_such_artifact".into() });
        let resp = srv.drain();
        assert_eq!(resp.len(), 1);
        assert!(!resp[0].ok);
        assert_eq!(srv.metrics.failed, 1);
        assert_eq!(srv.metrics.completed, 0);
    }

    #[test]
    fn metrics_totals_consistent() {
        let Some(reg) = registry() else { return };
        let mut srv = Server::new(reg, BatchPolicy { max_batch: 2 });
        for id in 0..5u64 {
            srv.submit(Request { id, artifact: "gemm_f32_tuned_n32".into() });
        }
        let t0 = Instant::now();
        let resp = srv.drain();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(resp.len(), 5);
        assert_eq!(srv.metrics.requests, 5);
        assert_eq!(srv.metrics.completed + srv.metrics.failed, 5);
        assert!(srv.metrics.throughput(wall) > 0.0);
        let s = srv.metrics.exec_summary().unwrap();
        assert!(s.median > 0.0);
        // latency includes queueing: never below exec time for any request
        for r in &resp {
            assert!(r.latency_seconds >= r.exec_seconds * 0.5);
        }
    }

    // -- sharded server unit tests (artifact-free; the full multi-worker
    //    invariant suite lives in rust/tests/serve_multiworker.rs) --

    fn synthetic_server(workers: usize, cache: usize) -> ShardedServer {
        ShardedServer::start(ServeConfig::new(workers).with_cache(cache), |_w| {
            Ok(SyntheticExecutor::new())
        })
    }

    #[test]
    fn sharded_serves_a_mixed_stream() {
        let mut srv = synthetic_server(2, 0);
        let names = workloads::serving_mix();
        for id in 0..12u64 {
            let artifact = names[id as usize % names.len()].artifact.clone();
            srv.submit(Request { id, artifact });
        }
        let out = srv.finish();
        assert_eq!(out.responses.len(), 12);
        assert!(out.responses.iter().all(|r| r.ok), "{:?}", out.responses);
        assert_eq!(out.metrics.requests, 12);
        assert_eq!(out.metrics.completed, 12);
        assert!(out.metrics.batches >= 1);
        assert!(out.wall_seconds > 0.0);
    }

    #[test]
    fn sharded_unknown_artifact_fails_cleanly() {
        let mut srv = synthetic_server(2, 8);
        srv.submit(Request { id: 0, artifact: "no_such_synthetic".into() });
        srv.submit(Request { id: 1, artifact: workloads::synthetic_artifact(32) });
        let out = srv.finish();
        assert_eq!(out.responses.len(), 2);
        let bad = out.responses.iter().find(|r| r.id == 0).unwrap();
        assert!(!bad.ok);
        assert!(bad.error.as_deref().unwrap().contains("synthetic"));
        let good = out.responses.iter().find(|r| r.id == 1).unwrap();
        assert!(good.ok);
        assert_eq!(out.metrics.completed, 1);
        assert_eq!(out.metrics.failed, 1);
    }

    #[test]
    fn cache_profiles_surface_worker_pressure() {
        use crate::hw::profile_by_name;
        use crate::telemetry::synthetic_gemm_profile;

        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let profiles: BTreeMap<String, CacheProfile> = mix
            .iter()
            .take(3)
            .map(|m| (m.artifact.clone(), synthetic_gemm_profile(&cpu, &m.artifact, m.n)))
            .collect();
        let profiles = Arc::new(profiles);
        let mut srv = ShardedServer::start(
            ServeConfig::new(2).with_profiles(profiles.clone()),
            |_w| Ok(SyntheticExecutor::new()),
        );
        for id in 0..16u64 {
            let artifact = mix[id as usize % mix.len()].artifact.clone();
            srv.submit(Request { id, artifact });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.worker_pressure.len(), 2);
        let total_artifacts: u64 =
            out.metrics.worker_pressure.iter().map(|p| p.artifacts).sum();
        assert_eq!(total_artifacts, mix.len() as u64, "each artifact on exactly one worker");
        let total_profiled: u64 =
            out.metrics.worker_pressure.iter().map(|p| p.profiled).sum();
        assert_eq!(total_profiled, 3);
        let resident: u64 =
            out.metrics.worker_pressure.iter().map(|p| p.resident_bytes).sum();
        let expected: u64 = profiles.values().map(|p| p.working_set_bytes).sum();
        assert_eq!(resident, expected);
    }

    #[test]
    fn no_profiles_means_no_pressure_rows() {
        let mut srv = synthetic_server(2, 0);
        srv.submit(Request { id: 0, artifact: workloads::synthetic_artifact(32) });
        let out = srv.finish();
        assert!(out.metrics.worker_pressure.is_empty());
    }

    /// The shared (cached) serving-mix profiles — the replays dominate
    /// test time, so every test reuses one traced set.
    fn mix_profiles() -> Arc<BTreeMap<String, CacheProfile>> {
        crate::telemetry::serving_mix_profiles(&profile_by_name("a53").unwrap().cpu)
    }

    #[test]
    fn cache_aware_placement_routes_by_plan() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_profiles(mix_profiles())
                .with_placement(PlacementPolicy::CacheAware)
                .with_cpu(cpu),
            |_w| Ok(SyntheticExecutor::new()),
        );
        let plan = srv.placement().expect("profiles + cache-aware => a plan").clone();
        assert_eq!(plan.assignments.len(), mix.len());
        for id in 0..20u64 {
            let artifact = mix[id as usize % mix.len()].artifact.clone();
            srv.submit(Request { id, artifact });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.completed, 20);
        // every artifact was served, so observed pressure must reconcile
        // exactly with the plan's per-worker prediction — proof the
        // admission path actually routed by the plan
        assert_eq!(out.metrics.worker_pressure.len(), 2);
        for row in &out.metrics.worker_pressure {
            assert_eq!(row.predicted_bytes, plan.predicted_bytes(row.worker));
            assert_eq!(
                row.resident_bytes, row.predicted_bytes,
                "worker {} diverged from the plan",
                row.worker
            );
        }
        assert!(out.rebalanced.is_none(), "no divergence when the stream matches the plan");
    }

    #[test]
    fn hash_placement_reports_no_predicted_pressure() {
        let mut srv = ShardedServer::start(
            ServeConfig::new(2).with_profiles(mix_profiles()),
            |_w| Ok(SyntheticExecutor::new()),
        );
        assert!(srv.placement().is_none());
        srv.submit(Request { id: 0, artifact: workloads::synthetic_artifact(32) });
        let out = srv.finish();
        assert!(out.metrics.worker_pressure.iter().all(|p| p.predicted_bytes == 0));
        assert!(out.rebalanced.is_none());
    }

    #[test]
    fn pressure_divergence_triggers_rebalance_hint() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_profiles(mix_profiles())
                .with_placement(PlacementPolicy::CacheAware)
                .with_cpu(cpu),
            |_w| Ok(SyntheticExecutor::new()),
        );
        // the plan expected the whole mix; serve only one artifact
        for id in 0..8u64 {
            srv.submit(Request { id, artifact: mix[0].artifact.clone() });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.completed, 8);
        let re = out.rebalanced.expect("one-artifact stream must diverge from the plan");
        assert_eq!(re.assignments.len(), 1, "re-planned over what was actually served");
        assert!(re.assignments.contains_key(&mix[0].artifact));
    }

    #[test]
    fn worker_factory_failure_fails_requests_not_process() {
        let mut srv = ShardedServer::start(ServeConfig::new(2), |_w| {
            Err::<SyntheticExecutor, _>(anyhow!("no backend on this host"))
        });
        for id in 0..4u64 {
            srv.submit(Request { id, artifact: workloads::synthetic_artifact(32) });
        }
        let out = srv.finish();
        assert_eq!(out.responses.len(), 4);
        assert!(out.responses.iter().all(|r| !r.ok));
        assert_eq!(out.metrics.failed, 4);
        assert!(out.responses[0].error.as_deref().unwrap().contains("no backend"));
    }
}
