//! Inference serving: admission queue → artifact shards → worker pool.
//!
//! The deployment face of the L3 coordinator, in two tiers:
//!
//! * [`Server`] — the original single-threaded leader loop (request queue →
//!   compile-once batcher → PJRT execution).  Kept as the reference
//!   implementation and the baseline that `bench_serve` scales against.
//! * [`ShardedServer`] — the multi-worker serving core.  A front-end
//!   admission queue hashes each request's artifact name to one of
//!   `n_shards` queues ([`super::shard::shard_for`]); each worker owns the
//!   disjoint set of shards `{s : s mod workers == w}`, so an artifact's
//!   compiled executable, protocol inputs and response-cache entry live on
//!   exactly one worker.  Workers batch consecutive same-artifact requests
//!   (the compile-once batching axis that matters for shape-static XLA
//!   executables), consult a per-worker LRU response cache for repeated
//!   pure requests, and record per-shard latency histograms that roll up
//!   into the aggregate [`Metrics`].
//!
//! Execution is abstracted behind [`Executor`] so the core is testable and
//! benchmarkable without AOT artifacts: [`PjrtExecutor`] serves compiled
//! HLO through the PJRT registry (constructed *inside* each worker thread —
//! the PJRT client is not `Send`, only the parsed manifest is shared, via
//! `Arc`), while [`SyntheticExecutor`] serves the native synthetic
//! workloads of `operators::workloads::serving_mix_tiered` — tiled f32
//! GEMM plus its int8 and packed bit-serial precision-tier twins.
//!
//! Invariants (tested in `rust/tests/serve_multiworker.rs` and, across
//! live migrations, `rust/tests/serve_migration.rs`):
//!
//! * **per-artifact FIFO** — an artifact maps to one shard queue on one
//!   (consistently chosen) worker, and each shard queue is drained
//!   front-to-back, so responses for any given artifact are emitted in
//!   admission order even with many workers and no global lock.  Under
//!   hash placement a shard has exactly one owning worker; a cache-aware
//!   plan may split a shard's artifacts across workers, in which case the
//!   per-shard rollup keeps one [`ShardMetrics`] row per (shard, worker);
//! * **exactly one disposition per request** — every submitted request is
//!   answered exactly once: served (success, failure, or cache hit),
//!   shed at the front door by admission control, or served *degraded*
//!   as a smaller synthetic variant.  Never silent, never duplicated;
//! * **metrics totals** — `completed + failed + shed == requests` in the
//!   aggregate [`Metrics`], the per-[`ShardMetrics`] sums equal the
//!   aggregate minus front-door answers (`Metrics::rejected` +
//!   `Metrics::shed`), which never reach a shard, and
//!   `latency_seconds` holds one sample per disposition — shed requests
//!   contribute their time-to-rejection instead of vanishing from the
//!   percentile population;
//! * **cache purity** — a cache hit returns a payload bit-identical to the
//!   original execution, with `exec_seconds == 0` and `cached == true`.
//!
//! # Live migration
//!
//! [`RebalanceMode::Live`] closes the telemetry → scheduling feedback loop
//! *mid-stream*: when the observed per-worker working-set pressure diverges
//! from the active plan past `ServeConfig::rebalance_threshold`, the
//! coordinator thread re-plans over the artifacts actually being served and
//! moves the ones whose assignment changed ([`ShardedServer::maybe_rebalance`];
//! [`ShardedServer::migrate`] is the forced variant the chaos tests drive).
//! One artifact moves in four steps, fenced so the protocol stays correct
//! even while other threads admit concurrently (§Admission concurrency):
//!
//! 1. **hold** — the target worker is told to *pen* incoming requests for
//!    the artifact (a `Hold` fence down its channel): they queue in
//!    arrival order but are not served until the state arrives;
//! 2. **swap + grace** — the coordinator publishes the new route as a
//!    fresh epoch of the [`super::routing`] table (one atomic pointer
//!    swap), then waits for every admission reader to advance past the
//!    old epoch ([`super::routing::RouteWriter::wait_for_readers`]).
//!    After the grace period, every request routed by the *old* table has
//!    already reached the source's channel, and every *new* admission
//!    routes to the target — where the pen holds it;
//! 3. **quiesce** — a `Quiesce` fence is sent down the source worker's
//!    request channel.  Channel FIFO means every pre-swap request is
//!    already in the worker's local queues when the fence is dequeued;
//!    the worker extracts and serves *only the migrating artifact's*
//!    queued requests (other shard queues are untouched), then exports
//!    the artifact's LRU response-cache entry and transferable executor
//!    state ([`Executor::export_state`]) and acks;
//! 4. **adopt** — the state is forwarded down the target worker's
//!    channel, which installs it and releases the pen.  The ack → adopt →
//!    release ordering makes every penned response *causally after* the
//!    source's last response, which is what preserves per-artifact FIFO
//!    end to end.
//!
//! No request is ever dropped or duplicated: quiesce and the pen release
//! serve queued work through the ordinary path, and the route swap is one
//! atomic publish.  Every move is logged as a [`MigrationRecord`].
//!
//! # Admission concurrency
//!
//! Admission used to serialize on the coordinator thread's authoritative
//! `routes: BTreeMap` — the next throughput ceiling once the operators
//! run at the cache bound.  Routing now lives in an epoch-versioned,
//! immutable [`super::routing::RouteTable`]: admission pins a snapshot
//! with one atomic load, makes the *entire* disposition decision
//! (catalog check, route, shed/degrade, enqueue) against that one table,
//! and unpins.  [`ShardedServer::admission_handle`] mints a movable
//! [`AdmissionHandle`] per admission thread; `serve --admission-threads N`
//! (and [`ServeConfig::admission_threads`]) drives the built-in streams
//! through N such handles, partitioned by artifact hash so per-artifact
//! admission order — and therefore the FIFO invariant — is preserved per
//! submitting thread.  The coordinator thread keeps the single-writer
//! roles: reaping responses, folding the handles' first-touch
//! notifications into the residency accounting, the rebalance cadence,
//! and every route publish.  The chaos suite
//! (`rust/tests/serve_admission.rs`) drives concurrent admission against
//! seeded migration storms; `rust/tests/proptests.rs` pins the
//! route-table invariants themselves.
//!
//! # Open-loop serving and admission control
//!
//! [`ShardedServer::serve_stream`] is closed-loop (submit all, drain) and
//! cannot exhibit queueing collapse.  [`ShardedServer::serve_open_loop`]
//! submits on the wall-clock schedule of a seeded arrival process
//! ([`super::loadgen::ArrivalConfig`]) instead, which is the regime where
//! [`AdmissionMode`] matters: when a request's target worker already has
//! `ServeConfig::admission_limit` requests in flight (halved when the
//! worker's profiled resident working set overflows the L2 — the
//! [`WorkerPressure`] signal), `Shed` answers it at the front door with
//! `Response::shed == true`, and `Degrade` reroutes it to a smaller
//! synthetic variant — down the size ladder of its own precision tier
//! ([`workloads::degrade_artifact_within_tier`], the default
//! [`TierPolicy::Pinned`]) or down the precision lattice fp32 → int8 →
//! bit-serial at the same N ([`workloads::degrade_artifact`], under
//! [`TierPolicy::DownshiftOnPressure`]) — the degrade-to-quantized policy
//! of DESIGN.md §Admission and §Tiers — shedding only when no smaller
//! variant exists.  Queue-depth samples, shed/degrade
//! counters and tail percentiles land in [`Metrics`]; the overload chaos
//! suite (`rust/tests/serve_overload.rs`) drives all of it over a seed
//! matrix.

use std::any::Any;
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::analysis::InterferenceModel;
use crate::hw::{profile_by_name, CpuSpec};
use crate::operators::bitserial::{self, Packed};
use crate::operators::gemm::{self, GemmSchedule};
use crate::operators::workloads::{self, Tier};
use crate::operators::{qnn, Tensor};
use crate::runtime::artifact_cache::{digest_hex, ArtifactCache, TOOLCHAIN_TAG};
use crate::runtime::inputs::literal_checksum;
use crate::runtime::{Manifest, Registry};
use crate::telemetry::CacheProfile;
use crate::util::lru::LruCache;
use crate::util::stats::{percentile_sorted, Summary};

use super::placement::{self, Placement, PlacementPolicy, RebalanceMode};
use super::routing::{RouteReader, RouteWriter};
use super::shard::{shard_for, ShardMetrics};

/// One inference request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    /// Caller-chosen request id, echoed in the response.
    pub id: u64,
    /// Artifact name to execute (the "model variant" being served).
    pub artifact: String,
}

/// One completed response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Id of the request this response answers.
    pub id: u64,
    /// Artifact that was executed.
    pub artifact: String,
    /// Execution wall time (excludes queueing; 0 for cache hits).
    pub exec_seconds: f64,
    /// Total latency including queue wait.
    pub latency_seconds: f64,
    /// Did execution succeed?
    pub ok: bool,
    /// Failure description when `ok` is false.
    pub error: Option<String>,
    /// Output checksum — the response payload.  Artifacts are pure
    /// functions of their protocol inputs, so this is identical across
    /// repeated requests (and bit-identical on cache hits).
    pub payload: Option<f64>,
    /// Served from the LRU response cache.
    pub cached: bool,
    /// Shard that owned the request (0 for the single-threaded [`Server`]).
    pub shard: usize,
    /// Worker that served the request (0 for front-door answers —
    /// rejections and sheds — and for the single-threaded [`Server`]).
    /// The coordinator's reaper decrements this worker's in-flight count,
    /// which stays correct across migrations: a quiesce serves queued
    /// envelopes at the source, so a request is always answered by the
    /// worker it was admitted to.
    pub worker: usize,
    /// Answered at the front door by admission control
    /// ([`AdmissionMode::Shed`], or [`AdmissionMode::Degrade`] with no
    /// smaller variant available).  Shed responses are not failures:
    /// `ok` is `false` but they count in [`Metrics::shed`], not
    /// [`Metrics::failed`].
    pub shed: bool,
    /// When admission control degraded this request, the artifact
    /// originally asked for; `artifact` (and `payload`) describe the
    /// smaller variant actually executed.
    pub degraded_from: Option<String>,
}

/// Aggregate serving metrics.
///
/// For the sharded server, totals equal the sums over `per_shard` (tested);
/// the single-threaded [`Server`] leaves `per_shard` empty.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Requests submitted (including rejected and shed ones).
    pub requests: u64,
    /// Successfully answered requests (degraded ones included).
    pub completed: u64,
    /// Failed requests (rejections included, shed ones NOT — a shed is a
    /// deliberate disposition, not an error).
    pub failed: u64,
    /// Executor batches formed.
    pub batches: u64,
    /// Responses served from the response cache (subset of `completed`).
    pub cache_hits: u64,
    /// Requests rejected at admission (unknown artifact under a catalog) —
    /// a subset of `failed` that reaches no shard, so per-shard sums cover
    /// `requests - rejected - shed`.
    pub rejected: u64,
    /// Requests shed by admission control ([`AdmissionMode::Shed`], or
    /// `Degrade` with no smaller variant).  Disjoint from `completed` and
    /// `failed`: `completed + failed + shed == requests`.
    pub shed: u64,
    /// Requests served as a smaller variant ([`AdmissionMode::Degrade`]) —
    /// a subset of `completed`; each carries `Response::degraded_from`.
    pub degraded: u64,
    /// Per-response execution times (successful executions only).
    pub exec_seconds: Vec<f64>,
    /// Per-response end-to-end latencies — one sample for *every*
    /// disposition: executed, cache hit, failed, rejected and shed (a
    /// shed's sample is its time-to-rejection), so
    /// `latency_seconds.len() == requests` and overload cannot silently
    /// thin the percentile population.
    pub latency_seconds: Vec<f64>,
    /// Queue-depth time series: `(seconds since server start, total
    /// in-flight requests)`, sampled at every submission.  Under the
    /// open-loop drive this is the collapse signal the overload chaos
    /// suite asserts on; under `serve_stream` it just records the
    /// submit burst.
    pub queue_depth: Vec<(f64, u64)>,
    /// Per-shard rollup (sharded server only): one row per
    /// (shard, worker) pair — a single row per shard under hash placement,
    /// possibly several when a cache-aware plan splits a shard's artifacts.
    pub per_shard: Vec<ShardMetrics>,
    /// Per-worker working-set-pressure estimates (populated only when the
    /// server was started with per-artifact [`CacheProfile`]s).
    pub worker_pressure: Vec<WorkerPressure>,
    /// Every live migration the run performed, in execution order (empty
    /// unless [`RebalanceMode::Live`] fired or [`ShardedServer::migrate`]
    /// was called).
    pub migrations: Vec<MigrationRecord>,
    /// Per-artifact preparation log (sharded server only): one row per
    /// (worker, artifact) first touch, recording how long the artifact
    /// took to become servable and whether it was compiled from scratch
    /// or loaded warm from the persistent artifact cache.  This is the
    /// cold-vs-warm observability surface the CLI summary prints.
    pub prep: Vec<PrepRecord>,
}

/// How an artifact became servable on a worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrepSource {
    /// Compiled/materialized from scratch (a cache miss, or no cache).
    Compiled,
    /// Loaded from the persistent artifact cache on disk.
    DiskWarm,
}

impl PrepSource {
    /// Stable lowercase label for logs and CLI summaries.
    pub fn name(self) -> &'static str {
        match self {
            PrepSource::Compiled => "compiled",
            PrepSource::DiskWarm => "disk-warm",
        }
    }
}

/// One artifact becoming servable on one worker: the first-touch
/// preparation (compile or warm load), timed.  Pre-warmed migration
/// targets also log a row here — their load happens *before* the quiesce
/// fence, which is exactly the pause this record makes visible.
#[derive(Clone, Debug, PartialEq)]
pub struct PrepRecord {
    /// Worker the artifact was prepared on.
    pub worker: usize,
    /// Artifact name.
    pub artifact: String,
    /// Wall time of the preparation (compile or disk load + install).
    pub seconds: f64,
    /// Compiled fresh, or loaded warm from disk.
    pub source: PrepSource,
}

/// One completed live migration: an artifact quiesced on its source
/// worker, its state handed to the target, and the route swapped.  The
/// log the CLI prints and the chaos suite reconciles against.
#[derive(Clone, Debug, PartialEq)]
pub struct MigrationRecord {
    /// Admission count when the migration ran (the "seeded point" of the
    /// chaos harness).
    pub at_request: u64,
    /// Artifact that moved.
    pub artifact: String,
    /// Worker the artifact was quiesced on.
    pub from_worker: usize,
    /// Worker that adopted the artifact.
    pub to_worker: usize,
    /// Requests for the artifact still queued at the source when the fence
    /// arrived — served there, in order, before the handoff.
    pub drained: u64,
    /// Did an LRU response-cache entry move with the artifact?
    pub cache_moved: bool,
    /// Did transferable executor state move ([`Executor::export_state`])?
    /// `false` means the target pays one [`Executor::prepare`] instead.
    pub state_moved: bool,
    /// Observed-vs-predicted pressure divergence that triggered the move
    /// (0 for forced migrations).
    pub divergence: f64,
    /// `true` for [`ShardedServer::migrate`] calls, `false` for moves the
    /// live divergence check decided.
    pub forced: bool,
}

/// Cache working-set pressure of one worker: how many bytes of cache its
/// resident artifact set wants, from the telemetry subsystem's
/// per-artifact profiles.  The shard→worker affinity makes this a
/// per-worker property: an artifact's executable *and* its cache working
/// set live on exactly one worker.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerPressure {
    /// Worker index this row describes.
    pub worker: usize,
    /// Distinct artifacts routed to this worker.
    pub artifacts: u64,
    /// Of those, how many had a profile attached.
    pub profiled: u64,
    /// Σ `working_set_bytes` over the profiled artifacts — compare against
    /// the part's L1/L2 sizes to see whether the worker's mix is
    /// cache-resident.
    pub resident_bytes: u64,
    /// What the cache-aware placement plan *predicted* this worker would
    /// hold (0 under hash placement).  The gap between this and
    /// `resident_bytes` is what drives [`super::placement::Placement::rebalance`].
    pub predicted_bytes: u64,
}

impl Metrics {
    /// Summary of execution times (None when empty).
    pub fn exec_summary(&self) -> Option<Summary> {
        (!self.exec_seconds.is_empty()).then(|| Summary::of(&self.exec_seconds))
    }

    /// Summary of end-to-end latencies (None when empty).
    pub fn latency_summary(&self) -> Option<Summary> {
        (!self.latency_seconds.is_empty()).then(|| Summary::of(&self.latency_seconds))
    }

    /// Completed requests per second of wall time.
    pub fn throughput(&self, wall_seconds: f64) -> f64 {
        self.completed as f64 / wall_seconds.max(1e-12)
    }

    /// Cache hits / completed (0 when nothing completed).
    pub fn cache_hit_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.completed as f64
        }
    }

    /// End-to-end latency percentiles (`ps` in `[0, 100]`; 100 = max),
    /// sorting the sample set once for any number of percentiles.  `None`
    /// when nothing completed.  The single rollup used by the CLI, the
    /// `ServeMix` job and the serving bench.
    pub fn latency_percentiles(&self, ps: &[f64]) -> Option<Vec<f64>> {
        if self.latency_seconds.is_empty() {
            return None;
        }
        let mut sorted = self.latency_seconds.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(ps.iter().map(|&p| percentile_sorted(&sorted, p)).collect())
    }

    /// Largest in-flight count the `queue_depth` series observed (0 when
    /// the series is empty) — the bounded-queue invariant the overload
    /// chaos suite asserts under [`AdmissionMode::Shed`].
    pub fn max_queue_depth(&self) -> u64 {
        self.queue_depth.iter().map(|&(_, d)| d).max().unwrap_or(0)
    }
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max consecutive same-artifact requests grouped into one batch.
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8 }
    }
}

/// What admission control does when a request's target worker is already
/// at its in-flight limit (see `ServeConfig::admission_limit`).  The
/// closed-loop drives work under any mode; the distinction matters under
/// the open-loop drive, where arrivals do not wait for completions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Admit everything — queues grow without bound past saturation (the
    /// collapse regime the overload chaos suite detects).
    #[default]
    None,
    /// Answer over-limit requests at the front door with
    /// `Response::shed == true` — bounded queues, explicit rejections.
    Shed,
    /// Reroute over-limit requests to a smaller synthetic variant — the
    /// degrade-to-quantized policy: a smaller working set stays
    /// cache-resident and drains faster on a pressured worker.  Which
    /// axis shrinks (size ladder vs precision lattice) is the
    /// [`TierPolicy`]; falls back to shedding when no smaller variant
    /// exists.
    Degrade,
}

impl AdmissionMode {
    /// Parse a CLI flag value ("none" | "shed" | "degrade").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" | "off" => Ok(AdmissionMode::None),
            "shed" => Ok(AdmissionMode::Shed),
            "degrade" => Ok(AdmissionMode::Degrade),
            other => bail!("unknown admission mode '{other}' (none | shed | degrade)"),
        }
    }

    /// Display name ("none" | "shed" | "degrade").
    pub fn name(self) -> &'static str {
        match self {
            AdmissionMode::None => "none",
            AdmissionMode::Shed => "shed",
            AdmissionMode::Degrade => "degrade",
        }
    }

    /// Short fragment for job/result keys (same as [`Self::name`]).
    pub fn key_part(self) -> &'static str {
        self.name()
    }
}

/// How [`AdmissionMode::Degrade`] picks the smaller variant for an
/// over-limit request (DESIGN.md §Tiers).  Both policies shrink the
/// working set; they differ in *which axis* shrinks:
///
/// * [`TierPolicy::Pinned`] keeps the request's precision tier and steps
///   down the size ladder of its own tier's serving mix — the pre-tier
///   behaviour, and the default.
/// * [`TierPolicy::DownshiftOnPressure`] keeps N and walks the precision
///   lattice down instead: fp32 → int8 → bit-serial.  The answer is for
///   the *same model size* at lower precision — usually the better trade
///   when callers care about the shape of the output, and the bigger
///   working-set reduction per step (4 B → 1 B → 0.25 B per operand
///   element).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TierPolicy {
    /// Degrade to the next-smaller N inside the request's own precision
    /// tier ([`workloads::degrade_artifact_within_tier`]); shed below the
    /// tier's smallest variant.
    #[default]
    Pinned,
    /// Downshift precision at the same N
    /// ([`workloads::degrade_artifact`]); shed only below the bit-serial
    /// floor.
    DownshiftOnPressure,
}

impl TierPolicy {
    /// Parse a CLI flag value ("pinned" | "downshift").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pinned" | "pin" => Ok(TierPolicy::Pinned),
            "downshift" | "down" => Ok(TierPolicy::DownshiftOnPressure),
            other => bail!("unknown tier policy '{other}' (pinned | downshift)"),
        }
    }

    /// Display name ("pinned" | "downshift").
    pub fn name(self) -> &'static str {
        match self {
            TierPolicy::Pinned => "pinned",
            TierPolicy::DownshiftOnPressure => "downshift",
        }
    }

    /// Short fragment for job/result keys ("pin" | "down").
    pub fn key_part(self) -> &'static str {
        match self {
            TierPolicy::Pinned => "pin",
            TierPolicy::DownshiftOnPressure => "down",
        }
    }
}

// ---------------------------------------------------------------------------
// Executors
// ---------------------------------------------------------------------------

/// Result of one artifact execution.
#[derive(Clone, Copy, Debug)]
pub struct Exec {
    /// Execution wall time, seconds.
    pub seconds: f64,
    /// Output checksum (the pure-function response payload).
    pub payload: f64,
}

/// Execution backend of the serving core.
///
/// An executor is created *inside* its worker thread (see
/// [`ShardedServer::start`]) so implementations holding non-`Send` state —
/// the PJRT client above all — work unchanged.
pub trait Executor {
    /// One-time per-batch warmup: compile the executable, materialize
    /// inputs.  Paid before the batch's first execution so `execute` times
    /// exclude cold-start cost.
    fn prepare(&mut self, artifact: &str) -> Result<()>;

    /// Execute `artifact` once on its protocol inputs.
    fn execute(&mut self, artifact: &str) -> Result<Exec>;

    /// Export `artifact`'s transferable state for a live migration.  The
    /// state *moves*: a non-`None` return must also forget the artifact
    /// locally, so exactly one worker ever holds it.  The default returns
    /// `None` — nothing transfers and the target worker rebuilds through
    /// [`Executor::prepare`] on the artifact's next request.  That is the
    /// honest contract for [`PjrtExecutor`]: the PJRT client (and its
    /// loaded executables) is not `Send`, so compiled state never crosses
    /// threads and migration costs one recompile on the target.
    fn export_state(&mut self, _artifact: &str) -> Option<Box<dyn Any + Send>> {
        None
    }

    /// Install state exported by [`Executor::export_state`] on the
    /// artifact's previous worker.  Implementations must tolerate a
    /// foreign payload (downcast and drop on mismatch); the default drops
    /// it, falling back to a fresh [`Executor::prepare`].
    fn import_state(&mut self, _artifact: &str, _state: Box<dyn Any + Send>) {}

    /// Stable content digest of `artifact`'s compiled form — the key the
    /// persistent artifact cache stores it under (DESIGN.md §Artifact
    /// cache).  Must cover everything the compiled bytes depend on (name,
    /// tier, shape, manifest entry, toolchain tag): a digest change *is*
    /// the invalidation rule.  The default `None` opts the executor out
    /// of disk caching entirely.
    fn artifact_digest(&self, _artifact: &str) -> Option<String> {
        None
    }

    /// Serialize `artifact`'s compiled form for the persistent cache —
    /// called after a fresh [`Executor::prepare`] so the next process can
    /// [`Executor::load_compiled`] instead of compiling.  The synthetic
    /// executor persists its materialized (bit-serial: packed) inputs;
    /// the PJRT executor persists the HLO program text.  `None` means
    /// nothing to persist (not prepared, or caching unsupported).
    fn store_compiled(&mut self, _artifact: &str) -> Option<Vec<u8>> {
        None
    }

    /// Install a compiled form previously produced by
    /// [`Executor::store_compiled`] (same digest, possibly another
    /// process).  Returns `Ok(true)` when the artifact is now warm —
    /// the following [`Executor::prepare`] must be a no-op — and
    /// `Ok(false)` when the payload was not usable (the caller compiles
    /// fresh; never an error path for stale bytes).
    fn load_compiled(&mut self, _artifact: &str, _bytes: &[u8]) -> Result<bool> {
        Ok(false)
    }
}

/// PJRT-backed executor: serves compiled HLO artifacts via [`Registry`].
pub struct PjrtExecutor {
    registry: Registry,
}

impl PjrtExecutor {
    /// Executor over `<artifacts_dir>/manifest.json`.
    pub fn open(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(PjrtExecutor { registry: Registry::open(artifacts_dir)? })
    }

    /// Build from a manifest already parsed by the admission front-end —
    /// the thread-safe handle sharing path: `Arc<Manifest>` crosses threads,
    /// the PJRT client is created fresh per worker.
    pub fn with_manifest(manifest: Arc<Manifest>) -> Result<Self> {
        Ok(PjrtExecutor { registry: Registry::with_manifest(manifest)? })
    }
}

impl Executor for PjrtExecutor {
    fn prepare(&mut self, artifact: &str) -> Result<()> {
        self.registry.executable(artifact)?;
        self.registry.inputs(artifact)?;
        Ok(())
    }

    fn execute(&mut self, artifact: &str) -> Result<Exec> {
        let out = self.registry.run_protocol(artifact)?;
        let mut payload = 0.0;
        for lit in &out.outputs {
            payload += literal_checksum(lit)?;
        }
        Ok(Exec { seconds: out.seconds, payload })
    }

    fn artifact_digest(&self, artifact: &str) -> Option<String> {
        let spec = self.registry.manifest.by_name(artifact)?;
        let macs = spec.macs.to_string();
        let inputs: String = spec
            .inputs
            .iter()
            .map(|i| format!("{:?}:{}:{}", i.shape, i.dtype, i.seed))
            .collect::<Vec<_>>()
            .join(",");
        Some(digest_hex(&[
            "pjrt",
            &spec.name,
            &spec.file,
            &spec.kind,
            &macs,
            &inputs,
            TOOLCHAIN_TAG,
        ]))
    }

    fn store_compiled(&mut self, artifact: &str) -> Option<Vec<u8>> {
        // The portable compiled form the xla crate gives us is the HLO
        // program text (no serialized-executable API); a warm load stages
        // it back through one PJRT compile without touching the manifest
        // or the artifacts directory.
        self.registry.hlo_bytes(artifact).ok()
    }

    fn load_compiled(&mut self, artifact: &str, bytes: &[u8]) -> Result<bool> {
        self.registry.install_hlo_text(artifact, bytes)?;
        Ok(true)
    }
}

/// Materialized inputs of one synthetic artifact, by precision tier.
/// Bit-serial operands are stored *packed* — packing happens once in
/// [`Executor::prepare`] (the quantized analog of compilation) and the
/// packed planes are what migrate with the artifact.
enum SynState {
    F32(Tensor<f32>, Tensor<f32>),
    Int8(Tensor<i8>, Tensor<i8>),
    BitSerial(Packed, Packed),
}

/// Deterministic unipolar operand for the bit-serial tier: n rows whose
/// reduction axis is zero-padded up to the next multiple of 32
/// (`pack_unipolar` requires word-aligned K; zero columns contribute
/// nothing to any AND/popcount dot product, so the padded GEMM is exact).
fn padded_unipolar(n: usize, bits: usize, seed: u64) -> Tensor<i32> {
    let kp = n.div_ceil(bitserial::LANES) * bitserial::LANES;
    let mut t = Tensor::rand_unipolar(&[n, kp], bits as u32, seed);
    for r in 0..n {
        for c in n..kp {
            t.data[r * kp + c] = 0;
        }
    }
    t
}

/// Byte-serialize one [`SynState`] for the persistent artifact cache:
/// a leading tier tag, then the two operands little-endian.  This is the
/// synthetic analog of compiled-executable bytes — materialization (and
/// for bit-serial, bit-plane packing) is the prepare-time cost a warm
/// load skips.
fn syn_state_to_bytes(state: &SynState) -> Vec<u8> {
    fn dims(out: &mut Vec<u8>, shape: &[usize]) {
        out.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
    }
    let mut out = Vec::new();
    match state {
        SynState::F32(a, b) => {
            out.push(0);
            for t in [a, b] {
                dims(&mut out, &t.shape);
                out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
                for &x in &t.data {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        SynState::Int8(a, b) => {
            out.push(1);
            for t in [a, b] {
                dims(&mut out, &t.shape);
                out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
                out.extend(t.data.iter().map(|&x| x as u8));
            }
        }
        SynState::BitSerial(a, b) => {
            out.push(2);
            for p in [a, b] {
                for field in [p.bits, p.rows, p.kw, p.k] {
                    out.extend_from_slice(&(field as u32).to_le_bytes());
                }
                out.extend_from_slice(&(p.data.len() as u64).to_le_bytes());
                for &x in &p.data {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    out
}

/// Inverse of [`syn_state_to_bytes`].  `None` on any structural mismatch
/// — the caller falls back to a fresh materialization, never panics on
/// foreign bytes.
fn syn_state_from_bytes(bytes: &[u8]) -> Option<SynState> {
    struct R<'a> {
        b: &'a [u8],
        at: usize,
    }
    impl R<'_> {
        fn take(&mut self, n: usize) -> Option<&[u8]> {
            let chunk = self.b.get(self.at..self.at + n)?;
            self.at += n;
            Some(chunk)
        }
        fn u32(&mut self) -> Option<u32> {
            Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
        }
        fn u64(&mut self) -> Option<u64> {
            Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
        }
        fn shape(&mut self) -> Option<Vec<usize>> {
            let ndim = self.u32()? as usize;
            (ndim <= 8).then_some(())?;
            (0..ndim).map(|_| Some(self.u32()? as usize)).collect()
        }
    }
    let mut r = R { b: bytes, at: 0 };
    let tag = *r.take(1)?.first()?;
    let state = match tag {
        0 => {
            let mut ts = Vec::with_capacity(2);
            for _ in 0..2 {
                let shape = r.shape()?;
                let len = r.u64()? as usize;
                (len == shape.iter().product::<usize>()).then_some(())?;
                let mut data = Vec::with_capacity(len);
                for _ in 0..len {
                    data.push(f32::from_le_bytes(r.take(4)?.try_into().ok()?));
                }
                ts.push(Tensor { shape, data });
            }
            let b = ts.pop()?;
            SynState::F32(ts.pop()?, b)
        }
        1 => {
            let mut ts = Vec::with_capacity(2);
            for _ in 0..2 {
                let shape = r.shape()?;
                let len = r.u64()? as usize;
                (len == shape.iter().product::<usize>()).then_some(())?;
                let data = r.take(len)?.iter().map(|&x| x as i8).collect();
                ts.push(Tensor { shape, data });
            }
            let b = ts.pop()?;
            SynState::Int8(ts.pop()?, b)
        }
        2 => {
            let mut ps = Vec::with_capacity(2);
            for _ in 0..2 {
                let (bits, rows, kw, k) =
                    (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
                let len = r.u64()? as usize;
                (len == bits * rows * kw).then_some(())?;
                let mut data = Vec::with_capacity(len);
                for _ in 0..len {
                    data.push(r.u32()?);
                }
                ps.push(Packed { bits, rows, kw, k, data });
            }
            let b = ps.pop()?;
            SynState::BitSerial(ps.pop()?, b)
        }
        _ => return None,
    };
    (r.at == bytes.len()).then_some(state)
}

/// Artifact-free executor: serves the synthetic workloads named by
/// [`workloads::tier_artifact`] — tiled f32 GEMM (`syn_gemm_n*`),
/// register-blocked int8 GEMM (`syn_gemm_i8_n*`) and packed bit-serial
/// GEMM (`syn_gemm_bs_n*`).  Inputs are generated deterministically per
/// artifact (the compile-once analog: first request pays materialization,
/// and for bit-serial also bit-plane packing), so payloads are
/// bit-identical across runs, workers and worker counts — which is what
/// the determinism and cache tests assert.
pub struct SyntheticExecutor {
    schedule: GemmSchedule,
    inputs: HashMap<String, SynState>,
}

impl SyntheticExecutor {
    /// Executor with empty input caches.
    pub fn new() -> Self {
        SyntheticExecutor {
            schedule: GemmSchedule::new(32, 32, 32, 4),
            inputs: HashMap::new(),
        }
    }
}

impl Default for SyntheticExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for SyntheticExecutor {
    fn prepare(&mut self, artifact: &str) -> Result<()> {
        let (tier, n) = workloads::synthetic_tier(artifact)
            .ok_or_else(|| anyhow!("'{artifact}' is not a synthetic serving artifact"))?;
        if !self.inputs.contains_key(artifact) {
            let (sa, sb) = (0xA0 + n as u64, 0xB0 + n as u64);
            let state = match tier {
                Tier::F32 => SynState::F32(
                    Tensor::rand_f32(&[n, n], sa),
                    Tensor::rand_f32(&[n, n], sb),
                ),
                Tier::Int8 => SynState::Int8(
                    Tensor::rand_i8(&[n, n], sa),
                    Tensor::rand_i8(&[n, n], sb),
                ),
                Tier::BitSerial => {
                    let bits = workloads::SERVING_BITSERIAL_BITS;
                    SynState::BitSerial(
                        bitserial::pack_unipolar(&padded_unipolar(n, bits, sa), bits),
                        bitserial::pack_unipolar(&padded_unipolar(n, bits, sb), bits),
                    )
                }
            };
            self.inputs.insert(artifact.to_string(), state);
        }
        Ok(())
    }

    fn execute(&mut self, artifact: &str) -> Result<Exec> {
        self.prepare(artifact)?;
        let t0 = Instant::now();
        let payload = match &self.inputs[artifact] {
            SynState::F32(a, b) => {
                let c = gemm::tiled(a, b, self.schedule);
                c.data.iter().map(|x| *x as f64).sum()
            }
            SynState::Int8(a, b) => {
                let c = qnn::gemm_blocked(a, b);
                c.data.iter().map(|x| *x as f64).sum()
            }
            SynState::BitSerial(a, w) => {
                let c = bitserial::gemm_unipolar(a, w);
                c.data.iter().map(|x| *x as f64).sum()
            }
        };
        let seconds = t0.elapsed().as_secs_f64();
        Ok(Exec { seconds, payload })
    }

    fn export_state(&mut self, artifact: &str) -> Option<Box<dyn Any + Send>> {
        // the materialized (for bit-serial: packed) input pair is the
        // compile-once analog: handing it over spares the target the
        // `prepare` warmup
        self.inputs
            .remove(artifact)
            .map(|io| Box::new(io) as Box<dyn Any + Send>)
    }

    fn import_state(&mut self, artifact: &str, state: Box<dyn Any + Send>) {
        if let Ok(io) = state.downcast::<SynState>() {
            self.inputs.insert(artifact.to_string(), *io);
        }
    }

    fn artifact_digest(&self, artifact: &str) -> Option<String> {
        let (tier, n) = workloads::synthetic_tier(artifact)?;
        let n_s = n.to_string();
        let bits = workloads::SERVING_BITSERIAL_BITS.to_string();
        let sched = format!(
            "t{}x{}x{}u{}",
            self.schedule.bm, self.schedule.bn, self.schedule.bk, self.schedule.unroll
        );
        Some(digest_hex(&["syn", artifact, tier.name(), &n_s, &bits, &sched, TOOLCHAIN_TAG]))
    }

    fn store_compiled(&mut self, artifact: &str) -> Option<Vec<u8>> {
        self.inputs.get(artifact).map(syn_state_to_bytes)
    }

    fn load_compiled(&mut self, artifact: &str, bytes: &[u8]) -> Result<bool> {
        match syn_state_from_bytes(bytes) {
            Some(state) => {
                self.inputs.insert(artifact.to_string(), state);
                Ok(true)
            }
            None => Ok(false),
        }
    }
}

// ---------------------------------------------------------------------------
// Single-threaded reference server
// ---------------------------------------------------------------------------

/// The original server: single-threaded leader loop over a PJRT registry.
///
/// Still the right tool when the PJRT client must stay on the leader and
/// worker parallelism is unwanted; [`ShardedServer`] is the scaling path.
pub struct Server {
    registry: Registry,
    policy: BatchPolicy,
    queue: VecDeque<(Request, Instant)>,
    /// Aggregate metrics of everything served so far.
    pub metrics: Metrics,
}

impl Server {
    /// Server over an opened registry.
    pub fn new(registry: Registry, policy: BatchPolicy) -> Self {
        Server {
            registry,
            policy,
            queue: VecDeque::new(),
            metrics: Metrics::default(),
        }
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests += 1;
        self.queue.push_back((req, Instant::now()));
    }

    /// Drain the queue, batching same-artifact runs; returns responses in
    /// completion order (FIFO except for batch grouping).
    pub fn drain(&mut self) -> Vec<Response> {
        let mut responses = Vec::with_capacity(self.queue.len());
        while let Some((head, t_enq)) = self.queue.pop_front() {
            // group consecutive same-artifact requests
            let mut batch = vec![(head, t_enq)];
            while batch.len() < self.policy.max_batch {
                match self.queue.front() {
                    Some((next, _)) if next.artifact == batch[0].0.artifact => {
                        batch.push(self.queue.pop_front().unwrap());
                    }
                    _ => break,
                }
            }
            self.metrics.batches += 1;
            // ensure compiled + inputs ready (first call pays compilation —
            // the server's warmup; excluded from exec time via pre-touch)
            let artifact = batch[0].0.artifact.clone();
            let prep: Result<()> = (|| {
                self.registry.executable(&artifact)?;
                self.registry.inputs(&artifact)?;
                Ok(())
            })();
            for (req, enq) in batch {
                match &prep {
                    Ok(()) => match self.registry.run_protocol(&req.artifact) {
                        Ok(out) => {
                            self.metrics.completed += 1;
                            self.metrics.exec_seconds.push(out.seconds);
                            let latency = enq.elapsed().as_secs_f64();
                            self.metrics.latency_seconds.push(latency);
                            responses.push(Response {
                                id: req.id,
                                artifact: req.artifact,
                                exec_seconds: out.seconds,
                                latency_seconds: latency,
                                ok: true,
                                error: None,
                                payload: None,
                                cached: false,
                                shard: 0,
                                worker: 0,
                                shed: false,
                                degraded_from: None,
                            });
                        }
                        Err(e) => responses.push(self.fail(req, enq, e.to_string())),
                    },
                    Err(e) => {
                        let msg = e.to_string();
                        responses.push(self.fail(req, enq, msg));
                    }
                }
            }
        }
        responses
    }

    fn fail(&mut self, req: Request, enq: Instant, error: String) -> Response {
        self.metrics.failed += 1;
        let latency = enq.elapsed().as_secs_f64();
        // failures count in the latency population too — every
        // disposition contributes one sample (ISSUE 6 satellite)
        self.metrics.latency_seconds.push(latency);
        Response {
            id: req.id,
            artifact: req.artifact,
            exec_seconds: 0.0,
            latency_seconds: latency,
            ok: false,
            error: Some(error),
            payload: None,
            cached: false,
            shard: 0,
            worker: 0,
            shed: false,
            degraded_from: None,
        }
    }

    /// Requests still queued.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

// ---------------------------------------------------------------------------
// Sharded multi-worker server
// ---------------------------------------------------------------------------

/// Configuration of the sharded serving core.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads.  Each owns the shards `{s : s mod workers == w}`.
    pub workers: usize,
    /// Shard count; 0 means auto (`4 × workers`).  More shards than workers
    /// smooths load imbalance without breaking artifact affinity.
    pub shards: usize,
    /// Per-worker LRU response-cache entries; 0 disables caching.
    pub cache_entries: usize,
    /// Batching policy (max consecutive same-artifact runs).
    pub batch: BatchPolicy,
    /// Admission-time catalog: requests whose artifact is not in the
    /// manifest are rejected at the front door without touching a worker.
    /// Shared with `PjrtExecutor` workers via `Arc` — the one registry
    /// handle that *is* thread-safe.
    pub catalog: Option<Arc<Manifest>>,
    /// Per-artifact cache profiles (telemetry subsystem).  When present,
    /// [`Metrics::worker_pressure`] reports each worker's resident
    /// working-set estimate, and [`PlacementPolicy::CacheAware`] has the
    /// data it needs to plan.
    pub profiles: Option<Arc<BTreeMap<String, CacheProfile>>>,
    /// How artifacts map to workers: the hash baseline, or a greedy
    /// cache-aware plan over `profiles` (`super::placement`).
    pub placement: PlacementPolicy,
    /// CPU profile pricing the cache-aware plan (None defaults to the
    /// Cortex-A53, the part the synthetic serving mix is calibrated
    /// against).
    pub cpu: Option<CpuSpec>,
    /// Observed-vs-predicted pressure divergence (fraction, `[0, 1]`)
    /// beyond which the rebalance machinery acts: at drain time
    /// ([`ServeOutcome::rebalanced`]) under [`RebalanceMode::Drain`], or
    /// mid-stream ([`ShardedServer::maybe_rebalance`]) under
    /// [`RebalanceMode::Live`].
    pub rebalance_threshold: f64,
    /// What the server does when the divergence crosses the threshold:
    /// nothing, a drain-time suggestion (default), or a live migration.
    pub rebalance: RebalanceMode,
    /// Admissions between live divergence checks ([`RebalanceMode::Live`]
    /// only).  Checks are cheap (O(artifacts seen)), but re-planning and
    /// migrating are not; the default of 32 keeps convergence fast without
    /// thrashing on every request.
    pub rebalance_check_every: usize,
    /// Start from this explicit placement plan instead of planning from
    /// `placement`/`profiles`.  This is how a drain-time suggestion from a
    /// previous run ([`ServeOutcome::rebalanced`]) is applied to the next
    /// one — the drain-rebalance leg of the `bench_serve` drifting-mix A/B.
    pub plan: Option<Arc<Placement>>,
    /// What admission control does when a request's target worker is at
    /// its in-flight limit (module docs, §Open-loop serving).  The
    /// default `None` preserves the pre-admission behaviour exactly.
    pub admission: AdmissionMode,
    /// Per-worker in-flight request limit admission control acts at.
    /// Halved for a worker whose profiled resident working set exceeds
    /// the L2 — the [`WorkerPressure`] signal: a cache-pressured worker
    /// drains slower, so it earns a shorter queue.  Ignored under
    /// [`AdmissionMode::None`].
    pub admission_limit: usize,
    /// Which axis [`AdmissionMode::Degrade`] shrinks: the size ladder
    /// within the request's precision tier (default), or the precision
    /// lattice fp32 → int8 → bit-serial at the same N.  Ignored under the
    /// other admission modes.
    pub tier_policy: TierPolicy,
    /// Admission threads the built-in drives
    /// ([`ShardedServer::serve_stream`] / [`ShardedServer::serve_open_loop`])
    /// use: 1 (the default) keeps the classic coordinator-thread admission
    /// loop; N > 1 partitions the stream by artifact hash across N
    /// [`AdmissionHandle`]s that classify, route and enqueue concurrently
    /// against pinned route snapshots (module docs, §Admission
    /// concurrency) while the coordinator reaps, rebalances and migrates.
    pub admission_threads: usize,
    /// Root of the persistent compiled-artifact cache
    /// ([`crate::runtime::ArtifactCache`]).  When set, each worker opens
    /// the store on startup: first-touch preparation loads warm artifacts
    /// from disk instead of compiling, fresh compiles are written back,
    /// and live-migration targets pre-warm from disk before the quiesce
    /// fence.  `None` (the default) preserves the compile-always
    /// behaviour exactly.
    pub cache_dir: Option<PathBuf>,
}

impl ServeConfig {
    /// Config for `workers` worker threads with every option at its
    /// baseline (auto shards, no cache, hash placement).
    pub fn new(workers: usize) -> Self {
        ServeConfig {
            workers: workers.max(1),
            shards: 0,
            cache_entries: 0,
            batch: BatchPolicy::default(),
            catalog: None,
            profiles: None,
            placement: PlacementPolicy::default(),
            cpu: None,
            rebalance_threshold: 0.25,
            rebalance: RebalanceMode::default(),
            rebalance_check_every: 32,
            plan: None,
            admission: AdmissionMode::None,
            admission_limit: 64,
            tier_policy: TierPolicy::Pinned,
            admission_threads: 1,
            cache_dir: None,
        }
    }

    /// Select what happens on pressure divergence (off / drain / live).
    pub fn with_rebalance(mut self, mode: RebalanceMode) -> Self {
        self.rebalance = mode;
        self
    }

    /// Select the admission-control policy (none / shed / degrade).
    pub fn with_admission(mut self, mode: AdmissionMode) -> Self {
        self.admission = mode;
        self
    }

    /// Set the per-worker in-flight limit admission control acts at
    /// (floored at 1).
    pub fn with_admission_limit(mut self, limit: usize) -> Self {
        self.admission_limit = limit.max(1);
        self
    }

    /// Select the degrade axis (pinned-tier size ladder / precision
    /// downshift) — see [`TierPolicy`].
    pub fn with_tier_policy(mut self, policy: TierPolicy) -> Self {
        self.tier_policy = policy;
        self
    }

    /// Admit the built-in drives' streams across `threads` concurrent
    /// admission threads (floored at 1 — the classic single-threaded
    /// loop).  See [`ServeConfig::admission_threads`].
    pub fn with_admission_threads(mut self, threads: usize) -> Self {
        self.admission_threads = threads.max(1);
        self
    }

    /// Start routing from an explicit plan (see [`ServeConfig::plan`]).
    /// Assignments naming workers beyond this config's worker count fall
    /// back to the hash route rather than panicking, so a plan from a
    /// larger deployment degrades gracefully.
    pub fn with_plan(mut self, plan: Arc<Placement>) -> Self {
        self.plan = Some(plan);
        self
    }

    /// Enable the per-worker LRU response cache with `entries` entries.
    pub fn with_cache(mut self, entries: usize) -> Self {
        self.cache_entries = entries;
        self
    }

    /// Attach the persistent compiled-artifact cache rooted at `dir`
    /// (see [`ServeConfig::cache_dir`]).
    pub fn with_cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Attach the admission-time artifact catalog.
    pub fn with_catalog(mut self, catalog: Arc<Manifest>) -> Self {
        self.catalog = Some(catalog);
        self
    }

    /// Attach per-artifact cache profiles (enables pressure reporting and
    /// cache-aware placement).
    pub fn with_profiles(mut self, profiles: Arc<BTreeMap<String, CacheProfile>>) -> Self {
        self.profiles = Some(profiles);
        self
    }

    /// Select the placement policy.
    pub fn with_placement(mut self, placement: PlacementPolicy) -> Self {
        self.placement = placement;
        self
    }

    /// Price the cache-aware plan against `cpu` instead of the default
    /// Cortex-A53.
    pub fn with_cpu(mut self, cpu: CpuSpec) -> Self {
        self.cpu = Some(cpu);
        self
    }

    fn n_shards(&self) -> usize {
        if self.shards == 0 {
            self.workers * 4
        } else {
            self.shards.max(self.workers)
        }
    }
}

struct Envelope {
    req: Request,
    enqueued: Instant,
    shard: usize,
    /// Original artifact when admission control degraded this request;
    /// `req.artifact` names the smaller variant actually executed.
    degraded_from: Option<String>,
}

/// Everything an admission thread can send a worker: ordinary requests
/// plus the control messages of the migration protocol.  Channel FIFO is
/// what makes the protocol correct — a `Hold` fence arrives before any
/// post-swap request for the migrating artifact, a `Quiesce` fence after
/// every pre-swap one, and the `Adopt` that releases the hold after the
/// source's ack.
enum WorkerMsg {
    /// An admitted request.
    Req(Envelope),
    /// Migration fence (target side): pen incoming requests for
    /// `artifact` — queue them in arrival order but do not serve them —
    /// until the `Adopt` carrying the artifact's state releases the pen.
    Hold { artifact: String },
    /// Migration fence (source side): serve everything already queued for
    /// `artifact`, export its state, ack on `reply`.
    Quiesce {
        artifact: String,
        reply: mpsc::Sender<ArtifactState>,
    },
    /// Install state another worker exported for `state.artifact`, and
    /// release any pen held for it.
    Adopt { state: ArtifactState },
    /// Migration pre-warm: load `artifact` from the persistent artifact
    /// cache *now*, ahead of the `Adopt` that will follow, so the target
    /// is compiled before the source even begins to quiesce.  Strictly
    /// best-effort — a miss (or no cache) is a no-op, and the `Adopt`
    /// still carries the authoritative state.
    Prewarm { artifact: String },
}

/// The transferable per-artifact state one worker hands another during a
/// migration.
struct ArtifactState {
    artifact: String,
    /// Requests served during the quiesce (for the migration log).
    drained: u64,
    /// The LRU response-cache entry, if one was resident.
    cached: Option<f64>,
    /// Opaque executor state ([`Executor::export_state`]).
    executor: Option<Box<dyn Any + Send>>,
}

/// Everything a finished serving run produced.
#[derive(Debug)]
pub struct ServeOutcome {
    /// Responses in completion order (per-artifact subsequences are in
    /// admission order — the FIFO invariant).
    pub responses: Vec<Response>,
    /// Aggregate serving metrics (per-shard and per-worker rollups inside).
    pub metrics: Metrics,
    /// Wall time from server start to drain completion.
    pub wall_seconds: f64,
    /// Set when a cache-aware run's observed per-worker pressure diverged
    /// from the plan beyond `ServeConfig::rebalance_threshold`: the
    /// re-planned placement over the artifacts actually served — the
    /// server's feedback hook ([`super::placement::Placement::rebalance`]).
    pub rebalanced: Option<Placement>,
}

/// The sharded multi-worker serving core.  See the module docs for the
/// design and invariants.
pub struct ShardedServer {
    n_shards: usize,
    workers: usize,
    catalog: Option<Arc<Manifest>>,
    profiles: Option<Arc<BTreeMap<String, CacheProfile>>>,
    /// The cache-aware plan, when the config asked for one and profiles
    /// were available; None under hash placement.  Routing reads it
    /// through the route table's snapshot, not this field.
    placement: Option<Arc<Placement>>,
    /// The plan adopted by a live rebalance — coordinator-side only
    /// (pressure prediction and the drain-time hook).  It never routes:
    /// a live plan covers exactly the observed artifacts, and adoption
    /// moves each diverging one with the fenced migration protocol, so
    /// the route table is always at least as current as this plan.
    live_plan: Option<Arc<Placement>>,
    /// CPU the plan was priced against (also used by the rebalance hook).
    cpu: CpuSpec,
    rebalance_threshold: f64,
    rebalance: RebalanceMode,
    check_every: u64,
    senders: Vec<mpsc::Sender<WorkerMsg>>,
    resp_rx: mpsc::Receiver<Response>,
    handles: Vec<thread::JoinHandle<(Vec<ShardMetrics>, Vec<PrepRecord>)>>,
    rejected: Vec<Response>,
    admission: AdmissionMode,
    admission_limit: usize,
    tier_policy: TierPolicy,
    /// Admission threads the concurrent drives partition the stream
    /// across (1 = the classic single-threaded coordinator loop).
    admission_threads: usize,
    /// Single-writer handle on the epoch-versioned route table
    /// ([`super::routing`]): the coordinator publishes placement pins and
    /// migration swaps here; admission threads read snapshots.
    router: RouteWriter,
    /// Counters shared with every [`AdmissionHandle`] (in-flight per
    /// worker, resident bytes per worker, total admitted).
    shared: Arc<AdmissionShared>,
    /// Every artifact ever admitted — the coordinator's view, fed by the
    /// handles' first-touch notices (lags concurrent admission by at most
    /// one `coordinate` pass).
    observed: BTreeSet<String>,
    /// First-touch notices from admission handles: `(artifact, worker)`.
    observed_tx: mpsc::Sender<(String, usize)>,
    observed_rx: mpsc::Receiver<(String, usize)>,
    /// Admitted count at the last live divergence check (concurrent
    /// drives can't use a `% check_every` cadence — admissions land in
    /// batches between `coordinate` calls).
    last_check: u64,
    /// Responses admission control produced at the front door under
    /// `Shed`/`Degrade`-without-a-variant.
    shed: Vec<Response>,
    /// Worker responses reaped before `finish` (open-loop pacing and the
    /// admission check both drain the channel opportunistically).
    collected: Vec<Response>,
    /// `(seconds since start, total in-flight)` — one sample per
    /// submission.
    depth_samples: Vec<(f64, u64)>,
    /// Distinct artifacts resident per worker (working-set accounting;
    /// migrations move entries between sets).
    worker_artifacts: Vec<BTreeSet<String>>,
    /// Completed migrations, in execution order.
    migrations: Vec<MigrationRecord>,
    started: Instant,
}

/// Counters shared between the coordinator and every [`AdmissionHandle`].
/// All loads/stores are `Relaxed`: these are statistics and backpressure
/// signals, not synchronization — the route table's SeqCst protocol and
/// the mpsc channels carry every ordering the protocol needs.
struct AdmissionShared {
    /// In-flight requests per worker: incremented at admission (any
    /// thread), decremented when the coordinator reaps that worker's
    /// response — the queue-depth signal admission control acts on.
    in_flight: Vec<AtomicU64>,
    /// Σ `working_set_bytes` of each worker's profiled resident
    /// artifacts, written by the coordinator on first touch and
    /// migration — the cheap [`WorkerPressure`] signal the admission
    /// check reads.
    resident_bytes: Vec<AtomicU64>,
    /// Total admitted requests (drives the rebalance cadence and the
    /// migration log's `at_request` stamps).
    admitted: AtomicU64,
}

impl AdmissionShared {
    fn new(workers: usize) -> Self {
        AdmissionShared {
            in_flight: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            resident_bytes: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            admitted: AtomicU64::new(0),
        }
    }

    /// Total in-flight across workers (the queue-depth sample).
    fn depth(&self) -> u64 {
        self.in_flight.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// The in-flight limit for `worker` right now: the configured limit,
    /// halved when the worker's profiled resident working set overflows
    /// the L2 — a cache-pressured worker drains slower, so it earns a
    /// shorter queue (the [`WorkerPressure`] signal feeding admission).
    fn effective_limit(&self, worker: usize, limit: u64, l2_bytes: u64) -> u64 {
        if self.resident_bytes[worker].load(Ordering::Relaxed) > l2_bytes {
            (limit / 2).max(1)
        } else {
            limit
        }
    }
}

/// Front-door rejection (unknown artifact).
fn reject_response(req: Request, enqueued: Instant) -> Response {
    Response {
        id: req.id,
        artifact: req.artifact,
        exec_seconds: 0.0,
        latency_seconds: enqueued.elapsed().as_secs_f64(),
        ok: false,
        error: Some("artifact not in manifest (rejected at admission)".into()),
        payload: None,
        cached: false,
        shard: 0,
        worker: 0,
        shed: false,
        degraded_from: None,
    }
}

/// Front-door shed disposition.
fn shed_response(req: Request, enqueued: Instant) -> Response {
    Response {
        id: req.id,
        artifact: req.artifact,
        exec_seconds: 0.0,
        // the shed's latency sample is its time-to-rejection — tiny, but
        // a real measurement, so shed traffic stays visible in the
        // percentile population
        latency_seconds: enqueued.elapsed().as_secs_f64(),
        ok: false,
        error: Some("shed by admission control (worker at in-flight limit)".into()),
        payload: None,
        cached: false,
        shard: 0,
        worker: 0,
        shed: true,
        degraded_from: None,
    }
}

impl ShardedServer {
    /// Spawn the worker pool.  `factory` runs once *inside* each worker
    /// thread to build that worker's executor (PJRT clients are not `Send`,
    /// so they must be born where they live); a factory error fails that
    /// worker's requests cleanly instead of panicking.
    pub fn start<E, F>(config: ServeConfig, factory: F) -> Self
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let n_shards = config.n_shards();
        let workers = config.workers;
        let cpu = config
            .cpu
            .clone()
            .unwrap_or_else(|| profile_by_name("a53").expect("builtin profile").cpu);
        // An explicit plan wins; otherwise the cache-aware policy needs
        // profiles to plan from — without them it silently degrades to
        // hash (the CLI surfaces a note).
        let placement_plan = match (config.plan, config.placement, &config.profiles) {
            (Some(plan), _, _) => Some(plan),
            (None, PlacementPolicy::CacheAware, Some(profiles)) => Some(Arc::new(
                placement::plan(&InterferenceModel::new(&cpu), profiles, workers),
            )),
            _ => None,
        };
        let factory = Arc::new(factory);
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            senders.push(tx);
            let resp_tx = resp_tx.clone();
            let factory = factory.clone();
            let batch = config.batch;
            let cache_entries = config.cache_entries;
            let cache_dir = config.cache_dir.clone();
            let handle = thread::Builder::new()
                .name(format!("serve-worker-{w}"))
                .spawn(move || {
                    worker_loop(w, rx, resp_tx, (*factory)(w), batch, cache_entries, cache_dir)
                })
                .expect("spawn serve worker");
            handles.push(handle);
        }
        let (observed_tx, observed_rx) = mpsc::channel();
        ShardedServer {
            n_shards,
            workers,
            catalog: config.catalog,
            profiles: config.profiles,
            placement: placement_plan.clone(),
            live_plan: None,
            cpu,
            rebalance_threshold: config.rebalance_threshold,
            rebalance: config.rebalance,
            check_every: config.rebalance_check_every.max(1) as u64,
            senders,
            resp_rx,
            handles,
            rejected: Vec::new(),
            admission: config.admission,
            admission_limit: config.admission_limit.max(1),
            tier_policy: config.tier_policy,
            admission_threads: config.admission_threads.max(1),
            router: RouteWriter::new(workers, n_shards, placement_plan),
            shared: Arc::new(AdmissionShared::new(workers)),
            observed: BTreeSet::new(),
            observed_tx,
            observed_rx,
            last_check: 0,
            shed: Vec::new(),
            collected: Vec::new(),
            depth_samples: Vec::new(),
            worker_artifacts: vec![BTreeSet::new(); workers],
            migrations: Vec::new(),
            started: Instant::now(),
        }
    }

    /// The cache-aware plan this server started routing by (None under
    /// hash placement or when no profiles were attached).
    pub fn placement(&self) -> Option<&Placement> {
        self.placement.as_deref()
    }

    /// The plan currently governing routing and pressure prediction: the
    /// latest live-adopted plan, else the starting plan.
    pub fn active_plan(&self) -> Option<&Placement> {
        self.live_plan.as_deref().or(self.placement.as_deref())
    }

    /// Worker currently serving `artifact` (None before its first
    /// admission, unless a forced migration pinned it).  Routes are
    /// deterministic even before first admission; this keeps the
    /// pre-snapshot "seen" semantics for callers that probe placement.
    pub fn route_of(&self, artifact: &str) -> Option<usize> {
        let table = self.router.current();
        if self.observed.contains(artifact) || table.pinned(artifact).is_some() {
            Some(table.worker_for(artifact))
        } else {
            None
        }
    }

    /// Migrations performed so far, in execution order.
    pub fn migrations(&self) -> &[MigrationRecord] {
        &self.migrations
    }

    /// Shard count of this server.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Worker-thread count of this server.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Current route-table epoch (0 at start; bumped only by migrations'
    /// route swaps — admission never publishes).
    pub fn route_epoch(&self) -> u64 {
        self.router.current().epoch()
    }

    /// Shard a request and hand it to the owning worker — or answer it at
    /// the front door.  Unknown artifacts (when a catalog is attached)
    /// are rejected; when admission control is on and the target worker
    /// is at its in-flight limit, the request is shed or degraded
    /// (module docs, §Open-loop serving).  Every submission gets exactly
    /// one disposition and one queue-depth sample.  In-flight accounting
    /// assumes caller-chosen ids are unique among concurrently live
    /// requests (every built-in drive assigns ids from `enumerate`).
    pub fn submit(&mut self, req: Request) {
        // reap finished responses first so the in-flight accounting —
        // and therefore the admission decision and the depth sample —
        // reflects work the workers have already retired
        self.reap();
        let enqueued = Instant::now();
        if let Some(cat) = &self.catalog {
            if cat.by_name(&req.artifact).is_none() {
                self.rejected.push(reject_response(req, enqueued));
                self.sample_depth();
                return;
            }
        }
        // One snapshot read routes the whole decision — the old
        // `routes.get` + first-admission re-insert double lookup is gone
        // (regression-tested by `admit_hot_path_is_one_snapshot_read`).
        let table = self.router.current().clone();
        let worker = table.worker_for(&req.artifact);
        if self.admission != AdmissionMode::None
            && self.shared.in_flight[worker].load(Ordering::Relaxed)
                >= self.shared.effective_limit(
                    worker,
                    self.admission_limit as u64,
                    self.cpu.l2.size_bytes as u64,
                )
        {
            match self.admission {
                AdmissionMode::Degrade => {
                    // degrade-to-smaller-variant: reroute to whatever the
                    // tier policy picks — the next size down in the same
                    // tier, or the same N one precision tier down (its
                    // own route, possibly another worker), remembering
                    // what was asked for
                    let smaller = match self.tier_policy {
                        TierPolicy::Pinned => {
                            workloads::degrade_artifact_within_tier(&req.artifact)
                        }
                        TierPolicy::DownshiftOnPressure => {
                            workloads::degrade_artifact(&req.artifact)
                        }
                    };
                    if let Some(smaller) = smaller {
                        let original = req.artifact;
                        let degraded = Request { id: req.id, artifact: smaller };
                        let worker = table.worker_for(&degraded.artifact);
                        self.dispatch(degraded, worker, enqueued, Some(original));
                    } else {
                        self.shed.push(shed_response(req, enqueued));
                    }
                }
                _ => self.shed.push(shed_response(req, enqueued)),
            }
            self.sample_depth();
            return;
        }
        self.dispatch(req, worker, enqueued, None);
        self.sample_depth();
    }

    /// Send one admitted request down its worker's channel, maintaining
    /// the in-flight accounting and the live-rebalance cadence.  (The
    /// single-threaded coordinator path; concurrent admission goes
    /// through [`AdmissionHandle::submit`].)
    fn dispatch(
        &mut self,
        req: Request,
        worker: usize,
        enqueued: Instant,
        degraded_from: Option<String>,
    ) {
        let shard = shard_for(&req.artifact, self.n_shards);
        self.note_observed(&req.artifact, worker);
        let admitted = self.shared.admitted.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.in_flight[worker].fetch_add(1, Ordering::Relaxed);
        self.senders[worker]
            .send(WorkerMsg::Req(Envelope { req, enqueued, shard, degraded_from }))
            .expect("serve worker alive");
        if self.rebalance == RebalanceMode::Live && admitted % self.check_every == 0 {
            self.maybe_rebalance();
        }
    }

    /// First-touch bookkeeping: the first admission of `artifact` makes it
    /// resident on `worker` (working-set accounting and the admission
    /// pressure signal).  Idempotent — later touches, including notices
    /// arriving after a migration already claimed the artifact, are no-ops.
    fn note_observed(&mut self, artifact: &str, worker: usize) {
        if self.observed.insert(artifact.to_string()) {
            self.worker_artifacts[worker].insert(artifact.to_string());
            if let Some(p) = self.profiles.as_ref().and_then(|ps| ps.get(artifact)) {
                self.shared.resident_bytes[worker]
                    .fetch_add(p.working_set_bytes, Ordering::Relaxed);
            }
        }
    }

    /// Absorb first-touch notices queued by concurrent admission handles.
    fn drain_observed(&mut self) {
        while let Ok((artifact, worker)) = self.observed_rx.try_recv() {
            self.note_observed(&artifact, worker);
        }
    }

    /// Drain every response already sitting in the channel, updating the
    /// in-flight accounting.  `Response::worker` pairs every decrement
    /// with the dispatch-side increment exactly once — front-door answers
    /// (rejects, sheds) never enter the channel.
    fn reap(&mut self) {
        while let Ok(r) = self.resp_rx.try_recv() {
            let _ = self.shared.in_flight[r.worker].fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |v| Some(v.saturating_sub(1)),
            );
            self.collected.push(r);
        }
    }

    /// Record one `(elapsed, total in-flight)` sample.
    fn sample_depth(&mut self) {
        self.depth_samples
            .push((self.started.elapsed().as_secs_f64(), self.shared.depth()));
    }

    /// The live divergence check ([`RebalanceMode::Live`]; run
    /// automatically every `ServeConfig::rebalance_check_every`
    /// admissions, callable directly for deterministic tests).  When the
    /// observed per-worker residency diverges from the active plan past
    /// the threshold, re-plan over the artifacts actually served and
    /// migrate every artifact whose assignment changed.  Returns the
    /// number of artifacts migrated.
    ///
    /// With no active plan (a hash-placed stream), any profiled residency
    /// is a full divergence — the semantics of
    /// [`Placement::divergence`][super::placement::Placement::divergence]
    /// with an all-zero prediction — so a hash-started live server
    /// converges to the cache-aware plan at its first check.
    pub fn maybe_rebalance(&mut self) -> usize {
        if self.rebalance != RebalanceMode::Live {
            return 0;
        }
        let Some(profiles) = self.profiles.clone() else { return 0 };
        self.drain_observed();
        if !self.observed.iter().any(|a| profiles.contains_key(a)) {
            return 0; // nothing profiled has been served: nothing to plan
        }
        // the cheap gate first — a quiet check costs one pressure pass,
        // no profile clones
        let divergence = match self.active_plan() {
            Some(plan) => {
                plan.divergence(&pressure_rows(&self.worker_artifacts, &profiles, Some(plan)))
            }
            None => 1.0,
        };
        if divergence <= self.rebalance_threshold {
            return 0;
        }
        let observed: BTreeMap<String, CacheProfile> = self
            .observed
            .iter()
            .filter_map(|a| profiles.get(a).map(|p| (a.clone(), p.clone())))
            .collect();
        let candidate = placement::plan(
            &InterferenceModel::new(&self.cpu),
            &observed,
            self.workers,
        );
        let table = self.router.current().clone();
        let moves: Vec<(String, usize)> = candidate
            .assignments
            .iter()
            .filter(|(a, &w)| table.worker_for(a) != w)
            .map(|(a, &w)| (a.clone(), w))
            .collect();
        // Adopt the candidate even when nothing moves: it covers exactly
        // the observed set, so the divergence signal resets and the check
        // stays quiet until the mix drifts again.  Adoption changes zero
        // routes — the plan stays coordinator-side, and each diverging
        // artifact moves through the fenced protocol below, so concurrent
        // admission never sees an unfenced route change.
        self.live_plan = Some(Arc::new(candidate));
        for (artifact, to) in &moves {
            self.migrate_with(artifact, *to, divergence, false);
        }
        moves.len()
    }

    /// Force-migrate `artifact` to `to_worker`, regardless of any plan —
    /// the injection point of the migration chaos harness
    /// (`rust/tests/serve_migration.rs`).  Returns the completed record,
    /// or `None` when the artifact is already routed there.
    ///
    /// # Panics
    /// When `to_worker` is out of range.
    pub fn migrate(&mut self, artifact: &str, to_worker: usize) -> Option<MigrationRecord> {
        assert!(to_worker < self.workers, "target worker {to_worker} out of range");
        if self.router.current().worker_for(artifact) == to_worker {
            return None;
        }
        Some(self.migrate_with(artifact, to_worker, 0.0, true))
    }

    /// The four-step migration protocol (see the module docs): hold the
    /// target, swap the route and wait out the reader grace period,
    /// quiesce the source, adopt.  Uniform for seen and unseen artifacts —
    /// an unseen one simply drains zero requests at its natural route's
    /// worker (under concurrent admission its first request may be in
    /// flight *right now*, so it gets the full fence like everything
    /// else).
    fn migrate_with(
        &mut self,
        artifact: &str,
        to: usize,
        divergence: f64,
        forced: bool,
    ) -> MigrationRecord {
        self.drain_observed();
        let from = self.router.current().worker_for(artifact);
        debug_assert_ne!(from, to, "caller filters same-worker moves");
        // 0. pre-warm: tell the target to load the compiled artifact from
        //    the persistent cache *before* the source quiesces, so the
        //    adopt step installs state into an already-compiled executor
        //    and the migration pause excludes the compile.  Best-effort:
        //    without a cache (or on a miss) this is a no-op and the
        //    protocol behaves exactly as before.
        self.senders[to]
            .send(WorkerMsg::Prewarm { artifact: artifact.to_string() })
            .expect("serve worker alive");
        // 1. hold: the target pens post-swap requests for the artifact
        //    until the adopt below releases them — they must not execute
        //    before the source's drained state arrives
        self.senders[to]
            .send(WorkerMsg::Hold { artifact: artifact.to_string() })
            .expect("serve worker alive");
        // 2. swap + grace: publish the new route, then wait until no
        //    admission thread can still be routing by an older epoch.
        //    After the wait, every pre-swap admission has reached the
        //    source's queue and every post-swap one lands behind the hold.
        let epoch = self.router.pin_route(artifact, to);
        self.router.wait_for_readers(epoch);
        // 3. quiesce: the source serves everything already queued for the
        //    artifact (channel FIFO puts the fence after every pre-swap
        //    request), then exports the transferable state
        let (reply_tx, reply_rx) = mpsc::channel();
        self.senders[from]
            .send(WorkerMsg::Quiesce { artifact: artifact.to_string(), reply: reply_tx })
            .expect("serve worker alive");
        let state = reply_rx.recv().expect("quiesce ack");
        let rec = MigrationRecord {
            at_request: self.shared.admitted.load(Ordering::Relaxed),
            artifact: artifact.to_string(),
            from_worker: from,
            to_worker: to,
            drained: state.drained,
            cache_moved: state.cached.is_some(),
            state_moved: state.executor.is_some(),
            divergence,
            forced,
        };
        // 4. adopt: installs the state and releases the hold — channel
        //    FIFO puts both before any request admitted after this point
        self.senders[to].send(WorkerMsg::Adopt { state }).expect("serve worker alive");
        // residency accounting follows the route
        let was_observed = self.observed.contains(artifact);
        if was_observed {
            self.worker_artifacts[from].remove(artifact);
        }
        self.worker_artifacts[to].insert(artifact.to_string());
        self.observed.insert(artifact.to_string());
        if let Some(p) = self.profiles.as_ref().and_then(|ps| ps.get(artifact)) {
            if was_observed {
                let _ = self.shared.resident_bytes[from].fetch_update(
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                    |v| Some(v.saturating_sub(p.working_set_bytes)),
                );
            }
            self.shared.resident_bytes[to].fetch_add(p.working_set_bytes, Ordering::Relaxed);
        }
        self.migrations.push(rec.clone());
        rec
    }

    /// Submit an entire request stream (ids assigned in stream order) and
    /// drain to completion — the synchronous drive shared by the CLI, the
    /// `ServeMix` job, the invariant tests and `bench_serve`.
    pub fn serve_stream<I>(mut self, stream: I) -> ServeOutcome
    where
        I: IntoIterator<Item = String>,
    {
        if self.admission_threads > 1 {
            let reqs: Vec<(u64, String, Option<f64>)> = stream
                .into_iter()
                .enumerate()
                .map(|(id, a)| (id as u64, a, None))
                .collect();
            return self.serve_concurrent(reqs);
        }
        for (id, artifact) in stream.into_iter().enumerate() {
            self.submit(Request { id: id as u64, artifact });
        }
        self.finish()
    }

    /// Submit `stream` on the wall-clock `arrivals` schedule (offsets in
    /// seconds from drive start — see
    /// [`ArrivalConfig::schedule`][super::loadgen::ArrivalConfig::schedule])
    /// and drain.  This is the open-loop drive: submissions never wait for
    /// completions, so queues genuinely build once the offered rate passes
    /// capacity — the regime admission control exists for, and the one the
    /// closed-loop [`ShardedServer::serve_stream`] structurally cannot
    /// reach.  Ids are assigned in stream order; the stream is truncated
    /// to the schedule's length.
    pub fn serve_open_loop<I>(mut self, stream: I, arrivals: &[f64]) -> ServeOutcome
    where
        I: IntoIterator<Item = String>,
    {
        if self.admission_threads > 1 {
            let reqs: Vec<(u64, String, Option<f64>)> = stream
                .into_iter()
                .zip(arrivals)
                .enumerate()
                .map(|(id, (a, &at))| (id as u64, a, Some(at)))
                .collect();
            return self.serve_concurrent(reqs);
        }
        let t0 = Instant::now();
        for (id, (artifact, &at)) in stream.into_iter().zip(arrivals).enumerate() {
            loop {
                let now = t0.elapsed().as_secs_f64();
                if now >= at {
                    break;
                }
                // reap while pacing so in-flight stays honest even when
                // the schedule leaves long gaps between submissions
                self.reap();
                thread::sleep(Duration::from_secs_f64((at - now).min(1e-3)));
            }
            self.submit(Request { id: id as u64, artifact });
        }
        self.finish()
    }

    /// Mint a [`AdmissionHandle`] for one admission thread: a route-table
    /// reader plus clones of everything the admission decision needs.
    /// Handles are `Send`; each lives on exactly one thread.
    pub fn admission_handle(&self) -> AdmissionHandle {
        AdmissionHandle {
            reader: self.router.reader(),
            senders: self.senders.clone(),
            catalog: self.catalog.clone(),
            admission: self.admission,
            admission_limit: self.admission_limit as u64,
            tier_policy: self.tier_policy,
            l2_bytes: self.cpu.l2.size_bytes as u64,
            n_shards: self.n_shards,
            shared: self.shared.clone(),
            observed_tx: self.observed_tx.clone(),
            seen: HashSet::new(),
            started: self.started,
            rejected: Vec::new(),
            shed: Vec::new(),
            depth_samples: Vec::new(),
        }
    }

    /// Fold a finished admission thread's front-door dispositions back
    /// into the coordinator before [`ShardedServer::finish`].
    pub fn absorb(&mut self, outcome: AdmissionOutcome) {
        self.rejected.extend(outcome.rejected);
        self.shed.extend(outcome.shed);
        self.depth_samples.extend(outcome.depth_samples);
    }

    /// One coordinator pass while admission threads run: reap worker
    /// responses, absorb first-touch notices, and run the live divergence
    /// check when enough new admissions accumulated (the concurrent
    /// analogue of `dispatch`'s `% check_every` cadence).
    pub fn coordinate(&mut self) {
        self.reap();
        self.drain_observed();
        let admitted = self.shared.admitted.load(Ordering::Relaxed);
        if self.rebalance == RebalanceMode::Live && admitted >= self.last_check + self.check_every
        {
            self.last_check = admitted;
            self.maybe_rebalance();
        }
    }

    /// The concurrent drive: partition the stream by artifact hash across
    /// `admission_threads` handles (each artifact has exactly one
    /// submitter, preserving per-artifact FIFO), run them under
    /// `thread::scope` while this thread keeps the coordinator duties
    /// (reap, rebalance, migrations), then absorb and finish.  Entries
    /// with an arrival offset pace themselves against one shared clock.
    fn serve_concurrent(mut self, reqs: Vec<(u64, String, Option<f64>)>) -> ServeOutcome {
        let threads = self.admission_threads;
        let mut parts: Vec<Vec<(u64, String, Option<f64>)>> =
            (0..threads).map(|_| Vec::new()).collect();
        for item in reqs {
            let t = shard_for(&item.1, threads);
            parts[t].push(item);
        }
        let mut handles: Vec<AdmissionHandle> =
            (0..threads).map(|_| self.admission_handle()).collect();
        let t0 = Instant::now();
        let outcomes: Vec<AdmissionOutcome> = thread::scope(|s| {
            let joins: Vec<_> = parts
                .into_iter()
                .zip(handles.drain(..))
                .map(|(part, mut handle)| {
                    s.spawn(move || {
                        for (id, artifact, at) in part {
                            if let Some(at) = at {
                                // pace without holding a pin — a sleeping
                                // reader must never stall a migration fence
                                loop {
                                    let now = t0.elapsed().as_secs_f64();
                                    if now >= at {
                                        break;
                                    }
                                    thread::sleep(Duration::from_secs_f64(
                                        (at - now).min(1e-3),
                                    ));
                                }
                            }
                            handle.submit(Request { id, artifact });
                        }
                        handle.into_outcome()
                    })
                })
                .collect();
            loop {
                self.coordinate();
                if joins.iter().all(|j| j.is_finished()) {
                    break;
                }
                thread::sleep(Duration::from_micros(200));
            }
            joins
                .into_iter()
                .map(|j| j.join().expect("admission thread panicked"))
                .collect()
        });
        for outcome in outcomes {
            self.absorb(outcome);
        }
        self.finish()
    }

    /// Drain any responses already available, without blocking.  The
    /// returned values are clones: the originals stay with the server so
    /// [`ShardedServer::finish`] still accounts for every disposition.
    pub fn poll_responses(&mut self) -> Vec<Response> {
        let before = self.collected.len();
        self.reap();
        self.collected[before..].to_vec()
    }

    /// Close admission, drain every in-flight request, join the workers and
    /// roll per-shard metrics up into the aggregate [`Metrics`].
    pub fn finish(mut self) -> ServeOutcome {
        // late first-touch notices still in the channel belong to this
        // run's residency accounting
        self.drain_observed();
        let ShardedServer {
            senders,
            resp_rx,
            handles,
            shared,
            rejected,
            shed,
            collected,
            mut depth_samples,
            started,
            profiles,
            placement,
            live_plan,
            cpu,
            rebalance_threshold,
            rebalance,
            worker_artifacts,
            migrations,
            ..
        } = self;
        let admitted = shared.admitted.load(Ordering::Relaxed);
        // concurrent admission interleaves samples from several threads;
        // restore chronological order for the depth series
        depth_samples.sort_by(|a, b| a.0.total_cmp(&b.0));
        // The active plan: pressure prediction and the drain-time hook
        // must follow a live plan swap — a stale `placement` here is
        // exactly the predicted-vs-observed bug the regression tests pin.
        let active_plan = live_plan.or(placement);
        drop(senders); // workers drain their queues and exit
        // worker responses: whatever open-loop pacing already reaped,
        // then the channel's remainder
        let mut responses: Vec<Response> = collected;
        responses.extend(resp_rx.iter());
        // Keyed by (shard, worker), not shard alone: a cache-aware plan may
        // route two same-shard artifacts to different workers, and folding
        // those rows together would misattribute the owning worker.  Under
        // hash placement a shard has exactly one owner, so the keys — and
        // the rollup — are identical to the shard-only version.
        let mut per_shard: BTreeMap<(usize, usize), ShardMetrics> = BTreeMap::new();
        let mut prep: Vec<PrepRecord> = Vec::new();
        for h in handles {
            let (shard_rows, prep_rows) = h.join().expect("serve worker panicked");
            for sm in shard_rows {
                per_shard
                    .entry((sm.shard, sm.worker))
                    .and_modify(|acc| acc.merge(&sm))
                    .or_insert(sm);
            }
            prep.extend(prep_rows);
        }
        let wall_seconds = started.elapsed().as_secs_f64();

        let mut metrics = Metrics {
            requests: admitted + (rejected.len() + shed.len()) as u64,
            ..Metrics::default()
        };
        // Every disposition contributes exactly one latency sample —
        // served at full latency, rejected and shed at time-to-rejection
        // — so `latency_seconds.len() == requests` and the percentile
        // population hides nothing (ISSUE 6 satellite; pinned by
        // `latency_population_covers_every_disposition`).
        for r in &responses {
            metrics.latency_seconds.push(r.latency_seconds);
            if r.degraded_from.is_some() {
                metrics.degraded += 1;
            }
            if r.ok {
                metrics.completed += 1;
                metrics.exec_seconds.push(r.exec_seconds);
                if r.cached {
                    metrics.cache_hits += 1;
                }
            } else {
                metrics.failed += 1;
            }
        }
        for r in rejected.iter().chain(&shed) {
            metrics.latency_seconds.push(r.latency_seconds);
        }
        metrics.failed += rejected.len() as u64;
        metrics.rejected = rejected.len() as u64;
        metrics.shed = shed.len() as u64;
        metrics.queue_depth = depth_samples;
        metrics.batches = per_shard.values().map(|s| s.batches).sum();
        metrics.per_shard = per_shard.into_values().collect();
        metrics.migrations = migrations;
        metrics.prep = prep;
        if let Some(profiles) = &profiles {
            metrics.worker_pressure =
                pressure_rows(&worker_artifacts, profiles, active_plan.as_deref());
        }
        // The drain-time rebalance hook: when the active plan's predicted
        // pressure diverged from what this run actually put on each
        // worker, re-plan over the artifacts that were really served.  A
        // live run that converged shows no divergence here — its active
        // plan *is* the re-plan — and `RebalanceMode::Off` disables the
        // hook entirely.
        let rebalanced = match (&active_plan, &profiles) {
            (Some(plan), Some(profiles))
                if rebalance != RebalanceMode::Off && !metrics.worker_pressure.is_empty() =>
            {
                let observed: BTreeMap<String, CacheProfile> = worker_artifacts
                    .iter()
                    .flatten()
                    .filter_map(|a| profiles.get(a).map(|p| (a.clone(), p.clone())))
                    .collect();
                plan.rebalance(
                    &InterferenceModel::new(&cpu),
                    &observed,
                    &metrics.worker_pressure,
                    rebalance_threshold,
                )
            }
            _ => None,
        };
        responses.extend(rejected);
        responses.extend(shed);
        ServeOutcome { responses, metrics, wall_seconds, rebalanced }
    }
}

/// One admission thread's working state: a route-table reader plus
/// clones of the classification/shed/degrade machinery, so N threads can
/// admit concurrently against snapshot routes while the coordinator keeps
/// the single-writer duties (route publishes, reaping, rebalance).
///
/// Mint with [`ShardedServer::admission_handle`], move to a thread, feed
/// it requests, then hand [`AdmissionHandle::into_outcome`] back to
/// [`ShardedServer::absorb`].  Per-artifact FIFO is the *caller's*
/// contract: give every artifact exactly one submitting thread (the
/// built-in drives partition the stream by artifact hash).  `Degrade` may
/// route a degraded variant owned by another thread — dispositions stay
/// exactly-once, but the variant's FIFO is then interleaved across
/// submitters.
pub struct AdmissionHandle {
    reader: RouteReader,
    senders: Vec<mpsc::Sender<WorkerMsg>>,
    catalog: Option<Arc<Manifest>>,
    admission: AdmissionMode,
    admission_limit: u64,
    tier_policy: TierPolicy,
    l2_bytes: u64,
    n_shards: usize,
    shared: Arc<AdmissionShared>,
    observed_tx: mpsc::Sender<(String, usize)>,
    /// Artifacts this handle already reported as first-touched (keeps the
    /// notice channel to one message per artifact per thread).
    seen: HashSet<String>,
    started: Instant,
    rejected: Vec<Response>,
    shed: Vec<Response>,
    depth_samples: Vec<(f64, u64)>,
}

impl AdmissionHandle {
    /// Admit one request: the same classify → route → shed/degrade →
    /// enqueue decision as [`ShardedServer::submit`], made against one
    /// pinned route-table snapshot.  The pin is held across the enqueue —
    /// that is what lets a migration's
    /// [`wait_for_readers`][super::routing::RouteWriter::wait_for_readers]
    /// grace period conclude that every pre-swap admission has reached its
    /// worker's queue.
    pub fn submit(&mut self, req: Request) {
        let enqueued = Instant::now();
        if let Some(cat) = &self.catalog {
            if cat.by_name(&req.artifact).is_none() {
                self.rejected.push(reject_response(req, enqueued));
                self.sample_depth();
                return;
            }
        }
        let snap = self.reader.pin();
        let worker = snap.worker_for(&req.artifact);
        if self.admission != AdmissionMode::None
            && self.shared.in_flight[worker].load(Ordering::Relaxed)
                >= self.shared.effective_limit(worker, self.admission_limit, self.l2_bytes)
        {
            match self.admission {
                AdmissionMode::Degrade => {
                    let smaller = match self.tier_policy {
                        TierPolicy::Pinned => {
                            workloads::degrade_artifact_within_tier(&req.artifact)
                        }
                        TierPolicy::DownshiftOnPressure => {
                            workloads::degrade_artifact(&req.artifact)
                        }
                    };
                    if let Some(smaller) = smaller {
                        let original = req.artifact;
                        let degraded = Request { id: req.id, artifact: smaller };
                        let worker = snap.worker_for(&degraded.artifact);
                        self.dispatch(degraded, worker, enqueued, Some(original));
                    } else {
                        self.shed.push(shed_response(req, enqueued));
                    }
                }
                _ => self.shed.push(shed_response(req, enqueued)),
            }
            drop(snap);
            self.sample_depth();
            return;
        }
        self.dispatch(req, worker, enqueued, None);
        drop(snap);
        self.sample_depth();
    }

    /// Enqueue an admitted request (counter bumps, first-touch notice,
    /// channel send).  Caller holds the route pin across this call.
    fn dispatch(
        &mut self,
        req: Request,
        worker: usize,
        enqueued: Instant,
        degraded_from: Option<String>,
    ) {
        let shard = shard_for(&req.artifact, self.n_shards);
        if self.seen.insert(req.artifact.clone()) {
            // a closed coordinator just means the run is draining;
            // residency bookkeeping is best-effort at that point
            let _ = self.observed_tx.send((req.artifact.clone(), worker));
        }
        self.shared.admitted.fetch_add(1, Ordering::Relaxed);
        self.shared.in_flight[worker].fetch_add(1, Ordering::Relaxed);
        self.senders[worker]
            .send(WorkerMsg::Req(Envelope { req, enqueued, shard, degraded_from }))
            .expect("serve worker alive");
    }

    /// Record one `(elapsed, total in-flight)` sample.
    fn sample_depth(&mut self) {
        self.depth_samples
            .push((self.started.elapsed().as_secs_f64(), self.shared.depth()));
    }

    /// Finish this thread's admission work: unpin the reader and surrender
    /// the locally buffered dispositions.
    pub fn into_outcome(self) -> AdmissionOutcome {
        AdmissionOutcome {
            rejected: self.rejected,
            shed: self.shed,
            depth_samples: self.depth_samples,
        }
    }
}

/// What one admission thread hands back to the coordinator: front-door
/// dispositions and depth samples buffered locally while it ran.  Feed to
/// [`ShardedServer::absorb`] before `finish`.
pub struct AdmissionOutcome {
    rejected: Vec<Response>,
    shed: Vec<Response>,
    depth_samples: Vec<(f64, u64)>,
}

/// Observed per-worker pressure rows: residency summed from the profiled
/// artifacts resident on each worker, prediction read off `plan` (0 with
/// no plan).  Shared by the live divergence check and the drain rollup so
/// both always price the *same* observation.
fn pressure_rows(
    worker_artifacts: &[BTreeSet<String>],
    profiles: &BTreeMap<String, CacheProfile>,
    plan: Option<&Placement>,
) -> Vec<WorkerPressure> {
    worker_artifacts
        .iter()
        .enumerate()
        .map(|(worker, artifacts)| {
            let mut p = WorkerPressure {
                worker,
                artifacts: artifacts.len() as u64,
                predicted_bytes: plan.map_or(0, |pl| pl.predicted_bytes(worker)),
                ..WorkerPressure::default()
            };
            for a in artifacts {
                if let Some(profile) = profiles.get(a) {
                    p.profiled += 1;
                    p.resident_bytes += profile.working_set_bytes;
                }
            }
            p
        })
        .collect()
}

/// The per-worker state `worker_loop` threads through its helpers: local
/// shard queues, per-shard metrics, the LRU response cache and the
/// (possibly failed) executor.
struct WorkerState<E> {
    worker: usize,
    queues: BTreeMap<usize, VecDeque<Envelope>>,
    metrics: BTreeMap<usize, ShardMetrics>,
    cache: LruCache<String, f64>,
    executor: Result<E>,
    batch_policy: BatchPolicy,
    resp_tx: mpsc::Sender<Response>,
    /// Persistent compiled-artifact store, when `ServeConfig::cache_dir`
    /// was set and the root opened cleanly (an open failure degrades to
    /// compile-always rather than failing the worker).
    artifact_cache: Option<ArtifactCache>,
    /// Artifacts already warmed (loaded or compiled+stored) on this
    /// worker — first-touch bookkeeping for the prep log.
    warmed: BTreeSet<String>,
    /// First-touch preparation log, returned to `finish` with the shard
    /// metrics.
    prep: Vec<PrepRecord>,
    /// Migration pens: requests for an artifact under a `Hold` fence wait
    /// here, in arrival order, until the matching `Adopt` releases them
    /// into the shard queues (or the channel closes — a drain must answer
    /// everything even if a migration was cut short).
    held: BTreeMap<String, Vec<Envelope>>,
}

/// One worker: drains its message channel into per-shard FIFO queues and
/// serves them batch-by-batch, oldest shard head first.  `Quiesce` and
/// `Adopt` control messages are handled the moment they are dequeued —
/// channel FIFO makes that the correct fence point (see the module docs).
fn worker_loop<E: Executor>(
    worker: usize,
    rx: mpsc::Receiver<WorkerMsg>,
    resp_tx: mpsc::Sender<Response>,
    executor: Result<E>,
    batch_policy: BatchPolicy,
    cache_entries: usize,
    cache_dir: Option<PathBuf>,
) -> (Vec<ShardMetrics>, Vec<PrepRecord>) {
    let mut st = WorkerState {
        worker,
        queues: BTreeMap::new(),
        metrics: BTreeMap::new(),
        cache: LruCache::new(cache_entries),
        executor,
        batch_policy,
        resp_tx,
        artifact_cache: cache_dir.and_then(|d| ArtifactCache::open(d).ok()),
        warmed: BTreeSet::new(),
        prep: Vec::new(),
        held: BTreeMap::new(),
    };
    let mut open = true;

    loop {
        if !open && !st.held.is_empty() {
            // the channel closed before an `Adopt` released these pens
            // (an interrupted migration): serve what we have — exactly
            // one response per request still holds
            release_pens(&mut st);
        }
        let queued = st.queues.values().map(|q| q.len()).sum::<usize>();
        if queued == 0 {
            if !open {
                break;
            }
            // idle: block for the next message (or channel close)
            match rx.recv() {
                Ok(msg) => handle_msg(&mut st, msg),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // soak up whatever else has arrived, without blocking
        while open {
            match rx.try_recv() {
                Ok(msg) => handle_msg(&mut st, msg),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // serve one batch from the shard whose head request is oldest
        let Some(shard) = st
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q.front().unwrap().enqueued)
            .map(|(s, _)| *s)
        else {
            continue;
        };
        let queue = st.queues.get_mut(&shard).unwrap();
        let mut batch = vec![queue.pop_front().unwrap()];
        while batch.len() < st.batch_policy.max_batch {
            match queue.front() {
                Some(next) if next.req.artifact == batch[0].req.artifact => {
                    batch.push(queue.pop_front().unwrap());
                }
                _ => break,
            }
        }
        serve_batch(&mut st, batch);
    }
    (st.metrics.into_values().collect(), st.prep)
}

/// Dispatch one admission-channel message.
fn handle_msg<E: Executor>(st: &mut WorkerState<E>, msg: WorkerMsg) {
    match msg {
        WorkerMsg::Req(env) => {
            // a held artifact's requests wait in the pen (arrival order)
            // until the migration's Adopt releases them
            if let Some(pen) = st.held.get_mut(&env.req.artifact) {
                pen.push(env);
            } else {
                st.queues.entry(env.shard).or_default().push_back(env);
            }
        }
        WorkerMsg::Hold { artifact } => {
            st.held.entry(artifact).or_default();
        }
        WorkerMsg::Quiesce { artifact, reply } => {
            // Extract every queued request for the migrating artifact.
            // The artifact lives on exactly one shard, and extraction
            // preserves both its internal order (per-artifact FIFO) and
            // the order of everything left behind; other shard queues are
            // untouched — only the affected queue quiesces.  (A pen for
            // the artifact cannot be live here — its Adopt always lands
            // first in channel order — but drain one defensively.)
            let mut pending: VecDeque<Envelope> = VecDeque::new();
            for q in st.queues.values_mut() {
                if !q.iter().any(|e| e.req.artifact == artifact) {
                    continue;
                }
                let mut rest = VecDeque::with_capacity(q.len());
                for env in q.drain(..) {
                    if env.req.artifact == artifact {
                        pending.push_back(env);
                    } else {
                        rest.push_back(env);
                    }
                }
                *q = rest;
            }
            if let Some(pen) = st.held.remove(&artifact) {
                pending.extend(pen);
            }
            let drained = pending.len() as u64;
            while !pending.is_empty() {
                // max_batch == 0 means "no grouping" on the normal path
                // (every batch still starts with one popped envelope);
                // mirror that here or the drain would never advance
                let take = pending.len().min(st.batch_policy.max_batch.max(1));
                serve_batch(st, pending.drain(..take).collect());
            }
            let cached = st.cache.remove(&artifact);
            let executor = match &mut st.executor {
                Ok(ex) => ex.export_state(&artifact),
                Err(_) => None,
            };
            // a dropped reply means the admission side is gone; nothing
            // left to do but keep serving
            let _ = reply.send(ArtifactState { artifact, drained, cached, executor });
        }
        WorkerMsg::Adopt { state } => {
            let ArtifactState { artifact, cached, executor, .. } = state;
            if let (Some(s), Ok(ex)) = (executor, &mut st.executor) {
                ex.import_state(&artifact, s);
            }
            if let Some(payload) = cached {
                st.cache.put(artifact.clone(), payload);
            }
            // release the pen: penned requests join the shard queues in
            // arrival order, now that the source's state is installed
            if let Some(pen) = st.held.remove(&artifact) {
                for env in pen {
                    st.queues.entry(env.shard).or_default().push_back(env);
                }
            }
        }
        WorkerMsg::Prewarm { artifact } => prewarm_from_disk(st, &artifact),
    }
}

/// Release every pen into the shard queues (channel closed before the
/// migration's `Adopt` arrived): served without the migrated state, but
/// served — the exactly-one-response invariant outranks state locality.
fn release_pens<E: Executor>(st: &mut WorkerState<E>) {
    let held = std::mem::take(&mut st.held);
    for (_, pen) in held {
        for env in pen {
            st.queues.entry(env.shard).or_default().push_back(env);
        }
    }
}

/// Migration pre-warm: load `artifact` from the persistent cache and
/// install it, *without* ever compiling.  A miss — no cache attached, no
/// digest, not on disk, or the executor declined the bytes — is a no-op:
/// the `Adopt` that follows (and the ordinary first-request path) still
/// make the artifact servable; pre-warming only moves the compile out of
/// the migration pause when the cache can oblige.
fn prewarm_from_disk<E: Executor>(st: &mut WorkerState<E>, artifact: &str) {
    if st.warmed.contains(artifact) {
        return;
    }
    let (Ok(ex), Some(cache)) = (&mut st.executor, &mut st.artifact_cache) else {
        return;
    };
    let Some(digest) = ex.artifact_digest(artifact) else {
        return;
    };
    let t0 = Instant::now();
    if let Some(bytes) = cache.load(&digest) {
        if matches!(ex.load_compiled(artifact, &bytes), Ok(true)) {
            st.warmed.insert(artifact.to_string());
            st.prep.push(PrepRecord {
                worker: st.worker,
                artifact: artifact.to_string(),
                seconds: t0.elapsed().as_secs_f64(),
                source: PrepSource::DiskWarm,
            });
        }
    }
}

/// First-touch preparation: make `artifact` servable on this worker,
/// preferring a warm load from the persistent cache over a fresh
/// compile, and write fresh compiles back to disk for the next start.
/// Exactly one [`PrepRecord`] is logged per (worker, artifact) first
/// touch; subsequent touches are plain `prepare` calls (idempotent and
/// unlogged, matching the pre-cache behaviour).
fn warm_artifact<E: Executor>(st: &mut WorkerState<E>, artifact: &str) -> Result<()> {
    let ex = match &mut st.executor {
        Ok(ex) => ex,
        Err(e) => return Err(anyhow!("executor unavailable: {e:#}")),
    };
    if st.warmed.contains(artifact) {
        return ex.prepare(artifact);
    }
    let digest = ex.artifact_digest(artifact);
    let t0 = Instant::now();
    // warm path: cached bytes the executor accepts make prepare a no-op
    if let (Some(digest), Some(cache)) = (&digest, &mut st.artifact_cache) {
        if let Some(bytes) = cache.load(digest) {
            if matches!(ex.load_compiled(artifact, &bytes), Ok(true)) {
                ex.prepare(artifact)?;
                st.warmed.insert(artifact.to_string());
                st.prep.push(PrepRecord {
                    worker: st.worker,
                    artifact: artifact.to_string(),
                    seconds: t0.elapsed().as_secs_f64(),
                    source: PrepSource::DiskWarm,
                });
                return Ok(());
            }
        }
    }
    // cold path: compile, then persist the compiled form for next time
    ex.prepare(artifact)?;
    let seconds = t0.elapsed().as_secs_f64();
    if let (Some(digest), Some(cache)) = (&digest, &mut st.artifact_cache) {
        if let Some(bytes) = ex.store_compiled(artifact) {
            let tier = workloads::synthetic_tier(artifact)
                .map(|(t, _)| t.name())
                .unwrap_or("pjrt");
            let _ = cache.store(digest, artifact, tier, &bytes);
        }
    }
    st.warmed.insert(artifact.to_string());
    st.prep.push(PrepRecord {
        worker: st.worker,
        artifact: artifact.to_string(),
        seconds,
        source: PrepSource::Compiled,
    });
    Ok(())
}

/// Serve one same-artifact batch: cache lookups, one shared warmup, then
/// per-request execution — every response is sent exactly once.
fn serve_batch<E: Executor>(st: &mut WorkerState<E>, batch: Vec<Envelope>) {
    debug_assert!(!batch.is_empty());
    debug_assert!(batch.iter().all(|e| e.shard == batch[0].shard));
    let shard = batch[0].shard;
    let artifact = batch[0].req.artifact.clone();
    let worker = st.worker;
    let sm = st
        .metrics
        .entry(shard)
        .or_insert_with(|| ShardMetrics::new(shard, worker));
    sm.batches += 1;
    sm.requests += batch.len() as u64;

    // skip executor warmup when the whole batch will hit the cache
    let prep = if st.cache.contains(&artifact) {
        Ok(())
    } else {
        warm_artifact(st, &artifact)
    };

    for env in batch {
        let sm = st
            .metrics
            .get_mut(&shard)
            .expect("shard metrics row created above");
        let latency = env.enqueued.elapsed().as_secs_f64();
        if let Some(&payload) = st.cache.get(&env.req.artifact) {
            sm.completed += 1;
            sm.cache_hits += 1;
            sm.latency.record(latency);
            let _ = st.resp_tx.send(Response {
                id: env.req.id,
                artifact: env.req.artifact,
                exec_seconds: 0.0,
                latency_seconds: latency,
                ok: true,
                error: None,
                payload: Some(payload),
                cached: true,
                shard,
                worker,
                shed: false,
                degraded_from: env.degraded_from,
            });
            continue;
        }
        let result = match (&mut st.executor, &prep) {
            (Ok(ex), Ok(())) => ex.execute(&env.req.artifact),
            (_, Err(e)) => Err(anyhow!("{e:#}")),
            (Err(e), _) => Err(anyhow!("executor unavailable: {e:#}")),
        };
        let sm = st
            .metrics
            .get_mut(&shard)
            .expect("shard metrics row created above");
        match result {
            Ok(exec) => {
                st.cache.put(env.req.artifact.clone(), exec.payload);
                let latency = env.enqueued.elapsed().as_secs_f64();
                sm.completed += 1;
                sm.latency.record(latency);
                let _ = st.resp_tx.send(Response {
                    id: env.req.id,
                    artifact: env.req.artifact,
                    exec_seconds: exec.seconds,
                    latency_seconds: latency,
                    ok: true,
                    error: None,
                    payload: Some(exec.payload),
                    cached: false,
                    shard,
                    worker,
                    shed: false,
                    degraded_from: env.degraded_from,
                });
            }
            Err(e) => {
                sm.failed += 1;
                let _ = st.resp_tx.send(Response {
                    id: env.req.id,
                    artifact: env.req.artifact,
                    exec_seconds: 0.0,
                    latency_seconds: env.enqueued.elapsed().as_secs_f64(),
                    ok: false,
                    error: Some(e.to_string()),
                    payload: None,
                    cached: false,
                    shard,
                    worker,
                    shed: false,
                    degraded_from: env.degraded_from,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Option<Registry> {
        Registry::open("artifacts").ok()
    }

    #[test]
    fn serves_requests_fifo_with_batching() {
        let Some(reg) = registry() else {
            eprintln!("skipping: no artifacts/");
            return;
        };
        let mut srv = Server::new(reg, BatchPolicy { max_batch: 4 });
        // interleaved artifacts: a a b a -> batches [a,a], [b], [a];
        // only *consecutive* same-artifact requests group, so completion
        // order stays strictly FIFO.
        for (id, art) in [
            (0u64, "gemm_f32_tuned_n32"),
            (1, "gemm_f32_tuned_n32"),
            (2, "gemm_f32_naive_n32"),
            (3, "gemm_f32_tuned_n32"),
        ] {
            srv.submit(Request { id, artifact: art.into() });
        }
        let resp = srv.drain();
        assert_eq!(resp.len(), 4);
        assert!(resp.iter().all(|r| r.ok), "{resp:?}");
        assert_eq!(resp.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(srv.metrics.batches, 3);
        assert_eq!(srv.metrics.completed, 4);
        assert_eq!(srv.queue_len(), 0);
    }

    #[test]
    fn unknown_artifact_fails_cleanly() {
        let Some(reg) = registry() else { return };
        let mut srv = Server::new(reg, BatchPolicy::default());
        srv.submit(Request { id: 9, artifact: "no_such_artifact".into() });
        let resp = srv.drain();
        assert_eq!(resp.len(), 1);
        assert!(!resp[0].ok);
        assert_eq!(srv.metrics.failed, 1);
        assert_eq!(srv.metrics.completed, 0);
    }

    #[test]
    fn metrics_totals_consistent() {
        let Some(reg) = registry() else { return };
        let mut srv = Server::new(reg, BatchPolicy { max_batch: 2 });
        for id in 0..5u64 {
            srv.submit(Request { id, artifact: "gemm_f32_tuned_n32".into() });
        }
        let t0 = Instant::now();
        let resp = srv.drain();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(resp.len(), 5);
        assert_eq!(srv.metrics.requests, 5);
        assert_eq!(srv.metrics.completed + srv.metrics.failed, 5);
        assert!(srv.metrics.throughput(wall) > 0.0);
        let s = srv.metrics.exec_summary().unwrap();
        assert!(s.median > 0.0);
        // latency includes queueing: never below exec time for any request
        for r in &resp {
            assert!(r.latency_seconds >= r.exec_seconds * 0.5);
        }
    }

    // -- sharded server unit tests (artifact-free; the full multi-worker
    //    invariant suite lives in rust/tests/serve_multiworker.rs) --

    fn synthetic_server(workers: usize, cache: usize) -> ShardedServer {
        ShardedServer::start(ServeConfig::new(workers).with_cache(cache), |_w| {
            Ok(SyntheticExecutor::new())
        })
    }

    #[test]
    fn sharded_serves_a_mixed_stream() {
        let mut srv = synthetic_server(2, 0);
        let names = workloads::serving_mix();
        for id in 0..12u64 {
            let artifact = names[id as usize % names.len()].artifact.clone();
            srv.submit(Request { id, artifact });
        }
        let out = srv.finish();
        assert_eq!(out.responses.len(), 12);
        assert!(out.responses.iter().all(|r| r.ok), "{:?}", out.responses);
        assert_eq!(out.metrics.requests, 12);
        assert_eq!(out.metrics.completed, 12);
        assert!(out.metrics.batches >= 1);
        assert!(out.wall_seconds > 0.0);
    }

    #[test]
    fn sharded_unknown_artifact_fails_cleanly() {
        let mut srv = synthetic_server(2, 8);
        srv.submit(Request { id: 0, artifact: "no_such_synthetic".into() });
        srv.submit(Request { id: 1, artifact: workloads::synthetic_artifact(32) });
        let out = srv.finish();
        assert_eq!(out.responses.len(), 2);
        let bad = out.responses.iter().find(|r| r.id == 0).unwrap();
        assert!(!bad.ok);
        assert!(bad.error.as_deref().unwrap().contains("synthetic"));
        let good = out.responses.iter().find(|r| r.id == 1).unwrap();
        assert!(good.ok);
        assert_eq!(out.metrics.completed, 1);
        assert_eq!(out.metrics.failed, 1);
    }

    #[test]
    fn cache_profiles_surface_worker_pressure() {
        use crate::hw::profile_by_name;
        use crate::telemetry::synthetic_gemm_profile;

        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let profiles: BTreeMap<String, CacheProfile> = mix
            .iter()
            .take(3)
            .map(|m| (m.artifact.clone(), synthetic_gemm_profile(&cpu, &m.artifact, m.n)))
            .collect();
        let profiles = Arc::new(profiles);
        let mut srv = ShardedServer::start(
            ServeConfig::new(2).with_profiles(profiles.clone()),
            |_w| Ok(SyntheticExecutor::new()),
        );
        for id in 0..16u64 {
            let artifact = mix[id as usize % mix.len()].artifact.clone();
            srv.submit(Request { id, artifact });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.worker_pressure.len(), 2);
        let total_artifacts: u64 =
            out.metrics.worker_pressure.iter().map(|p| p.artifacts).sum();
        assert_eq!(total_artifacts, mix.len() as u64, "each artifact on exactly one worker");
        let total_profiled: u64 =
            out.metrics.worker_pressure.iter().map(|p| p.profiled).sum();
        assert_eq!(total_profiled, 3);
        let resident: u64 =
            out.metrics.worker_pressure.iter().map(|p| p.resident_bytes).sum();
        let expected: u64 = profiles.values().map(|p| p.working_set_bytes).sum();
        assert_eq!(resident, expected);
    }

    #[test]
    fn no_profiles_means_no_pressure_rows() {
        let mut srv = synthetic_server(2, 0);
        srv.submit(Request { id: 0, artifact: workloads::synthetic_artifact(32) });
        let out = srv.finish();
        assert!(out.metrics.worker_pressure.is_empty());
    }

    // -- persistent artifact cache wiring (ISSUE 8 tentpole; the
    //    real-binary round trip lives in rust/tests/serve_cache.rs) --

    fn serve_cache_root(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cachebound_serve_cache_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn syn_state_codec_round_trips_every_tier() {
        let mut cold = SyntheticExecutor::new();
        let mut warm = SyntheticExecutor::new();
        for artifact in [
            workloads::tier_artifact(Tier::F32, 32),
            workloads::tier_artifact(Tier::Int8, 64),
            workloads::tier_artifact(Tier::BitSerial, 96),
        ] {
            cold.prepare(&artifact).unwrap();
            let bytes = cold.store_compiled(&artifact).unwrap();
            assert!(
                warm.load_compiled(&artifact, &bytes).unwrap(),
                "{artifact}: codec bytes accepted"
            );
            let a = cold.execute(&artifact).unwrap().payload;
            let b = warm.execute(&artifact).unwrap().payload;
            assert_eq!(a.to_bits(), b.to_bits(), "{artifact}: warm payload bit-identical");
        }
        // foreign bytes are declined (fall back to compile), never a panic
        assert!(!warm.load_compiled("syn_gemm_n32", b"not a payload").unwrap());
        // digests separate tiers sharing an N and are schedule-sensitive
        let d_f32 = warm.artifact_digest("syn_gemm_n64").unwrap();
        let d_i8 = warm.artifact_digest("syn_gemm_i8_n64").unwrap();
        assert_ne!(d_f32, d_i8);
        assert!(warm.artifact_digest("not_synthetic").is_none());
    }

    #[test]
    fn warm_server_start_performs_zero_compiles() {
        let root = serve_cache_root("warm_start");
        let run = || {
            let mut srv = ShardedServer::start(
                ServeConfig::new(2).with_cache_dir(root.clone()),
                |_w| Ok(SyntheticExecutor::new()),
            );
            let names: Vec<String> = workloads::serving_mix_tiered()
                .iter()
                .map(|m| m.artifact.clone())
                .collect();
            for (id, artifact) in names.iter().cycle().take(2 * names.len()).enumerate() {
                srv.submit(Request { id: id as u64, artifact: artifact.clone() });
            }
            srv.finish()
        };
        let cold = run();
        assert!(cold.responses.iter().all(|r| r.ok), "{:?}", cold.responses);
        assert!(!cold.metrics.prep.is_empty());
        assert!(
            cold.metrics.prep.iter().all(|p| p.source == PrepSource::Compiled),
            "first start compiles everything: {:?}",
            cold.metrics.prep
        );
        let warm = run();
        assert!(warm.responses.iter().all(|r| r.ok), "{:?}", warm.responses);
        assert_eq!(warm.metrics.prep.len(), cold.metrics.prep.len());
        assert_eq!(
            warm.metrics.prep.iter().filter(|p| p.source == PrepSource::Compiled).count(),
            0,
            "second start loads every artifact from disk: {:?}",
            warm.metrics.prep
        );
        // warm responses are bit-identical to cold ones, per artifact
        let payload_of = |out: &ServeOutcome| -> BTreeMap<String, u64> {
            out.responses
                .iter()
                .map(|r| (r.artifact.clone(), r.payload.unwrap().to_bits()))
                .collect()
        };
        assert_eq!(payload_of(&cold), payload_of(&warm));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn migration_prewarms_target_from_disk() {
        let root = serve_cache_root("prewarm");
        let mut srv = ShardedServer::start(
            ServeConfig::new(2).with_cache_dir(root.clone()),
            |_w| Ok(SyntheticExecutor::new()),
        );
        let artifact = workloads::synthetic_artifact(48);
        for id in 0..4u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        // wait for the responses: once they are in, the source's
        // first-touch compile *and* its disk store have happened
        let mut got = 0;
        while got < 4 {
            got += srv.poll_responses().len();
            thread::sleep(Duration::from_millis(1));
        }
        let from = srv.route_of(&artifact).expect("artifact routed");
        let to = (from + 1) % 2;
        let rec = srv.migrate(&artifact, to).expect("migration ran");
        assert_eq!((rec.from_worker, rec.to_worker), (from, to));
        assert!(rec.state_moved, "adopt still ships the authoritative state");
        for id in 4..8u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        assert_eq!(out.responses.len(), 8);
        assert!(out.responses.iter().all(|r| r.ok), "{:?}", out.responses);
        // exactly one payload value across the move (cache purity)
        let bits: BTreeSet<u64> =
            out.responses.iter().map(|r| r.payload.unwrap().to_bits()).collect();
        assert_eq!(bits.len(), 1, "payloads bit-identical across the migration");
        // the target pre-warmed from disk: a DiskWarm prep row on `to`,
        // logged by the Prewarm control message that precedes the fence
        assert!(
            out.metrics
                .prep
                .iter()
                .any(|p| p.worker == to
                    && p.artifact == artifact
                    && p.source == PrepSource::DiskWarm),
            "no pre-warm row on the target: {:?}",
            out.metrics.prep
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    /// The shared (cached) serving-mix profiles — the replays dominate
    /// test time, so every test reuses one traced set.
    fn mix_profiles() -> Arc<BTreeMap<String, CacheProfile>> {
        crate::telemetry::serving_mix_profiles(&profile_by_name("a53").unwrap().cpu)
    }

    #[test]
    fn cache_aware_placement_routes_by_plan() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_profiles(mix_profiles())
                .with_placement(PlacementPolicy::CacheAware)
                .with_cpu(cpu),
            |_w| Ok(SyntheticExecutor::new()),
        );
        let plan = srv.placement().expect("profiles + cache-aware => a plan").clone();
        assert_eq!(plan.assignments.len(), mix.len());
        for id in 0..20u64 {
            let artifact = mix[id as usize % mix.len()].artifact.clone();
            srv.submit(Request { id, artifact });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.completed, 20);
        // every artifact was served, so observed pressure must reconcile
        // exactly with the plan's per-worker prediction — proof the
        // admission path actually routed by the plan
        assert_eq!(out.metrics.worker_pressure.len(), 2);
        for row in &out.metrics.worker_pressure {
            assert_eq!(row.predicted_bytes, plan.predicted_bytes(row.worker));
            assert_eq!(
                row.resident_bytes, row.predicted_bytes,
                "worker {} diverged from the plan",
                row.worker
            );
        }
        assert!(out.rebalanced.is_none(), "no divergence when the stream matches the plan");
    }

    #[test]
    fn hash_placement_reports_no_predicted_pressure() {
        let mut srv = ShardedServer::start(
            ServeConfig::new(2).with_profiles(mix_profiles()),
            |_w| Ok(SyntheticExecutor::new()),
        );
        assert!(srv.placement().is_none());
        srv.submit(Request { id: 0, artifact: workloads::synthetic_artifact(32) });
        let out = srv.finish();
        assert!(out.metrics.worker_pressure.iter().all(|p| p.predicted_bytes == 0));
        assert!(out.rebalanced.is_none());
    }

    #[test]
    fn pressure_divergence_triggers_rebalance_hint() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_profiles(mix_profiles())
                .with_placement(PlacementPolicy::CacheAware)
                .with_cpu(cpu),
            |_w| Ok(SyntheticExecutor::new()),
        );
        // the plan expected the whole mix; serve only one artifact
        for id in 0..8u64 {
            srv.submit(Request { id, artifact: mix[0].artifact.clone() });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.completed, 8);
        let re = out.rebalanced.expect("one-artifact stream must diverge from the plan");
        assert_eq!(re.assignments.len(), 1, "re-planned over what was actually served");
        assert!(re.assignments.contains_key(&mix[0].artifact));
    }

    #[test]
    fn live_rebalance_converges_and_refreshes_predicted_pressure() {
        // Regression test for the stale-prediction bug: after a live plan
        // swap, `WorkerPressure::predicted_bytes` must come from the
        // *active* plan, not the one the server started with.
        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let mut cfg = ServeConfig::new(2)
            .with_profiles(mix_profiles())
            .with_placement(PlacementPolicy::CacheAware)
            .with_cpu(cpu)
            .with_rebalance(RebalanceMode::Live);
        cfg.rebalance_check_every = 8;
        let mut srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
        let initial = srv.placement().expect("cache-aware start").clone();
        // the plan expected the whole mix; serve only two artifacts, so
        // the divergence check must fire mid-stream and adopt a live plan
        for id in 0..24u64 {
            let artifact = mix[id as usize % 2].artifact.clone();
            srv.submit(Request { id, artifact });
        }
        let live = srv.active_plan().expect("live plan adopted").clone();
        assert_ne!(live, initial, "check must have re-planned over the observed pair");
        assert_eq!(live.assignments.len(), 2, "re-planned over what was served");
        let out = srv.finish();
        assert_eq!(out.metrics.completed, 24);
        for row in &out.metrics.worker_pressure {
            assert_eq!(
                row.predicted_bytes,
                live.predicted_bytes(row.worker),
                "worker {}: prediction must follow the live plan swap",
                row.worker
            );
            assert_eq!(
                row.resident_bytes, row.predicted_bytes,
                "worker {}: converged run must agree with its own plan",
                row.worker
            );
        }
        assert!(
            out.rebalanced.is_none(),
            "a converged live run has nothing left to suggest"
        );
    }

    #[test]
    fn rebalance_off_suppresses_hook_and_migrations() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let mix = workloads::serving_mix();
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_profiles(mix_profiles())
                .with_placement(PlacementPolicy::CacheAware)
                .with_cpu(cpu)
                .with_rebalance(RebalanceMode::Off),
            |_w| Ok(SyntheticExecutor::new()),
        );
        // the same divergent one-artifact stream that fires the Drain hook
        for id in 0..8u64 {
            srv.submit(Request { id, artifact: mix[0].artifact.clone() });
        }
        let out = srv.finish();
        assert_eq!(out.metrics.completed, 8);
        assert!(out.rebalanced.is_none(), "off means off");
        assert!(out.metrics.migrations.is_empty());
    }

    #[test]
    fn forced_migration_reroutes_and_logs() {
        let mut srv = synthetic_server(2, 8);
        let artifact = workloads::synthetic_artifact(32);
        for id in 0..4u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let from = srv.route_of(&artifact).expect("routed at first admission");
        let to = 1 - from;
        // moving to the current worker is a no-op...
        assert!(srv.migrate(&artifact, from).is_none());
        // ...moving away quiesces, hands state over and swaps the route
        let rec = srv.migrate(&artifact, to).expect("a real move");
        assert_eq!((rec.from_worker, rec.to_worker), (from, to));
        assert!(rec.forced);
        assert_eq!(srv.route_of(&artifact), Some(to));
        for id in 4..8u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        assert_eq!(out.responses.len(), 8);
        assert!(out.responses.iter().all(|r| r.ok));
        assert_eq!(out.metrics.migrations.len(), 1);
        // per-artifact FIFO across the migration
        let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "{ids:?}");
        ids.sort();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        // the artifact's one shard shows up under both owner epochs, and
        // the rows still reconcile with the aggregate
        let shard = out.responses[0].shard;
        let owners: Vec<usize> = out
            .metrics
            .per_shard
            .iter()
            .filter(|s| s.shard == shard)
            .map(|s| s.worker)
            .collect();
        assert_eq!(owners.len(), 2, "{:?}", out.metrics.per_shard);
        let total: u64 = out.metrics.per_shard.iter().map(|s| s.requests).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn forced_migration_of_unseen_artifact_pins_the_route() {
        let mut srv = synthetic_server(2, 0);
        let artifact = workloads::synthetic_artifact(48);
        let natural = shard_for(&artifact, srv.n_shards()) % srv.workers();
        let pinned = 1 - natural;
        let rec = srv.migrate(&artifact, pinned).expect("pin counts as a move");
        assert_eq!(rec.drained, 0);
        srv.submit(Request { id: 0, artifact: artifact.clone() });
        assert_eq!(srv.route_of(&artifact), Some(pinned));
        let out = srv.finish();
        assert!(out.responses[0].ok);
        let row = out.metrics.per_shard.iter().find(|s| s.requests > 0).unwrap();
        assert_eq!(row.worker, pinned, "the pinned route, not the hash, served it");
    }

    #[test]
    fn migrated_cache_entry_keeps_hitting_on_the_target() {
        let mut srv = synthetic_server(2, 8);
        let artifact = workloads::synthetic_artifact(64);
        for id in 0..3u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let from = srv.route_of(&artifact).unwrap();
        let rec = srv.migrate(&artifact, 1 - from).expect("moves");
        assert!(
            rec.cache_moved,
            "the response-cache entry must travel with the artifact: {rec:?}"
        );
        assert!(rec.state_moved, "synthetic inputs are transferable state");
        for id in 3..6u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        assert!(out.responses.iter().all(|r| r.ok));
        let by_id: BTreeMap<u64, &Response> =
            out.responses.iter().map(|r| (r.id, r)).collect();
        let payload = by_id[&0].payload.unwrap();
        for id in 3..6u64 {
            let r = by_id[&id];
            assert!(r.cached, "request {id} must hit the migrated cache entry");
            assert_eq!(r.exec_seconds, 0.0);
            assert_eq!(r.payload, Some(payload), "bit-identical across the move");
        }
    }

    #[test]
    fn admit_hot_path_is_one_snapshot_read() {
        // Regression for the old routes.get + re-insert double lookup:
        // admission must never write the route table.  Epochs advance
        // only on migrations, so any number of admissions — including
        // first admissions of brand-new artifacts — leaves the epoch
        // untouched, and the resolved route is identical before and after.
        let mut srv = synthetic_server(2, 8);
        assert_eq!(srv.route_epoch(), 0);
        let artifact = workloads::synthetic_artifact(32);
        assert_eq!(srv.route_of(&artifact), None, "unseen and unpinned");
        for id in 0..6u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
            assert_eq!(srv.route_epoch(), 0, "admission published a route epoch");
        }
        let routed = srv.route_of(&artifact).expect("observed after admission");
        assert_eq!(routed, shard_for(&artifact, srv.n_shards()) % srv.workers());
        // a migration is the only writer
        let rec = srv.migrate(&artifact, 1 - routed).expect("moves");
        assert_eq!(rec.to_worker, 1 - routed);
        assert_eq!(srv.route_epoch(), 1);
        let out = srv.finish();
        assert_eq!(out.metrics.completed, 6);
    }

    #[test]
    fn concurrent_admission_serves_the_mix_exactly_once() {
        // The concurrent drive must preserve the serving invariants the
        // single-threaded one guarantees: every request answered exactly
        // once, per-artifact FIFO (each artifact has one submitting
        // thread), totals reconciled.
        let mix = workloads::serving_mix();
        let stream: Vec<String> = (0..96)
            .map(|i| mix[i % mix.len()].artifact.clone())
            .collect();
        let srv = ShardedServer::start(
            ServeConfig::new(2).with_cache(8).with_admission_threads(4),
            |_w| Ok(SyntheticExecutor::new()),
        );
        let out = srv.serve_stream(stream.clone());
        assert_eq!(out.responses.len(), 96, "exactly one disposition each");
        assert_eq!(out.metrics.completed, 96);
        assert_eq!(out.metrics.requests, 96);
        let mut ids: Vec<u64> = out.responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..96).collect::<Vec<_>>(), "no lost or duplicated ids");
        // per-artifact FIFO: completion order restricted to one artifact
        // is its admission order
        let mut last: BTreeMap<&str, u64> = BTreeMap::new();
        for r in &out.responses {
            if let Some(&prev) = last.get(r.artifact.as_str()) {
                assert!(prev < r.id, "FIFO broke for {}: {} then {}", r.artifact, prev, r.id);
            }
            last.insert(r.artifact.as_str(), r.id);
        }
        // depth series is chronological after the merge sort in finish
        assert!(out
            .metrics
            .queue_depth
            .windows(2)
            .all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn worker_factory_failure_fails_requests_not_process() {
        let mut srv = ShardedServer::start(ServeConfig::new(2), |_w| {
            Err::<SyntheticExecutor, _>(anyhow!("no backend on this host"))
        });
        for id in 0..4u64 {
            srv.submit(Request { id, artifact: workloads::synthetic_artifact(32) });
        }
        let out = srv.finish();
        assert_eq!(out.responses.len(), 4);
        assert!(out.responses.iter().all(|r| !r.ok));
        assert_eq!(out.metrics.failed, 4);
        assert!(out.responses[0].error.as_deref().unwrap().contains("no backend"));
        // failures still contribute latency samples (every disposition does)
        assert_eq!(out.metrics.latency_seconds.len() as u64, out.metrics.requests);
    }

    // -- admission control and the open-loop drive --

    #[test]
    fn admission_mode_parses_and_names() {
        assert_eq!(AdmissionMode::parse("none").unwrap(), AdmissionMode::None);
        assert_eq!(AdmissionMode::parse("off").unwrap(), AdmissionMode::None);
        assert_eq!(AdmissionMode::parse("shed").unwrap(), AdmissionMode::Shed);
        assert_eq!(AdmissionMode::parse("degrade").unwrap(), AdmissionMode::Degrade);
        assert!(AdmissionMode::parse("drop").is_err());
        assert_eq!(AdmissionMode::Shed.name(), "shed");
        assert_eq!(AdmissionMode::Degrade.key_part(), "degrade");
    }

    #[test]
    fn shed_mode_bounds_the_queue_and_reconciles_dispositions() {
        // one big artifact routed to one worker, limit 1: a fast submit
        // burst must shed nearly everything while the worker chews
        let mut srv = ShardedServer::start(
            ServeConfig::new(2).with_admission(AdmissionMode::Shed).with_admission_limit(1),
            |_w| Ok(SyntheticExecutor::new()),
        );
        let artifact = workloads::synthetic_artifact(128);
        let n = 30u64;
        for id in 0..n {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        let m = &out.metrics;
        assert_eq!(m.requests, n);
        assert_eq!(out.responses.len() as u64, n, "exactly one disposition each");
        assert_eq!(m.completed + m.failed + m.shed, m.requests);
        assert_eq!(m.latency_seconds.len() as u64, m.requests);
        assert!(m.shed > 0, "a burst past limit 1 must shed: {m:?}");
        assert!(m.failed == 0, "sheds are not failures");
        // with a per-worker limit of 1, total in-flight never exceeds the
        // worker count
        assert!(
            m.max_queue_depth() <= 2,
            "bounded queue under Shed, saw {}",
            m.max_queue_depth()
        );
        for r in out.responses.iter().filter(|r| r.shed) {
            assert!(!r.ok);
            assert!(r.error.as_deref().unwrap().contains("shed"));
        }
        // served responses stay FIFO per artifact even mid-overload
        let served: Vec<u64> =
            out.responses.iter().filter(|r| r.ok).map(|r| r.id).collect();
        assert!(served.windows(2).all(|w| w[0] < w[1]), "{served:?}");
    }

    #[test]
    fn degrade_mode_serves_smaller_variants_and_counts_them() {
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_admission(AdmissionMode::Degrade)
                .with_admission_limit(1),
            |_w| Ok(SyntheticExecutor::new()),
        );
        let artifact = workloads::synthetic_artifact(128);
        let n = 16u64;
        for id in 0..n {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        let m = &out.metrics;
        assert_eq!(m.requests, n);
        assert_eq!(m.completed + m.failed + m.shed, m.requests);
        assert_eq!(m.latency_seconds.len() as u64, m.requests);
        assert!(m.degraded > 0, "a burst past limit 1 must degrade: {m:?}");
        assert!(m.degraded <= m.completed, "degraded is a subset of completed");
        let degraded: Vec<&Response> =
            out.responses.iter().filter(|r| r.degraded_from.is_some()).collect();
        assert_eq!(degraded.len() as u64, m.degraded);
        for r in &degraded {
            assert!(r.ok);
            assert_eq!(r.degraded_from.as_deref(), Some(artifact.as_str()));
            assert_eq!(r.artifact, workloads::synthetic_artifact(96), "next size down");
        }
    }

    #[test]
    fn degrade_falls_back_to_shed_at_the_smallest_variant() {
        let mut srv = ShardedServer::start(
            ServeConfig::new(1)
                .with_admission(AdmissionMode::Degrade)
                .with_admission_limit(1),
            |_w| Ok(SyntheticExecutor::new()),
        );
        // n32 has no smaller variant, so over-limit requests must shed
        let artifact = workloads::synthetic_artifact(32);
        for id in 0..20u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        let m = &out.metrics;
        assert_eq!(m.completed + m.failed + m.shed, m.requests);
        assert_eq!(m.degraded, 0, "nothing below n32 to degrade to");
        assert!(m.shed > 0, "over-limit n32 requests must shed: {m:?}");
    }

    #[test]
    fn tier_policy_parses_and_names() {
        assert_eq!(TierPolicy::parse("pinned").unwrap(), TierPolicy::Pinned);
        assert_eq!(TierPolicy::parse("pin").unwrap(), TierPolicy::Pinned);
        assert_eq!(
            TierPolicy::parse("downshift").unwrap(),
            TierPolicy::DownshiftOnPressure
        );
        assert_eq!(TierPolicy::parse("down").unwrap(), TierPolicy::DownshiftOnPressure);
        assert!(TierPolicy::parse("quantize").is_err());
        assert_eq!(TierPolicy::default(), TierPolicy::Pinned);
        assert_eq!(TierPolicy::Pinned.name(), "pinned");
        assert_eq!(TierPolicy::Pinned.key_part(), "pin");
        assert_eq!(TierPolicy::DownshiftOnPressure.name(), "downshift");
        assert_eq!(TierPolicy::DownshiftOnPressure.key_part(), "down");
    }

    #[test]
    fn sharded_serves_the_tiered_mix_across_all_precisions() {
        let mut srv = synthetic_server(2, 0);
        let mix = workloads::serving_mix_tiered();
        for (id, item) in mix.iter().enumerate() {
            srv.submit(Request { id: id as u64, artifact: item.artifact.clone() });
        }
        let out = srv.finish();
        assert_eq!(out.responses.len(), mix.len());
        assert!(out.responses.iter().all(|r| r.ok), "{:?}", out.responses);
        assert_eq!(out.metrics.completed, mix.len() as u64);
        // every tier produced a real payload, int8 and bit-serial included
        for item in &mix {
            let r = out.responses.iter().find(|r| r.artifact == item.artifact).unwrap();
            assert!(r.payload.is_some(), "{} returned no payload", item.artifact);
        }
    }

    #[test]
    fn downshift_policy_degrades_precision_at_the_same_n() {
        let mut srv = ShardedServer::start(
            ServeConfig::new(2)
                .with_admission(AdmissionMode::Degrade)
                .with_admission_limit(1)
                .with_tier_policy(TierPolicy::DownshiftOnPressure),
            |_w| Ok(SyntheticExecutor::new()),
        );
        let artifact = workloads::synthetic_artifact(128);
        let n = 16u64;
        for id in 0..n {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        let m = &out.metrics;
        assert_eq!(m.completed + m.failed + m.shed, m.requests);
        assert!(m.degraded > 0, "a burst past limit 1 must downshift: {m:?}");
        for r in out.responses.iter().filter(|r| r.degraded_from.is_some()) {
            assert!(r.ok);
            assert_eq!(r.degraded_from.as_deref(), Some(artifact.as_str()));
            assert_eq!(
                r.artifact,
                workloads::tier_artifact(Tier::Int8, 128),
                "precision drops, N stays"
            );
        }
    }

    #[test]
    fn downshift_sheds_below_the_bitserial_floor() {
        let mut srv = ShardedServer::start(
            ServeConfig::new(1)
                .with_admission(AdmissionMode::Degrade)
                .with_admission_limit(1)
                .with_tier_policy(TierPolicy::DownshiftOnPressure),
            |_w| Ok(SyntheticExecutor::new()),
        );
        // bit-serial is the lattice floor: nothing below it to downshift to
        let artifact = workloads::tier_artifact(Tier::BitSerial, 96);
        for id in 0..16u64 {
            srv.submit(Request { id, artifact: artifact.clone() });
        }
        let out = srv.finish();
        let m = &out.metrics;
        assert_eq!(m.completed + m.failed + m.shed, m.requests);
        assert_eq!(m.degraded, 0, "nothing below the bit-serial floor");
        assert!(m.shed > 0, "over-limit floor requests must shed: {m:?}");
    }

    #[test]
    fn open_loop_drive_answers_every_arrival() {
        use super::super::loadgen::ArrivalConfig;

        let srv = synthetic_server(2, 0);
        let n = 16;
        let schedule = ArrivalConfig::poisson(2000.0, n, 5).schedule();
        let names = workloads::serving_mix();
        let stream =
            (0..n).map(|i| names[i % names.len()].artifact.clone()).collect::<Vec<_>>();
        let out = srv.serve_open_loop(stream, &schedule);
        let m = &out.metrics;
        assert_eq!(m.requests, n as u64);
        assert_eq!(out.responses.len(), n);
        assert_eq!(m.completed + m.failed + m.shed, m.requests);
        assert_eq!(m.latency_seconds.len(), n);
        assert_eq!(m.queue_depth.len(), n, "one depth sample per submission");
        // the drive paced submissions, so the run spans the schedule
        assert!(out.wall_seconds >= *schedule.last().unwrap());
    }

    #[test]
    fn latency_percentiles_edge_cases() {
        let m = Metrics::default();
        assert!(m.latency_percentiles(&[50.0]).is_none(), "empty set has no percentiles");

        let one = Metrics { latency_seconds: vec![5.0], ..Metrics::default() };
        assert_eq!(
            one.latency_percentiles(&[0.0, 50.0, 99.9, 100.0]).unwrap(),
            vec![5.0; 4],
            "single sample answers every percentile"
        );

        let many = Metrics {
            latency_seconds: (1..=100).map(|i| i as f64).collect(),
            ..Metrics::default()
        };
        let ps = many.latency_percentiles(&[0.0, 99.0, 99.9, 100.0]).unwrap();
        assert_eq!(ps[0], 1.0);
        assert_eq!(ps[3], 100.0);
        assert!(ps[1] < ps[2] && ps[2] < ps[3], "p99 < p999 < max: {ps:?}");
    }

    #[test]
    fn max_queue_depth_of_empty_series_is_zero() {
        assert_eq!(Metrics::default().max_queue_depth(), 0);
        let m = Metrics {
            queue_depth: vec![(0.0, 1), (0.1, 5), (0.2, 3)],
            ..Metrics::default()
        };
        assert_eq!(m.max_queue_depth(), 5);
    }
}
