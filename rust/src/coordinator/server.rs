//! Inference-serving loop: request queue → batcher → PJRT execution.
//!
//! The deployment face of the L3 coordinator: clients submit operator
//! requests (by artifact name); the server groups consecutive requests to
//! the same executable (compile-once batching — the useful batching axis
//! for shape-static XLA executables), executes through the PJRT registry
//! on the leader thread, and returns per-request latencies plus aggregate
//! metrics.  Python is nowhere in this loop — the binary serves purely
//! from `artifacts/`.
//!
//! Invariants (tested): FIFO completion order per artifact, exactly one
//! response per request, metrics totals match request counts.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::Registry;
use crate::util::stats::Summary;

/// One inference request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    /// Artifact name to execute (the "model variant" being served).
    pub artifact: String,
}

/// One completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub artifact: String,
    /// Execution wall time (excludes queueing).
    pub exec_seconds: f64,
    /// Total latency including queue wait.
    pub latency_seconds: f64,
    pub ok: bool,
    pub error: Option<String>,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub exec_seconds: Vec<f64>,
    pub latency_seconds: Vec<f64>,
}

impl Metrics {
    pub fn exec_summary(&self) -> Option<Summary> {
        (!self.exec_seconds.is_empty()).then(|| Summary::of(&self.exec_seconds))
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        (!self.latency_seconds.is_empty()).then(|| Summary::of(&self.latency_seconds))
    }

    pub fn throughput(&self, wall_seconds: f64) -> f64 {
        self.completed as f64 / wall_seconds.max(1e-12)
    }
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max consecutive same-artifact requests grouped into one batch.
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8 }
    }
}

/// The server: single-threaded leader loop over a PJRT registry.
pub struct Server {
    registry: Registry,
    policy: BatchPolicy,
    queue: VecDeque<(Request, Instant)>,
    pub metrics: Metrics,
}

impl Server {
    pub fn new(registry: Registry, policy: BatchPolicy) -> Self {
        Server {
            registry,
            policy,
            queue: VecDeque::new(),
            metrics: Metrics::default(),
        }
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests += 1;
        self.queue.push_back((req, Instant::now()));
    }

    /// Drain the queue, batching same-artifact runs; returns responses in
    /// completion order (FIFO except for batch grouping).
    pub fn drain(&mut self) -> Vec<Response> {
        let mut responses = Vec::with_capacity(self.queue.len());
        while let Some((head, t_enq)) = self.queue.pop_front() {
            // group consecutive same-artifact requests
            let mut batch = vec![(head, t_enq)];
            while batch.len() < self.policy.max_batch {
                match self.queue.front() {
                    Some((next, _)) if next.artifact == batch[0].0.artifact => {
                        batch.push(self.queue.pop_front().unwrap());
                    }
                    _ => break,
                }
            }
            self.metrics.batches += 1;
            // ensure compiled + inputs ready (first call pays compilation —
            // the server's warmup; excluded from exec time via pre-touch)
            let artifact = batch[0].0.artifact.clone();
            let prep: Result<()> = (|| {
                self.registry.executable(&artifact)?;
                self.registry.inputs(&artifact)?;
                Ok(())
            })();
            for (req, enq) in batch {
                match &prep {
                    Ok(()) => match self.registry.run_protocol(&req.artifact) {
                        Ok(out) => {
                            self.metrics.completed += 1;
                            self.metrics.exec_seconds.push(out.seconds);
                            let latency = enq.elapsed().as_secs_f64();
                            self.metrics.latency_seconds.push(latency);
                            responses.push(Response {
                                id: req.id,
                                artifact: req.artifact,
                                exec_seconds: out.seconds,
                                latency_seconds: latency,
                                ok: true,
                                error: None,
                            });
                        }
                        Err(e) => responses.push(self.fail(req, enq, e.to_string())),
                    },
                    Err(e) => {
                        let msg = e.to_string();
                        responses.push(self.fail(req, enq, msg));
                    }
                }
            }
        }
        responses
    }

    fn fail(&mut self, req: Request, enq: Instant, error: String) -> Response {
        self.metrics.failed += 1;
        Response {
            id: req.id,
            artifact: req.artifact,
            exec_seconds: 0.0,
            latency_seconds: enq.elapsed().as_secs_f64(),
            ok: false,
            error: Some(error),
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Option<Registry> {
        Registry::open("artifacts").ok()
    }

    #[test]
    fn serves_requests_fifo_with_batching() {
        let Some(reg) = registry() else {
            eprintln!("skipping: no artifacts/");
            return;
        };
        let mut srv = Server::new(reg, BatchPolicy { max_batch: 4 });
        // interleaved artifacts: a a b a -> batches [a,a], [b], [a];
        // only *consecutive* same-artifact requests group, so completion
        // order stays strictly FIFO.
        for (id, art) in [
            (0u64, "gemm_f32_tuned_n32"),
            (1, "gemm_f32_tuned_n32"),
            (2, "gemm_f32_naive_n32"),
            (3, "gemm_f32_tuned_n32"),
        ] {
            srv.submit(Request { id, artifact: art.into() });
        }
        let resp = srv.drain();
        assert_eq!(resp.len(), 4);
        assert!(resp.iter().all(|r| r.ok), "{resp:?}");
        assert_eq!(resp.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(srv.metrics.batches, 3);
        assert_eq!(srv.metrics.completed, 4);
        assert_eq!(srv.queue_len(), 0);
    }

    #[test]
    fn unknown_artifact_fails_cleanly() {
        let Some(reg) = registry() else { return };
        let mut srv = Server::new(reg, BatchPolicy::default());
        srv.submit(Request { id: 9, artifact: "no_such_artifact".into() });
        let resp = srv.drain();
        assert_eq!(resp.len(), 1);
        assert!(!resp[0].ok);
        assert_eq!(srv.metrics.failed, 1);
        assert_eq!(srv.metrics.completed, 0);
    }

    #[test]
    fn metrics_totals_consistent() {
        let Some(reg) = registry() else { return };
        let mut srv = Server::new(reg, BatchPolicy { max_batch: 2 });
        for id in 0..5u64 {
            srv.submit(Request { id, artifact: "gemm_f32_tuned_n32".into() });
        }
        let t0 = Instant::now();
        let resp = srv.drain();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(resp.len(), 5);
        assert_eq!(srv.metrics.requests, 5);
        assert_eq!(srv.metrics.completed + srv.metrics.failed, 5);
        assert!(srv.metrics.throughput(wall) > 0.0);
        let s = srv.metrics.exec_summary().unwrap();
        assert!(s.median > 0.0);
        // latency includes queueing: never below exec time for any request
        for r in &resp {
            assert!(r.latency_seconds >= r.exec_seconds * 0.5);
        }
    }
}
