//! Worker pool: leader/worker job routing over std channels.
//!
//! CPU-pure jobs fan out to `n_workers` threads; `leader_only` jobs (PJRT)
//! stay on the calling thread and are interleaved with result collection.
//! This pool runs *finite experiment batches*; open-ended request streams
//! are the sharded server's territory (`coordinator::server`), which trades
//! the shared job channel for per-artifact shard ownership so executables
//! stay cache-resident on one worker.
//! Invariants (property-tested in `rust/tests/proptests.rs`):
//!
//! * every submitted job produces exactly one result, failure or not;
//! * leader-only jobs never execute on a worker thread;
//! * results preserve job ids (no cross-wiring under concurrency).

use std::sync::mpsc;
use std::thread;

use crate::runtime::Registry;
use crate::util::bench::BenchConfig;

use super::jobs::{run_cpu_job, Job, JobOutput, JobSpec};

/// A completed job.
#[derive(Clone, Debug)]
pub struct Completed {
    /// Job sequence number.
    pub id: u64,
    /// Stable result key of the job spec.
    pub key: String,
    /// What the job produced.
    pub output: JobOutput,
    /// Thread label that executed the job ("leader" or "worker-<i>").
    pub executed_on: String,
}

/// Fixed-size worker pool.
pub struct WorkerPool {
    /// Worker threads the pool spawns per batch.
    pub n_workers: usize,
}

impl WorkerPool {
    /// Pool with `n_workers` threads (min 1).
    pub fn new(n_workers: usize) -> Self {
        WorkerPool {
            n_workers: n_workers.max(1),
        }
    }

    /// A one-worker pool.  Used for jobs that spawn their own thread pools
    /// (e.g. `JobSpec::ServeMix`, which runs a whole sharded server) and
    /// must therefore execute one at a time to avoid core contention.
    pub fn serial() -> Self {
        WorkerPool::new(1)
    }

    /// Run a batch of jobs to completion.  `registry` (PJRT) is used by the
    /// leader for `leader_only` jobs; pass `None` to fail those gracefully.
    pub fn run(&self, jobs: Vec<Job>, mut registry: Option<&mut Registry>) -> Vec<Completed> {
        let (leader_jobs, worker_jobs): (Vec<Job>, Vec<Job>) =
            jobs.into_iter().partition(|j| j.spec.leader_only());

        // spawn workers over a shared channel
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = std::sync::Arc::new(std::sync::Mutex::new(job_rx));
        let (res_tx, res_rx) = mpsc::channel::<Completed>();
        let mut handles = Vec::new();
        for w in 0..self.n_workers {
            let rx = job_rx.clone();
            let tx = res_tx.clone();
            handles.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        let output = run_cpu_job(&job.spec);
                        let _ = tx.send(Completed {
                            id: job.id,
                            key: job.spec.key(),
                            output,
                            executed_on: format!("worker-{w}"),
                        });
                    }
                    Err(_) => break, // channel closed: drain done
                }
            }));
        }
        drop(res_tx);

        let n_worker_jobs = worker_jobs.len();
        for job in worker_jobs {
            job_tx.send(job).expect("worker channel open");
        }
        drop(job_tx);

        // leader executes PJRT jobs while workers chew
        let mut completed = Vec::new();
        for job in leader_jobs {
            let output = Self::run_leader_job(&job.spec, registry.as_deref_mut());
            completed.push(Completed {
                id: job.id,
                key: job.spec.key(),
                output,
                executed_on: "leader".into(),
            });
        }

        for _ in 0..n_worker_jobs {
            completed.push(res_rx.recv().expect("worker result"));
        }
        for h in handles {
            let _ = h.join();
        }
        completed
    }

    fn run_leader_job(spec: &JobSpec, registry: Option<&mut Registry>) -> JobOutput {
        let Some(registry) = registry else {
            return JobOutput::Failed {
                error: "no artifact registry available (run `make artifacts`)".into(),
            };
        };
        match spec {
            JobSpec::ArtifactValidate { name } => match registry.validate(name) {
                Ok(v) => JobOutput::Validated {
                    passed: v.passed,
                    detail: format!("{:?}", v.details),
                },
                Err(e) => JobOutput::Failed { error: e.to_string() },
            },
            JobSpec::ArtifactMeasure { name } => {
                match registry.measure(name, &BenchConfig::quick()) {
                    Ok(m) => JobOutput::Seconds {
                        secs: m.seconds.median,
                        bound: None,
                    },
                    Err(e) => JobOutput::Failed { error: e.to_string() },
                }
            }
            other => run_cpu_job(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::profile_by_name;
    use crate::operators::gemm::GemmSchedule;

    fn sim_job(id: u64, n: usize) -> Job {
        Job {
            id,
            spec: JobSpec::SimGemm {
                cpu: profile_by_name("a53").unwrap().cpu,
                n,
                schedule: GemmSchedule::new(64, 64, 64, 4),
                elem_bits: 32,
            },
        }
    }

    #[test]
    fn all_jobs_complete_exactly_once() {
        let pool = WorkerPool::new(3);
        let jobs: Vec<Job> = (0..20).map(|i| sim_job(i, 64 + (i as usize % 4) * 32)).collect();
        let done = pool.run(jobs, None);
        assert_eq!(done.len(), 20);
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn leader_jobs_fail_gracefully_without_registry() {
        let pool = WorkerPool::new(2);
        let jobs = vec![Job {
            id: 0,
            spec: JobSpec::ArtifactValidate { name: "nope".into() },
        }];
        let done = pool.run(jobs, None);
        assert_eq!(done.len(), 1);
        assert!(done[0].output.is_failure());
        assert_eq!(done[0].executed_on, "leader");
    }

    #[test]
    fn cpu_jobs_run_on_workers() {
        let pool = WorkerPool::new(2);
        let done = pool.run(vec![sim_job(7, 64)], None);
        assert!(done[0].executed_on.starts_with("worker-"));
    }
}
