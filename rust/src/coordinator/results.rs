//! Result store: keyed measurement results + JSON/CSV persistence.
//!
//! Every experiment result lands here under its job key; the report layer
//! queries by prefix, and `save`/`load` persist runs under `results/` so
//! expensive sweeps (native timings, tuning) are reusable across commands.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

/// A result key (the job key, e.g. "sim_gemm/cortex-a53/n128/b64x64x64u4/e32").
pub type ResultKey = String;

/// A stored value.
#[derive(Clone, Debug, PartialEq)]
pub struct ResultValue {
    /// Headline timing, when the result has one.
    pub seconds: Option<f64>,
    /// Simulator bound / predicted class, when present.
    pub bound: Option<String>,
    /// Pass/fail verdict, when the result is a check.
    pub passed: Option<bool>,
    /// Free-form detail line for reports.
    pub detail: Option<String>,
}

impl ResultValue {
    /// A plain timing result.
    pub fn seconds(secs: f64) -> Self {
        ResultValue {
            seconds: Some(secs),
            bound: None,
            passed: None,
            detail: None,
        }
    }
}

/// The store.
#[derive(Clone, Debug, Default)]
pub struct ResultStore {
    map: BTreeMap<ResultKey, ResultValue>,
}

impl ResultStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or replace one result.
    pub fn insert(&mut self, key: impl Into<String>, value: ResultValue) {
        self.map.insert(key.into(), value);
    }

    /// Ingest a batch of completed jobs.
    pub fn ingest(&mut self, completed: &[super::pool::Completed]) {
        for c in completed {
            let v = match &c.output {
                super::jobs::JobOutput::Seconds { secs, bound } => ResultValue {
                    seconds: Some(*secs),
                    bound: bound.clone(),
                    passed: None,
                    detail: None,
                },
                super::jobs::JobOutput::Tuned { best_seconds, best_desc, trials, space } => {
                    ResultValue {
                        seconds: Some(*best_seconds),
                        bound: None,
                        passed: None,
                        detail: Some(format!("{best_desc} ({trials}/{space} trials)")),
                    }
                }
                super::jobs::JobOutput::Served {
                    throughput_rps,
                    p50_s,
                    p99_s,
                    completed,
                    failed,
                    shed,
                    cache_hits,
                    migrations,
                    compiled,
                    disk_warm,
                } => ResultValue {
                    // p50 end-to-end latency is the headline "seconds" of a
                    // serving run; the rest rides in `detail`.  Sheds are a
                    // deliberate admission disposition, not failures, so
                    // they don't affect `passed`.
                    seconds: Some(*p50_s),
                    bound: None,
                    passed: Some(*failed == 0),
                    detail: Some(format!(
                        "{throughput_rps:.1} req/s, p99 {:.3} ms, {completed} ok / {failed} \
                         failed / {shed} shed, {cache_hits} cache hits, {migrations} migrations, \
                         {compiled} compiled / {disk_warm} disk-warm",
                        p99_s * 1e3
                    )),
                },
                super::jobs::JobOutput::Traced { summary } => ResultValue {
                    seconds: None,
                    // the headline verdict: the MRC-predicted boundness
                    bound: Some(summary.predicted_class.clone()),
                    passed: Some(summary.classes_agree()),
                    detail: Some(summary.render()),
                },
                super::jobs::JobOutput::Validated { passed, detail } => ResultValue {
                    seconds: None,
                    bound: None,
                    passed: Some(*passed),
                    detail: Some(detail.clone()),
                },
                super::jobs::JobOutput::Failed { error } => ResultValue {
                    seconds: None,
                    bound: None,
                    passed: Some(false),
                    detail: Some(error.clone()),
                },
            };
            self.insert(c.key.clone(), v);
        }
    }

    /// Look up one result by key.
    pub fn get(&self, key: &str) -> Option<&ResultValue> {
        self.map.get(key)
    }

    /// The `seconds` field of a result, if both exist.
    pub fn seconds(&self, key: &str) -> Option<f64> {
        self.map.get(key).and_then(|v| v.seconds)
    }

    /// All entries whose key starts with `prefix`.
    pub fn by_prefix(&self, prefix: &str) -> Vec<(&str, &ResultValue)> {
        self.map
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), v))
            .collect()
    }

    /// Stored result count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Persist to JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut entries = BTreeMap::new();
        for (k, v) in &self.map {
            let mut obj = BTreeMap::new();
            if let Some(s) = v.seconds {
                obj.insert("seconds".to_string(), Value::Num(s));
            }
            if let Some(b) = &v.bound {
                obj.insert("bound".to_string(), Value::Str(b.clone()));
            }
            if let Some(p) = v.passed {
                obj.insert("passed".to_string(), Value::Bool(p));
            }
            if let Some(d) = &v.detail {
                obj.insert("detail".to_string(), Value::Str(d.clone()));
            }
            entries.insert(k.clone(), Value::Obj(obj));
        }
        fs::write(path, json::to_string_pretty(&Value::Obj(entries)))
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load from JSON written by `save`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = fs::read_to_string(path.as_ref())?;
        let v = json::parse(&text)?;
        let mut store = ResultStore::new();
        for (k, entry) in v.as_obj()? {
            store.insert(
                k.clone(),
                ResultValue {
                    seconds: entry.get("seconds").and_then(|x| x.as_f64().ok()),
                    bound: entry.get("bound").and_then(|x| x.as_str().ok()).map(String::from),
                    passed: entry.get("passed").and_then(|x| x.as_bool().ok()),
                    detail: entry.get("detail").and_then(|x| x.as_str().ok()).map(String::from),
                },
            );
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_query_prefix() {
        let mut s = ResultStore::new();
        s.insert("sim_gemm/a53/n128", ResultValue::seconds(1.0));
        s.insert("sim_gemm/a53/n256", ResultValue::seconds(2.0));
        s.insert("sim_conv/a53/C2", ResultValue::seconds(3.0));
        assert_eq!(s.by_prefix("sim_gemm/").len(), 2);
        assert_eq!(s.seconds("sim_conv/a53/C2"), Some(3.0));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut s = ResultStore::new();
        s.insert("a/b", ResultValue::seconds(0.25));
        s.insert(
            "c/d",
            ResultValue {
                seconds: None,
                bound: Some("L1-read".into()),
                passed: Some(true),
                detail: Some("ok".into()),
            },
        );
        let path = std::env::temp_dir().join("cachebound_results_test/r.json");
        s.save(&path).unwrap();
        let loaded = ResultStore::load(&path).unwrap();
        assert_eq!(loaded.seconds("a/b"), Some(0.25));
        assert_eq!(loaded.get("c/d").unwrap().bound.as_deref(), Some("L1-read"));
        let _ = fs::remove_dir_all(path.parent().unwrap());
    }
}
