//! Shard primitives for the serving core: artifact→shard hashing and
//! per-shard latency accounting.
//!
//! The sharded server ([`super::server::ShardedServer`]) keys every request
//! by its artifact name.  [`shard_for`] maps a name to one of `n_shards`
//! queues; under hash placement each shard is owned by exactly one worker
//! (shard id mod worker count; a cache-aware plan —
//! [`super::placement`] — may instead split a shard's artifacts across
//! workers, keeping per-artifact affinity).  This gives the two
//! properties the whole design rests on:
//!
//! * **cache affinity** — an artifact's compiled executable, inputs and
//!   response cache live on one worker, so repeated requests stay hot in
//!   that worker's caches (the L1-bandwidth-bound story of the paper,
//!   applied at the serving layer);
//! * **per-artifact FIFO without a global lock** — one owner means requests
//!   for an artifact are executed in admission order with no cross-worker
//!   coordination.
//!
//! [`LatencyHistogram`] is a log₂-bucketed histogram (nanoseconds up to
//! ~2.3 minutes) cheap enough to update per request; [`ShardMetrics`]
//! aggregates one shard's counters and histogram, and rolls up into the
//! aggregate `Metrics` via [`ShardMetrics::merge`].
//!
//! A live migration ([`super::server::ShardedServer::migrate`]) moves an
//! artifact's *worker*, never its shard — [`shard_for`] is a pure function
//! of the name — so after a move the same shard id accumulates one
//! [`ShardMetrics`] row per owner epoch, keyed `(shard, worker)`, and the
//! rows still sum to the aggregate totals (the reconciliation the
//! migration chaos suite asserts).

use crate::util::rng::mix;

/// Number of log₂ latency buckets: bucket `i` holds latencies in
/// `[2^i, 2^(i+1))` nanoseconds; bucket 37 tops out at ~2.3 min.
pub const HISTOGRAM_BUCKETS: usize = 38;

/// Stable artifact→shard mapping: FNV-1a over the name, finished with a
/// SplitMix64 avalanche, reduced by Lemire multiply-shift.  Deterministic
/// across runs and platforms (no `RandomState`), well-spread for the short
/// structured names artifacts use.
pub fn shard_for(artifact: &str, n_shards: usize) -> usize {
    debug_assert!(n_shards > 0);
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in artifact.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    ((mix(h) as u128 * n_shards as u128) >> 64) as usize
}

/// Log₂-bucketed latency histogram.
///
/// Percentiles are approximate (resolved to the geometric midpoint of the
/// matching bucket), which is exactly the fidelity a serving dashboard
/// needs; exact min/max/sum are kept alongside.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; HISTOGRAM_BUCKETS],
    count: u64,
    sum_seconds: f64,
    min_seconds: f64,
    max_seconds: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [0; HISTOGRAM_BUCKETS],
            count: 0,
            sum_seconds: 0.0,
            min_seconds: f64::INFINITY,
            max_seconds: 0.0,
        }
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(seconds: f64) -> usize {
        let ns = (seconds * 1e9).max(1.0) as u64;
        (63 - ns.leading_zeros() as usize).min(HISTOGRAM_BUCKETS - 1)
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        self.buckets[Self::bucket_of(seconds)] += 1;
        self.count += 1;
        self.sum_seconds += seconds;
        self.min_seconds = self.min_seconds.min(seconds);
        self.max_seconds = self.max_seconds.max(seconds);
    }

    /// Recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean of the recorded samples.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_seconds / self.count as f64
        }
    }

    /// Exact minimum (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min_seconds }
    }

    /// Exact maximum.
    pub fn max(&self) -> f64 {
        self.max_seconds
    }

    /// Approximate percentile (`p` in `[0, 100]`) in seconds: the geometric
    /// midpoint of the bucket containing the p-th sample, clamped to the
    /// exact observed min/max.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                let mid_ns = (1u64 << i) as f64 * 1.5;
                return (mid_ns / 1e9).clamp(self.min_seconds, self.max_seconds);
            }
        }
        self.max_seconds
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_seconds += other.sum_seconds;
        if other.count > 0 {
            self.min_seconds = self.min_seconds.min(other.min_seconds);
            self.max_seconds = self.max_seconds.max(other.max_seconds);
        }
    }

    /// Non-empty `(bucket_floor_seconds, count)` rows, for display.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| ((1u64 << i) as f64 / 1e9, n))
            .collect()
    }
}

/// Per-shard serving counters.
///
/// Invariant (tested in `rust/tests/serve_multiworker.rs`):
/// `completed + failed == requests` once the server has been drained, and
/// the sums over all shards equal the aggregate `Metrics` totals minus
/// admission-rejected requests, which never reach a shard.
#[derive(Clone, Debug, Default)]
pub struct ShardMetrics {
    /// Shard id these counters belong to.
    pub shard: usize,
    /// Worker that owned this shard.
    pub worker: usize,
    /// Requests routed to this shard.
    pub requests: u64,
    /// Successfully answered requests.
    pub completed: u64,
    /// Failed requests.
    pub failed: u64,
    /// Executor batches formed from this shard's queue.
    pub batches: u64,
    /// Responses served from the LRU response cache (subset of `completed`).
    pub cache_hits: u64,
    /// End-to-end latency (queue wait + execution) of completed requests.
    pub latency: LatencyHistogram,
}

impl ShardMetrics {
    /// Zeroed counters for one shard owned by `worker`.
    pub fn new(shard: usize, worker: usize) -> Self {
        ShardMetrics {
            shard,
            worker,
            ..Default::default()
        }
    }

    /// Fold `other` (same `(shard, worker)` row) into this record.
    pub fn merge(&mut self, other: &ShardMetrics) {
        debug_assert_eq!(self.shard, other.shard);
        debug_assert_eq!(
            self.worker, other.worker,
            "rows from different owner epochs must stay separate"
        );
        self.requests += other.requests;
        self.completed += other.completed;
        self.failed += other.failed;
        self.batches += other.batches;
        self.cache_hits += other.cache_hits;
        self.latency.merge(&other.latency);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_for_is_stable_and_in_range() {
        for n in [1usize, 2, 7, 32] {
            for name in ["gemm_f32_tuned_n32", "conv_qnn8_c11", "syn_gemm_n64", ""] {
                let s = shard_for(name, n);
                assert!(s < n, "{name} -> {s} of {n}");
                assert_eq!(s, shard_for(name, n), "stable");
            }
        }
    }

    #[test]
    fn shard_for_spreads_names() {
        let n_shards = 16;
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            seen.insert(shard_for(&format!("artifact_{i}"), n_shards));
        }
        // 64 names over 16 shards must touch most shards
        assert!(seen.len() >= 12, "only {} shards hit", seen.len());
    }

    #[test]
    fn histogram_percentiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for us in [10.0f64, 20.0, 40.0, 80.0, 160.0, 320.0, 640.0, 1280.0] {
            h.record(us * 1e-6);
        }
        assert_eq!(h.count(), 8);
        assert!(h.min() <= h.percentile(50.0));
        assert!(h.percentile(50.0) <= h.percentile(99.0));
        assert!(h.percentile(99.0) <= h.max());
        assert!((h.mean() - 2550e-6 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_matches_combined() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut both = LatencyHistogram::new();
        for i in 1..50 {
            let s = i as f64 * 1e-5;
            if i % 2 == 0 { a.record(s) } else { b.record(s) }
            both.record(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.rows(), both.rows());
        assert_eq!(a.percentile(90.0), both.percentile(90.0));
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
    }

    #[test]
    fn shard_metrics_merge_sums_counters() {
        let mut m = ShardMetrics::new(3, 1);
        m.requests = 5;
        m.completed = 4;
        m.failed = 1;
        let mut n = ShardMetrics::new(3, 1);
        n.requests = 2;
        n.completed = 2;
        n.cache_hits = 1;
        m.merge(&n);
        assert_eq!(m.requests, 7);
        assert_eq!(m.completed, 6);
        assert_eq!(m.failed, 1);
        assert_eq!(m.cache_hits, 1);
    }
}
