//! Cache-aware artifact placement for the sharded serving core.
//!
//! The hash placement of [`super::shard`] spreads artifacts uniformly —
//! which is exactly wrong when two L2-hungry artifacts land on the same
//! worker and thrash the shared cache the paper shows every operator is
//! bound by.  This module closes the telemetry → scheduling loop:
//!
//! * [`plan`] runs a greedy bin-packing assigner over the per-artifact
//!   [`CacheProfile`]s: artifacts are sorted by L2 demand (working-set /
//!   footprint knee, largest first — the classic first-fit-decreasing
//!   order) and each is placed on the worker that minimizes the increase
//!   in predicted total slowdown under the co-run model
//!   ([`InterferenceModel`]), breaking ties toward the least-loaded worker
//!   so equal-cost placements still balance.  The result is deterministic
//!   for a fixed profile set (tested).
//! * [`Placement::rebalance`] is the feedback hook: when the server's
//!   *observed* per-worker pressure diverges from the plan beyond a
//!   threshold (artifacts the plan never saw, planned artifacts that never
//!   arrived), it re-plans over the artifacts actually being served.
//!
//! Greedy-vs-hash guarantee: with at most one artifact per worker the two
//! policies predict identical cost (no co-residency anywhere), and greedy
//! never co-locates two artifacts when a free worker would predict
//! strictly less slowdown — so on the adversarial two-artifact mix (demand
//! sum past the L2) greedy always splits, while hash co-locates whenever
//! the names collide.  See `DESIGN.md` §Placement for the math.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::analysis::InterferenceModel;
use crate::hw::CpuSpec;
use crate::operators::workloads::synthetic_artifact;
use crate::telemetry::{synthetic_gemm_profile_budgeted, CacheProfile};

use super::server::WorkerPressure;
use super::shard::shard_for;

/// How the sharded server maps artifacts to workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Stable hash of the artifact name ([`shard_for`]) — the baseline,
    /// oblivious to cache working sets.
    #[default]
    Hash,
    /// Greedy bin-packing over [`CacheProfile`]s via [`plan`]; falls back
    /// to hash for artifacts without a profile.
    CacheAware,
}

impl PlacementPolicy {
    /// Parse a CLI flag value ("hash" | "cache-aware").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "hash" => Ok(PlacementPolicy::Hash),
            "cache-aware" | "cacheaware" | "cache" => Ok(PlacementPolicy::CacheAware),
            other => bail!("unknown placement policy '{other}' (hash | cache-aware)"),
        }
    }

    /// Display name ("hash" | "cache-aware").
    pub fn name(self) -> &'static str {
        match self {
            PlacementPolicy::Hash => "hash",
            PlacementPolicy::CacheAware => "cache-aware",
        }
    }

    /// Short fragment for job/result keys ("hash" | "cache").
    pub fn key_part(self) -> &'static str {
        match self {
            PlacementPolicy::Hash => "hash",
            PlacementPolicy::CacheAware => "cache",
        }
    }
}

/// When the sharded server acts on observed-vs-predicted pressure
/// divergence (`ServeConfig::rebalance_threshold`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RebalanceMode {
    /// Never rebalance: no mid-stream checks, no drain-time suggestion.
    Off,
    /// Suggest a re-plan at drain time (`ServeOutcome::rebalanced`) but
    /// never touch a live stream — the PR 4 behaviour, and the default.
    #[default]
    Drain,
    /// Migrate mid-stream: when the divergence check fires, quiesce the
    /// affected artifacts, move their executor/cache state to the workers
    /// of a fresh plan and swap the routing atomically
    /// (`ShardedServer::maybe_rebalance`).
    Live,
}

impl RebalanceMode {
    /// Parse a CLI flag value ("off" | "drain" | "live").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" | "none" => Ok(RebalanceMode::Off),
            "drain" => Ok(RebalanceMode::Drain),
            "live" => Ok(RebalanceMode::Live),
            other => bail!("unknown rebalance mode '{other}' (off | drain | live)"),
        }
    }

    /// Display name ("off" | "drain" | "live").
    pub fn name(self) -> &'static str {
        match self {
            RebalanceMode::Off => "off",
            RebalanceMode::Drain => "drain",
            RebalanceMode::Live => "live",
        }
    }

    /// Short fragment for job/result keys (same as [`Self::name`]).
    pub fn key_part(self) -> &'static str {
        self.name()
    }
}

/// One worker's share of a [`Placement`].
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerPlan {
    /// Worker index.
    pub worker: usize,
    /// Artifacts assigned to this worker, in assignment order.
    pub artifacts: Vec<String>,
    /// Σ `working_set_bytes` of the assigned profiles — the predicted
    /// pressure [`super::server::Metrics`] compares observations against.
    pub resident_bytes: u64,
    /// Σ predicted co-run slowdowns of the assigned set (1.0 per artifact
    /// when interference-free).
    pub slowdown: f64,
}

/// A full artifact → worker assignment with its predicted cost.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    /// Worker count the plan was built for.
    pub workers: usize,
    /// Artifact → worker map consulted by the server's admission path.
    pub assignments: BTreeMap<String, usize>,
    /// Per-worker breakdown (`plan[w].worker == w` for all `w`).
    pub plan: Vec<WorkerPlan>,
    /// Σ over workers of the predicted co-run slowdown sums.
    pub total_slowdown: f64,
}

/// Greedy bin-packing: sort profiles by L2 demand (descending, ties by
/// name), then place each artifact on the worker whose predicted total
/// slowdown grows the least, breaking ties toward the smaller resident
/// byte count and then the lower worker index.
pub fn plan(
    model: &InterferenceModel,
    profiles: &BTreeMap<String, CacheProfile>,
    workers: usize,
) -> Placement {
    let workers = workers.max(1);
    let mut order: Vec<&CacheProfile> = profiles.values().collect();
    order.sort_by(|a, b| {
        model
            .demand_bytes(b)
            .cmp(&model.demand_bytes(a))
            .then_with(|| a.artifact.cmp(&b.artifact))
    });

    let mut assigned: Vec<Vec<&CacheProfile>> = vec![Vec::new(); workers];
    let mut cost: Vec<f64> = vec![0.0; workers];
    let mut bytes: Vec<u64> = vec![0; workers];
    let mut assignments = BTreeMap::new();
    for p in order {
        let mut best: Option<(f64, u64, usize, f64)> = None;
        for w in 0..workers {
            let mut candidate = assigned[w].clone();
            candidate.push(p);
            let new_cost = model.total_slowdown(&candidate);
            let delta = new_cost - cost[w];
            let key = (delta, bytes[w]);
            let better = match &best {
                Some((bd, bb, _, _)) => key < (*bd, *bb),
                None => true,
            };
            if better {
                best = Some((delta, bytes[w], w, new_cost));
            }
        }
        let (_, _, w, new_cost) = best.expect("workers >= 1");
        assigned[w].push(p);
        cost[w] = new_cost;
        bytes[w] += p.working_set_bytes;
        assignments.insert(p.artifact.clone(), w);
    }

    let plan: Vec<WorkerPlan> = (0..workers)
        .map(|w| WorkerPlan {
            worker: w,
            artifacts: assigned[w].iter().map(|p| p.artifact.clone()).collect(),
            resident_bytes: assigned[w].iter().map(|p| p.working_set_bytes).sum(),
            slowdown: cost[w],
        })
        .collect();
    Placement {
        workers,
        assignments,
        total_slowdown: cost.iter().sum(),
        plan,
    }
}

impl Placement {
    /// Worker assigned to `artifact`, if the plan covers it.
    pub fn worker_for(&self, artifact: &str) -> Option<usize> {
        self.assignments.get(artifact).copied()
    }

    /// Predicted resident working-set bytes of one worker (0 beyond the
    /// plan).
    pub fn predicted_bytes(&self, worker: usize) -> u64 {
        self.plan.get(worker).map_or(0, |p| p.resident_bytes)
    }

    /// Worst relative gap between predicted and observed per-worker
    /// pressure, in `[0, 1]`: `|observed − predicted| / max(both, 1)`,
    /// maximized over workers.  0 when every worker's residency matched
    /// the plan.
    pub fn divergence(&self, observed: &[WorkerPressure]) -> f64 {
        let mut worst = 0.0f64;
        for w in 0..self.workers.max(observed.len()) {
            let pred = self.predicted_bytes(w);
            let obs = observed
                .iter()
                .find(|p| p.worker == w)
                .map_or(0, |p| p.resident_bytes);
            let denom = pred.max(obs).max(1) as f64;
            worst = worst.max((pred as f64 - obs as f64).abs() / denom);
        }
        worst
    }

    /// The feedback hook the server calls after a run: when the observed
    /// pressure diverges from this plan by more than `threshold`, re-plan
    /// over `observed_profiles` (the artifacts actually served) and return
    /// the new placement; `None` while the plan still matches reality.
    pub fn rebalance(
        &self,
        model: &InterferenceModel,
        observed_profiles: &BTreeMap<String, CacheProfile>,
        observed: &[WorkerPressure],
        threshold: f64,
    ) -> Option<Placement> {
        if self.divergence(observed) <= threshold {
            return None;
        }
        Some(plan(model, observed_profiles, self.workers))
    }
}

/// Smallest worker count for which the greedy [`plan`] predicts an
/// (approximately) interference-free deployment: total slowdown within
/// `tol` of the ideal `profiles.len() × 1.0`.  Scans worker counts
/// upward; one artifact per worker can never interfere, so the scan
/// terminates at `profiles.len()` (and returns 1 for an empty map).
///
/// This is the per-tier "how many workers does this mix cost?" figure of
/// merit behind the quantized-tier A/B (DESIGN.md §Tiers): a lower
/// precision tier shrinks every operand working set, so the packer fits
/// more artifacts per worker before the co-run model prices in L2
/// contention — fewer workers for the same predicted interference.
pub fn min_workers_interference_free(
    model: &InterferenceModel,
    profiles: &BTreeMap<String, CacheProfile>,
    tol: f64,
) -> usize {
    let n = profiles.len().max(1);
    let ideal = profiles.len() as f64;
    for workers in 1..n {
        if plan(model, profiles, workers).total_slowdown <= ideal + tol {
            return workers;
        }
    }
    n
}

/// Candidate sizes for [`adversarial_mix`], profiled lazily in order.
const ADVERSARIAL_CANDIDATES: [usize; 4] = [160, 192, 224, 256];

/// Row budget of the adversarial-candidate traces: two full M-tiles, so
/// the cross-tile B-panel reuse (the L2-scale knee) is captured without
/// replaying the whole matrix.
const ADVERSARIAL_TRACE_ROWS: usize = 128;

/// Build the adversarial two-artifact co-run mix: the first pair of
/// synthetic GEMM artifacts that (a) hash placement co-locates on one
/// worker under `workers`/`n_shards`, and (b) whose L2 demands each fit
/// the part's L2 alone but sum past it — the configuration where
/// cache-aware placement must split what hashing collides.  `None` if no
/// candidate pair qualifies on this CPU profile.
pub fn adversarial_mix(
    cpu: &CpuSpec,
    workers: usize,
    n_shards: usize,
) -> Option<Vec<(String, CacheProfile)>> {
    let model = InterferenceModel::new(cpu);
    let l2 = cpu.l2.size_bytes as u64;
    let mut profiled: Vec<(String, CacheProfile)> = Vec::new();
    for &n in &ADVERSARIAL_CANDIDATES {
        let name = synthetic_artifact(n);
        let profile =
            synthetic_gemm_profile_budgeted(cpu, &name, n, ADVERSARIAL_TRACE_ROWS);
        profiled.push((name, profile));
        let (nj, pj) = profiled.last().expect("just pushed");
        for (ni, pi) in &profiled[..profiled.len() - 1] {
            let same_worker =
                shard_for(ni, n_shards) % workers == shard_for(nj, n_shards) % workers;
            let (di, dj) = (model.demand_bytes(pi), model.demand_bytes(pj));
            if same_worker && di < l2 && dj < l2 && di + dj > l2 {
                return Some(vec![(ni.clone(), pi.clone()), (nj.clone(), pj.clone())]);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::interference::step_profile;
    use crate::hw::profile_by_name;

    fn a53() -> CpuSpec {
        profile_by_name("a53").unwrap().cpu
    }

    fn profile_map(ps: Vec<CacheProfile>) -> BTreeMap<String, CacheProfile> {
        ps.into_iter().map(|p| (p.artifact.clone(), p)).collect()
    }

    #[test]
    fn policy_parses_and_names() {
        assert_eq!(PlacementPolicy::parse("hash").unwrap(), PlacementPolicy::Hash);
        assert_eq!(
            PlacementPolicy::parse("cache-aware").unwrap(),
            PlacementPolicy::CacheAware
        );
        assert!(PlacementPolicy::parse("round-robin").is_err());
        assert_eq!(PlacementPolicy::default(), PlacementPolicy::Hash);
        assert_eq!(PlacementPolicy::CacheAware.name(), "cache-aware");
        assert_eq!(PlacementPolicy::CacheAware.key_part(), "cache");
    }

    #[test]
    fn rebalance_mode_parses_and_names() {
        assert_eq!(RebalanceMode::parse("off").unwrap(), RebalanceMode::Off);
        assert_eq!(RebalanceMode::parse("drain").unwrap(), RebalanceMode::Drain);
        assert_eq!(RebalanceMode::parse("live").unwrap(), RebalanceMode::Live);
        assert!(RebalanceMode::parse("sometimes").is_err());
        assert_eq!(RebalanceMode::default(), RebalanceMode::Drain);
        assert_eq!(RebalanceMode::Live.name(), "live");
        assert_eq!(RebalanceMode::Live.key_part(), "live");
    }

    #[test]
    fn two_big_artifacts_are_split_across_workers() {
        let model = InterferenceModel::new(&a53());
        // each fits the 512 KiB L2 alone, together they spill it
        let profiles = profile_map(vec![
            step_profile("big_a", 300 * 1024, 0.9),
            step_profile("big_b", 300 * 1024, 0.9),
        ]);
        let p = plan(&model, &profiles, 2);
        assert_ne!(
            p.worker_for("big_a"),
            p.worker_for("big_b"),
            "greedy must split the adversarial pair: {p:?}"
        );
        assert!((p.total_slowdown - 2.0).abs() < 1e-9, "split = no interference");
    }

    #[test]
    fn equal_cost_placements_balance_by_load() {
        let model = InterferenceModel::new(&a53());
        // four tiny interference-free artifacts on two workers: the
        // slowdown deltas all tie at 1.0, so the load tie-break must
        // spread them 2 + 2
        let profiles = profile_map(
            (0..4)
                .map(|i| step_profile(&format!("tiny{i}"), 16 * 1024, 0.9))
                .collect(),
        );
        let p = plan(&model, &profiles, 2);
        assert_eq!(p.plan[0].artifacts.len(), 2, "{p:?}");
        assert_eq!(p.plan[1].artifacts.len(), 2, "{p:?}");
    }

    #[test]
    fn plan_is_deterministic() {
        let cpu = a53();
        let model = InterferenceModel::new(&cpu);
        let profiles = profile_map(vec![
            step_profile("a", 300 * 1024, 0.9),
            step_profile("b", 200 * 1024, 0.85),
            step_profile("c", 120 * 1024, 0.7),
            step_profile("d", 64 * 1024, 0.95),
            step_profile("e", 300 * 1024, 0.9),
        ]);
        let first = plan(&model, &profiles, 3);
        for _ in 0..5 {
            assert_eq!(plan(&model, &profiles, 3), first, "identical placement across runs");
        }
        // every artifact is assigned exactly once, workers within range
        assert_eq!(first.assignments.len(), 5);
        assert!(first.assignments.values().all(|&w| w < 3));
        let planned: usize = first.plan.iter().map(|w| w.artifacts.len()).sum();
        assert_eq!(planned, 5);
    }

    #[test]
    fn single_worker_plan_puts_everything_there() {
        let model = InterferenceModel::new(&a53());
        let profiles = profile_map(vec![
            step_profile("a", 300 * 1024, 0.9),
            step_profile("b", 300 * 1024, 0.9),
        ]);
        let p = plan(&model, &profiles, 1);
        assert!(p.assignments.values().all(|&w| w == 0));
        // forced co-residency: the plan prices the interference honestly
        assert!(p.total_slowdown > 2.0, "{}", p.total_slowdown);
    }

    #[test]
    fn divergence_and_rebalance_fire_on_drift() {
        let model = InterferenceModel::new(&a53());
        let profiles = profile_map(vec![
            step_profile("a", 300 * 1024, 0.9),
            step_profile("b", 300 * 1024, 0.9),
        ]);
        let p = plan(&model, &profiles, 2);
        // observation matching the plan: no divergence, no rebalance
        let matching: Vec<WorkerPressure> = (0..2)
            .map(|w| WorkerPressure {
                worker: w,
                artifacts: 1,
                profiled: 1,
                resident_bytes: p.predicted_bytes(w),
                predicted_bytes: p.predicted_bytes(w),
            })
            .collect();
        assert_eq!(p.divergence(&matching), 0.0);
        assert!(p.rebalance(&model, &profiles, &matching, 0.25).is_none());

        // all traffic actually landed on worker 0: full divergence
        let skewed = vec![
            WorkerPressure {
                worker: 0,
                artifacts: 2,
                profiled: 2,
                resident_bytes: 600 * 1024,
                predicted_bytes: p.predicted_bytes(0),
            },
            WorkerPressure {
                worker: 1,
                artifacts: 0,
                profiled: 0,
                resident_bytes: 0,
                predicted_bytes: p.predicted_bytes(1),
            },
        ];
        assert!(p.divergence(&skewed) > 0.25, "{}", p.divergence(&skewed));
        let re = p.rebalance(&model, &profiles, &skewed, 0.25).expect("rebalance fires");
        assert_eq!(re.assignments.len(), 2);
        assert_ne!(re.worker_for("a"), re.worker_for("b"));
    }

    #[test]
    fn quantized_tiers_need_fewer_interference_free_workers() {
        let model = InterferenceModel::new(&a53());
        // four fp32-scale artifacts at 300 KiB: any pair spills the
        // 512 KiB L2, so interference-free costs one worker each...
        let f32_mix = profile_map(
            (0..4)
                .map(|i| step_profile(&format!("f32_{i}"), 300 * 1024, 0.9))
                .collect(),
        );
        // ...while their int8 twins, at a quarter the working set, all
        // fit one worker's L2 together — the tier-demand math of
        // DESIGN.md §Tiers
        let i8_mix = profile_map(
            (0..4)
                .map(|i| step_profile(&format!("i8_{i}"), 75 * 1024, 0.9))
                .collect(),
        );
        let need_f32 = min_workers_interference_free(&model, &f32_mix, 1e-9);
        let need_i8 = min_workers_interference_free(&model, &i8_mix, 1e-9);
        assert_eq!(need_f32, 4, "every fp32 pair interferes");
        assert_eq!(need_i8, 1, "the whole int8 mix is co-residable");
        assert!(need_i8 < need_f32, "quantizing must save workers");
        // sanity at the edges: the adversarial pair needs exactly 2, and
        // an empty mix prices as a single idle worker
        let pair = profile_map(vec![
            step_profile("big_a", 300 * 1024, 0.9),
            step_profile("big_b", 300 * 1024, 0.9),
        ]);
        assert_eq!(min_workers_interference_free(&model, &pair, 1e-9), 2);
        assert_eq!(min_workers_interference_free(&model, &BTreeMap::new(), 1e-9), 1);
    }

    #[test]
    fn adversarial_mix_collides_under_hash_and_splits_under_plan() {
        let cpu = a53();
        // the default serve geometry: 2 workers, 4x shards
        let mix = adversarial_mix(&cpu, 2, 8).expect("a qualifying pair exists on the A53");
        assert_eq!(mix.len(), 2);
        let (na, pa) = &mix[0];
        let (nb, pb) = &mix[1];
        // hash co-locates them...
        assert_eq!(shard_for(na, 8) % 2, shard_for(nb, 8) % 2);
        // ...and their demands straddle the L2
        let model = InterferenceModel::new(&cpu);
        let l2 = cpu.l2.size_bytes as u64;
        assert!(model.demand_bytes(pa) < l2 && model.demand_bytes(pb) < l2);
        assert!(model.demand_bytes(pa) + model.demand_bytes(pb) > l2);
        // the greedy plan splits them
        let profiles = profile_map(vec![pa.clone(), pb.clone()]);
        let p = plan(&model, &profiles, 2);
        assert_ne!(p.worker_for(na), p.worker_for(nb), "{p:?}");
        // and the split strictly beats the co-located alternative
        let colocated = model.total_slowdown(&[pa, pb]);
        assert!(
            p.total_slowdown < colocated || (colocated - 2.0).abs() < 1e-9,
            "split {} vs co-located {}",
            p.total_slowdown,
            colocated
        );
    }
}
