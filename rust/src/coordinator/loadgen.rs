//! Seeded open-loop load generation.
//!
//! Every serve bench before this module was closed-loop: submit N
//! requests, drain, divide.  Closed loops cannot exhibit queueing
//! collapse — the submitter slows down with the server — so they
//! structurally hide the regime where the paper's cache-boundness story
//! becomes an SLO story (a cache-bound fp32 artifact saturates earlier
//! than its quantized variant, which is what makes degrade routing a
//! principled shedding policy; see DESIGN.md §Admission).
//!
//! [`ArrivalConfig::schedule`] turns a `u64` seed into a vector of
//! arrival *offsets* (seconds from stream start).  The process is a
//! non-homogeneous Poisson process sampled by thinning: candidate events
//! are drawn at the peak rate from i.i.d. exponential gaps and accepted
//! with probability `rate_at(t) / peak`, where the instantaneous rate is
//!
//! ```text
//! rate_at(t) = base · (1 + A·sin(2πt/P)) · (m if t inside a flash crowd)
//! ```
//!
//! — a diurnal drift term (amplitude `A`, period `P`) multiplied by
//! seeded flash-crowd windows (`m`-fold rate for `flash_duration_s`
//! starting at uniformly drawn instants).  Everything, including the
//! flash-window positions, derives from the one seed, so the same config
//! always produces the identical schedule (property-tested in
//! `rust/tests/proptests.rs`), while wall-clock pacing of the submission
//! loop lives with the caller ([`super::server::ShardedServer::serve_open_loop`]).

use crate::util::rng::Xoshiro256;

/// A seeded open-loop arrival process: Poisson base rate, optional
/// diurnal drift, optional flash-crowd bursts.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalConfig {
    /// Base arrival rate, requests per second.  Must be positive.
    pub rate_rps: f64,
    /// Number of arrivals to schedule.
    pub n: usize,
    /// The one seed everything derives from.
    pub seed: u64,
    /// Diurnal drift amplitude in `[0, 1]`: the rate swings between
    /// `base·(1−A)` and `base·(1+A)`.  0 disables drift.
    pub diurnal_amplitude: f64,
    /// Diurnal drift period, seconds.
    pub diurnal_period_s: f64,
    /// Number of flash-crowd windows, at seeded uniform positions over
    /// the expected stream duration.  0 disables bursts.
    pub flash_crowds: usize,
    /// Rate multiplier inside a flash-crowd window (≥ 1).
    pub flash_multiplier: f64,
    /// Duration of each flash-crowd window, seconds.
    pub flash_duration_s: f64,
}

impl ArrivalConfig {
    /// A pure Poisson process: no drift, no flash crowds.  The builders
    /// below layer the modulation on.
    pub fn poisson(rate_rps: f64, n: usize, seed: u64) -> Self {
        ArrivalConfig {
            rate_rps,
            n,
            seed,
            diurnal_amplitude: 0.0,
            diurnal_period_s: 60.0,
            flash_crowds: 0,
            flash_multiplier: 4.0,
            flash_duration_s: 1.0,
        }
    }

    /// Add diurnal drift (`amplitude` clamped to `[0, 1]`).
    pub fn with_diurnal(mut self, amplitude: f64, period_s: f64) -> Self {
        self.diurnal_amplitude = amplitude.clamp(0.0, 1.0);
        self.diurnal_period_s = period_s.max(1e-9);
        self
    }

    /// Add `crowds` flash-crowd windows of `duration_s` seconds at
    /// `multiplier`× the base rate (`multiplier` floored at 1).
    pub fn with_flash(mut self, crowds: usize, multiplier: f64, duration_s: f64) -> Self {
        self.flash_crowds = crowds;
        self.flash_multiplier = multiplier.max(1.0);
        self.flash_duration_s = duration_s.max(0.0);
        self
    }

    /// The peak instantaneous rate — the thinning envelope.
    pub fn peak_rate(&self) -> f64 {
        let diurnal = 1.0 + self.diurnal_amplitude.clamp(0.0, 1.0);
        let flash = if self.flash_crowds > 0 { self.flash_multiplier.max(1.0) } else { 1.0 };
        self.rate_rps * diurnal * flash
    }

    /// Instantaneous rate at offset `t`, given the drawn flash-window
    /// start times.
    fn rate_at(&self, t: f64, flashes: &[f64]) -> f64 {
        let amp = self.diurnal_amplitude.clamp(0.0, 1.0);
        let mut rate = self.rate_rps
            * (1.0 + amp * (std::f64::consts::TAU * t / self.diurnal_period_s).sin());
        if flashes.iter().any(|&f| t >= f && t < f + self.flash_duration_s) {
            rate *= self.flash_multiplier.max(1.0);
        }
        rate.max(0.0)
    }

    /// The arrival schedule: `n` strictly non-decreasing offsets in
    /// seconds from stream start, fully determined by the config
    /// (identical config ⇒ identical schedule, bit for bit).
    ///
    /// # Panics
    /// When `rate_rps` is not positive.
    pub fn schedule(&self) -> Vec<f64> {
        assert!(self.rate_rps > 0.0, "arrival rate must be positive");
        let mut rng = Xoshiro256::new(self.seed);
        // flash windows land anywhere in the expected stream duration —
        // drawn first so the same seed pins them regardless of how many
        // candidates thinning later rejects
        let horizon = self.n as f64 / self.rate_rps;
        let flashes: Vec<f64> =
            (0..self.flash_crowds).map(|_| rng.f64() * horizon).collect();
        let peak = self.peak_rate();
        let mut t = 0.0_f64;
        let mut out = Vec::with_capacity(self.n);
        while out.len() < self.n {
            // exponential gap at the peak rate (inverse CDF; 1-u avoids
            // ln(0) since f64() is in [0, 1))
            t += -(1.0 - rng.f64()).ln() / peak;
            // thin: accept with probability rate_at(t)/peak
            if rng.f64() * peak <= self.rate_at(t, &flashes) {
                out.push(t);
            }
        }
        out
    }
}

/// Observed mean rate of a schedule (events per second of span) — the
/// quantity the rate-conservation property checks against `rate_rps`.
pub fn observed_rate(schedule: &[f64]) -> f64 {
    match schedule.last() {
        Some(&last) if last > 0.0 => schedule.len() as f64 / last,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let cfg = ArrivalConfig::poisson(250.0, 512, 0xFACE)
            .with_diurnal(0.5, 2.0)
            .with_flash(2, 4.0, 0.25);
        assert_eq!(cfg.schedule(), cfg.schedule());
        let other = ArrivalConfig { seed: 0xFACE + 1, ..cfg.clone() };
        assert_ne!(cfg.schedule(), other.schedule());
    }

    #[test]
    fn schedule_is_sorted_and_sized() {
        let s = ArrivalConfig::poisson(1000.0, 256, 7).schedule();
        assert_eq!(s.len(), 256);
        assert!(s.windows(2).all(|w| w[0] <= w[1]));
        assert!(s[0] >= 0.0);
    }

    #[test]
    fn pure_poisson_conserves_the_configured_rate() {
        let s = ArrivalConfig::poisson(500.0, 4096, 0xABCD).schedule();
        let observed = observed_rate(&s);
        assert!(
            (observed - 500.0).abs() / 500.0 < 0.1,
            "observed {observed} req/s vs configured 500"
        );
    }

    #[test]
    fn flash_crowd_concentrates_arrivals() {
        // with one seeded flash window at 8x, the densest window of the
        // stream must be markedly denser than the base rate
        let cfg = ArrivalConfig::poisson(200.0, 2048, 0x11).with_flash(1, 8.0, 1.0);
        let s = cfg.schedule();
        let dur = cfg.flash_duration_s;
        let max_in_window = s
            .iter()
            .map(|&t0| s.iter().filter(|&&t| t >= t0 && t < t0 + dur).count())
            .max()
            .unwrap();
        // base expectation is ~200 events per 1s window; the flash runs 8x
        assert!(
            max_in_window as f64 > 2.0 * 200.0 * dur,
            "densest window held {max_in_window} events"
        );
    }

    #[test]
    fn diurnal_drift_modulates_but_keeps_determinism() {
        let flat = ArrivalConfig::poisson(300.0, 1024, 3).schedule();
        let wavy = ArrivalConfig::poisson(300.0, 1024, 3).with_diurnal(0.9, 0.5).schedule();
        assert_ne!(flat, wavy, "drift must change the schedule");
        // modulation averages out: long-run rate stays near base
        let observed = observed_rate(&wavy);
        assert!(
            (observed - 300.0).abs() / 300.0 < 0.2,
            "diurnal drift should conserve the mean rate, got {observed}"
        );
    }

    #[test]
    #[should_panic(expected = "arrival rate must be positive")]
    fn zero_rate_panics() {
        ArrivalConfig::poisson(0.0, 8, 1).schedule();
    }
}
