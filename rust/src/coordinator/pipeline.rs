//! The experiment pipeline: paper table/figure → job set → results.
//!
//! `Pipeline` owns the worker pool, the (optional) artifact registry and
//! the result store, and exposes one method per paper experiment.  Each
//! method is idempotent: results land in the store under stable keys and
//! are reused by later calls (e.g. fig9 reuses the gemm-table sweeps).

use anyhow::Result;

use crate::hw::{profile_by_name, CpuSpec};
use crate::operators::conv::ConvSchedule;
use crate::operators::gemm::GemmSchedule;
use crate::operators::workloads::{self, BenchWorkload, ConvLayer};
use crate::runtime::Registry;

use super::jobs::{Job, JobSpec, NativeGemmVariant};
use super::placement::{PlacementPolicy, RebalanceMode};
use super::server::{AdmissionMode, TierPolicy};
use super::pool::WorkerPool;
use super::results::ResultStore;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Worker threads of the job pool.
    pub n_workers: usize,
    /// Tuning trials per workload.
    pub tune_trials: usize,
    /// Skip host-wallclock native measurements (fast mode).
    pub skip_native: bool,
    /// Cap native GEMM sizes (naive native is O(N^3) scalar on the host).
    pub native_max_n: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            n_workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            tune_trials: 64,
            skip_native: false,
            native_max_n: 256,
        }
    }
}

/// The tuned schedule the simulator sweeps use when tuning is skipped.
pub fn default_tuned_schedule() -> GemmSchedule {
    GemmSchedule::new(64, 64, 64, 4)
}

/// The tuned conv schedule used when tuning is skipped.
pub fn default_conv_schedule() -> ConvSchedule {
    ConvSchedule::new(32, 4)
}

/// The experiment pipeline: owns the pool, the optional artifact
/// registry and the result store; one method per paper experiment.
pub struct Pipeline {
    /// Pipeline configuration.
    pub config: PipelineConfig,
    /// Worker pool experiment jobs fan out over.
    pub pool: WorkerPool,
    /// Results keyed by stable job keys.
    pub store: ResultStore,
    /// AOT artifact registry (enables `Artifact*` jobs).
    pub registry: Option<Registry>,
}

impl Pipeline {
    /// Pipeline with an empty store and a fresh pool.
    pub fn new(config: PipelineConfig) -> Self {
        Pipeline {
            pool: WorkerPool::new(config.n_workers),
            config,
            store: ResultStore::new(),
            registry: None,
        }
    }

    /// Attach the AOT artifact registry (enables `Artifact*` jobs).
    pub fn with_registry(mut self, registry: Registry) -> Self {
        self.registry = Some(registry);
        self
    }

    fn run_jobs(&mut self, specs: Vec<JobSpec>) {
        let jobs: Vec<Job> = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| Job { id: i as u64, spec })
            .collect();
        let completed = self.pool.run(jobs, self.registry.as_mut());
        self.store.ingest(&completed);
    }

    fn profile(&self, name: &str) -> Result<CpuSpec> {
        Ok(profile_by_name(name)?.cpu)
    }

    /// Tables IV/V: the GEMM sweep for one profile — naive/tuned simulator
    /// times plus (optionally) native host measurements.
    pub fn gemm_table(&mut self, profile: &str, sizes: &[usize]) -> Result<()> {
        let cpu = self.profile(profile)?;
        let mut specs = Vec::new();
        for &n in sizes {
            specs.push(JobSpec::SimGemm {
                cpu: cpu.clone(),
                n,
                schedule: GemmSchedule::naive(),
                elem_bits: 32,
            });
            specs.push(JobSpec::SimGemm {
                cpu: cpu.clone(),
                n,
                schedule: default_tuned_schedule(),
                elem_bits: 32,
            });
            // tuned via the auto-tuner (the paper's actual methodology)
            specs.push(JobSpec::TuneSimGemm {
                cpu: cpu.clone(),
                n,
                n_trials: self.config.tune_trials,
                use_gbt: true,
            });
            if !self.config.skip_native && n <= self.config.native_max_n {
                for variant in [
                    NativeGemmVariant::Naive,
                    NativeGemmVariant::Tiled,
                    NativeGemmVariant::Blocked,
                ] {
                    specs.push(JobSpec::NativeGemm {
                        n,
                        schedule: default_tuned_schedule(),
                        variant,
                    });
                }
            }
        }
        self.run_jobs(specs);
        Ok(())
    }

    /// Figs 2/3: ResNet-18 conv layers for one profile, f32.
    pub fn conv_layers(&mut self, profile: &str) -> Result<Vec<ConvLayer>> {
        let cpu = self.profile(profile)?;
        let layers = workloads::resnet18_layers();
        let mut specs = Vec::new();
        for l in &layers {
            specs.push(JobSpec::SimConv {
                cpu: cpu.clone(),
                layer: *l,
                schedule: default_conv_schedule(),
                elem_bits: 32,
            });
            specs.push(JobSpec::TuneSimConv {
                cpu: cpu.clone(),
                layer: *l,
                n_trials: self.config.tune_trials,
                use_gbt: true,
            });
        }
        self.run_jobs(specs);
        Ok(layers)
    }

    /// Figs 6/7/8: quantized conv layers (QNN int8 + bit-serial 1..8).
    pub fn quantized_conv(&mut self, profile: &str, bits: &[usize]) -> Result<()> {
        let cpu = self.profile(profile)?;
        let layers = workloads::resnet18_layers();
        let mut specs = Vec::new();
        for l in &layers {
            // int8 QNN: same schedule, quarter operand width
            specs.push(JobSpec::SimConv {
                cpu: cpu.clone(),
                layer: *l,
                schedule: default_conv_schedule(),
                elem_bits: 8,
            });
            // bit-serial via im2col'd GEMM geometry: M = ho*wo, N = cout,
            // K = cin*k*k (NHWC packing, §V-C)
            for &b in bits {
                for unipolar in [true, false] {
                    specs.push(JobSpec::SimBitserial {
                        cpu: cpu.clone(),
                        n: bitserial_equiv_n(l),
                        abits: b,
                        wbits: b,
                        unipolar,
                    });
                }
            }
        }
        self.run_jobs(specs);
        Ok(())
    }

    /// Figs 4/5: bit-serial GEMM size sweep.
    pub fn bitserial_gemm_sweep(
        &mut self,
        profile: &str,
        sizes: &[usize],
        bits: &[usize],
    ) -> Result<()> {
        let cpu = self.profile(profile)?;
        let mut specs = Vec::new();
        for &n in sizes {
            for &b in bits {
                for unipolar in [true, false] {
                    specs.push(JobSpec::SimBitserial {
                        cpu: cpu.clone(),
                        n,
                        abits: b,
                        wbits: b,
                        unipolar,
                    });
                }
            }
        }
        self.run_jobs(specs);
        Ok(())
    }

    /// Serving-throughput scaling sweep (EXPERIMENTS.md §Serving): one
    /// `ServeMix` run per worker count over the identical request stream,
    /// routed by `placement` (hash baseline or the cache-aware plan) with
    /// `rebalance` deciding what a pressure divergence does (off / drain
    /// suggestion / live migration).  Runs on a *serial* pool — each job
    /// spawns its own sharded-server worker threads, and concurrent
    /// servers would contend for cores and corrupt the scaling
    /// measurement.
    /// `tiers` swaps the fp32-only stream for the full precision-tier
    /// menu ([`workloads::serving_mix_tiered`]) and hands the packer the
    /// int8/bit-serial cache profiles; `tier_policy` picks which axis
    /// `AdmissionMode::Degrade` shrinks (shape ladder vs precision
    /// lattice — DESIGN.md §Tiers).
    /// `cache_dir` attaches the persistent compiled-artifact cache to
    /// every run in the sweep, so later worker counts (and later sweeps
    /// over the same root) start warm — the restart-cost story of
    /// DESIGN.md §Artifact cache.
    /// `admission_threads` > 1 switches every run to the concurrent
    /// admission drive (stream partitioned by artifact hash, routes read
    /// from epoch snapshots — `coordinator::routing`).
    #[allow(clippy::too_many_arguments)]
    pub fn serve_scaling(
        &mut self,
        worker_counts: &[usize],
        requests: usize,
        arrival_rps: u32,
        admission: AdmissionMode,
        placement: PlacementPolicy,
        rebalance: RebalanceMode,
        tiers: bool,
        tier_policy: TierPolicy,
        admission_threads: usize,
        cache_dir: Option<std::path::PathBuf>,
    ) -> Result<()> {
        let specs: Vec<JobSpec> = worker_counts
            .iter()
            .map(|&w| JobSpec::ServeMix {
                workers: w,
                requests,
                seed: 0xD15C,
                cache_entries: 0,
                arrival_rps,
                admission,
                placement,
                rebalance,
                tiers,
                tier_policy,
                admission_threads,
                cache_dir: cache_dir.clone(),
            })
            .collect();
        let jobs: Vec<Job> = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| Job { id: i as u64, spec })
            .collect();
        let completed = WorkerPool::serial().run(jobs, None);
        self.store.ingest(&completed);
        Ok(())
    }

    /// Roofline-bench sweep (`cachebound bench`): one `BenchSweep` job per
    /// workload for `profile`, results under `bench/{sim|native}/<cpu>/`.
    ///
    /// Simulator sweeps fan out across the pool (analytic timing is
    /// CPU-pure and parallel-safe); native host-wallclock sweeps run on a
    /// *serial* pool like `serve_scaling` — concurrent measurements would
    /// contend for cores and corrupt every number.
    pub fn bench_sweep(
        &mut self,
        profile: &str,
        workloads: &[BenchWorkload],
        native: bool,
        quick: bool,
    ) -> Result<()> {
        let cpu = self.profile(profile)?;
        let specs: Vec<JobSpec> = workloads
            .iter()
            .map(|&workload| JobSpec::BenchSweep {
                cpu: cpu.clone(),
                workload,
                native,
                quick,
            })
            .collect();
        if native {
            let jobs: Vec<Job> = specs
                .into_iter()
                .enumerate()
                .map(|(i, spec)| Job { id: i as u64, spec })
                .collect();
            let completed = WorkerPool::serial().run(jobs, None);
            self.store.ingest(&completed);
        } else {
            self.run_jobs(specs);
        }
        Ok(())
    }

    /// Telemetry-trace sweep (`cachebound trace`, `bench --telemetry`):
    /// one `Trace` job per workload, fanned across the pool (trace replays
    /// are CPU-pure and deterministic).  Returns `(result key, summary)`
    /// pairs in workload order; summaries also land in the store under
    /// their keys (`trace/<cpu>/<family>/<shape>/r<rows>`).
    pub fn trace_grid(
        &mut self,
        profile: &str,
        workloads: &[BenchWorkload],
        max_rows: usize,
    ) -> Result<Vec<(String, crate::telemetry::TraceSummary)>> {
        let cpu = self.profile(profile)?;
        let specs: Vec<JobSpec> = workloads
            .iter()
            .map(|&workload| JobSpec::Trace {
                cpu: cpu.clone(),
                workload,
                max_rows,
            })
            .collect();
        let keys: Vec<String> = specs.iter().map(|s| s.key()).collect();
        let jobs: Vec<Job> = specs
            .into_iter()
            .enumerate()
            .map(|(i, spec)| Job { id: i as u64, spec })
            .collect();
        let completed = self.pool.run(jobs, self.registry.as_mut());
        // match by job id, not key: duplicate workloads share a key but
        // still deserve one summary each
        let mut by_id: std::collections::HashMap<u64, crate::telemetry::TraceSummary> = completed
            .iter()
            .filter_map(|c| match &c.output {
                super::jobs::JobOutput::Traced { summary } => Some((c.id, summary.clone())),
                _ => None,
            })
            .collect();
        self.store.ingest(&completed);
        keys.into_iter()
            .enumerate()
            .map(|(i, k)| {
                let s = by_id
                    .remove(&(i as u64))
                    .ok_or_else(|| anyhow::anyhow!("trace produced no result for {k}"))?;
                Ok((k, s))
            })
            .collect()
    }

    /// Validate every artifact in the manifest through PJRT.
    pub fn validate_artifacts(&mut self) -> Result<Vec<(String, bool)>> {
        let names = match &self.registry {
            Some(r) => r.names(None),
            None => return Ok(Vec::new()),
        };
        let specs: Vec<JobSpec> = names
            .iter()
            .map(|n| JobSpec::ArtifactValidate { name: n.clone() })
            .collect();
        self.run_jobs(specs);
        Ok(names
            .into_iter()
            .map(|n| {
                let passed = self
                    .store
                    .get(&format!("validate/{n}"))
                    .and_then(|v| v.passed)
                    .unwrap_or(false);
                (n, passed)
            })
            .collect())
    }
}

/// The equivalent square-GEMM size for a conv layer's bit-serial im2col
/// contraction (geometric mean of M=ho·wo, N=cout, K=cin·k²).
pub fn bitserial_equiv_n(l: &ConvLayer) -> usize {
    let m = (l.ho() * l.wo()) as f64;
    let n = l.cout as f64;
    let k = (l.cin * l.k * l.k) as f64;
    (m * n * k).powf(1.0 / 3.0).round() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config() -> PipelineConfig {
        PipelineConfig {
            n_workers: 2,
            tune_trials: 8,
            skip_native: true,
            native_max_n: 0,
        }
    }

    #[test]
    fn gemm_table_populates_store() {
        let mut p = Pipeline::new(quick_config());
        p.gemm_table("a53", &[64, 128]).unwrap();
        // naive + tuned sim results for both sizes
        assert!(p.store.seconds("sim_gemm/cortex-a53/n64/b8x8x8u1/e32").is_some());
        assert!(p.store.seconds("sim_gemm/cortex-a53/n128/b64x64x64u4/e32").is_some());
        assert!(!p.store.by_prefix("tune_gemm/").is_empty());
    }

    #[test]
    fn conv_layers_cover_table_iii() {
        let mut p = Pipeline::new(quick_config());
        let layers = p.conv_layers("a72").unwrap();
        assert_eq!(layers.len(), 10);
        assert_eq!(p.store.by_prefix("sim_conv/cortex-a72/").len(), 10);
    }

    #[test]
    fn quantized_conv_produces_bitserial_keys() {
        let mut p = Pipeline::new(quick_config());
        p.quantized_conv("a53", &[1, 2]).unwrap();
        assert!(!p.store.by_prefix("sim_bs/").is_empty());
        // int8 conv entries
        assert_eq!(p.store.by_prefix("sim_conv/cortex-a53/").iter()
            .filter(|(k, _)| k.ends_with("/e8")).count(), 10);
    }

    #[test]
    fn serve_scaling_populates_store() {
        let mut p = Pipeline::new(quick_config());
        p.serve_scaling(
            &[1, 2],
            16,
            0,
            AdmissionMode::None,
            PlacementPolicy::Hash,
            RebalanceMode::Drain,
            false,
            TierPolicy::Pinned,
            1,
            None,
        )
        .unwrap();
        let rows = p.store.by_prefix("serve_mix/");
        assert_eq!(rows.len(), 2);
        for (k, v) in rows {
            assert!(k.contains("/phash"), "{k} must carry the placement policy");
            assert!(k.contains("/rbdrain"), "{k} must carry the rebalance mode");
            assert!(
                k.ends_with("/t0/tppin/at1/cd0"),
                "{k} must carry the tier+admission+cache config"
            );
            assert!(v.seconds.is_some(), "{k} missing p50");
            assert_eq!(v.passed, Some(true), "{k} had failures");
            assert!(v.detail.as_deref().unwrap().contains("req/s"));
        }
    }

    #[test]
    fn serve_scaling_carries_cache_aware_policy() {
        let mut p = Pipeline::new(quick_config());
        p.serve_scaling(
            &[2],
            12,
            0,
            AdmissionMode::None,
            PlacementPolicy::CacheAware,
            RebalanceMode::Drain,
            false,
            TierPolicy::Pinned,
            1,
            None,
        )
        .unwrap();
        let rows = p.store.by_prefix("serve_mix/");
        assert_eq!(rows.len(), 1);
        let (k, v) = &rows[0];
        assert!(k.contains("/pcache"), "{k}");
        assert_eq!(v.passed, Some(true), "{k} had failures");
    }

    #[test]
    fn serve_scaling_accepts_live_rebalancing() {
        let mut p = Pipeline::new(quick_config());
        p.serve_scaling(
            &[2],
            48,
            0,
            AdmissionMode::None,
            PlacementPolicy::Hash,
            RebalanceMode::Live,
            false,
            TierPolicy::Pinned,
            4,
            None,
        )
        .unwrap();
        let rows = p.store.by_prefix("serve_mix/");
        assert_eq!(rows.len(), 1);
        let (k, v) = &rows[0];
        assert!(k.contains("/rblive"), "{k}");
        assert!(k.contains("/at4/"), "{k} must carry the admission-thread count");
        assert_eq!(v.passed, Some(true), "{k}: migrations must not fail requests");
        assert!(v.detail.as_deref().unwrap().contains("migrations"), "{v:?}");
    }

    #[test]
    fn serve_scaling_runs_the_tiered_menu_with_downshift() {
        let mut p = Pipeline::new(quick_config());
        p.serve_scaling(
            &[2],
            24,
            0,
            AdmissionMode::None,
            PlacementPolicy::CacheAware,
            RebalanceMode::Drain,
            true,
            TierPolicy::DownshiftOnPressure,
            1,
            None,
        )
        .unwrap();
        let rows = p.store.by_prefix("serve_mix/");
        assert_eq!(rows.len(), 1);
        let (k, v) = &rows[0];
        assert!(k.ends_with("/t1/tpdown/at1/cd0"), "{k} must carry the tier config");
        assert_eq!(v.passed, Some(true), "{k}: tiered serving had failures");
    }

    #[test]
    fn bench_sweep_populates_store_under_bench_keys() {
        let mut p = Pipeline::new(quick_config());
        let ws = [
            BenchWorkload::Gemm { n: 128 },
            BenchWorkload::Conv { layer: workloads::layer_by_name("C2").unwrap() },
            BenchWorkload::Bitserial { n: 256, bits: 2 },
        ];
        p.bench_sweep("a53", &ws, false, true).unwrap();
        let rows = p.store.by_prefix("bench/sim/cortex-a53/");
        assert_eq!(rows.len(), 3);
        for (k, v) in rows {
            assert!(v.seconds.unwrap() > 0.0, "{k}");
            assert!(v.bound.is_some(), "{k} missing sim bound");
        }
    }

    #[test]
    fn trace_grid_returns_summaries_and_populates_store() {
        let mut p = Pipeline::new(quick_config());
        let ws = [
            BenchWorkload::Gemm { n: 64 },
            BenchWorkload::Bitserial { n: 64, bits: 1 },
        ];
        let out = p.trace_grid("a53", &ws, 32).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0, "trace/cortex-a53/gemm/n64/r32");
        assert_eq!(out[0].1.key, "gemm/n64");
        let rows = p.store.by_prefix("trace/cortex-a53/");
        assert_eq!(rows.len(), 2);
        for (k, v) in rows {
            assert!(v.bound.is_some(), "{k} missing predicted class");
            assert!(v.detail.as_deref().unwrap().contains("L1"), "{k}");
        }
    }

    #[test]
    fn equiv_n_is_plausible() {
        let c2 = workloads::layer_by_name("C2").unwrap();
        let n = bitserial_equiv_n(&c2);
        assert!(n > 100 && n < 2000, "{n}");
    }
}
