//! Experiment job definitions.
//!
//! A [`Job`] is one self-contained measurement/evaluation unit.  CPU-pure
//! jobs (`Sim*`, `Native*`, `Tune*`, `Membench`) may run on any worker
//! thread; `Artifact*` jobs touch the PJRT client and are routed to the
//! leader thread by the pool (the routing invariant is property-tested).

use std::path::PathBuf;

use crate::hw::CpuSpec;
use crate::operators::conv::ConvSchedule;
use crate::operators::gemm::GemmSchedule;
use crate::operators::workloads::{BenchWorkload, ConvLayer};

use super::placement::{PlacementPolicy, RebalanceMode};
use super::server::{AdmissionMode, TierPolicy};

/// What to run.
#[derive(Clone, Debug, PartialEq)]
pub enum JobSpec {
    /// Analytic-simulator GEMM timing on a calibrated profile.
    SimGemm {
        /// Calibrated profile to simulate.
        cpu: CpuSpec,
        /// Square GEMM size.
        n: usize,
        /// Tile schedule.
        schedule: GemmSchedule,
        /// Element width in bits.
        elem_bits: usize,
    },
    /// Analytic-simulator conv timing.
    SimConv {
        /// Calibrated profile to simulate.
        cpu: CpuSpec,
        /// Table III conv layer.
        layer: ConvLayer,
        /// Blocking schedule.
        schedule: ConvSchedule,
        /// Element width in bits.
        elem_bits: usize,
    },
    /// Analytic-simulator bit-serial GEMM timing.
    SimBitserial {
        /// Calibrated profile to simulate.
        cpu: CpuSpec,
        /// Square GEMM size.
        n: usize,
        /// Activation bit width.
        abits: usize,
        /// Weight bit width.
        wbits: usize,
        /// Unipolar (vs bipolar) encoding.
        unipolar: bool,
    },
    /// Host-wallclock native GEMM timing.
    NativeGemm {
        /// Square GEMM size.
        n: usize,
        /// Tile schedule (tiled variant only).
        schedule: GemmSchedule,
        /// Which native implementation to time.
        variant: NativeGemmVariant,
    },
    /// Tune a GEMM schedule on the simulator for a profile.
    TuneSimGemm {
        /// Calibrated profile to tune for.
        cpu: CpuSpec,
        /// Square GEMM size.
        n: usize,
        /// Measurement budget.
        n_trials: usize,
        /// GBT cost model (vs random search).
        use_gbt: bool,
    },
    /// Tune a conv schedule on the simulator.
    TuneSimConv {
        /// Calibrated profile to tune for.
        cpu: CpuSpec,
        /// Table III conv layer.
        layer: ConvLayer,
        /// Measurement budget.
        n_trials: usize,
        /// GBT cost model (vs random search).
        use_gbt: bool,
    },
    /// Validate an AOT artifact's numerics (leader-only).
    ArtifactValidate {
        /// Artifact name.
        name: String,
    },
    /// Time an AOT artifact (leader-only).
    ArtifactMeasure {
        /// Artifact name.
        name: String,
    },
    /// Run the synthetic serving mix through the sharded server (CPU-pure:
    /// the synthetic executor serves native tiled GEMMs, no PJRT).
    /// `placement: CacheAware` traces the mix's cache profiles first and
    /// routes by the greedy co-run plan instead of the artifact hash;
    /// `rebalance: Live` lets the server migrate artifacts mid-stream when
    /// the observed pressure diverges from the plan.
    ServeMix {
        /// Worker threads.
        workers: usize,
        /// Stream length.
        requests: usize,
        /// Stream RNG seed.
        seed: u64,
        /// Per-worker LRU response-cache entries.
        cache_entries: usize,
        /// Open-loop arrival rate, requests/second; 0 keeps the
        /// closed-loop submit-and-drain drive (the pre-PR-6 behaviour).
        /// Positive rates pace submissions on a seeded Poisson schedule
        /// ([`crate::coordinator::loadgen::ArrivalConfig`], same `seed`).
        arrival_rps: u32,
        /// Admission-control policy (none / shed / degrade).
        admission: AdmissionMode,
        /// Artifact→worker policy (hash vs cache-aware).
        placement: PlacementPolicy,
        /// Divergence response (off / drain suggestion / live migration).
        rebalance: RebalanceMode,
        /// Serve the full precision-tier menu
        /// ([`crate::operators::workloads::serving_mix_tiered`]: fp32 +
        /// int8 + packed bit-serial) instead of the fp32-only mix.
        tiers: bool,
        /// Which axis [`AdmissionMode::Degrade`] shrinks (shape ladder vs
        /// precision lattice).
        tier_policy: TierPolicy,
        /// Admission threads (>1 partitions the stream by artifact hash
        /// and admits concurrently against route-table snapshots —
        /// `coordinator::routing`; 1 keeps the single-threaded drive).
        admission_threads: usize,
        /// Root of the persistent compiled-artifact cache
        /// ([`crate::runtime::ArtifactCache`]); `None` keeps the
        /// compile-always behaviour.  The key records only presence —
        /// the digest scheme makes the contents path-independent.
        cache_dir: Option<PathBuf>,
    },
    /// One telemetry trace (`cachebound trace`, `bench --telemetry`):
    /// replay the workload through the hierarchy with a reuse-distance
    /// sink and report simulated vs MRC-predicted hit rates and boundness
    /// class.  CPU-pure, parallel-safe.
    Trace {
        /// Calibrated profile to trace against.
        cpu: CpuSpec,
        /// Workload to replay.
        workload: BenchWorkload,
        /// Row budget of the replay (`telemetry::TraceBudget`).
        max_rows: usize,
    },
    /// One roofline-bench workload (`cachebound bench`, `bench::sweep`).
    ///
    /// `native: false` times the workload on the calibrated simulator
    /// (deterministic — what CI gates on); `native: true` measures the
    /// native operator's host wallclock through `util::bench::measure`,
    /// with `quick` selecting the fast vs thorough measurement profile
    /// (`quick` is deliberately NOT part of the key: a quick and a full
    /// run of the same workload are the same measurement for `compare`).
    /// Native sweeps must run on a serial pool — concurrent wallclock
    /// measurements contend for cores (see `Pipeline::bench_sweep`).
    BenchSweep {
        /// Profile whose bound lines score the run.
        cpu: CpuSpec,
        /// Workload to time.
        workload: BenchWorkload,
        /// Host wallclock instead of the simulator.
        native: bool,
        /// Fast measurement profile.
        quick: bool,
    },
}

/// Which native GEMM implementation a `NativeGemm` job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NativeGemmVariant {
    /// Triple-loop scalar GEMM.
    Naive,
    /// Schedule-parameterized cache-blocked GEMM.
    Tiled,
    /// Fixed-block reference implementation.
    Blocked,
}

impl JobSpec {
    /// Jobs that must run on the leader (PJRT client is not Send).
    pub fn leader_only(&self) -> bool {
        matches!(self, JobSpec::ArtifactValidate { .. } | JobSpec::ArtifactMeasure { .. })
    }

    /// Stable identifier used as the result key.
    pub fn key(&self) -> String {
        match self {
            JobSpec::SimGemm { cpu, n, schedule, elem_bits } => format!(
                "sim_gemm/{}/n{}/b{}x{}x{}u{}/e{}",
                cpu.name, n, schedule.bm, schedule.bn, schedule.bk, schedule.unroll, elem_bits
            ),
            JobSpec::SimConv { cpu, layer, schedule, elem_bits } => format!(
                "sim_conv/{}/{}/co{}r{}/e{}",
                cpu.name, layer.name, schedule.bco, schedule.brow, elem_bits
            ),
            JobSpec::SimBitserial { cpu, n, abits, wbits, unipolar } => format!(
                "sim_bs/{}/n{}/a{}w{}/{}",
                cpu.name,
                n,
                abits,
                wbits,
                if *unipolar { "uni" } else { "bi" }
            ),
            JobSpec::NativeGemm { n, schedule, variant } => format!(
                "native_gemm/{variant:?}/n{}/b{}x{}x{}u{}",
                n, schedule.bm, schedule.bn, schedule.bk, schedule.unroll
            ),
            JobSpec::TuneSimGemm { cpu, n, n_trials, use_gbt } => {
                format!("tune_gemm/{}/n{}/t{}/gbt{}", cpu.name, n, n_trials, use_gbt)
            }
            JobSpec::TuneSimConv { cpu, layer, n_trials, use_gbt } => {
                format!("tune_conv/{}/{}/t{}/gbt{}", cpu.name, layer.name, n_trials, use_gbt)
            }
            JobSpec::ArtifactValidate { name } => format!("validate/{name}"),
            JobSpec::ArtifactMeasure { name } => format!("measure/{name}"),
            JobSpec::ServeMix {
                workers,
                requests,
                seed,
                cache_entries,
                arrival_rps,
                admission,
                placement,
                rebalance,
                tiers,
                tier_policy,
                admission_threads,
                cache_dir,
            } => {
                format!(
                    "serve_mix/w{workers}/r{requests}/s{seed}/c{cache_entries}/a{arrival_rps}/ad{}/p{}/rb{}/t{}/tp{}/at{}/cd{}",
                    admission.key_part(),
                    placement.key_part(),
                    rebalance.key_part(),
                    *tiers as u8,
                    tier_policy.key_part(),
                    admission_threads,
                    cache_dir.is_some() as u8
                )
            }
            JobSpec::Trace { cpu, workload, max_rows } => {
                format!("trace/{}/{}/r{}", cpu.name, workload.key_part(), max_rows)
            }
            JobSpec::BenchSweep { cpu, workload, native, .. } => format!(
                "bench/{}/{}/{}",
                if *native { "native" } else { "sim" },
                cpu.name,
                workload.key_part()
            ),
        }
    }
}

/// A queued job with its sequence number.
#[derive(Clone, Debug)]
pub struct Job {
    /// Sequence number (also the result-matching key).
    pub id: u64,
    /// What to run.
    pub spec: JobSpec,
}

/// What a job produced.
#[derive(Clone, Debug)]
pub enum JobOutput {
    /// A timing in seconds (+ optional bound name from the simulator).
    Seconds {
        /// Measured/simulated time, seconds.
        secs: f64,
        /// Binding-resource name from the simulator, when one exists.
        bound: Option<String>,
    },
    /// Tuning outcome.
    Tuned {
        /// Best time found, seconds.
        best_seconds: f64,
        /// Human-readable description of the best config.
        best_desc: String,
        /// Trials actually measured.
        trials: usize,
        /// Total size of the searched space.
        space: usize,
    },
    /// Validation outcome.
    Validated {
        /// All outputs matched their checksums.
        passed: bool,
        /// Per-output detail line.
        detail: String,
    },
    /// Telemetry-trace outcome (simulated vs MRC-predicted cache profile).
    Traced {
        /// The compact trace record.
        summary: crate::telemetry::TraceSummary,
    },
    /// Serving-run outcome (sharded server over the synthetic mix).
    Served {
        /// Completed requests per second of wall time.
        throughput_rps: f64,
        /// Median end-to-end latency, seconds.
        p50_s: f64,
        /// 99th-percentile end-to-end latency, seconds.
        p99_s: f64,
        /// Successfully answered requests.
        completed: u64,
        /// Failed requests.
        failed: u64,
        /// Requests shed by admission control (not failures).
        shed: u64,
        /// Responses served from the LRU response cache.
        cache_hits: u64,
        /// Artifacts migrated mid-stream by live rebalancing.
        migrations: u64,
        /// First-touch preparations compiled from scratch.
        compiled: u64,
        /// First-touch preparations loaded warm from the persistent
        /// artifact cache (nonzero only with a `cache_dir`).
        disk_warm: u64,
    },
    /// Job failed.
    Failed {
        /// What went wrong.
        error: String,
    },
}

impl JobOutput {
    /// The headline seconds of timing-shaped outputs.
    pub fn seconds(&self) -> Option<f64> {
        match self {
            JobOutput::Seconds { secs, .. } => Some(*secs),
            JobOutput::Tuned { best_seconds, .. } => Some(*best_seconds),
            _ => None,
        }
    }

    /// Did the job fail?
    pub fn is_failure(&self) -> bool {
        matches!(self, JobOutput::Failed { .. })
    }
}

/// Execute a CPU-pure job (everything except `Artifact*`).
pub fn run_cpu_job(spec: &JobSpec) -> JobOutput {
    use crate::sim::timing;
    match spec {
        JobSpec::SimGemm { cpu, n, schedule, elem_bits } => {
            let tb = timing::simulate_gemm_time(cpu, *n, *n, *n, *schedule, *elem_bits);
            JobOutput::Seconds {
                secs: tb.total_s,
                bound: Some(tb.bound.name().to_string()),
            }
        }
        JobSpec::SimConv { cpu, layer, schedule, elem_bits } => {
            let tb = timing::simulate_conv_time(cpu, layer, *schedule, *elem_bits);
            JobOutput::Seconds {
                secs: tb.total_s,
                bound: Some(tb.bound.name().to_string()),
            }
        }
        JobSpec::SimBitserial { cpu, n, abits, wbits, unipolar } => {
            let tb =
                timing::simulate_bitserial_gemm_time(cpu, *n, *n, *n, *abits, *wbits, *unipolar);
            JobOutput::Seconds {
                secs: tb.total_s,
                bound: Some(tb.bound.name().to_string()),
            }
        }
        JobSpec::NativeGemm { n, schedule, variant } => {
            let a = crate::operators::Tensor::rand_f32(&[*n, *n], 11);
            let b = crate::operators::Tensor::rand_f32(&[*n, *n], 12);
            let cfg = crate::util::bench::BenchConfig::quick();
            let m = crate::util::bench::measure(&cfg, || match variant {
                NativeGemmVariant::Naive => crate::operators::gemm::naive(&a, &b),
                NativeGemmVariant::Tiled => crate::operators::gemm::tiled(&a, &b, *schedule),
                NativeGemmVariant::Blocked => crate::operators::gemm::blocked(&a, &b),
            });
            JobOutput::Seconds {
                secs: m.seconds.median,
                bound: None,
            }
        }
        JobSpec::TuneSimGemm { cpu, n, n_trials, use_gbt } => {
            let space = crate::tuner::GemmSpace::new(cpu, *n, *n, *n);
            let mut target = crate::tuner::SimGemmTarget::square(cpu, *n);
            let kind = if *use_gbt {
                crate::tuner::TunerKind::Gbt
            } else {
                crate::tuner::TunerKind::Random
            };
            let tuner = crate::tuner::Tuner::new(kind, *n_trials);
            match crate::tuner::tune(&tuner, &space, &mut target) {
                Ok(res) => JobOutput::Tuned {
                    best_seconds: res.best_seconds,
                    best_desc: format!("{:?}", res.best_config),
                    trials: res.trials.len(),
                    space: res.space_size,
                },
                Err(e) => JobOutput::Failed { error: e.to_string() },
            }
        }
        JobSpec::TuneSimConv { cpu, layer, n_trials, use_gbt } => {
            let space = crate::tuner::ConvSpace::new(cpu, *layer);
            let mut target = crate::tuner::SimConvTarget {
                cpu: cpu.clone(),
                layer: *layer,
                elem_bits: 32,
            };
            let kind = if *use_gbt {
                crate::tuner::TunerKind::Gbt
            } else {
                crate::tuner::TunerKind::Random
            };
            let tuner = crate::tuner::Tuner::new(kind, *n_trials);
            match crate::tuner::tune(&tuner, &space, &mut target) {
                Ok(res) => JobOutput::Tuned {
                    best_seconds: res.best_seconds,
                    best_desc: format!("{:?}", res.best_config),
                    trials: res.trials.len(),
                    space: res.space_size,
                },
                Err(e) => JobOutput::Failed { error: e.to_string() },
            }
        }
        JobSpec::Trace { cpu, workload, max_rows } => {
            let report = crate::telemetry::trace_workload(
                cpu,
                workload,
                crate::telemetry::TraceBudget::new(*max_rows),
            );
            JobOutput::Traced { summary: report.summary() }
        }
        JobSpec::ServeMix {
            workers,
            requests,
            seed,
            cache_entries,
            arrival_rps,
            admission,
            placement,
            rebalance,
            tiers,
            tier_policy,
            admission_threads,
            cache_dir,
        } => {
            use super::loadgen::ArrivalConfig;
            use super::server::{PrepSource, ServeConfig, ShardedServer, SyntheticExecutor};
            let mut cfg = ServeConfig::new(*workers)
                .with_cache(*cache_entries)
                .with_placement(*placement)
                .with_rebalance(*rebalance)
                .with_admission(*admission)
                .with_tier_policy(*tier_policy)
                .with_admission_threads(*admission_threads);
            if let Some(dir) = cache_dir {
                cfg = cfg.with_cache_dir(dir.clone());
            }
            if *placement == PlacementPolicy::CacheAware || *rebalance == RebalanceMode::Live {
                // both the upfront plan and the live divergence check need
                // per-artifact profiles: the synthetic mix traced against
                // the part the bounds are calibrated for (cached, so a
                // scaling sweep pays the replays only once); the tiered
                // menu hands the packer the int8/bit-serial profiles too,
                // which is how quantized artifacts pack denser
                let cpu = crate::hw::profile_by_name("a53").expect("builtin profile").cpu;
                let profiles = if *tiers {
                    crate::telemetry::serving_tier_mix_profiles(&cpu)
                } else {
                    crate::telemetry::serving_mix_profiles(&cpu)
                };
                cfg = cfg.with_profiles(profiles).with_cpu(cpu);
            }
            let srv = ShardedServer::start(cfg, |_w| Ok(SyntheticExecutor::new()));
            let stream = if *tiers {
                crate::operators::workloads::serving_requests_tiered(*requests, *seed)
            } else {
                crate::operators::workloads::serving_requests(*requests, *seed)
            };
            let out = if *arrival_rps > 0 {
                // open-loop: pace submissions on the seeded schedule (the
                // same seed drives both the stream mix and the arrivals)
                let schedule =
                    ArrivalConfig::poisson(*arrival_rps as f64, *requests, *seed).schedule();
                srv.serve_open_loop(stream, &schedule)
            } else {
                srv.serve_stream(stream)
            };
            let (p50, p99) = match out.metrics.latency_percentiles(&[50.0, 99.0]).as_deref() {
                Some([p50, p99]) => (*p50, *p99),
                _ => (0.0, 0.0),
            };
            JobOutput::Served {
                throughput_rps: out.metrics.throughput(out.wall_seconds),
                p50_s: p50,
                p99_s: p99,
                completed: out.metrics.completed,
                failed: out.metrics.failed,
                shed: out.metrics.shed,
                cache_hits: out.metrics.cache_hits,
                migrations: out.metrics.migrations.len() as u64,
                compiled: out
                    .metrics
                    .prep
                    .iter()
                    .filter(|p| p.source == PrepSource::Compiled)
                    .count() as u64,
                disk_warm: out
                    .metrics
                    .prep
                    .iter()
                    .filter(|p| p.source == PrepSource::DiskWarm)
                    .count() as u64,
            }
        }
        JobSpec::BenchSweep { cpu, workload, native, quick } => {
            if *native {
                run_native_bench(workload, *quick)
            } else {
                let tb = match workload {
                    BenchWorkload::Gemm { n } => timing::simulate_gemm_time(
                        cpu,
                        *n,
                        *n,
                        *n,
                        super::pipeline::default_tuned_schedule(),
                        32,
                    ),
                    BenchWorkload::Conv { layer } => timing::simulate_conv_time(
                        cpu,
                        layer,
                        super::pipeline::default_conv_schedule(),
                        32,
                    ),
                    BenchWorkload::QnnConv { layer } => timing::simulate_conv_time(
                        cpu,
                        layer,
                        super::pipeline::default_conv_schedule(),
                        8,
                    ),
                    BenchWorkload::QnnGemm { n } => timing::simulate_gemm_time(
                        cpu,
                        *n,
                        *n,
                        *n,
                        super::pipeline::default_tuned_schedule(),
                        8,
                    ),
                    BenchWorkload::Bitserial { n, bits } => {
                        timing::simulate_bitserial_gemm_time(cpu, *n, *n, *n, *bits, *bits, true)
                    }
                };
                JobOutput::Seconds {
                    secs: tb.total_s,
                    bound: Some(tb.bound.name().to_string()),
                }
            }
        }
        JobSpec::ArtifactValidate { .. } | JobSpec::ArtifactMeasure { .. } => JobOutput::Failed {
            error: "artifact jobs must run on the leader".into(),
        },
    }
}

/// Host-wallclock measurement of one bench workload through the shared
/// harness (`util::bench::measure`) — the native mode of `cachebound bench`.
fn run_native_bench(workload: &BenchWorkload, quick: bool) -> JobOutput {
    use crate::operators::{bitserial, conv, gemm, qnn, Tensor};
    let cfg = if quick {
        crate::util::bench::BenchConfig::quick()
    } else {
        crate::util::bench::BenchConfig::default()
    };
    let m = match workload {
        BenchWorkload::Gemm { n } => {
            let a = Tensor::rand_f32(&[*n, *n], 21);
            let b = Tensor::rand_f32(&[*n, *n], 22);
            let s = super::pipeline::default_tuned_schedule();
            crate::util::bench::measure(&cfg, || gemm::tiled(&a, &b, s))
        }
        BenchWorkload::Conv { layer: l } => {
            let x = Tensor::rand_f32(&[l.b, l.cin, l.h, l.w], 23);
            let w = Tensor::rand_f32(&[l.cout, l.cin, l.k, l.k], 24);
            crate::util::bench::measure(&cfg, || {
                conv::spatial_pack(&x, &w, l.stride, l.pad, conv::ConvSchedule::default_tuned())
            })
        }
        BenchWorkload::QnnConv { layer: l } => {
            let x = Tensor::rand_i8(&[l.b, l.cin, l.h, l.w], 25);
            let w = Tensor::rand_i8(&[l.cout, l.cin, l.k, l.k], 26);
            crate::util::bench::measure(&cfg, || qnn::conv2d(&x, &w, l.stride, l.pad))
        }
        BenchWorkload::QnnGemm { n } => {
            let a = Tensor::rand_i8(&[*n, *n], 25);
            let b = Tensor::rand_i8(&[*n, *n], 26);
            crate::util::bench::measure(&cfg, || qnn::gemm_blocked(&a, &b))
        }
        BenchWorkload::Bitserial { n, bits } => {
            let a = Tensor::rand_unipolar(&[*n, *n], *bits as u32, 27);
            let w = Tensor::rand_unipolar(&[*n, *n], *bits as u32, 28);
            let wp = bitserial::pack_unipolar(&w, *bits);
            // weights pre-packed, activations packed at runtime (§V-A)
            crate::util::bench::measure(&cfg, || {
                let ap = bitserial::pack_unipolar(&a, *bits);
                bitserial::gemm_unipolar(&ap, &wp)
            })
        }
    };
    JobOutput::Seconds {
        secs: m.seconds.median,
        bound: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::profile_by_name;

    #[test]
    fn keys_are_unique_and_stable() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let a = JobSpec::SimGemm {
            cpu: cpu.clone(),
            n: 128,
            schedule: GemmSchedule::new(64, 64, 64, 4),
            elem_bits: 32,
        };
        let b = JobSpec::SimGemm {
            cpu,
            n: 256,
            schedule: GemmSchedule::new(64, 64, 64, 4),
            elem_bits: 32,
        };
        assert_ne!(a.key(), b.key());
        assert_eq!(a.key(), a.clone().key());
    }

    #[test]
    fn leader_routing_flag() {
        let v = JobSpec::ArtifactValidate { name: "x".into() };
        assert!(v.leader_only());
        let cpu = profile_by_name("a53").unwrap().cpu;
        let s = JobSpec::SimGemm {
            cpu,
            n: 64,
            schedule: GemmSchedule::naive(),
            elem_bits: 32,
        };
        assert!(!s.leader_only());
    }

    #[test]
    fn cpu_job_produces_seconds() {
        let cpu = profile_by_name("a72").unwrap().cpu;
        let out = run_cpu_job(&JobSpec::SimGemm {
            cpu,
            n: 128,
            schedule: GemmSchedule::new(64, 64, 64, 4),
            elem_bits: 32,
        });
        assert!(out.seconds().unwrap() > 0.0);
        assert!(!out.is_failure());
    }

    #[test]
    fn artifact_job_on_worker_fails_loudly() {
        let out = run_cpu_job(&JobSpec::ArtifactValidate { name: "x".into() });
        assert!(out.is_failure());
    }

    #[test]
    fn bench_sweep_sim_job_times_and_classifies() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let spec = JobSpec::BenchSweep {
            cpu,
            workload: BenchWorkload::Gemm { n: 256 },
            native: false,
            quick: true,
        };
        assert_eq!(spec.key(), "bench/sim/cortex-a53/gemm/n256");
        match run_cpu_job(&spec) {
            JobOutput::Seconds { secs, bound } => {
                assert!(secs > 0.0);
                // the tuned sim GEMM at N=256 is the paper's L1-bound regime
                assert_eq!(bound.as_deref(), Some("L1-read"));
            }
            other => panic!("expected Seconds, got {other:?}"),
        }
    }

    #[test]
    fn bench_sweep_native_job_measures_wallclock() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let spec = JobSpec::BenchSweep {
            cpu,
            workload: BenchWorkload::Gemm { n: 48 },
            native: true,
            quick: true,
        };
        assert_eq!(spec.key(), "bench/native/cortex-a53/gemm/n48");
        match run_cpu_job(&spec) {
            JobOutput::Seconds { secs, bound } => {
                assert!(secs > 0.0);
                assert!(bound.is_none(), "native timings carry no sim bound");
            }
            other => panic!("expected Seconds, got {other:?}"),
        }
    }

    #[test]
    fn trace_job_reports_both_classifications() {
        let cpu = profile_by_name("a53").unwrap().cpu;
        let spec = JobSpec::Trace {
            cpu,
            workload: BenchWorkload::Gemm { n: 64 },
            max_rows: 32,
        };
        assert_eq!(spec.key(), "trace/cortex-a53/gemm/n64/r32");
        assert!(!spec.leader_only());
        match run_cpu_job(&spec) {
            JobOutput::Traced { summary } => {
                assert_eq!(summary.key, "gemm/n64");
                assert!(summary.accesses > 0);
                assert!(!summary.sim_class.is_empty());
                assert!(!summary.predicted_class.is_empty());
            }
            other => panic!("expected Traced, got {other:?}"),
        }
    }

    #[test]
    fn serve_mix_job_serves_and_reports() {
        let spec = JobSpec::ServeMix {
            workers: 2,
            requests: 24,
            seed: 7,
            cache_entries: 16,
            arrival_rps: 0,
            admission: AdmissionMode::None,
            placement: PlacementPolicy::Hash,
            rebalance: RebalanceMode::Drain,
            tiers: false,
            tier_policy: TierPolicy::Pinned,
            admission_threads: 1,
            cache_dir: None,
        };
        assert_eq!(
            spec.key(),
            "serve_mix/w2/r24/s7/c16/a0/adnone/phash/rbdrain/t0/tppin/at1/cd0"
        );
        let out = run_cpu_job(&spec);
        match out {
            JobOutput::Served { throughput_rps, completed, failed, shed, migrations, .. } => {
                assert_eq!(completed, 24);
                assert_eq!(failed, 0);
                assert_eq!(shed, 0, "no admission control, nothing shed");
                assert!(throughput_rps > 0.0);
                assert_eq!(migrations, 0, "drain mode never migrates");
            }
            other => panic!("expected Served, got {other:?}"),
        }
    }

    #[test]
    fn serve_mix_job_accepts_cache_aware_placement() {
        let spec = JobSpec::ServeMix {
            workers: 2,
            requests: 16,
            seed: 7,
            cache_entries: 0,
            arrival_rps: 0,
            admission: AdmissionMode::None,
            placement: PlacementPolicy::CacheAware,
            rebalance: RebalanceMode::Drain,
            tiers: false,
            tier_policy: TierPolicy::Pinned,
            admission_threads: 1,
            cache_dir: None,
        };
        assert_eq!(
            spec.key(),
            "serve_mix/w2/r16/s7/c0/a0/adnone/pcache/rbdrain/t0/tppin/at1/cd0"
        );
        match run_cpu_job(&spec) {
            JobOutput::Served { completed, failed, .. } => {
                assert_eq!(completed, 16);
                assert_eq!(failed, 0);
            }
            other => panic!("expected Served, got {other:?}"),
        }
    }

    #[test]
    fn serve_mix_job_runs_live_rebalancing_from_a_hash_start() {
        // live mode attaches the mix profiles even under hash placement,
        // so the divergence check has data to act on mid-stream
        let spec = JobSpec::ServeMix {
            workers: 2,
            requests: 80,
            seed: 7,
            cache_entries: 0,
            arrival_rps: 0,
            admission: AdmissionMode::None,
            placement: PlacementPolicy::Hash,
            rebalance: RebalanceMode::Live,
            tiers: false,
            tier_policy: TierPolicy::Pinned,
            admission_threads: 4,
            cache_dir: None,
        };
        assert_eq!(
            spec.key(),
            "serve_mix/w2/r80/s7/c0/a0/adnone/phash/rblive/t0/tppin/at4/cd0"
        );
        match run_cpu_job(&spec) {
            JobOutput::Served { completed, failed, .. } => {
                assert_eq!(completed, 80, "migrations must not lose or fail requests");
                assert_eq!(failed, 0);
            }
            other => panic!("expected Served, got {other:?}"),
        }
    }

    #[test]
    fn serve_mix_job_runs_open_loop_with_shedding() {
        // open-loop at a rate far past what two workers sustain on the
        // big variants: shed must engage, and every request must still
        // get exactly one disposition
        let spec = JobSpec::ServeMix {
            workers: 2,
            requests: 32,
            seed: 7,
            cache_entries: 0,
            arrival_rps: 5000,
            admission: AdmissionMode::Shed,
            placement: PlacementPolicy::Hash,
            rebalance: RebalanceMode::Drain,
            tiers: false,
            tier_policy: TierPolicy::Pinned,
            admission_threads: 1,
            cache_dir: None,
        };
        assert_eq!(
            spec.key(),
            "serve_mix/w2/r32/s7/c0/a5000/adshed/phash/rbdrain/t0/tppin/at1/cd0"
        );
        match run_cpu_job(&spec) {
            JobOutput::Served { completed, failed, shed, .. } => {
                assert_eq!(completed + failed + shed, 32, "one disposition each");
                assert_eq!(failed, 0, "sheds are not failures");
            }
            other => panic!("expected Served, got {other:?}"),
        }
    }
}
