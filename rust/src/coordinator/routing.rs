//! Epoch-versioned route snapshots: lock-free admission reads, single-writer
//! publishes (DESIGN.md §Admission concurrency).
//!
//! The sharded server routes every request by artifact name.  Before this
//! module the authoritative `routes: BTreeMap<String, usize>` lived behind
//! the coordinator thread, so admission, the rebalance check and the
//! migration protocol all serialized on it — the next throughput ceiling
//! once the operators themselves run at the cache bound.  The fix is the
//! classic read-copy-update shape, hand-rolled on `std` only (the build is
//! offline, no `arc-swap` crate):
//!
//! * [`RouteTable`] is an **immutable** value: a pin set (artifact →
//!   worker, written only by migrations and plan adoptions) over a
//!   deterministic fallback chain (start placement, then the artifact
//!   hash).  Resolving a route never mutates anything, which is what kills
//!   the old `routes.get` + re-insert double lookup on the admit hot path.
//! * [`RouteWriter`] is the **single writer** (the coordinator thread).
//!   [`RouteWriter::publish`] swaps in a new `Arc<RouteTable>` with one
//!   atomic pointer store and bumps the epoch counter; old tables are
//!   retired but kept alive for the router's lifetime, so readers may
//!   dereference the current-table pointer without a reclamation scheme
//!   (tables are a few hundred bytes and epochs advance only on
//!   migrations — dozens per run, not millions).
//! * [`RouteReader`] is a per-thread handle.  [`RouteReader::pin`] takes a
//!   [`Snapshot`] with one atomic load plus an epoch announcement in the
//!   reader's own slot; the whole admission decision (classify, route,
//!   shed/degrade, enqueue) runs against that one immutable table.
//!
//! The migration fence rides on the epoch slots.  Publication order is
//! *pointer first, epoch second*, and the pin loop is the store-load
//! (Dekker) pattern under `SeqCst`: a reader announces the epoch it
//! observed, then re-validates it before trusting the pointer.  In the
//! sequentially-consistent total order, a reader that re-validated an old
//! epoch made its slot visible *before* the writer's epoch bump, so
//! [`RouteWriter::wait_for_readers`]`(e)` returning guarantees every
//! in-flight admission that could still be routing by a pre-`e` table has
//! unpinned — the quiesce fence of the migration protocol
//! (`server` module docs, §Live migration) is then safe to drop.  A pinned
//! snapshot may resolve by a table *newer* than its announced epoch (the
//! writer raced the pointer load); that is conservative in the only
//! direction that matters: a slot value of `e` never hides a table older
//! than `e`.
//!
//! Invariants (property-tested in `rust/tests/proptests.rs`):
//! snapshots never observe a partially applied swap (a `RouteTable` is
//! immutable after construction), epochs are monotone, and a reader pinned
//! across any number of writer publishes still resolves every artifact it
//! saw at pin time, to the same worker.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::placement::Placement;
use super::shard::shard_for;

/// Slot value of a reader that is not currently pinned.
const IDLE: u64 = u64::MAX;

/// One immutable routing epoch: the complete artifact → worker function.
///
/// Resolution order is pins → start placement → artifact hash.  Pins are
/// written only by the single writer (migrations pin the artifact at its
/// new worker; plan adoptions pin every planned artifact at its *current*
/// worker so adopting a plan changes zero routes — only the fenced
/// migrations that follow do).
#[derive(Clone, Debug)]
pub struct RouteTable {
    epoch: u64,
    pins: BTreeMap<String, usize>,
    placement: Option<Arc<Placement>>,
    workers: usize,
    n_shards: usize,
}

impl RouteTable {
    /// The epoch this table was published at (0 for the initial table).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Worker count the table routes over.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Resolve `artifact` to its worker: pinned route, else the start
    /// placement's assignment (ignored when it names a worker outside the
    /// pool), else the deterministic artifact hash.  Total and pure — the
    /// same table resolves the same name to the same worker forever.
    pub fn worker_for(&self, artifact: &str) -> usize {
        if let Some(&w) = self.pins.get(artifact) {
            return w;
        }
        self.placement
            .as_ref()
            .and_then(|p| p.worker_for(artifact))
            .filter(|&w| w < self.workers)
            .unwrap_or_else(|| shard_for(artifact, self.n_shards) % self.workers)
    }

    /// The pinned route for `artifact`, when one exists.
    pub fn pinned(&self, artifact: &str) -> Option<usize> {
        self.pins.get(artifact).copied()
    }

    /// Every pinned route, in name order.
    pub fn pins(&self) -> &BTreeMap<String, usize> {
        &self.pins
    }
}

/// State shared between the writer and every reader handle.
struct RouterShared {
    /// Borrow of the most recently published table.  Valid to dereference
    /// for the shared state's whole lifetime: `retired` owns every table
    /// ever published and is only drained on drop.
    current: AtomicPtr<RouteTable>,
    /// Epoch of the most recently published table.  Published *after* the
    /// pointer, so a reader that observed epoch `e` loads a table of epoch
    /// ≥ `e` — never older.
    epoch: AtomicU64,
    /// Owns every published table (keeps `current` dereferenceable).
    retired: Mutex<Vec<Arc<RouteTable>>>,
    /// One epoch-announcement slot per reader handle ever registered
    /// (`IDLE` when the reader is between pins or dropped).
    slots: Mutex<Vec<Arc<AtomicU64>>>,
}

impl Drop for RouterShared {
    fn drop(&mut self) {
        // invalidate the raw pointer before the retired list frees its
        // target; nothing can be pinned here (readers hold the Arc)
        self.current = AtomicPtr::new(std::ptr::null_mut());
    }
}

/// The single-writer handle: owns route publication and the reader fence.
///
/// Exactly one exists per server (the coordinator thread).  Readers are
/// minted with [`RouteWriter::reader`] and may be moved to other threads.
pub struct RouteWriter {
    shared: Arc<RouterShared>,
    /// Writer-side clone of the latest table (spares the writer the
    /// raw-pointer dance; it is the only thread that replaces it).
    latest: Arc<RouteTable>,
}

impl RouteWriter {
    /// A router at epoch 0 with no pins: `placement` (when given) over the
    /// `shard_for(name, n_shards) % workers` hash.
    pub fn new(workers: usize, n_shards: usize, placement: Option<Arc<Placement>>) -> RouteWriter {
        assert!(workers > 0, "a router needs at least one worker");
        let latest = Arc::new(RouteTable {
            epoch: 0,
            pins: BTreeMap::new(),
            placement,
            workers,
            n_shards: n_shards.max(1),
        });
        let shared = Arc::new(RouterShared {
            current: AtomicPtr::new(Arc::as_ptr(&latest) as *mut RouteTable),
            epoch: AtomicU64::new(0),
            retired: Mutex::new(vec![latest.clone()]),
            slots: Mutex::new(Vec::new()),
        });
        RouteWriter { shared, latest }
    }

    /// The current table, writer-side (no pin needed: only this handle
    /// replaces it, and callers on the writer thread cannot race it).
    pub fn current(&self) -> &Arc<RouteTable> {
        &self.latest
    }

    /// Register a reader handle (its own epoch slot, initially idle).
    pub fn reader(&self) -> RouteReader {
        let slot = Arc::new(AtomicU64::new(IDLE));
        self.shared.slots.lock().unwrap().push(slot.clone());
        RouteReader { shared: self.shared.clone(), slot }
    }

    /// Publish a new epoch whose pin set is the current one transformed by
    /// `edit`.  Returns the new epoch.  The swap is pointer-then-epoch so
    /// no reader can pair the new epoch with the old table.
    pub fn publish(&mut self, edit: impl FnOnce(&mut BTreeMap<String, usize>)) -> u64 {
        let mut pins = self.latest.pins.clone();
        edit(&mut pins);
        let epoch = self.latest.epoch + 1;
        let next = Arc::new(RouteTable {
            epoch,
            pins,
            placement: self.latest.placement.clone(),
            workers: self.latest.workers,
            n_shards: self.latest.n_shards,
        });
        self.shared.retired.lock().unwrap().push(next.clone());
        self.shared
            .current
            .store(Arc::as_ptr(&next) as *mut RouteTable, Ordering::SeqCst);
        self.shared.epoch.store(epoch, Ordering::SeqCst);
        self.latest = next;
        epoch
    }

    /// Pin `artifact` to `worker` in a new epoch (the migration route
    /// swap).  Returns the new epoch.
    pub fn pin_route(&mut self, artifact: &str, worker: usize) -> u64 {
        assert!(worker < self.latest.workers, "pin to a worker outside the pool");
        self.publish(|pins| {
            pins.insert(artifact.to_string(), worker);
        })
    }

    /// Block until every reader is idle or pinned at epoch ≥ `epoch` — the
    /// migration protocol's grace period.  After this returns, no admission
    /// can still be routing by a table older than `epoch`, so every request
    /// for a migrating artifact admitted before the route swap has already
    /// reached the source worker's queue and the quiesce fence will drain
    /// it.  Must only be called from the writer thread (a reader waiting on
    /// itself would spin forever).
    pub fn wait_for_readers(&self, epoch: u64) {
        loop {
            let settled = {
                let slots = self.shared.slots.lock().unwrap();
                slots.iter().all(|s| {
                    let v = s.load(Ordering::SeqCst);
                    v == IDLE || v >= epoch
                })
            };
            if settled {
                return;
            }
            std::thread::yield_now();
        }
    }
}

/// A per-thread reader handle: pins snapshots of the current route table.
///
/// Each handle owns one epoch slot; dropping the handle parks the slot
/// idle forever (slots are never removed — a server mints a handful, not
/// millions).
pub struct RouteReader {
    shared: Arc<RouterShared>,
    slot: Arc<AtomicU64>,
}

impl RouteReader {
    /// Pin the current table: announce the observed epoch in this reader's
    /// slot, re-validate it (the store-load fence against the writer's
    /// pointer-then-epoch publish), then load the pointer.  The returned
    /// guard keeps the writer's [`RouteWriter::wait_for_readers`] honest
    /// until it drops; hold it across the *entire* admission decision
    /// including the enqueue, and never across a blocking wait.  One pin
    /// may be live per reader at a time (a second pin would overwrite the
    /// slot announcement).
    pub fn pin(&self) -> Snapshot {
        loop {
            let e = self.shared.epoch.load(Ordering::SeqCst);
            self.slot.store(e, Ordering::SeqCst);
            if self.shared.epoch.load(Ordering::SeqCst) == e {
                let table = self.shared.current.load(Ordering::SeqCst);
                debug_assert!(
                    unsafe { &*table }.epoch() >= e,
                    "publish order is pointer, then epoch"
                );
                return Snapshot {
                    _shared: self.shared.clone(),
                    slot: self.slot.clone(),
                    table,
                };
            }
            // a publish raced the announcement: retract and retry so the
            // slot never advertises an epoch older than the one we use
            self.slot.store(IDLE, Ordering::SeqCst);
        }
    }
}

impl Drop for RouteReader {
    fn drop(&mut self) {
        self.slot.store(IDLE, Ordering::SeqCst);
    }
}

/// A pinned, immutable view of one routing epoch (derefs to
/// [`RouteTable`]).  Dropping it retires the pin.  Owns its handles (no
/// borrow of the reader), so admission can hold a pin across `&mut self`
/// bookkeeping; the raw table pointer keeps it `!Send` — a pin lives and
/// dies on the thread that took it.
pub struct Snapshot {
    /// Keeps the retired list — and therefore `table`'s target — alive.
    _shared: Arc<RouterShared>,
    slot: Arc<AtomicU64>,
    table: *const RouteTable,
}

impl std::ops::Deref for Snapshot {
    type Target = RouteTable;

    fn deref(&self) -> &RouteTable {
        // Safety: `_shared.retired` owns every table ever published and is
        // only drained when the shared state drops, which `_shared` forbids
        // while this snapshot lives.
        unsafe { &*self.table }
    }
}

impl Drop for Snapshot {
    fn drop(&mut self) {
        self.slot.store(IDLE, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn resolution_order_is_pin_then_placement_then_hash() {
        use crate::analysis::InterferenceModel;
        use crate::hw::profile_by_name;
        use crate::telemetry::serving_mix_profiles;

        let cpu = profile_by_name("a53").unwrap().cpu;
        let profiles = serving_mix_profiles(&cpu);
        let plan = Arc::new(super::super::placement::plan(
            &InterferenceModel::new(&cpu),
            &profiles,
            2,
        ));
        let planned = profiles.keys().next().unwrap().clone();
        let mut w = RouteWriter::new(2, 8, Some(plan.clone()));

        // placement wins over the hash for planned artifacts
        assert_eq!(w.current().worker_for(&planned), plan.worker_for(&planned).unwrap());
        // hash fallback for everything else
        assert_eq!(w.current().worker_for("unplanned"), shard_for("unplanned", 8) % 2);
        // a pin beats both
        let pinned_to = 1 - plan.worker_for(&planned).unwrap();
        w.pin_route(&planned, pinned_to);
        assert_eq!(w.current().worker_for(&planned), pinned_to);
        assert_eq!(w.current().pinned(&planned), Some(pinned_to));
    }

    #[test]
    fn publishes_bump_the_epoch_monotonically() {
        let mut w = RouteWriter::new(2, 8, None);
        assert_eq!(w.current().epoch(), 0);
        for k in 1..=5u64 {
            let e = w.pin_route("a", (k % 2) as usize);
            assert_eq!(e, k);
            assert_eq!(w.current().epoch(), k);
        }
    }

    #[test]
    fn pinned_snapshot_keeps_its_epoch_while_the_writer_advances() {
        let mut w = RouteWriter::new(2, 8, None);
        w.pin_route("a", 0);
        let reader = w.reader();
        let snap = reader.pin();
        let at_pin = snap.worker_for("a");
        w.pin_route("a", 1);
        // the pinned view is immutable: same resolution as at pin time,
        // while the writer already sees the new epoch
        assert_eq!(snap.worker_for("a"), at_pin);
        assert_eq!(w.current().worker_for("a"), 1);
        assert!(w.current().epoch() > snap.epoch());
    }

    #[test]
    fn wait_for_readers_blocks_on_a_stale_pin_and_releases_on_drop() {
        let mut w = RouteWriter::new(2, 8, None);
        let reader = w.reader();
        let snap = reader.pin(); // pinned at epoch 0
        let target = w.pin_route("hot", 1);

        let done = Arc::new(AtomicBool::new(false));
        let handle = {
            // the writer side of the fence, on its own thread so the test
            // can observe it blocking
            let done = done.clone();
            let shared_writer_view = (w.shared.clone(), target);
            std::thread::spawn(move || {
                let (shared, epoch) = shared_writer_view;
                loop {
                    let settled = shared.slots.lock().unwrap().iter().all(|s| {
                        let v = s.load(Ordering::SeqCst);
                        v == IDLE || v >= epoch
                    });
                    if settled {
                        break;
                    }
                    std::thread::yield_now();
                }
                done.store(true, Ordering::SeqCst);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!done.load(Ordering::SeqCst), "fence must wait on the stale pin");
        drop(snap);
        handle.join().unwrap();
        assert!(done.load(Ordering::SeqCst));
    }

    #[test]
    fn concurrent_readers_never_observe_a_partial_swap() {
        // the writer always pins the pair ("x", "y") to the same worker in
        // one publish; a torn or partially applied swap would let a reader
        // see them split
        let mut w = RouteWriter::new(4, 16, None);
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let r = w.reader();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut last_epoch = 0u64;
                    let mut observed = 0usize;
                    while !stop.load(Ordering::SeqCst) {
                        let snap = r.pin();
                        assert_eq!(
                            snap.worker_for("x"),
                            snap.worker_for("y"),
                            "partial swap observed at epoch {}",
                            snap.epoch()
                        );
                        assert!(snap.epoch() >= last_epoch, "epochs ran backwards");
                        last_epoch = snap.epoch();
                        observed += 1;
                    }
                    observed
                })
            })
            .collect();
        for k in 0..500usize {
            let target = k % 4;
            let e = w.publish(|pins| {
                pins.insert("x".into(), target);
                pins.insert("y".into(), target);
            });
            if k % 8 == 0 {
                w.wait_for_readers(e);
            }
        }
        stop.store(true, Ordering::SeqCst);
        for h in readers {
            assert!(h.join().unwrap() > 0, "reader never pinned");
        }
    }
}
