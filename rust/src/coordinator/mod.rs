//! The L3 coordinator: experiment orchestration and serving.
//!
//! The paper's methodology is a large grid of measurements (two boards ×
//! {GEMM sweep, 10 conv layers} × {f32, int8, 8 bit-serial variants} ×
//! {naive, tuned, blas} plus tuning runs).  The coordinator turns that grid
//! into [`jobs`], runs CPU-pure jobs on a [`pool`] of worker threads
//! (simulator evaluations, native-operator timings, tuning), keeps
//! PJRT-bound jobs on the leader thread (the `xla` client is not `Send`),
//! and collects everything into a [`results`] store that the [`report`]
//! layer renders into the paper's tables and figures.
//!
//! The deployment face is [`server`]: the single-threaded reference
//! [`Server`] and the sharded multi-worker [`ShardedServer`], which hashes
//! requests to per-artifact [`shard`]s so each worker owns a disjoint,
//! cache-resident slice of the artifact set.  Division of labor with the
//! [`pool`]: the pool fans out *finite experiment batches* and routes
//! PJRT-bound jobs to the leader; the sharded server runs *open-ended
//! request streams* and sidesteps the leader bottleneck by giving every
//! worker its own thread-confined executor.
//!
//! [`report`]: crate::report
//! [`Server`]: server::Server
//! [`ShardedServer`]: server::ShardedServer

pub mod jobs;
pub mod pipeline;
pub mod pool;
pub mod results;
pub mod server;
pub mod shard;

pub use jobs::{Job, JobOutput, JobSpec};
pub use pipeline::{Pipeline, PipelineConfig};
pub use pool::WorkerPool;
pub use results::{ResultKey, ResultStore, ResultValue};
pub use server::{
    BatchPolicy, Exec, Executor, Metrics, PjrtExecutor, Request, Response, ServeConfig,
    ServeOutcome, Server, ShardedServer, SyntheticExecutor,
};
pub use shard::{shard_for, LatencyHistogram, ShardMetrics};
