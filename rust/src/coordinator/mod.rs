//! The L3 coordinator: experiment orchestration and serving.
//!
//! The paper's methodology is a large grid of measurements (two boards ×
//! {GEMM sweep, 10 conv layers} × {f32, int8, 8 bit-serial variants} ×
//! {naive, tuned, blas} plus tuning runs).  The coordinator turns that grid
//! into [`jobs`], runs CPU-pure jobs on a [`pool`] of worker threads
//! (simulator evaluations, native-operator timings, tuning), keeps
//! PJRT-bound jobs on the leader thread (the `xla` client is not `Send`),
//! and collects everything into a [`results`] store that the [`report`]
//! layer renders into the paper's tables and figures.
//!
//! The deployment face is [`server`]: the single-threaded reference
//! [`Server`] and the sharded multi-worker [`ShardedServer`], which hashes
//! requests to per-artifact [`shard`]s so each worker owns a disjoint,
//! cache-resident slice of the artifact set.  [`placement`] upgrades that
//! hash to telemetry-driven scheduling: per-artifact
//! [`CacheProfile`]s feed the co-run interference model
//! ([`crate::analysis::interference`]) and a greedy packer assigns
//! artifacts to workers by predicted slowdown on the shared L2
//! ([`PlacementPolicy::CacheAware`]); under [`RebalanceMode::Live`] the
//! server acts on the same signal *mid-stream*, quiescing and migrating
//! artifacts whose observed pressure diverges from the plan while
//! preserving per-artifact FIFO (`server` module docs, §Live migration).
//! [`routing`] epoch-versions the artifact→worker table so N admission
//! threads route by lock-free snapshots (`serve --admission-threads`)
//! while migrations keep their fenced atomic swap (`server` module docs,
//! §Admission concurrency).
//! Division of labor with the
//! [`pool`]: the pool fans out *finite experiment batches* and routes
//! PJRT-bound jobs to the leader; the sharded server runs *open-ended
//! request streams* and sidesteps the leader bottleneck by giving every
//! worker its own thread-confined executor.
//!
//! Serving the synthetic mix in three lines:
//!
//! ```
//! use cachebound::coordinator::server::{Request, ServeConfig, ShardedServer, SyntheticExecutor};
//!
//! let mut srv = ShardedServer::start(ServeConfig::new(2), |_| Ok(SyntheticExecutor::new()));
//! srv.submit(Request { id: 0, artifact: "syn_gemm_n32".into() });
//! assert_eq!(srv.finish().metrics.completed, 1);
//! ```
//!
//! [`report`]: crate::report
//! [`Server`]: server::Server
//! [`ShardedServer`]: server::ShardedServer
//! [`CacheProfile`]: crate::telemetry::CacheProfile
//! [`PlacementPolicy::CacheAware`]: placement::PlacementPolicy::CacheAware

pub mod jobs;
pub mod loadgen;
pub mod pipeline;
pub mod placement;
pub mod pool;
pub mod results;
pub mod routing;
pub mod server;
pub mod shard;

pub use jobs::{Job, JobOutput, JobSpec};
pub use loadgen::ArrivalConfig;
pub use pipeline::{Pipeline, PipelineConfig};
pub use placement::{
    min_workers_interference_free, Placement, PlacementPolicy, RebalanceMode, WorkerPlan,
};
pub use pool::WorkerPool;
pub use results::{ResultKey, ResultStore, ResultValue};
pub use routing::{RouteReader, RouteTable, RouteWriter, Snapshot};
pub use server::{
    AdmissionHandle, AdmissionMode, AdmissionOutcome, BatchPolicy, Exec, Executor, Metrics,
    MigrationRecord, PjrtExecutor, PrepRecord, PrepSource, Request, Response, ServeConfig,
    ServeOutcome, Server, ShardedServer, SyntheticExecutor, TierPolicy, WorkerPressure,
};
pub use shard::{shard_for, LatencyHistogram, ShardMetrics};
