//! The L3 coordinator: experiment orchestration.
//!
//! The paper's methodology is a large grid of measurements (two boards ×
//! {GEMM sweep, 10 conv layers} × {f32, int8, 8 bit-serial variants} ×
//! {naive, tuned, blas} plus tuning runs).  The coordinator turns that grid
//! into [`jobs`], runs CPU-pure jobs on a [`pool`] of worker threads
//! (simulator evaluations, native-operator timings, tuning), keeps
//! PJRT-bound jobs on the leader thread (the `xla` client is not `Send`),
//! and collects everything into a [`results`] store that the [`report`]
//! layer renders into the paper's tables and figures.

pub mod jobs;
pub mod pipeline;
pub mod pool;
pub mod results;
pub mod server;

pub use jobs::{Job, JobOutput, JobSpec};
pub use pipeline::{Pipeline, PipelineConfig};
pub use pool::WorkerPool;
pub use results::{ResultKey, ResultStore, ResultValue};
pub use server::{BatchPolicy, Request, Response, Server};
