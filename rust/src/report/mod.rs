//! Report generation: every table and figure of the paper.
//!
//! * [`paper`] — the published reference numbers (Tables I–V rows and the
//!   qualitative expectations of the figures) for side-by-side columns.
//! * [`tables`] — Tables I/II (bandwidths) and IV/V (GEMM GFLOP/s).
//! * [`figures`] — Figs 1–9 data series as CSV + markdown summaries.
//!
//! Every renderer writes markdown to stdout-friendly strings and CSV rows
//! under `results/`, and returns the data so tests can assert the *shape*
//! (who wins, crossovers) matches the paper.

pub mod figures;
pub mod paper;
pub mod tables;

pub use figures::*;
pub use tables::*;
