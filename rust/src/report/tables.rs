//! Table renderers: Tables I/II (bandwidths) and IV/V (GEMM performance).

use anyhow::Result;

use crate::coordinator::pipeline::{default_tuned_schedule, Pipeline};
use crate::hw::{profile_by_name, MemLevel, ProfileSpec};
use crate::membench::bandwidth::BwPoint;
use crate::operators::gemm::GemmSchedule;
use crate::operators::workloads::gemm_macs;
use crate::util::csv::Csv;
use crate::util::table::{fmt_gflops, fmt_mibs, Align, Table};

use super::paper;

/// Render Table I or II: calibrated profile numbers, paper reference, and
/// (optionally) host-measured points from the membench sweep.
pub fn bandwidth_table(profile: &ProfileSpec, host: Option<&[BwPoint]>) -> (Table, Csv) {
    let cpu = &profile.cpu;
    let idx = match cpu.name.as_str() {
        "cortex-a53" => "I",
        "cortex-a72" => "II",
        _ => "I'",
    };
    let mut t = Table::new(
        format!("Table {idx} — memory bandwidth, {} ({})", cpu.name, cpu.soc),
        &[
            "Memory",
            "Block",
            "Read MiB/s",
            "Write MiB/s",
            "Paper read",
            "Paper write",
            "Host read",
            "Host write",
        ],
    )
    .align(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    let mut csv = Csv::new(&[
        "level", "block_bytes", "read_mibs", "write_mibs", "paper_read_mibs", "paper_write_mibs",
        "host_read_mibs", "host_write_mibs",
    ]);

    let paper_rows = paper::bandwidth_table(&cpu.name);
    let rows = [
        (MemLevel::Ram, "16 MB", 16 << 20),
        (MemLevel::L2, "256 KB", 256 << 10),
        (MemLevel::L1, "4 KB", 4 << 10),
    ];
    for (level, label, block) in rows {
        let read = cpu.read_bw_bytes(level);
        let write = cpu.write_bw_bytes(level);
        let (pr, pw) = paper_rows
            .iter()
            .find(|(l, _, _, _)| *l == level.name())
            .map(|(_, _, r, w)| (*r, *w))
            .unwrap_or((f64::NAN, f64::NAN));
        let host_pt = host.and_then(|pts| pts.iter().find(|p| p.block_bytes == block));
        let (hr, hw) = host_pt
            .map(|p| (fmt_mibs(p.read_bw), fmt_mibs(p.write_bw)))
            .unwrap_or(("-".into(), "-".into()));
        t.row(vec![
            level.name().into(),
            label.into(),
            fmt_mibs(read),
            fmt_mibs(write),
            format!("{pr:.0}"),
            format!("{pw:.0}"),
            hr.clone(),
            hw.clone(),
        ]);
        csv.row(vec![
            level.name().into(),
            block.to_string(),
            fmt_mibs(read),
            fmt_mibs(write),
            format!("{pr:.0}"),
            format!("{pw:.0}"),
            hr,
            hw,
        ]);
    }
    (t, csv)
}

/// One rendered row of Table IV/V (simulated + paper).
#[derive(Clone, Debug)]
pub struct GemmTableRow {
    /// Matrix size.
    pub n: usize,
    /// OpenBLAS reference GFLOP/s (paper column).
    pub blas_gflops: f64,
    /// Naive-schedule simulated GFLOP/s.
    pub naive_gflops: f64,
    /// Default-tuned-schedule simulated GFLOP/s.
    pub tuned_gflops: f64,
    /// Auto-tuner-schedule simulated GFLOP/s.
    pub tuned_autotuned_gflops: f64,
    /// Eq. (1) theoretical GFLOP/s.
    pub theoretical_peak: f64,
}

/// Render Table IV (A53) or V (A72) from pipeline results.
///
/// The "tuned" column comes from the auto-tuner's best config if a tuning
/// result is in the store, else the default tuned schedule.
pub fn gemm_table(
    pipeline: &mut Pipeline,
    profile_name: &str,
    sizes: &[usize],
) -> Result<(Table, Csv, Vec<GemmTableRow>)> {
    pipeline.gemm_table(profile_name, sizes)?;
    let profile = profile_by_name(profile_name)?;
    let cpu = &profile.cpu;
    let idx = if cpu.name == "cortex-a53" { "IV" } else { "V" };
    let peak = cpu.peak_flops(32) / 1e9;
    let paper_rows = paper::gemm_table(&cpu.name);

    let mut t = Table::new(
        format!("Table {idx} — GEMM float32, {} (simulated | paper)", cpu.name),
        &["N", "blas sim", "naive sim", "tuned sim", "autotuned sim",
          "blas paper", "naive paper", "tuned paper", "peak theor."],
    );
    let mut csv = Csv::new(&[
        "n", "blas_sim_gflops", "naive_sim_gflops", "tuned_sim_gflops", "autotuned_sim_gflops",
        "blas_paper", "naive_paper", "tuned_paper", "peak_theoretical",
    ]);

    let gf = |secs: f64, n: usize| 2.0 * gemm_macs(n) as f64 / secs / 1e9;
    let mut rows = Vec::new();
    for &n in sizes {
        let naive_key = {
            let s = GemmSchedule::naive();
            format!("sim_gemm/{}/n{}/b{}x{}x{}u{}/e32", cpu.name, n, s.bm, s.bn, s.bk, s.unroll)
        };
        let tuned_key = {
            let s = default_tuned_schedule();
            format!("sim_gemm/{}/n{}/b{}x{}x{}u{}/e32", cpu.name, n, s.bm, s.bn, s.bk, s.unroll)
        };
        let tune_key = format!(
            "tune_gemm/{}/n{}/t{}/gbttrue",
            cpu.name, n, pipeline.config.tune_trials
        );
        let naive_s = pipeline.store.seconds(&naive_key).unwrap_or(f64::NAN);
        let tuned_s = pipeline.store.seconds(&tuned_key).unwrap_or(f64::NAN);
        let auto_s = pipeline.store.seconds(&tune_key).unwrap_or(tuned_s);
        // blas = hand-blocked baseline ≈ default tuned running slightly
        // below the autotuned optimum (the paper's Fig 9 relationship);
        // modelled via the same simulator with the blocked kernel's
        // fixed 4x16x256 register schedule.
        let blas_s = {
            let s = GemmSchedule::new(4, 16, 256, 4);
            crate::sim::timing::simulate_gemm_time(cpu, n, n, n, s, 32).total_s
        };
        let row = GemmTableRow {
            n,
            blas_gflops: gf(blas_s, n),
            naive_gflops: gf(naive_s, n),
            tuned_gflops: gf(tuned_s, n),
            tuned_autotuned_gflops: gf(auto_s, n),
            theoretical_peak: peak,
        };
        let p = paper_rows.iter().find(|r| r.n == n);
        t.row(vec![
            n.to_string(),
            fmt_gflops(row.blas_gflops * 1e9),
            fmt_gflops(row.naive_gflops * 1e9),
            fmt_gflops(row.tuned_gflops * 1e9),
            fmt_gflops(row.tuned_autotuned_gflops * 1e9),
            p.map(|r| format!("{:.2}", r.openblas)).unwrap_or("-".into()),
            p.map(|r| format!("{:.2}", r.naive)).unwrap_or("-".into()),
            p.map(|r| format!("{:.2}", r.tuned)).unwrap_or("-".into()),
            format!("{peak:.1}"),
        ]);
        csv.row(vec![
            n.to_string(),
            format!("{:.3}", row.blas_gflops),
            format!("{:.3}", row.naive_gflops),
            format!("{:.3}", row.tuned_gflops),
            format!("{:.3}", row.tuned_autotuned_gflops),
            p.map(|r| r.openblas.to_string()).unwrap_or_default(),
            p.map(|r| r.naive.to_string()).unwrap_or_default(),
            p.map(|r| r.tuned.to_string()).unwrap_or_default(),
            format!("{peak:.1}"),
        ]);
        rows.push(row);
    }
    Ok((t, csv, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::PipelineConfig;

    #[test]
    fn bandwidth_table_renders_paper_numbers() {
        let p = profile_by_name("a53").unwrap();
        let (t, csv) = bandwidth_table(&p, None);
        let md = t.to_markdown();
        assert!(md.contains("14363"), "{md}");
        assert!(md.contains("2040"));
        assert_eq!(csv.len(), 3);
    }

    #[test]
    fn gemm_table_reproduces_paper_shape() {
        let mut pipeline = Pipeline::new(PipelineConfig {
            n_workers: 2,
            tune_trials: 16,
            skip_native: true,
            native_max_n: 0,
        });
        let (_t, _csv, rows) = gemm_table(&mut pipeline, "a53", &[128, 512]).unwrap();
        for r in &rows {
            // the paper's headline: tuned ≫ naive, both far below peak
            assert!(r.tuned_autotuned_gflops > r.naive_gflops, "N={}", r.n);
            assert!(r.tuned_autotuned_gflops < 0.5 * r.theoretical_peak, "N={}", r.n);
        }
    }
}
