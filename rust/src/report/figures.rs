//! Figure data generators: Figs 1–9.
//!
//! Each function runs the needed pipeline sweeps, assembles the exact data
//! series the paper plots, writes a CSV under `results/`, and returns the
//! series so callers (CLI, examples, tests) can check the qualitative
//! shape.  No plotting — CSVs re-plot with any tool.

use anyhow::Result;

use crate::analysis::bounds::{gemm_bounds, workload_bounds, BoundSet};
use crate::analysis::classify::correlate_bounds;
use crate::analysis::required_bw::{bitserial_d, required_bandwidth};
use crate::coordinator::pipeline::{
    bitserial_equiv_n, default_conv_schedule, default_tuned_schedule, Pipeline,
};
use crate::hw::{profile_by_name, CpuSpec, MemLevel};
use crate::operators::gemm::GemmSchedule;
use crate::operators::workloads::{self, gemm_macs};
use crate::util::csv::Csv;

fn sim_gemm_key(cpu: &CpuSpec, n: usize, s: GemmSchedule) -> String {
    format!("sim_gemm/{}/n{}/b{}x{}x{}u{}/e32", cpu.name, n, s.bm, s.bn, s.bk, s.unroll)
}

/// Fig 1: execution time vs matrix size with hardware bound lines.
pub struct Fig1 {
    /// Matrix sizes of the sweep.
    pub sizes: Vec<usize>,
    /// Tuned-schedule simulated times.
    pub tuned_s: Vec<f64>,
    /// Naive-schedule simulated times.
    pub naive_s: Vec<f64>,
    /// The four bound lines per size.
    pub bounds: Vec<BoundSet>,
    /// Which bound line best explains the tuned times (expected: L1-read).
    pub best_bound: String,
}

/// Build Fig 1 (time vs size + bound lines) for `profile`.
pub fn fig1(pipeline: &mut Pipeline, profile: &str) -> Result<(Fig1, Csv)> {
    let cpu = profile_by_name(profile)?.cpu;
    let sizes = workloads::gemm_sweep_sizes();
    pipeline.gemm_table(profile, &sizes)?;

    let mut csv = Csv::new(&[
        "n", "tuned_s", "naive_s", "compute_bound_s", "l1_read_s", "l2_read_s", "ram_read_s",
    ]);
    let mut tuned_s = Vec::new();
    let mut naive_s = Vec::new();
    let mut bounds = Vec::new();
    for &n in &sizes {
        let t = pipeline
            .store
            .seconds(&sim_gemm_key(&cpu, n, default_tuned_schedule()))
            .unwrap_or(f64::NAN);
        let nv = pipeline
            .store
            .seconds(&sim_gemm_key(&cpu, n, GemmSchedule::naive()))
            .unwrap_or(f64::NAN);
        let b = gemm_bounds(&cpu, n);
        csv.row(vec![
            n.to_string(),
            format!("{t:.6e}"),
            format!("{nv:.6e}"),
            format!("{:.6e}", b.compute_s),
            format!("{:.6e}", b.l1_read_s),
            format!("{:.6e}", b.l2_read_s),
            format!("{:.6e}", b.ram_read_s),
        ]);
        tuned_s.push(t);
        naive_s.push(nv);
        bounds.push(b);
    }
    // correlate only the N >= 100 regime like the paper
    let big: Vec<usize> = sizes
        .iter()
        .enumerate()
        .filter(|(_, &n)| n >= 100)
        .map(|(i, _)| i)
        .collect();
    let m: Vec<f64> = big.iter().map(|&i| tuned_s[i]).collect();
    let bs: Vec<BoundSet> = big.iter().map(|&i| bounds[i]).collect();
    let rep = correlate_bounds(&m, &bs);
    Ok((
        Fig1 {
            sizes,
            tuned_s,
            naive_s,
            bounds,
            best_bound: rep.best,
        },
        csv,
    ))
}

/// Fig 2/3: conv layer times (fig2) and sorted GFLOP/s (fig3) vs bounds.
pub struct Fig23 {
    /// Table III layer names, in order.
    pub layers: Vec<String>,
    /// Simulated time per layer.
    pub measured_s: Vec<f64>,
    /// The four bound lines per layer.
    pub bounds: Vec<BoundSet>,
    /// (layer, gflops) sorted descending — the Fig 3 ordering.
    pub sorted_perf: Vec<(String, f64)>,
}

/// Build Figs 2/3 (conv times + sorted GFLOP/s) for `profile`.
pub fn fig2_fig3(pipeline: &mut Pipeline, profile: &str) -> Result<(Fig23, Csv)> {
    let cpu = profile_by_name(profile)?.cpu;
    let layers = pipeline.conv_layers(profile)?;
    let s = default_conv_schedule();
    let mut csv = Csv::new(&[
        "layer", "macs", "measured_s", "compute_bound_s", "l1_read_s", "l2_read_s", "ram_read_s",
        "gflops",
    ]);
    let mut names = Vec::new();
    let mut measured = Vec::new();
    let mut bounds = Vec::new();
    let mut perf = Vec::new();
    for l in &layers {
        let key = format!("sim_conv/{}/{}/co{}r{}/e32", cpu.name, l.name, s.bco, s.brow);
        let t = pipeline.store.seconds(&key).unwrap_or(f64::NAN);
        let b = workload_bounds(&cpu, l.macs(), 4.0, 32);
        let gf = 2.0 * l.macs() as f64 / t / 1e9;
        csv.row(vec![
            l.name.into(),
            l.macs().to_string(),
            format!("{t:.6e}"),
            format!("{:.6e}", b.compute_s),
            format!("{:.6e}", b.l1_read_s),
            format!("{:.6e}", b.l2_read_s),
            format!("{:.6e}", b.ram_read_s),
            format!("{gf:.3}"),
        ]);
        names.push(l.name.to_string());
        measured.push(t);
        bounds.push(b);
        perf.push((l.name.to_string(), gf));
    }
    perf.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    Ok((
        Fig23 {
            layers: names,
            measured_s: measured,
            bounds,
            sorted_perf: perf,
        },
        csv,
    ))
}

/// Fig 4/5: bit-serial GEMM performance vs size + required bandwidth.
pub struct Fig45 {
    /// (bits, unipolar, size, gops, bw_req bytes/s)
    pub points: Vec<(usize, bool, usize, f64, f64)>,
    /// L1 read bandwidth, bytes/s (the Fig 5 reference line).
    pub l1_bw: f64,
}

/// Build Figs 4/5 (bit-serial perf + required bandwidth).
pub fn fig4_fig5(pipeline: &mut Pipeline, profile: &str) -> Result<(Fig45, Csv, Csv)> {
    let cpu = profile_by_name(profile)?.cpu;
    let sizes = vec![128, 256, 512, 1024, 2048, 4096, 8192];
    let bits = vec![1usize, 2, 4, 8];
    pipeline.bitserial_gemm_sweep(profile, &sizes, &bits)?;

    let mut csv4 = Csv::new(&["bits", "polarity", "n", "gops"]);
    let mut csv5 = Csv::new(&["bits", "polarity", "n", "bw_req_mibs", "l1_bw_mibs"]);
    let l1_bw = cpu.read_bw_bytes(MemLevel::L1);
    let mut points = Vec::new();
    for &b in &bits {
        for unipolar in [true, false] {
            for &n in &sizes {
                let key = format!(
                    "sim_bs/{}/n{}/a{}w{}/{}",
                    cpu.name,
                    n,
                    b,
                    b,
                    if unipolar { "uni" } else { "bi" }
                );
                let t = pipeline.store.seconds(&key).unwrap_or(f64::NAN);
                let gops = 2.0 * gemm_macs(n) as f64 / t / 1e9;
                let bw = required_bandwidth(gops * 1e9, bitserial_d(b as u32)).bw_req;
                csv4.row(vec![
                    b.to_string(),
                    polarity(unipolar).into(),
                    n.to_string(),
                    format!("{gops:.3}"),
                ]);
                csv5.row(vec![
                    b.to_string(),
                    polarity(unipolar).into(),
                    n.to_string(),
                    format!("{:.0}", bw / (1 << 20) as f64),
                    format!("{:.0}", l1_bw / (1 << 20) as f64),
                ]);
                points.push((b, unipolar, n, gops, bw));
            }
        }
    }
    Ok((Fig45 { points, l1_bw }, csv4, csv5))
}

fn polarity(unipolar: bool) -> &'static str {
    if unipolar {
        "unipolar"
    } else {
        "bipolar"
    }
}

/// Fig 6/7/8: quantized conv speedups, required bandwidth and GFLOP/s.
pub struct Fig678 {
    /// per layer: (name, f32_s, qnn8_s, map bits -> bitserial_s (unipolar))
    pub rows: Vec<QuantRow>,
    /// L1 read bandwidth, bytes/s (the Fig 7 reference line).
    pub l1_bw: f64,
}

#[derive(Clone, Debug)]
/// One layer's quantization outcomes (f32 vs int8 vs bit-serial).
pub struct QuantRow {
    /// Layer name.
    pub layer: String,
    /// Layer MACs (paper accounting).
    pub macs: u64,
    /// Float32 simulated time.
    pub f32_s: f64,
    /// Int8 QNN simulated time.
    pub qnn8_s: f64,
    /// (bits, unipolar seconds, bipolar seconds)
    pub bitserial_s: Vec<(usize, f64, f64)>,
}

impl QuantRow {
    /// Int8 speedup over float32.
    pub fn speedup_qnn(&self) -> f64 {
        self.f32_s / self.qnn8_s
    }

    /// Bit-serial speedup over float32 at `bits`, if swept.
    pub fn speedup_bits(&self, bits: usize, unipolar: bool) -> Option<f64> {
        self.bitserial_s
            .iter()
            .find(|(b, _, _)| *b == bits)
            .map(|(_, u, bi)| self.f32_s / if unipolar { *u } else { *bi })
    }
}

/// Build Figs 6/7/8 (quantized conv speedups/bw/GFLOP/s).
pub fn fig6_fig7_fig8(pipeline: &mut Pipeline, profile: &str) -> Result<(Fig678, Csv, Csv, Csv)> {
    let cpu = profile_by_name(profile)?.cpu;
    let bits = vec![1usize, 2, 4, 8];
    pipeline.conv_layers(profile)?;
    pipeline.quantized_conv(profile, &bits)?;

    let s = default_conv_schedule();
    let mut rows = Vec::new();
    for l in workloads::resnet18_layers() {
        let f32_key = format!("sim_conv/{}/{}/co{}r{}/e32", cpu.name, l.name, s.bco, s.brow);
        let qnn_key = format!("sim_conv/{}/{}/co{}r{}/e8", cpu.name, l.name, s.bco, s.brow);
        let f32_s = pipeline.store.seconds(&f32_key).unwrap_or(f64::NAN);
        let qnn8_s = pipeline.store.seconds(&qnn_key).unwrap_or(f64::NAN);
        let eq_n = bitserial_equiv_n(&l);
        // scale the equivalent-GEMM time to the layer's true MAC count
        let scale = l.macs() as f64 / (gemm_macs(eq_n) as f64);
        let mut bss = Vec::new();
        for &b in &bits {
            let uni_key = format!("sim_bs/{}/n{}/a{}w{}/uni", cpu.name, eq_n, b, b);
            let bi_key = format!("sim_bs/{}/n{}/a{}w{}/bi", cpu.name, eq_n, b, b);
            // NHWC small-image penalty (§V-C): packing efficiency collapses
            // when the spatial extent is small (C11-like layers)
            let nhwc_penalty = if l.ho() * l.wo() < 128 { 2.0 } else { 1.0 };
            let uni = pipeline.store.seconds(&uni_key).unwrap_or(f64::NAN) * scale * nhwc_penalty;
            let bi = pipeline.store.seconds(&bi_key).unwrap_or(f64::NAN) * scale * nhwc_penalty;
            bss.push((b, uni, bi));
        }
        rows.push(QuantRow {
            layer: l.name.to_string(),
            macs: l.macs(),
            f32_s,
            qnn8_s,
            bitserial_s: bss,
        });
    }

    let mut csv6 = Csv::new(&["layer", "qnn8_speedup", "bs1_uni", "bs2_uni", "bs4_uni", "bs8_uni"]);
    let mut csv7 = Csv::new(&["layer", "dtype", "bw_req_mibs", "l1_bw_mibs"]);
    let mut csv8 = Csv::new(&[
        "layer",
        "f32_gflops",
        "qnn8_gflops",
        "bs1_bi_gops",
        "bs2_bi_gops",
        "bs8_bi_gops",
    ]);
    let l1_bw = cpu.read_bw_bytes(MemLevel::L1);
    for r in &rows {
        csv6.row(vec![
            r.layer.clone(),
            format!("{:.2}", r.speedup_qnn()),
            format!("{:.2}", r.speedup_bits(1, true).unwrap_or(f64::NAN)),
            format!("{:.2}", r.speedup_bits(2, true).unwrap_or(f64::NAN)),
            format!("{:.2}", r.speedup_bits(4, true).unwrap_or(f64::NAN)),
            format!("{:.2}", r.speedup_bits(8, true).unwrap_or(f64::NAN)),
        ]);
        let flops = 2.0 * r.macs as f64;
        for (label, secs, d) in [
            ("f32", r.f32_s, 4.0),
            ("qnn8", r.qnn8_s, 1.0),
            (
                "bs2",
                r.bitserial_s
                    .iter()
                    .find(|(b, _, _)| *b == 2)
                    .map(|x| x.1)
                    .unwrap_or(f64::NAN),
                0.25,
            ),
        ] {
            let bw = required_bandwidth(flops / secs, d).bw_req;
            csv7.row(vec![
                r.layer.clone(),
                label.into(),
                format!("{:.0}", bw / (1 << 20) as f64),
                format!("{:.0}", l1_bw / (1 << 20) as f64),
            ]);
        }
        let gf = |secs: f64| flops / secs / 1e9;
        let bs = |bits: usize| {
            r.bitserial_s
                .iter()
                .find(|(b, _, _)| *b == bits)
                .map(|x| gf(x.2))
                .unwrap_or(f64::NAN)
        };
        csv8.row(vec![
            r.layer.clone(),
            format!("{:.2}", gf(r.f32_s)),
            format!("{:.2}", gf(r.qnn8_s)),
            format!("{:.2}", bs(1)),
            format!("{:.2}", bs(2)),
            format!("{:.2}", bs(8)),
        ]);
    }
    Ok((Fig678 { rows, l1_bw }, csv6, csv7, csv8))
}

/// MRC figure (telemetry subsystem, alongside Fig 1): predicted hit rate
/// versus cache capacity for one traced workload, with the profile's
/// L1/L2 sizes marked and predicted-vs-simulated classification.
pub struct FigMrc {
    /// "family/shape" of the traced workload.
    pub workload: String,
    /// `(capacity_bytes, predicted_hit_rate)` — the curve.
    pub points: Vec<(u64, f64)>,
    /// Profile L1 capacity (the first marked line).
    pub l1_bytes: u64,
    /// Profile L2 capacity (the second marked line).
    pub l2_bytes: u64,
    /// Predicted hit rates at the profile's L1/L2 geometry.
    pub l1_hit_rate: f64,
    /// Predicted L2 hit rate over the L1-miss stream.
    pub l2_hit_rate: f64,
    /// Working-set estimate (98% of peak hit rate).
    pub working_set_bytes: u64,
    /// Boundness class of the full-simulation time.
    pub sim_class: String,
    /// Boundness class of the MRC prediction.
    pub predicted_class: String,
}

/// Build the MRC figure for a tuned GEMM of size `n` on `profile`.
pub fn fig_mrc(profile: &str, n: usize) -> Result<(FigMrc, Csv)> {
    use crate::operators::workloads::BenchWorkload;
    use crate::telemetry::{trace_workload, TraceBudget};

    let cpu = profile_by_name(profile)?.cpu;
    let r = trace_workload(&cpu, &BenchWorkload::Gemm { n }, TraceBudget::default());
    let mut csv = Csv::new(&["capacity_kib", "hit_rate", "l1_kib", "l2_kib"]);
    for &(bytes, rate) in &r.mrc_points {
        csv.row(vec![
            format!("{:.2}", bytes as f64 / 1024.0),
            format!("{rate:.6}"),
            (cpu.l1.size_bytes / 1024).to_string(),
            (cpu.l2.size_bytes / 1024).to_string(),
        ]);
    }
    Ok((
        FigMrc {
            workload: r.key(),
            points: r.mrc_points.clone(),
            l1_bytes: cpu.l1.size_bytes as u64,
            l2_bytes: cpu.l2.size_bytes as u64,
            l1_hit_rate: r.prediction.rates.l1_hit_rate,
            l2_hit_rate: r.prediction.rates.l2_hit_rate,
            working_set_bytes: r.working_set_bytes,
            sim_class: r.sim_class.clone(),
            predicted_class: r.predicted_class.clone(),
        },
        csv,
    ))
}

/// Fig 9: GEMM GFLOP/s over size for naive/tuned/blas (the appendix plot).
pub struct Fig9 {
    /// Matrix sizes of the sweep.
    pub sizes: Vec<usize>,
    /// Tuned GFLOP/s per size.
    pub tuned_gflops: Vec<f64>,
    /// Naive GFLOP/s per size.
    pub naive_gflops: Vec<f64>,
    /// OpenBLAS reference GFLOP/s (paper column).
    pub blas_gflops: Vec<f64>,
    /// Eq. (1) theoretical peak.
    pub peak_gflops: f64,
}

/// Build Fig 9 (GFLOP/s over size, three implementations).
pub fn fig9(pipeline: &mut Pipeline, profile: &str) -> Result<(Fig9, Csv)> {
    let cpu = profile_by_name(profile)?.cpu;
    let sizes = workloads::gemm_sweep_sizes();
    pipeline.gemm_table(profile, &sizes)?;
    let mut csv = Csv::new(&["n", "tuned_gflops", "naive_gflops", "blas_gflops", "peak_gflops"]);
    let gf = |secs: f64, n: usize| 2.0 * gemm_macs(n) as f64 / secs / 1e9;
    let peak = cpu.peak_flops(32) / 1e9;
    let mut tuned = Vec::new();
    let mut naive = Vec::new();
    let mut blas = Vec::new();
    for &n in &sizes {
        let t = pipeline
            .store
            .seconds(&sim_gemm_key(&cpu, n, default_tuned_schedule()))
            .map(|s| gf(s, n))
            .unwrap_or(f64::NAN);
        let nv = pipeline
            .store
            .seconds(&sim_gemm_key(&cpu, n, GemmSchedule::naive()))
            .map(|s| gf(s, n))
            .unwrap_or(f64::NAN);
        let blas_schedule = GemmSchedule::new(4, 16, 256, 4);
        let bl = gf(
            crate::sim::timing::simulate_gemm_time(&cpu, n, n, n, blas_schedule, 32).total_s,
            n,
        );
        csv.row(vec![
            n.to_string(),
            format!("{t:.3}"),
            format!("{nv:.3}"),
            format!("{bl:.3}"),
            format!("{peak:.1}"),
        ]);
        tuned.push(t);
        naive.push(nv);
        blas.push(bl);
    }
    Ok((
        Fig9 {
            sizes,
            tuned_gflops: tuned,
            naive_gflops: naive,
            blas_gflops: blas,
            peak_gflops: peak,
        },
        csv,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::PipelineConfig;

    fn quick_pipeline() -> Pipeline {
        Pipeline::new(PipelineConfig {
            n_workers: 2,
            tune_trials: 8,
            skip_native: true,
            native_max_n: 0,
        })
    }

    #[test]
    fn fig1_attributes_tuned_gemm_to_l1() {
        let mut p = quick_pipeline();
        let (f, csv) = fig1(&mut p, "a53").unwrap();
        assert_eq!(f.best_bound, "L1-read", "the paper's central claim");
        assert_eq!(csv.len(), f.sizes.len());
    }

    #[test]
    fn fig3_3x3_layers_lead_the_sorted_order() {
        let mut p = quick_pipeline();
        let (f, _) = fig2_fig3(&mut p, "a53").unwrap();
        // the top of the sorted perf list must be 3x3 layers (C2/C5/C8/C11
        // class), the bottom must contain 1x1 strided layers (C4/C7/C10)
        let top = &f.sorted_perf[0].0;
        let bottom = &f.sorted_perf.last().unwrap().0;
        assert!(["C2", "C5", "C8", "C11"].contains(&top.as_str()), "top {top}");
        assert!(["C4", "C7", "C10"].contains(&bottom.as_str()), "bottom {bottom}");
    }

    #[test]
    fn fig4_lower_bits_peak_later_and_higher() {
        let mut p = quick_pipeline();
        let (f, _, _) = fig4_fig5(&mut p, "a72").unwrap();
        let series = |bits: usize| -> Vec<(usize, f64)> {
            f.points
                .iter()
                .filter(|(b, uni, _, _, _)| *b == bits && !*uni)
                .map(|(_, _, n, g, _)| (*n, *g))
                .collect()
        };
        let s1 = series(1);
        let s8 = series(8);
        // 1-bit at its largest size beats 8-bit anywhere
        let max1 = s1.iter().map(|x| x.1).fold(0.0, f64::max);
        let max8 = s8.iter().map(|x| x.1).fold(0.0, f64::max);
        assert!(max1 > 2.0 * max8, "1-bit {max1} vs 8-bit {max8}");
        // 1-bit grows from 128 to 4096 (peaks later)
        assert!(s1.last().unwrap().1 > s1.first().unwrap().1 * 1.5);
    }

    #[test]
    fn fig5_required_bw_below_l1() {
        let mut p = quick_pipeline();
        let (f, _, _) = fig4_fig5(&mut p, "a72").unwrap();
        // paper: all bit-serial required bandwidths stay below the L1 line
        for (bits, _, n, _, bw) in &f.points {
            assert!(
                *bw < f.l1_bw * 1.05,
                "bits={bits} n={n}: bw {:.2e} vs L1 {:.2e}",
                bw,
                f.l1_bw
            );
        }
    }

    #[test]
    fn fig6_low_bit_speedups_best_and_c11_weak() {
        let mut p = quick_pipeline();
        let (f, ..) = fig6_fig7_fig8(&mut p, "a72").unwrap();
        for r in &f.rows {
            let s1 = r.speedup_bits(1, true).unwrap();
            let s8 = r.speedup_bits(8, true).unwrap();
            assert!(s1 > s8, "{}: 1-bit {s1} vs 8-bit {s8}", r.layer);
        }
        // C11 (7x7 image) must show a weaker bit-serial speedup than C2
        let c2 = f.rows.iter().find(|r| r.layer == "C2").unwrap();
        let c11 = f.rows.iter().find(|r| r.layer == "C11").unwrap();
        assert!(
            c2.speedup_bits(2, true).unwrap() > c11.speedup_bits(2, true).unwrap(),
            "NHWC small-image penalty"
        );
    }

    #[test]
    fn fig_mrc_curve_is_monotone_and_classified() {
        let (f, csv) = fig_mrc("a53", 96).unwrap();
        assert_eq!(f.workload, "gemm/n96");
        assert_eq!(csv.len(), f.points.len());
        for w in f.points.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12, "MRC must be monotone");
        }
        assert!(f.l1_hit_rate > 0.0 && f.l1_hit_rate <= 1.0);
        assert!(!f.predicted_class.is_empty());
        assert!(!f.sim_class.is_empty());
        assert!(f.working_set_bytes > 0);
    }

    #[test]
    fn fig9_tuned_above_naive_everywhere() {
        let mut p = quick_pipeline();
        let (f, _) = fig9(&mut p, "a72").unwrap();
        for i in 0..f.sizes.len() {
            assert!(f.tuned_gflops[i] > f.naive_gflops[i], "n={}", f.sizes[i]);
            assert!(f.tuned_gflops[i] < f.peak_gflops);
        }
    }
}
