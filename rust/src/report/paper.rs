//! Published reference numbers from the paper, verbatim.
//!
//! Used for the "paper" column of every report and asserted against in
//! EXPERIMENTS.md.  Units: bandwidths MiB/s, performance GFLOP/s.

/// Table I / II: (level, block size label, read MiB/s, write MiB/s).
pub fn bandwidth_table(profile: &str) -> Vec<(&'static str, &'static str, f64, f64)> {
    match profile {
        "cortex-a53" => vec![
            ("RAM", "16 MB", 2040.0, 1600.0),
            ("L2", "256 KB", 7039.0, 3467.0),
            ("L1", "4 KB", 14363.0, 23703.0),
        ],
        "cortex-a72" => vec![
            ("RAM", "16 MB", 3661.0, 2984.0),
            ("L2", "256 KB", 12934.0, 7407.0),
            ("L1", "4 KB", 45733.0, 30423.0),
        ],
        _ => Vec::new(),
    }
}

/// One row of Table IV/V: (N, openBLAS, naive, tuned, measured peak, theoretical peak).
pub struct GemmRow {
    /// Matrix size.
    pub n: usize,
    /// OpenBLAS GFLOP/s.
    pub openblas: f64,
    /// TVM-naive GFLOP/s.
    pub naive: f64,
    /// TVM-tuned GFLOP/s.
    pub tuned: f64,
    /// arm-peak measured GFLOP/s.
    pub measured_peak: f64,
    /// Eq. (1) theoretical GFLOP/s.
    pub theoretical_peak: f64,
}

/// Table IV (Cortex-A53) in GFLOP/s.
pub fn gemm_table_a53() -> Vec<GemmRow> {
    [
        (32, 1.07, 1.16, 4.43, 16.49),
        (128, 4.96, 2.07, 6.58, 37.38),
        (256, 4.71, 1.83, 6.93, 38.04),
        (512, 4.87, 0.60, 5.06, 38.15),
        (1024, 4.99, 0.54, 5.01, 38.18),
    ]
    .into_iter()
    .map(|(n, blas, naive, tuned, peak)| GemmRow {
        n,
        openblas: blas,
        naive,
        tuned,
        measured_peak: peak,
        theoretical_peak: 38.4,
    })
    .collect()
}

/// Table V (Cortex-A72) in GFLOP/s.
pub fn gemm_table_a72() -> Vec<GemmRow> {
    [
        (32, 3.01, 3.59, 9.20, 21.92),
        (128, 14.22, 4.68, 16.72, 47.11),
        (256, 14.86, 4.77, 17.24, 47.83),
        (512, 14.33, 2.04, 17.99, 47.92),
        (1024, 14.98, 1.36, 15.75, 47.93),
    ]
    .into_iter()
    .map(|(n, blas, naive, tuned, peak)| GemmRow {
        n,
        openblas: blas,
        naive,
        tuned,
        measured_peak: peak,
        theoretical_peak: 48.0,
    })
    .collect()
}

/// Table IV or V by profile name (empty for unknown profiles).
pub fn gemm_table(profile: &str) -> Vec<GemmRow> {
    match profile {
        "cortex-a53" => gemm_table_a53(),
        "cortex-a72" => gemm_table_a72(),
        _ => Vec::new(),
    }
}

/// The paper's qualitative figure expectations, used in report footers and
/// asserted by the integration tests.
pub mod expectations {
    /// Fig 1: tuned GEMM times track the L1-read line for N >= 100.
    pub const FIG1: &str = "measured time correlates with L1-cache-read bound (N >= 100)";
    /// Fig 3: 3x3 convs reach higher GFLOP/s than 1x1; all far below peak.
    pub const FIG3: &str = "3x3 layers outperform 1x1 per-FLOP; all layers cache-bound";
    /// Fig 4: lower bit widths need larger matrices to peak.
    pub const FIG4: &str = "lower bit widths reach peak only at larger N";
    /// Fig 5/7: required bandwidth stays below L1 read bandwidth.
    pub const FIG5: &str = "required bandwidth below L1 read bw: not cache-bound";
    /// Fig 6: quantized speedups over f32; low-bit best; C11 bit-serial poor.
    pub const FIG6: &str = "1-2 bit best speedups; NHWC bit-serial weak on small images (C11)";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_have_five_rows_and_peaks_match_eq1() {
        let a53 = gemm_table_a53();
        assert_eq!(a53.len(), 5);
        assert!(a53.iter().all(|r| r.theoretical_peak == 38.4));
        let a72 = gemm_table_a72();
        assert!(a72.iter().all(|r| r.theoretical_peak == 48.0));
    }

    #[test]
    fn paper_shape_tuned_beats_blas_beats_naive_midrange() {
        for t in [gemm_table_a53(), gemm_table_a72()] {
            for r in t.iter().filter(|r| r.n >= 128) {
                assert!(r.tuned > r.openblas, "N={}", r.n);
                assert!(r.openblas > r.naive, "N={}", r.n);
            }
        }
    }

    #[test]
    fn bandwidth_rows_sorted_fastest_last() {
        for p in ["cortex-a53", "cortex-a72"] {
            let rows = bandwidth_table(p);
            assert_eq!(rows.len(), 3);
            assert!(rows[2].2 > rows[1].2 && rows[1].2 > rows[0].2);
        }
    }
}
