//! Structured cache events — the vocabulary of the telemetry subsystem.
//!
//! One [`CacheEvent`] is emitted per observable cache action: an access
//! resolving to a hit or a miss at a level, an eviction of a resident line,
//! and a dirty writeback travelling to the level below.  Events carry the
//! *operand tag* ([`Operand`]) the trace generator assigned, which is what
//! turns a flat address stream into per-operand reuse-distance profiles —
//! the "is it A-panel reuse or B-stream reuse that thrashes L1?" question
//! the aggregate hit/miss counters of `sim::CacheStats` cannot answer.

use crate::hw::MemLevel;
use crate::sim::cache::AccessKind;

/// Which logical operand of the operator an access belongs to.
///
/// The convention across the replay generators (`sim::trace`):
/// `A` = first input (GEMM A panel / conv activations / bit-serial
/// activation planes), `B` = second input (GEMM B panel / conv weights /
/// bit-serial weight planes), `C` = output accumulator.  `Other` tags
/// untraced traffic (the default of the sink-free `access` path).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Operand {
    /// First input (GEMM A panel / activations).
    A,
    /// Second input (GEMM B panel / weights).
    B,
    /// Output accumulator.
    C,
    /// Untraced traffic (the sink-free `access` path).
    Other,
}

impl Operand {
    /// Every operand, in [`Operand::index`] order.
    pub const ALL: [Operand; 4] = [Operand::A, Operand::B, Operand::C, Operand::Other];

    /// Display name ("A", "B", "C", "other").
    pub fn name(self) -> &'static str {
        match self {
            Operand::A => "A",
            Operand::B => "B",
            Operand::C => "C",
            Operand::Other => "other",
        }
    }

    /// Dense index into per-operand tables (matches [`Operand::ALL`]).
    pub fn index(self) -> usize {
        match self {
            Operand::A => 0,
            Operand::B => 1,
            Operand::C => 2,
            Operand::Other => 3,
        }
    }
}

/// What happened.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// The access found its line resident at `level`.
    Hit,
    /// The access missed at `level`; a fill from below follows.
    Miss,
    /// A resident line was displaced to make room (addr = victim line).
    Eviction,
    /// A dirty victim's line is written to the level below (addr = victim).
    Writeback,
}

impl EventKind {
    /// Display name ("hit", "miss", "eviction", "writeback").
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Hit => "hit",
            EventKind::Miss => "miss",
            EventKind::Eviction => "eviction",
            EventKind::Writeback => "writeback",
        }
    }
}

/// One structured cache event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheEvent {
    /// Which cache level produced the event.
    pub level: MemLevel,
    /// What happened (hit/miss/eviction/writeback).
    pub kind: EventKind,
    /// Read/write flavour of the triggering access (for `Eviction` and
    /// `Writeback` this is the access that *caused* the displacement).
    pub access: AccessKind,
    /// Element address for `Hit`/`Miss`; victim *line* base address for
    /// `Eviction`/`Writeback`.
    pub addr: u64,
    /// Bytes requested by the access (element width for L1 accesses, line
    /// width for fills and writebacks).
    pub bytes: u32,
    /// Operand stream the event belongs to.
    pub operand: Operand,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operand_indices_match_all_order() {
        for (i, op) in Operand::ALL.iter().enumerate() {
            assert_eq!(op.index(), i);
        }
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Operand::B.name(), "B");
        assert_eq!(EventKind::Writeback.name(), "writeback");
    }
}
