//! Event sinks — where the simulator's structured cache events go.
//!
//! [`EventSink`] is the pluggable receiving end of
//! `sim::SetAssocCache::access_traced` / `sim::Hierarchy::access_traced`.
//! The contract that keeps the existing hot path free: sinks are passed by
//! generic parameter (monomorphized, no `dyn` dispatch, no allocation), and
//! [`NullSink`]'s `record` is an empty `#[inline]` body, so the untraced
//! `access` entry points compile to exactly the pre-telemetry code.

use super::event::{CacheEvent, EventKind};
use crate::hw::MemLevel;

/// Receiver of structured cache events.
pub trait EventSink {
    /// Consume one event (called inline on the traced access path).
    fn record(&mut self, ev: &CacheEvent);
}

/// The no-op sink: the default of every untraced `access` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    #[inline(always)]
    fn record(&mut self, _ev: &CacheEvent) {}
}

/// Per-(level, kind) event counters — cheap structural validation that the
/// emitting side and `CacheStats` agree, and the backing of the CLI's event
/// summary table.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EventCounts {
    /// Hit events.
    pub hits: u64,
    /// Miss events.
    pub misses: u64,
    /// Eviction events (victim displaced).
    pub evictions: u64,
    /// Dirty-victim writeback events.
    pub writebacks: u64,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
/// Sink that tallies events per level (L1, and L2 with RAM folded in).
pub struct CountingSink {
    /// L1 event counters.
    pub l1: EventCounts,
    /// L2 event counters (RAM events fold in here).
    pub l2: EventCounts,
}

impl CountingSink {
    /// All counters at zero.
    pub fn new() -> Self {
        Self::default()
    }

    fn level_mut(&mut self, level: MemLevel) -> &mut EventCounts {
        match level {
            MemLevel::L1 => &mut self.l1,
            // RAM emits no events; L2 misses imply the RAM transfer.
            MemLevel::L2 | MemLevel::Ram => &mut self.l2,
        }
    }
}

impl EventSink for CountingSink {
    fn record(&mut self, ev: &CacheEvent) {
        let c = self.level_mut(ev.level);
        match ev.kind {
            EventKind::Hit => c.hits += 1,
            EventKind::Miss => c.misses += 1,
            EventKind::Eviction => c.evictions += 1,
            EventKind::Writeback => c.writebacks += 1,
        }
    }
}

/// Bounded in-memory event capture, for tests and event-trace dumps.  Once
/// `capacity` events are stored further events are counted but dropped, so
/// a long replay cannot exhaust memory.
#[derive(Clone, Debug)]
pub struct VecSink {
    /// Captured events, in emission order.
    pub events: Vec<CacheEvent>,
    /// Events dropped once `capacity` was reached.
    pub dropped: u64,
    capacity: usize,
}

impl VecSink {
    /// Capture up to `capacity` events, then count drops.
    pub fn new(capacity: usize) -> Self {
        VecSink {
            events: Vec::new(),
            dropped: 0,
            capacity,
        }
    }
}

impl EventSink for VecSink {
    fn record(&mut self, ev: &CacheEvent) {
        if self.events.len() < self.capacity {
            self.events.push(*ev);
        } else {
            self.dropped += 1;
        }
    }
}

/// Fan one event stream out to two sinks (e.g. a reuse analyzer plus a
/// counting sink) without boxing.
pub struct TeeSink<'a, S1: EventSink, S2: EventSink> {
    /// First receiver.
    pub first: &'a mut S1,
    /// Second receiver.
    pub second: &'a mut S2,
}

impl<'a, S1: EventSink, S2: EventSink> TeeSink<'a, S1, S2> {
    /// Tee into `first` and `second`.
    pub fn new(first: &'a mut S1, second: &'a mut S2) -> Self {
        TeeSink { first, second }
    }
}

impl<'a, S1: EventSink, S2: EventSink> EventSink for TeeSink<'a, S1, S2> {
    fn record(&mut self, ev: &CacheEvent) {
        self.first.record(ev);
        self.second.record(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::AccessKind;
    use crate::telemetry::event::Operand;

    fn ev(level: MemLevel, kind: EventKind) -> CacheEvent {
        CacheEvent {
            level,
            kind,
            access: AccessKind::Read,
            addr: 0x40,
            bytes: 4,
            operand: Operand::A,
        }
    }

    #[test]
    fn counting_sink_buckets_by_level_and_kind() {
        let mut s = CountingSink::new();
        s.record(&ev(MemLevel::L1, EventKind::Hit));
        s.record(&ev(MemLevel::L1, EventKind::Miss));
        s.record(&ev(MemLevel::L2, EventKind::Writeback));
        assert_eq!(s.l1.hits, 1);
        assert_eq!(s.l1.misses, 1);
        assert_eq!(s.l2.writebacks, 1);
        assert_eq!(s.l2.hits, 0);
    }

    #[test]
    fn vec_sink_bounds_memory() {
        let mut s = VecSink::new(2);
        for _ in 0..5 {
            s.record(&ev(MemLevel::L1, EventKind::Hit));
        }
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.dropped, 3);
    }

    #[test]
    fn tee_sink_duplicates() {
        let mut a = CountingSink::new();
        let mut b = VecSink::new(8);
        let mut tee = TeeSink::new(&mut a, &mut b);
        tee.record(&ev(MemLevel::L1, EventKind::Eviction));
        assert_eq!(a.l1.evictions, 1);
        assert_eq!(b.events.len(), 1);
    }
}
