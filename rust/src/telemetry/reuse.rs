//! Streaming reuse-distance (stack-distance) analysis over cache lines.
//!
//! For every access, the *stack distance* is the number of **distinct other
//! cache lines** touched since the previous access to the same line (cold
//! first touches have infinite distance).  Under fully-associative LRU the
//! access hits a cache of capacity `C` lines **iff** its distance is
//! `< C` — which is what lets one traced replay predict hit rates for
//! *every* cache size at once (`misscurve`), instead of re-simulating per
//! configuration.
//!
//! The analyzer is streaming and bounded-memory:
//!
//! * distances are computed with a Fenwick tree over access-time slots
//!   (the classic O(log n) stack-distance algorithm); the slot window is
//!   periodically *compacted* down to the set of live lines, so memory is
//!   O(distinct lines), not O(trace length);
//! * histograms store exact counts only up to [`MAX_EXACT_DISTANCE`]
//!   (2^18 lines = 16 MiB of 64-byte lines — beyond every cache this
//!   framework models); farther reuses fold into a single `far` bucket
//!   that any realistic capacity scores as a miss.
//!
//! Histograms are kept **per operand** (A/B/C tags from `sim::trace`) so a
//! schedule's pathology is attributable: a B-stream whose distance
//! distribution sits just beyond the L1 capacity is the paper's
//! L1-cache-bound GEMM in one picture.

use std::collections::HashMap;

use crate::hw::MemLevel;
use crate::sim::cache::AccessKind;

use super::event::{CacheEvent, EventKind, Operand};
use super::sink::EventSink;

/// Largest stack distance recorded exactly (in lines).  16 MiB of 64 B
/// lines — larger than any L2 this framework models, so folding farther
/// distances into one bucket loses nothing for hit-rate prediction.
pub const MAX_EXACT_DISTANCE: usize = 1 << 18;

/// A reuse-distance histogram (distances in cache lines).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReuseHistogram {
    /// `counts[d]` = accesses with stack distance exactly `d`; grown on
    /// demand, capped at [`MAX_EXACT_DISTANCE`] entries.
    counts: Vec<u64>,
    /// Finite distances `>= MAX_EXACT_DISTANCE`.
    far: u64,
    /// Cold first touches (infinite distance).
    cold: u64,
}

impl ReuseHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one access; `None` = cold first touch.
    pub fn record(&mut self, distance: Option<u64>) {
        match distance {
            Some(d) if (d as usize) < MAX_EXACT_DISTANCE => {
                let d = d as usize;
                if d >= self.counts.len() {
                    self.counts.resize(d + 1, 0);
                }
                self.counts[d] += 1;
            }
            Some(_) => self.far += 1,
            None => self.cold += 1,
        }
    }

    /// Total recorded accesses (exact + far + cold).
    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.far + self.cold
    }

    /// Cold first touches (infinite distance).
    pub fn cold(&self) -> u64 {
        self.cold
    }

    /// Accesses with distance `< capacity_lines` — the fully-associative
    /// LRU hits of a cache of that many lines.  Capacities beyond
    /// [`MAX_EXACT_DISTANCE`] are clamped (the `far` bucket stays a miss).
    pub fn hits_within(&self, capacity_lines: usize) -> u64 {
        let cap = capacity_lines.min(self.counts.len());
        self.counts[..cap].iter().sum()
    }

    /// Predicted hit rate at `capacity_lines` (0 when the histogram is
    /// empty).
    pub fn hit_rate(&self, capacity_lines: usize) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        self.hits_within(capacity_lines) as f64 / total as f64
    }

    /// Smallest distance `d` such that at least `p`% of accesses have
    /// distance `<= d`; `None` when that mass is only reached through the
    /// far/cold buckets.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        let total = self.total();
        if total == 0 {
            return None;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (d, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(d as u64);
            }
        }
        None
    }

    /// Log₂-bucketed view `(lo, hi, count)` with `hi` exclusive, plus the
    /// far and cold buckets — the compact rendering for CLI/JSON output.
    pub fn log_buckets(&self) -> Vec<DistanceBucket> {
        let mut out = Vec::new();
        let mut lo = 0usize;
        let mut hi = 1usize;
        while lo < self.counts.len() {
            let end = hi.min(self.counts.len());
            let count: u64 = self.counts[lo..end].iter().sum();
            if count > 0 {
                out.push(DistanceBucket {
                    lo: lo as u64,
                    hi: hi as u64,
                    count,
                    kind: BucketKind::Exact,
                });
            }
            lo = hi;
            hi *= 2;
        }
        if self.far > 0 {
            out.push(DistanceBucket {
                lo: MAX_EXACT_DISTANCE as u64,
                hi: u64::MAX,
                count: self.far,
                kind: BucketKind::Far,
            });
        }
        if self.cold > 0 {
            out.push(DistanceBucket {
                lo: u64::MAX,
                hi: u64::MAX,
                count: self.cold,
                kind: BucketKind::Cold,
            });
        }
        out
    }

    /// Accumulate another histogram into this one.
    pub fn merge(&mut self, other: &ReuseHistogram) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (d, &c) in other.counts.iter().enumerate() {
            self.counts[d] += c;
        }
        self.far += other.far;
        self.cold += other.cold;
    }
}

/// One log-bucket row of a histogram rendering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DistanceBucket {
    /// Inclusive lower distance bound (lines).
    pub lo: u64,
    /// Exclusive upper bound (`u64::MAX` for far/cold).
    pub hi: u64,
    /// Accesses falling in this bucket.
    pub count: u64,
    /// Exact-range, far-overflow or cold bucket.
    pub kind: BucketKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// What a [`DistanceBucket`] row represents.
pub enum BucketKind {
    /// Distances counted exactly (`lo..hi` lines).
    Exact,
    /// Finite distances beyond [`MAX_EXACT_DISTANCE`].
    Far,
    /// First touches (no previous access to the line).
    Cold,
}

/// Fenwick (binary indexed) tree of slot-occupancy counts.
#[derive(Clone, Debug)]
struct Fenwick {
    tree: Vec<u32>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Fenwick { tree: vec![0; n + 1] }
    }

    fn len(&self) -> usize {
        self.tree.len() - 1
    }

    /// Add `delta` at slot `i` (0-based).
    fn add(&mut self, i: usize, delta: i32) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] = (self.tree[i] as i64 + delta as i64) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of slots `[0, i)` (0-based, `i` exclusive).
    fn prefix(&self, i: usize) -> u64 {
        let mut i = i.min(self.len());
        let mut s = 0u64;
        while i > 0 {
            s += self.tree[i] as u64;
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Minimum slot-window size (keeps compaction amortized for tiny traces).
const MIN_SLOTS: usize = 1 << 12;

/// Per-set LRU stack depth kept by [`SetHistograms`].  Within-set stack
/// distances only matter up to the associativity (at most 16 ways in the
/// modelled parts); anything deeper is a guaranteed miss, so re-accesses
/// of truncated lines fold into the `far` bucket.  64 keeps the per-set
/// linear scan cache-resident while leaving headroom for property tests
/// that probe distances well past any real associativity.
pub const SET_STACK_DEPTH: usize = 64;

/// Per-set stack-distance histograms: the set-associative refinement of
/// the fully-associative analysis.
///
/// Each set of a `W`-way set-associative LRU cache behaves as an
/// *independent fully-associative LRU cache of `W` lines* over the
/// sub-stream of accesses mapping to it, so the Mattson stack property
/// applies per set: an access hits **iff** its within-set stack distance
/// is `< W`.  Unlike the fully-associative approximation this is *exact*
/// for the simulated hierarchy (`sim::cache` is true-LRU per set), which
/// is what lets `misscurve::predict_set_aware` price conflict misses the
/// fully-associative curve cannot see.
///
/// Set indexing matches `sim/cache.rs` exactly:
/// `set = (addr >> line_shift) as usize & (sets - 1)`.
#[derive(Clone, Debug)]
pub struct SetHistograms {
    sets: usize,
    /// Per-set LRU stacks of line addresses, MRU first, truncated at
    /// [`SET_STACK_DEPTH`].
    stacks: Vec<Vec<u64>>,
    hists: Vec<ReuseHistogram>,
}

impl SetHistograms {
    /// Empty tracker for a cache with `sets` sets (must be a power of two,
    /// mirroring the simulator's index arithmetic).
    pub fn new(sets: usize) -> Self {
        assert!(sets.is_power_of_two(), "set count must be a power of two");
        SetHistograms {
            sets,
            stacks: vec![Vec::new(); sets],
            hists: vec![ReuseHistogram::new(); sets],
        }
    }

    /// Number of sets tracked.
    pub fn sets(&self) -> usize {
        self.sets
    }

    /// One line-granular access.  `cold` is the *global* first-touch flag
    /// from the fully-associative analyzer: a line absent from its set's
    /// (truncated) stack but seen before globally records as `far`, not
    /// cold, so cold mass is conserved between the two views.
    pub fn record(&mut self, line: u64, cold: bool) {
        let s = (line as usize) & (self.sets - 1);
        let stack = &mut self.stacks[s];
        match stack.iter().position(|&l| l == line) {
            Some(pos) => {
                stack.remove(pos);
                stack.insert(0, line);
                self.hists[s].record(Some(pos as u64));
            }
            None => {
                stack.insert(0, line);
                if stack.len() > SET_STACK_DEPTH {
                    stack.pop();
                }
                self.hists[s].record(if cold {
                    None
                } else {
                    // truncated out of the bounded stack: finite but
                    // deeper than any associativity we evaluate
                    Some(MAX_EXACT_DISTANCE as u64)
                });
            }
        }
    }

    /// The within-set distance histogram of one set.
    pub fn histogram(&self, set: usize) -> &ReuseHistogram {
        &self.hists[set]
    }

    /// Accesses whose within-set distance is `< ways` — the exact hit
    /// count of a `ways`-associative LRU cache with this set count
    /// (for `ways <= SET_STACK_DEPTH`).
    pub fn hits_within_ways(&self, ways: usize) -> u64 {
        self.hists.iter().map(|h| h.hits_within(ways)).sum()
    }

    /// Total accesses recorded across all sets.
    pub fn total(&self) -> u64 {
        self.hists.iter().map(|h| h.total()).sum()
    }

    /// Cold first touches across all sets (equals the fully-associative
    /// analyzer's cold count — conservation the proptests pin).
    pub fn cold(&self) -> u64 {
        self.hists.iter().map(|h| h.cold()).sum()
    }

    /// Set-associative hit rate at `ways` (0 when empty).
    pub fn hit_rate_within_ways(&self, ways: usize) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        self.hits_within_ways(ways) as f64 / total as f64
    }
}

/// The streaming analyzer: feeds per-operand [`ReuseHistogram`]s from a
/// line-granular address stream.  Implements [`EventSink`], consuming the
/// L1 hit/miss events of a traced replay (exactly one per core access).
#[derive(Clone, Debug)]
pub struct ReuseAnalyzer {
    line_shift: u32,
    /// line -> most recent access slot.
    last: HashMap<u64, usize>,
    /// 1 at each live line's most recent slot.
    occupied: Fenwick,
    /// Next free slot.
    time: usize,
    per_operand: [ReuseHistogram; 4],
    /// Per-set refinement (only with [`ReuseAnalyzer::with_sets`]).
    set_hists: Option<SetHistograms>,
    /// Total element bytes requested (for traffic extrapolation).
    pub bytes_accessed: u64,
    /// Write-flavoured accesses (C-store stream estimate).
    pub write_accesses: u64,
}

impl ReuseAnalyzer {
    /// Analyzer for `line_bytes`-sized cache lines.
    pub fn new(line_bytes: usize) -> Self {
        assert!(line_bytes.is_power_of_two(), "line size must be a power of two");
        ReuseAnalyzer {
            line_shift: line_bytes.trailing_zeros(),
            last: HashMap::new(),
            occupied: Fenwick::new(MIN_SLOTS),
            time: 0,
            per_operand: Default::default(),
            set_hists: None,
            bytes_accessed: 0,
            write_accesses: 0,
        }
    }

    /// Analyzer that additionally keeps per-set stack distances for a
    /// cache with `sets` sets (the L1 geometry of the CPU the trace will
    /// be scored against) — the data `misscurve::predict_set_aware` needs
    /// for exact conflict-miss accounting.
    pub fn with_sets(line_bytes: usize, sets: usize) -> Self {
        let mut a = Self::new(line_bytes);
        a.set_hists = Some(SetHistograms::new(sets));
        a
    }

    /// Cache-line size distances are measured in.
    pub fn line_bytes(&self) -> usize {
        1usize << self.line_shift
    }

    /// Distinct lines seen so far.
    pub fn lines_touched(&self) -> usize {
        self.last.len()
    }

    /// Total accesses recorded across all operands.
    pub fn accesses(&self) -> u64 {
        self.per_operand.iter().map(|h| h.total()).sum()
    }

    /// One element access tagged with its operand.
    pub fn touch(&mut self, addr: u64, operand: Operand) {
        // Compact *before* touching any bookkeeping: compaction rebuilds
        // the window from `last`, so running it mid-access (after the old
        // slot's occupancy was cleared but before `last` is repointed)
        // would resurrect the in-flight line's old slot as a phantom that
        // inflates every later distance by one.
        if self.time == self.occupied.len() {
            self.compact();
        }
        let line = addr >> self.line_shift;
        let distance = match self.last.get(&line) {
            Some(&prev) => {
                // live slots strictly after prev = distinct other lines
                // touched since the previous access to this line
                let d = self.occupied.prefix(self.time) - self.occupied.prefix(prev + 1);
                self.occupied.add(prev, -1);
                Some(d)
            }
            None => None,
        };
        let slot = self.time;
        self.occupied.add(slot, 1);
        self.last.insert(line, slot);
        self.time += 1;
        self.per_operand[operand.index()].record(distance);
        if let Some(sh) = &mut self.set_hists {
            // globally-cold flag keeps cold mass identical in both views
            sh.record(line, distance.is_none());
        }
    }

    /// Rebuild the slot window keeping only live lines, preserving their
    /// recency order.  Runs every `O(window)` accesses; each rebuild is
    /// `O(lines · log lines)`, so the amortized cost per access stays
    /// logarithmic and memory stays proportional to the working set.
    fn compact(&mut self) {
        let mut live: Vec<(usize, u64)> =
            self.last.iter().map(|(&line, &slot)| (slot, line)).collect();
        live.sort_unstable();
        let window = (2 * live.len()).max(MIN_SLOTS);
        self.occupied = Fenwick::new(window);
        for (new_slot, &(_, line)) in live.iter().enumerate() {
            self.occupied.add(new_slot, 1);
            self.last.insert(line, new_slot);
        }
        self.time = live.len();
    }

    /// The reuse histogram of one operand stream.
    pub fn histogram(&self, operand: Operand) -> &ReuseHistogram {
        &self.per_operand[operand.index()]
    }

    /// The combined (all-operand) histogram.
    pub fn combined(&self) -> ReuseHistogram {
        let mut out = ReuseHistogram::new();
        for h in &self.per_operand {
            out.merge(h);
        }
        out
    }

    /// The per-set refinement, when this analyzer was built
    /// [`with_sets`](Self::with_sets).
    pub fn set_histograms(&self) -> Option<&SetHistograms> {
        self.set_hists.as_ref()
    }

    /// Move the per-set refinement out (for handing to
    /// `MissRatioCurve::with_sets` without cloning).
    pub fn take_set_histograms(&mut self) -> Option<SetHistograms> {
        self.set_hists.take()
    }
}

impl EventSink for ReuseAnalyzer {
    fn record(&mut self, ev: &CacheEvent) {
        // Exactly one L1 hit-or-miss event per core access; evictions,
        // writebacks and L2 events describe consequences, not reuses.
        if ev.level == MemLevel::L1 && matches!(ev.kind, EventKind::Hit | EventKind::Miss) {
            self.bytes_accessed += ev.bytes as u64;
            if ev.access == AccessKind::Write {
                self.write_accesses += 1;
            }
            self.touch(ev.addr, ev.operand);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn touch_all(a: &mut ReuseAnalyzer, lines: &[u64]) {
        for &l in lines {
            a.touch(l * 64, Operand::A);
        }
    }

    #[test]
    fn textbook_distances() {
        // A B C A: distance(A₂) = 2 (B, C); B and C are cold.
        let mut a = ReuseAnalyzer::new(64);
        touch_all(&mut a, &[0, 1, 2, 0]);
        let h = a.histogram(Operand::A);
        assert_eq!(h.cold(), 3);
        assert_eq!(h.hits_within(3), 1, "distance 2 < 3");
        assert_eq!(h.hits_within(2), 0, "distance 2 not < 2");
    }

    #[test]
    fn repeat_access_is_distance_zero() {
        let mut a = ReuseAnalyzer::new(64);
        touch_all(&mut a, &[5, 5, 5]);
        let h = a.histogram(Operand::A);
        assert_eq!(h.cold(), 1);
        assert_eq!(h.hits_within(1), 2);
    }

    #[test]
    fn same_line_different_elements_share_distance() {
        // 64 B lines: addresses 0 and 60 are the same line.
        let mut a = ReuseAnalyzer::new(64);
        a.touch(0, Operand::B);
        a.touch(60, Operand::B);
        assert_eq!(a.histogram(Operand::B).hits_within(1), 1);
        assert_eq!(a.lines_touched(), 1);
    }

    #[test]
    fn intervening_reaccess_counts_once() {
        // A B B A: distance(A₂) = 1 (B once, not twice).
        let mut a = ReuseAnalyzer::new(64);
        touch_all(&mut a, &[0, 1, 1, 0]);
        assert_eq!(a.histogram(Operand::A).hits_within(2), 2);
    }

    #[test]
    fn cyclic_sweep_matches_lru_theory() {
        // Sweeping W distinct lines R times: after the cold pass every
        // access has distance W-1 — hits iff capacity >= W.
        let (w, rounds) = (10u64, 4);
        let mut a = ReuseAnalyzer::new(64);
        for _ in 0..rounds {
            touch_all(&mut a, &(0..w).collect::<Vec<_>>());
        }
        let h = a.combined();
        assert_eq!(h.cold(), w);
        assert_eq!(h.hits_within(w as usize), (rounds - 1) * w);
        assert_eq!(h.hits_within(w as usize - 1), 0);
    }

    #[test]
    fn compaction_preserves_distances() {
        // Drive well past MIN_SLOTS so several compactions happen, with a
        // small live set; distances must stay exact throughout.
        let mut a = ReuseAnalyzer::new(64);
        let lines = 16u64;
        let rounds = (MIN_SLOTS as u64 / lines) * 3 + 7;
        for _ in 0..rounds {
            touch_all(&mut a, &(0..lines).collect::<Vec<_>>());
        }
        let h = a.combined();
        assert_eq!(h.total(), rounds * lines);
        assert_eq!(h.cold(), lines);
        assert_eq!(h.hits_within(lines as usize), (rounds - 1) * lines);
        assert_eq!(h.hits_within(lines as usize - 1), 0);
        assert_eq!(a.lines_touched(), lines as usize);
    }

    #[test]
    fn compaction_on_a_mid_stack_reuse_leaves_no_phantom_slot() {
        // Arrange the window so compaction fires exactly when a line from
        // the *middle* of the LRU stack is re-accessed: compaction rebuilds
        // from `last`, and a phantom occupancy left for the in-flight line
        // would inflate every later distance by one.
        let lines = 10u64;
        let mut a = ReuseAnalyzer::new(64);
        // exactly MIN_SLOTS touches of a pure cycle; the next touch
        // triggers compaction at entry
        for i in 0..MIN_SLOTS as u64 {
            a.touch((i % lines) * 64, Operand::A);
        }
        // mid-stack reuse at the compaction boundary: line 2 was followed
        // by 3, 4, 5 — distance exactly 3
        a.touch(2 * 64, Operand::A);
        // one more sweep (skipping 2): every distance is exactly 9
        for l in [6u64, 7, 8, 9, 0, 1, 3, 4, 5] {
            a.touch(l * 64, Operand::A);
        }
        let h = a.combined();
        let total = MIN_SLOTS as u64 + 10;
        assert_eq!(h.total(), total);
        assert_eq!(h.cold(), lines);
        assert_eq!(h.hits_within(4), 1, "the distance-3 mid-stack reuse");
        assert_eq!(
            h.hits_within(lines as usize),
            total - lines,
            "a phantom slot would inflate some distances past {lines}"
        );
    }

    #[test]
    fn per_operand_split_and_combined_total() {
        let mut a = ReuseAnalyzer::new(64);
        a.touch(0, Operand::A);
        a.touch(64, Operand::B);
        a.touch(0, Operand::A);
        assert_eq!(a.histogram(Operand::A).total(), 2);
        assert_eq!(a.histogram(Operand::B).total(), 1);
        assert_eq!(a.combined().total(), 3);
        assert_eq!(a.accesses(), 3);
    }

    #[test]
    fn percentile_and_buckets() {
        let mut h = ReuseHistogram::new();
        for _ in 0..90 {
            h.record(Some(1));
        }
        for _ in 0..10 {
            h.record(Some(300));
        }
        assert_eq!(h.percentile(50.0), Some(1));
        assert_eq!(h.percentile(95.0), Some(300));
        let buckets = h.log_buckets();
        assert!(buckets.iter().any(|b| b.lo <= 1 && 1 < b.hi && b.count == 90));
        assert_eq!(buckets.iter().map(|b| b.count).sum::<u64>(), 100);
    }

    #[test]
    fn far_distances_fold_into_miss_bucket() {
        let mut h = ReuseHistogram::new();
        h.record(Some(MAX_EXACT_DISTANCE as u64 + 5));
        h.record(Some(2));
        assert_eq!(h.total(), 2);
        assert_eq!(h.hits_within(MAX_EXACT_DISTANCE), 1);
        assert!((h.hit_rate(MAX_EXACT_DISTANCE) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_rates_are_zero() {
        let h = ReuseHistogram::new();
        assert_eq!(h.hit_rate(1024), 0.0);
        assert_eq!(h.percentile(50.0), None);
        assert!(h.log_buckets().is_empty());
    }

    #[test]
    fn per_set_distances_contract_against_the_global_view() {
        // 2 sets: lines 0, 2 -> set 0; line 1 -> set 1.  Trace 0 1 2 0:
        // global distance of the 0-reuse is 2 (lines 1 and 2 intervene),
        // but its within-set distance is 1 (only line 2 shares the set).
        let mut a = ReuseAnalyzer::with_sets(64, 2);
        touch_all(&mut a, &[0, 1, 2, 0]);
        let sh = a.set_histograms().unwrap();
        assert_eq!(sh.sets(), 2);
        assert_eq!(sh.total(), 4);
        assert_eq!(sh.cold(), 3);
        assert_eq!(sh.hits_within_ways(2), 1, "within-set distance 1 < 2 ways");
        assert_eq!(sh.hits_within_ways(1), 0);
        assert_eq!(a.combined().hits_within(2), 0, "global view sees distance 2");
    }

    #[test]
    fn conflict_misses_visible_only_per_set() {
        // Stride of 4 lines maps everything to set 0 of a 4-set tracker:
        // cycling 3 lines thrashes a 2-way set (within-set distance 2 >= 2
        // ways) while the fully-associative view at the same total
        // capacity (8 lines) scores every warm access a hit.
        let mut a = ReuseAnalyzer::with_sets(64, 4);
        for _ in 0..4 {
            touch_all(&mut a, &[0, 4, 8]);
        }
        let sh = a.set_histograms().unwrap();
        assert_eq!(sh.hits_within_ways(2), 0, "3 lines in one 2-way set thrash");
        assert_eq!(a.combined().hits_within(8), 9, "fully-assoc view hits");
        assert_eq!(sh.total(), a.combined().total());
        assert_eq!(sh.cold(), a.combined().cold());
    }

    #[test]
    fn truncated_reaccess_records_far_not_cold() {
        // More live lines than the bounded stack holds: second-round
        // accesses fall off the stack, so they must score as far (finite,
        // deep) rather than cold — conserving cold mass with the
        // fully-associative analyzer.
        let mut a = ReuseAnalyzer::with_sets(64, 1);
        let lines: Vec<u64> = (0..SET_STACK_DEPTH as u64 + 8).collect();
        touch_all(&mut a, &lines);
        touch_all(&mut a, &lines);
        let sh = a.set_histograms().unwrap();
        assert_eq!(sh.cold(), lines.len() as u64);
        assert_eq!(sh.cold(), a.combined().cold());
        assert_eq!(sh.total(), 2 * lines.len() as u64);
        assert_eq!(sh.hits_within_ways(SET_STACK_DEPTH), 0, "truncated reuses are far");
    }
}
