//! Streaming reuse-distance (stack-distance) analysis over cache lines.
//!
//! For every access, the *stack distance* is the number of **distinct other
//! cache lines** touched since the previous access to the same line (cold
//! first touches have infinite distance).  Under fully-associative LRU the
//! access hits a cache of capacity `C` lines **iff** its distance is
//! `< C` — which is what lets one traced replay predict hit rates for
//! *every* cache size at once (`misscurve`), instead of re-simulating per
//! configuration.
//!
//! The analyzer is streaming and bounded-memory:
//!
//! * distances are computed with a Fenwick tree over access-time slots
//!   (the classic O(log n) stack-distance algorithm); the slot window is
//!   periodically *compacted* down to the set of live lines, so memory is
//!   O(distinct lines), not O(trace length);
//! * histograms store exact counts only up to [`MAX_EXACT_DISTANCE`]
//!   (2^18 lines = 16 MiB of 64-byte lines — beyond every cache this
//!   framework models); farther reuses fold into a single `far` bucket
//!   that any realistic capacity scores as a miss.
//!
//! Histograms are kept **per operand** (A/B/C tags from `sim::trace`) so a
//! schedule's pathology is attributable: a B-stream whose distance
//! distribution sits just beyond the L1 capacity is the paper's
//! L1-cache-bound GEMM in one picture.

use std::collections::HashMap;

use crate::hw::MemLevel;
use crate::sim::cache::AccessKind;

use super::event::{CacheEvent, EventKind, Operand};
use super::sink::EventSink;

/// Largest stack distance recorded exactly (in lines).  16 MiB of 64 B
/// lines — larger than any L2 this framework models, so folding farther
/// distances into one bucket loses nothing for hit-rate prediction.
pub const MAX_EXACT_DISTANCE: usize = 1 << 18;

/// A reuse-distance histogram (distances in cache lines).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReuseHistogram {
    /// `counts[d]` = accesses with stack distance exactly `d`; grown on
    /// demand, capped at [`MAX_EXACT_DISTANCE`] entries.
    counts: Vec<u64>,
    /// Finite distances `>= MAX_EXACT_DISTANCE`.
    far: u64,
    /// Cold first touches (infinite distance).
    cold: u64,
}

impl ReuseHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one access; `None` = cold first touch.
    pub fn record(&mut self, distance: Option<u64>) {
        match distance {
            Some(d) if (d as usize) < MAX_EXACT_DISTANCE => {
                let d = d as usize;
                if d >= self.counts.len() {
                    self.counts.resize(d + 1, 0);
                }
                self.counts[d] += 1;
            }
            Some(_) => self.far += 1,
            None => self.cold += 1,
        }
    }

    /// Total recorded accesses (exact + far + cold).
    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.far + self.cold
    }

    /// Cold first touches (infinite distance).
    pub fn cold(&self) -> u64 {
        self.cold
    }

    /// Accesses with distance `< capacity_lines` — the fully-associative
    /// LRU hits of a cache of that many lines.  Capacities beyond
    /// [`MAX_EXACT_DISTANCE`] are clamped (the `far` bucket stays a miss).
    pub fn hits_within(&self, capacity_lines: usize) -> u64 {
        let cap = capacity_lines.min(self.counts.len());
        self.counts[..cap].iter().sum()
    }

    /// Predicted hit rate at `capacity_lines` (0 when the histogram is
    /// empty).
    pub fn hit_rate(&self, capacity_lines: usize) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        self.hits_within(capacity_lines) as f64 / total as f64
    }

    /// Smallest distance `d` such that at least `p`% of accesses have
    /// distance `<= d`; `None` when that mass is only reached through the
    /// far/cold buckets.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        let total = self.total();
        if total == 0 {
            return None;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (d, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(d as u64);
            }
        }
        None
    }

    /// Log₂-bucketed view `(lo, hi, count)` with `hi` exclusive, plus the
    /// far and cold buckets — the compact rendering for CLI/JSON output.
    pub fn log_buckets(&self) -> Vec<DistanceBucket> {
        let mut out = Vec::new();
        let mut lo = 0usize;
        let mut hi = 1usize;
        while lo < self.counts.len() {
            let end = hi.min(self.counts.len());
            let count: u64 = self.counts[lo..end].iter().sum();
            if count > 0 {
                out.push(DistanceBucket {
                    lo: lo as u64,
                    hi: hi as u64,
                    count,
                    kind: BucketKind::Exact,
                });
            }
            lo = hi;
            hi *= 2;
        }
        if self.far > 0 {
            out.push(DistanceBucket {
                lo: MAX_EXACT_DISTANCE as u64,
                hi: u64::MAX,
                count: self.far,
                kind: BucketKind::Far,
            });
        }
        if self.cold > 0 {
            out.push(DistanceBucket {
                lo: u64::MAX,
                hi: u64::MAX,
                count: self.cold,
                kind: BucketKind::Cold,
            });
        }
        out
    }

    /// Accumulate another histogram into this one.
    pub fn merge(&mut self, other: &ReuseHistogram) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (d, &c) in other.counts.iter().enumerate() {
            self.counts[d] += c;
        }
        self.far += other.far;
        self.cold += other.cold;
    }
}

/// One log-bucket row of a histogram rendering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DistanceBucket {
    /// Inclusive lower distance bound (lines).
    pub lo: u64,
    /// Exclusive upper bound (`u64::MAX` for far/cold).
    pub hi: u64,
    /// Accesses falling in this bucket.
    pub count: u64,
    /// Exact-range, far-overflow or cold bucket.
    pub kind: BucketKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// What a [`DistanceBucket`] row represents.
pub enum BucketKind {
    /// Distances counted exactly (`lo..hi` lines).
    Exact,
    /// Finite distances beyond [`MAX_EXACT_DISTANCE`].
    Far,
    /// First touches (no previous access to the line).
    Cold,
}

/// Fenwick (binary indexed) tree of slot-occupancy counts.
#[derive(Clone, Debug)]
struct Fenwick {
    tree: Vec<u32>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Fenwick { tree: vec![0; n + 1] }
    }

    fn len(&self) -> usize {
        self.tree.len() - 1
    }

    /// Add `delta` at slot `i` (0-based).
    fn add(&mut self, i: usize, delta: i32) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] = (self.tree[i] as i64 + delta as i64) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of slots `[0, i)` (0-based, `i` exclusive).
    fn prefix(&self, i: usize) -> u64 {
        let mut i = i.min(self.len());
        let mut s = 0u64;
        while i > 0 {
            s += self.tree[i] as u64;
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Minimum slot-window size (keeps compaction amortized for tiny traces).
const MIN_SLOTS: usize = 1 << 12;

/// The streaming analyzer: feeds per-operand [`ReuseHistogram`]s from a
/// line-granular address stream.  Implements [`EventSink`], consuming the
/// L1 hit/miss events of a traced replay (exactly one per core access).
#[derive(Clone, Debug)]
pub struct ReuseAnalyzer {
    line_shift: u32,
    /// line -> most recent access slot.
    last: HashMap<u64, usize>,
    /// 1 at each live line's most recent slot.
    occupied: Fenwick,
    /// Next free slot.
    time: usize,
    per_operand: [ReuseHistogram; 4],
    /// Total element bytes requested (for traffic extrapolation).
    pub bytes_accessed: u64,
    /// Write-flavoured accesses (C-store stream estimate).
    pub write_accesses: u64,
}

impl ReuseAnalyzer {
    /// Analyzer for `line_bytes`-sized cache lines.
    pub fn new(line_bytes: usize) -> Self {
        assert!(line_bytes.is_power_of_two(), "line size must be a power of two");
        ReuseAnalyzer {
            line_shift: line_bytes.trailing_zeros(),
            last: HashMap::new(),
            occupied: Fenwick::new(MIN_SLOTS),
            time: 0,
            per_operand: Default::default(),
            bytes_accessed: 0,
            write_accesses: 0,
        }
    }

    /// Cache-line size distances are measured in.
    pub fn line_bytes(&self) -> usize {
        1usize << self.line_shift
    }

    /// Distinct lines seen so far.
    pub fn lines_touched(&self) -> usize {
        self.last.len()
    }

    /// Total accesses recorded across all operands.
    pub fn accesses(&self) -> u64 {
        self.per_operand.iter().map(|h| h.total()).sum()
    }

    /// One element access tagged with its operand.
    pub fn touch(&mut self, addr: u64, operand: Operand) {
        // Compact *before* touching any bookkeeping: compaction rebuilds
        // the window from `last`, so running it mid-access (after the old
        // slot's occupancy was cleared but before `last` is repointed)
        // would resurrect the in-flight line's old slot as a phantom that
        // inflates every later distance by one.
        if self.time == self.occupied.len() {
            self.compact();
        }
        let line = addr >> self.line_shift;
        let distance = match self.last.get(&line) {
            Some(&prev) => {
                // live slots strictly after prev = distinct other lines
                // touched since the previous access to this line
                let d = self.occupied.prefix(self.time) - self.occupied.prefix(prev + 1);
                self.occupied.add(prev, -1);
                Some(d)
            }
            None => None,
        };
        let slot = self.time;
        self.occupied.add(slot, 1);
        self.last.insert(line, slot);
        self.time += 1;
        self.per_operand[operand.index()].record(distance);
    }

    /// Rebuild the slot window keeping only live lines, preserving their
    /// recency order.  Runs every `O(window)` accesses; each rebuild is
    /// `O(lines · log lines)`, so the amortized cost per access stays
    /// logarithmic and memory stays proportional to the working set.
    fn compact(&mut self) {
        let mut live: Vec<(usize, u64)> =
            self.last.iter().map(|(&line, &slot)| (slot, line)).collect();
        live.sort_unstable();
        let window = (2 * live.len()).max(MIN_SLOTS);
        self.occupied = Fenwick::new(window);
        for (new_slot, &(_, line)) in live.iter().enumerate() {
            self.occupied.add(new_slot, 1);
            self.last.insert(line, new_slot);
        }
        self.time = live.len();
    }

    /// The reuse histogram of one operand stream.
    pub fn histogram(&self, operand: Operand) -> &ReuseHistogram {
        &self.per_operand[operand.index()]
    }

    /// The combined (all-operand) histogram.
    pub fn combined(&self) -> ReuseHistogram {
        let mut out = ReuseHistogram::new();
        for h in &self.per_operand {
            out.merge(h);
        }
        out
    }
}

impl EventSink for ReuseAnalyzer {
    fn record(&mut self, ev: &CacheEvent) {
        // Exactly one L1 hit-or-miss event per core access; evictions,
        // writebacks and L2 events describe consequences, not reuses.
        if ev.level == MemLevel::L1 && matches!(ev.kind, EventKind::Hit | EventKind::Miss) {
            self.bytes_accessed += ev.bytes as u64;
            if ev.access == AccessKind::Write {
                self.write_accesses += 1;
            }
            self.touch(ev.addr, ev.operand);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn touch_all(a: &mut ReuseAnalyzer, lines: &[u64]) {
        for &l in lines {
            a.touch(l * 64, Operand::A);
        }
    }

    #[test]
    fn textbook_distances() {
        // A B C A: distance(A₂) = 2 (B, C); B and C are cold.
        let mut a = ReuseAnalyzer::new(64);
        touch_all(&mut a, &[0, 1, 2, 0]);
        let h = a.histogram(Operand::A);
        assert_eq!(h.cold(), 3);
        assert_eq!(h.hits_within(3), 1, "distance 2 < 3");
        assert_eq!(h.hits_within(2), 0, "distance 2 not < 2");
    }

    #[test]
    fn repeat_access_is_distance_zero() {
        let mut a = ReuseAnalyzer::new(64);
        touch_all(&mut a, &[5, 5, 5]);
        let h = a.histogram(Operand::A);
        assert_eq!(h.cold(), 1);
        assert_eq!(h.hits_within(1), 2);
    }

    #[test]
    fn same_line_different_elements_share_distance() {
        // 64 B lines: addresses 0 and 60 are the same line.
        let mut a = ReuseAnalyzer::new(64);
        a.touch(0, Operand::B);
        a.touch(60, Operand::B);
        assert_eq!(a.histogram(Operand::B).hits_within(1), 1);
        assert_eq!(a.lines_touched(), 1);
    }

    #[test]
    fn intervening_reaccess_counts_once() {
        // A B B A: distance(A₂) = 1 (B once, not twice).
        let mut a = ReuseAnalyzer::new(64);
        touch_all(&mut a, &[0, 1, 1, 0]);
        assert_eq!(a.histogram(Operand::A).hits_within(2), 2);
    }

    #[test]
    fn cyclic_sweep_matches_lru_theory() {
        // Sweeping W distinct lines R times: after the cold pass every
        // access has distance W-1 — hits iff capacity >= W.
        let (w, rounds) = (10u64, 4);
        let mut a = ReuseAnalyzer::new(64);
        for _ in 0..rounds {
            touch_all(&mut a, &(0..w).collect::<Vec<_>>());
        }
        let h = a.combined();
        assert_eq!(h.cold(), w);
        assert_eq!(h.hits_within(w as usize), (rounds - 1) * w);
        assert_eq!(h.hits_within(w as usize - 1), 0);
    }

    #[test]
    fn compaction_preserves_distances() {
        // Drive well past MIN_SLOTS so several compactions happen, with a
        // small live set; distances must stay exact throughout.
        let mut a = ReuseAnalyzer::new(64);
        let lines = 16u64;
        let rounds = (MIN_SLOTS as u64 / lines) * 3 + 7;
        for _ in 0..rounds {
            touch_all(&mut a, &(0..lines).collect::<Vec<_>>());
        }
        let h = a.combined();
        assert_eq!(h.total(), rounds * lines);
        assert_eq!(h.cold(), lines);
        assert_eq!(h.hits_within(lines as usize), (rounds - 1) * lines);
        assert_eq!(h.hits_within(lines as usize - 1), 0);
        assert_eq!(a.lines_touched(), lines as usize);
    }

    #[test]
    fn compaction_on_a_mid_stack_reuse_leaves_no_phantom_slot() {
        // Arrange the window so compaction fires exactly when a line from
        // the *middle* of the LRU stack is re-accessed: compaction rebuilds
        // from `last`, and a phantom occupancy left for the in-flight line
        // would inflate every later distance by one.
        let lines = 10u64;
        let mut a = ReuseAnalyzer::new(64);
        // exactly MIN_SLOTS touches of a pure cycle; the next touch
        // triggers compaction at entry
        for i in 0..MIN_SLOTS as u64 {
            a.touch((i % lines) * 64, Operand::A);
        }
        // mid-stack reuse at the compaction boundary: line 2 was followed
        // by 3, 4, 5 — distance exactly 3
        a.touch(2 * 64, Operand::A);
        // one more sweep (skipping 2): every distance is exactly 9
        for l in [6u64, 7, 8, 9, 0, 1, 3, 4, 5] {
            a.touch(l * 64, Operand::A);
        }
        let h = a.combined();
        let total = MIN_SLOTS as u64 + 10;
        assert_eq!(h.total(), total);
        assert_eq!(h.cold(), lines);
        assert_eq!(h.hits_within(4), 1, "the distance-3 mid-stack reuse");
        assert_eq!(
            h.hits_within(lines as usize),
            total - lines,
            "a phantom slot would inflate some distances past {lines}"
        );
    }

    #[test]
    fn per_operand_split_and_combined_total() {
        let mut a = ReuseAnalyzer::new(64);
        a.touch(0, Operand::A);
        a.touch(64, Operand::B);
        a.touch(0, Operand::A);
        assert_eq!(a.histogram(Operand::A).total(), 2);
        assert_eq!(a.histogram(Operand::B).total(), 1);
        assert_eq!(a.combined().total(), 3);
        assert_eq!(a.accesses(), 3);
    }

    #[test]
    fn percentile_and_buckets() {
        let mut h = ReuseHistogram::new();
        for _ in 0..90 {
            h.record(Some(1));
        }
        for _ in 0..10 {
            h.record(Some(300));
        }
        assert_eq!(h.percentile(50.0), Some(1));
        assert_eq!(h.percentile(95.0), Some(300));
        let buckets = h.log_buckets();
        assert!(buckets.iter().any(|b| b.lo <= 1 && 1 < b.hi && b.count == 90));
        assert_eq!(buckets.iter().map(|b| b.count).sum::<u64>(), 100);
    }

    #[test]
    fn far_distances_fold_into_miss_bucket() {
        let mut h = ReuseHistogram::new();
        h.record(Some(MAX_EXACT_DISTANCE as u64 + 5));
        h.record(Some(2));
        assert_eq!(h.total(), 2);
        assert_eq!(h.hits_within(MAX_EXACT_DISTANCE), 1);
        assert!((h.hit_rate(MAX_EXACT_DISTANCE) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_rates_are_zero() {
        let h = ReuseHistogram::new();
        assert_eq!(h.hit_rate(1024), 0.0);
        assert_eq!(h.percentile(50.0), None);
        assert!(h.log_buckets().is_empty());
    }
}
