//! Per-workload cache profiles: one traced replay → reuse histograms, MRC,
//! knees, and predicted-vs-simulated classification.
//!
//! [`trace_workload`] is the single driver everything rides on: the CLI's
//! `cachebound trace`, the `JobSpec::Trace` coordinator job, the optional
//! `telemetry` section of `BENCH.json`, and the serving core's
//! [`CacheProfile`]s.  It replays one operator through `sim::Hierarchy`
//! with a `ReuseAnalyzer` sink attached, so the *same pass* yields both
//! the set-associative ground truth (cache stats) and the MRC prediction —
//! which is what makes predicted-vs-simulated a meaningful validation.
//!
//! Replays are row-budgeted ([`TraceBudget`]): the loop nests repeat the
//! same tile-level reuse pattern along their outer dimension, so tracing
//! `max_rows` of it and scaling linearly reproduces the full-shape traffic
//! at a fraction of the cost (the budget is recorded in the report).

use crate::analysis::predict::{
    classify_traffic, predict_workload, traffic_from_counts, MrcPrediction, TraceMeta,
};
use crate::bench::sweep::CLASSIFY_SLACK;
use crate::hw::CpuSpec;
use crate::operators::conv::ConvSchedule;
use crate::operators::gemm::GemmSchedule;
use crate::operators::workloads::BenchWorkload;
use crate::sim::hierarchy::{Hierarchy, LevelCounts};
use crate::sim::trace::{
    replay_bitserial_gemm_traced, replay_conv_spatial_pack_traced, replay_gemm_traced,
};
use crate::util::json::{self, Value};

use super::event::Operand;
use super::misscurve::{Knee, MissRatioCurve};
use super::reuse::{DistanceBucket, ReuseAnalyzer};

/// Fraction of the peak finite hit rate defining the working-set estimate.
/// High because the distance-0 (within-line) mass alone reaches ~90% for
/// streaming operators; the knee of interest is the last few percent.
pub const WORKING_SET_FRACTION: f64 = 0.98;

/// How much of a workload's outer dimension a trace replays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceBudget {
    /// Cap on the outer extent (GEMM/bit-serial rows, conv input rows).
    pub max_rows: usize,
}

impl TraceBudget {
    pub fn new(max_rows: usize) -> Self {
        TraceBudget { max_rows: max_rows.max(1) }
    }
}

impl Default for TraceBudget {
    fn default() -> Self {
        TraceBudget { max_rows: 64 }
    }
}

/// Reuse profile of one operand stream.
#[derive(Clone, Debug, PartialEq)]
pub struct OperandProfile {
    pub operand: String,
    pub accesses: u64,
    pub cold: u64,
    /// Median reuse distance in lines (None when cold/far dominates).
    pub p50_lines: Option<u64>,
    pub buckets: Vec<DistanceBucket>,
}

/// Everything one traced replay produced.
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub family: String,
    pub shape: String,
    pub cpu_name: String,
    /// Row budget the replay ran under.
    pub max_rows: usize,
    /// Full-shape work / traced work.
    pub scale: f64,
    pub accesses: u64,
    pub lines_touched: u64,
    /// Trace-simulator per-level byte counts (the ground truth).
    pub counts: LevelCounts,
    /// Set-associative simulated hit rates (L1 over all accesses, L2 over
    /// the L1-miss stream).
    pub sim_l1_hit_rate: f64,
    pub sim_l2_hit_rate: f64,
    /// Full-simulation roofline time and class (same classifier as the
    /// prediction — agreement is the validation).
    pub sim_time_s: f64,
    pub sim_class: String,
    /// The MRC-side prediction.
    pub prediction: MrcPrediction,
    pub predicted_class: String,
    /// Smallest capacity reaching [`WORKING_SET_FRACTION`] of the peak
    /// finite hit rate.
    pub working_set_bytes: u64,
    pub operands: Vec<OperandProfile>,
    /// `(capacity_bytes, predicted_hit_rate)` — the MRC data series.
    pub mrc_points: Vec<(u64, f64)>,
    pub knees: Vec<Knee>,
}

/// Trace one workload on one CPU profile: replay through the hierarchy
/// with a reuse-analyzer sink, then predict and classify both ways.
pub fn trace_workload(cpu: &CpuSpec, w: &BenchWorkload, budget: TraceBudget) -> TraceReport {
    let mut h = Hierarchy::new(cpu);
    let mut analyzer = ReuseAnalyzer::new(cpu.l1.line_bytes);
    let (scale, max_rows) = match w {
        BenchWorkload::Gemm { n } => {
            let m = (*n).min(budget.max_rows);
            replay_gemm_traced(&mut h, m, *n, *n, GemmSchedule::default_tuned(), 4, &mut analyzer);
            (*n as f64 / m as f64, m)
        }
        BenchWorkload::Conv { layer } | BenchWorkload::QnnConv { layer } => {
            let elem = if matches!(w, BenchWorkload::QnnConv { .. }) { 1 } else { 4 };
            let mut traced = *layer;
            traced.h = traced.h.min(budget.max_rows);
            replay_conv_spatial_pack_traced(
                &mut h,
                &traced,
                ConvSchedule::default_tuned(),
                elem,
                &mut analyzer,
            );
            (
                layer.macs_exact() as f64 / traced.macs_exact() as f64,
                traced.h,
            )
        }
        BenchWorkload::Bitserial { n, bits } => {
            let m = (*n).min(budget.max_rows);
            let kw = n.div_ceil(32);
            replay_bitserial_gemm_traced(&mut h, m, *n, kw, *bits, *bits, &mut analyzer);
            (*n as f64 / m as f64, m)
        }
    };

    let meta = TraceMeta {
        traced_accesses: analyzer.accesses(),
        traced_bytes: analyzer.bytes_accessed,
        traced_write_accesses: analyzer.write_accesses,
        scale,
    };
    let mrc = MissRatioCurve::new(analyzer.combined(), cpu.l1.line_bytes);
    let prediction = predict_workload(cpu, w, &mrc, &meta, CLASSIFY_SLACK);

    let sim_traffic = traffic_from_counts(cpu, w, &h.counts, analyzer.write_accesses, scale);
    let (sim_time, sim_class) = classify_traffic(cpu, w, &sim_traffic, CLASSIFY_SLACK);

    let operands = Operand::ALL
        .iter()
        .filter_map(|&op| {
            let hist = analyzer.histogram(op);
            if hist.total() == 0 {
                return None;
            }
            Some(OperandProfile {
                operand: op.name().to_string(),
                accesses: hist.total(),
                cold: hist.cold(),
                p50_lines: hist.percentile(50.0),
                buckets: hist.log_buckets(),
            })
        })
        .collect();

    TraceReport {
        family: w.family().to_string(),
        shape: w.shape(),
        cpu_name: cpu.name.clone(),
        max_rows,
        scale,
        accesses: analyzer.accesses(),
        lines_touched: analyzer.lines_touched() as u64,
        counts: h.counts,
        sim_l1_hit_rate: h.l1.stats.hit_rate(),
        sim_l2_hit_rate: h.l2.stats.hit_rate(),
        sim_time_s: sim_time.total_s,
        sim_class: sim_class.name(),
        predicted_class: prediction.class.name(),
        working_set_bytes: mrc.capacity_for_fraction(WORKING_SET_FRACTION),
        prediction,
        operands,
        mrc_points: mrc.points(),
        knees: mrc.knees(0.05),
    }
}

impl TraceReport {
    /// "family/shape" — the stable identity used in job keys and BENCH
    /// records.
    pub fn key(&self) -> String {
        format!("{}/{}", self.family, self.shape)
    }

    /// |predicted − simulated| L1 hit rate, percentage points.
    pub fn l1_err_pp(&self) -> f64 {
        (self.prediction.rates.l1_hit_rate - self.sim_l1_hit_rate).abs() * 100.0
    }

    /// |predicted − simulated| L2 hit rate, percentage points.
    pub fn l2_err_pp(&self) -> f64 {
        (self.prediction.rates.l2_hit_rate - self.sim_l2_hit_rate).abs() * 100.0
    }

    /// Did prediction and full simulation reach the same boundness class?
    pub fn classes_agree(&self) -> bool {
        self.predicted_class == self.sim_class
    }

    /// The compact record the coordinator store and `BENCH.json` carry.
    pub fn summary(&self) -> TraceSummary {
        TraceSummary {
            key: self.key(),
            profile: self.cpu_name.clone(),
            accesses: self.accesses,
            sim_l1_hit_rate: self.sim_l1_hit_rate,
            sim_l2_hit_rate: self.sim_l2_hit_rate,
            mrc_l1_hit_rate: self.prediction.rates.l1_hit_rate,
            mrc_l2_hit_rate: self.prediction.rates.l2_hit_rate,
            sim_class: self.sim_class.clone(),
            predicted_class: self.predicted_class.clone(),
            working_set_bytes: self.working_set_bytes,
        }
    }

    /// Per-artifact profile for the serving core.
    pub fn cache_profile(&self, artifact: &str) -> CacheProfile {
        CacheProfile {
            artifact: artifact.to_string(),
            accesses: self.accesses,
            l1_hit_rate: self.prediction.rates.l1_hit_rate,
            l2_hit_rate: self.prediction.rates.l2_hit_rate,
            working_set_bytes: self.working_set_bytes,
            predicted_class: self.predicted_class.clone(),
        }
    }

    /// Full JSON document (the `cachebound trace --json` payload).
    pub fn to_json(&self) -> Value {
        let bucket_json = |b: &DistanceBucket| {
            json::obj(vec![
                (
                    "lo",
                    if b.lo == u64::MAX { Value::Null } else { json::num(b.lo as f64) },
                ),
                (
                    "hi",
                    if b.hi == u64::MAX { Value::Null } else { json::num(b.hi as f64) },
                ),
                ("count", json::num(b.count as f64)),
            ])
        };
        let operands = self
            .operands
            .iter()
            .map(|o| {
                json::obj(vec![
                    ("operand", json::s(o.operand.as_str())),
                    ("accesses", json::num(o.accesses as f64)),
                    ("cold", json::num(o.cold as f64)),
                    (
                        "p50_lines",
                        o.p50_lines.map_or(Value::Null, |d| json::num(d as f64)),
                    ),
                    (
                        "histogram",
                        Value::Arr(o.buckets.iter().map(bucket_json).collect()),
                    ),
                ])
            })
            .collect();
        let mrc = self
            .mrc_points
            .iter()
            .map(|&(bytes, rate)| json::arr(vec![json::num(bytes as f64), json::num(rate)]))
            .collect();
        let knees = self
            .knees
            .iter()
            .map(|k| {
                json::obj(vec![
                    ("capacity_bytes", json::num(k.capacity_bytes as f64)),
                    ("hit_rate", json::num(k.hit_rate)),
                    ("gain", json::num(k.gain)),
                ])
            })
            .collect();
        json::obj(vec![
            ("version", json::num(1.0)),
            ("workload", json::s(self.key())),
            ("family", json::s(self.family.as_str())),
            ("shape", json::s(self.shape.as_str())),
            ("profile", json::s(self.cpu_name.as_str())),
            ("max_rows", json::num(self.max_rows as f64)),
            ("scale", json::num(self.scale)),
            ("accesses", json::num(self.accesses as f64)),
            ("lines_touched", json::num(self.lines_touched as f64)),
            ("working_set_bytes", json::num(self.working_set_bytes as f64)),
            ("operands", Value::Arr(operands)),
            ("mrc", Value::Arr(mrc)),
            ("knees", Value::Arr(knees)),
            (
                "simulated",
                json::obj(vec![
                    ("l1_hit_rate", json::num(self.sim_l1_hit_rate)),
                    ("l2_hit_rate", json::num(self.sim_l2_hit_rate)),
                    ("time_s", json::num(self.sim_time_s)),
                    ("class", json::s(self.sim_class.as_str())),
                ]),
            ),
            (
                "predicted",
                json::obj(vec![
                    ("l1_hit_rate", json::num(self.prediction.rates.l1_hit_rate)),
                    ("l2_hit_rate", json::num(self.prediction.rates.l2_hit_rate)),
                    ("ram_fraction", json::num(self.prediction.rates.ram_fraction)),
                    ("time_s", json::num(self.prediction.time.total_s)),
                    ("class", json::s(self.predicted_class.as_str())),
                    ("l1_err_pp", json::num(self.l1_err_pp())),
                    ("l2_err_pp", json::num(self.l2_err_pp())),
                    ("classes_agree", Value::Bool(self.classes_agree())),
                ]),
            ),
        ])
    }
}

/// Compact per-trace record: what `JobOutput::Traced`, the result store
/// and `BENCH.json` carry.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSummary {
    pub key: String,
    pub profile: String,
    pub accesses: u64,
    pub sim_l1_hit_rate: f64,
    pub sim_l2_hit_rate: f64,
    pub mrc_l1_hit_rate: f64,
    pub mrc_l2_hit_rate: f64,
    pub sim_class: String,
    pub predicted_class: String,
    pub working_set_bytes: u64,
}

impl TraceSummary {
    pub fn classes_agree(&self) -> bool {
        self.sim_class == self.predicted_class
    }

    /// One-line rendering for result-store details and logs.
    pub fn render(&self) -> String {
        format!(
            "L1 {:.1}%/{:.1}% L2 {:.1}%/{:.1}% (sim/mrc), ws {} KiB, class {}/{}",
            self.sim_l1_hit_rate * 100.0,
            self.mrc_l1_hit_rate * 100.0,
            self.sim_l2_hit_rate * 100.0,
            self.mrc_l2_hit_rate * 100.0,
            self.working_set_bytes / 1024,
            self.sim_class,
            self.predicted_class,
        )
    }
}

/// Per-artifact cache profile for the serving core: what a worker's cache
/// working set looks like when this artifact is resident.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheProfile {
    pub artifact: String,
    pub accesses: u64,
    pub l1_hit_rate: f64,
    pub l2_hit_rate: f64,
    /// Estimated working-set size (bytes of cache for
    /// [`WORKING_SET_FRACTION`] of the peak hit rate).
    pub working_set_bytes: u64,
    pub predicted_class: String,
}

/// Profile a synthetic serving artifact (`syn_gemm_n<N>`) by tracing its
/// tiled GEMM untruncated (serving GEMMs are small).
pub fn synthetic_gemm_profile(cpu: &CpuSpec, artifact: &str, n: usize) -> CacheProfile {
    trace_workload(cpu, &BenchWorkload::Gemm { n }, TraceBudget::new(n)).cache_profile(artifact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::profile_by_name;
    use crate::operators::workloads::ConvLayer;

    fn a53() -> CpuSpec {
        profile_by_name("a53").unwrap().cpu
    }

    fn tiny_conv() -> ConvLayer {
        ConvLayer {
            name: "tiny",
            b: 1,
            cin: 8,
            cout: 8,
            h: 12,
            w: 12,
            k: 3,
            stride: 1,
            pad: 1,
        }
    }

    #[test]
    fn gemm_trace_produces_consistent_report() {
        let cpu = a53();
        let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 96 }, TraceBudget::new(32));
        assert_eq!(r.key(), "gemm/n96");
        assert_eq!(r.max_rows, 32);
        assert!((r.scale - 3.0).abs() < 1e-12);
        assert_eq!(r.accesses, r.counts.accesses);
        assert!(r.lines_touched > 0);
        // operand split covers A, B and C
        let names: Vec<&str> = r.operands.iter().map(|o| o.operand.as_str()).collect();
        assert_eq!(names, vec!["A", "B", "C"]);
        // histogram mass equals accesses
        let total: u64 = r.operands.iter().map(|o| o.accesses).sum();
        assert_eq!(total, r.accesses);
        assert!(r.working_set_bytes > 0);
    }

    #[test]
    fn every_family_traces_and_serializes() {
        let cpu = a53();
        let layer = tiny_conv();
        let workloads = [
            BenchWorkload::Gemm { n: 48 },
            BenchWorkload::Conv { layer },
            BenchWorkload::QnnConv { layer },
            BenchWorkload::Bitserial { n: 48, bits: 2 },
        ];
        for w in &workloads {
            let r = trace_workload(&cpu, w, TraceBudget::default());
            assert!(r.accesses > 0, "{}", r.key());
            assert!(!r.mrc_points.is_empty(), "{}", r.key());
            let text = json::to_string_pretty(&r.to_json());
            let v = json::parse(&text).expect("valid JSON");
            assert_eq!(v.req("workload").unwrap().as_str().unwrap(), r.key());
            assert!(v.req("predicted").unwrap().req("class").is_ok());
            assert!(!v.req("mrc").unwrap().as_arr().unwrap().is_empty());
        }
    }

    #[test]
    fn small_gemm_prediction_matches_simulation_closely() {
        // 48³ f32 fits comfortably in L2 and mostly in L1: the MRC and the
        // set-associative simulation must agree tightly.
        let cpu = a53();
        let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 48 }, TraceBudget::new(48));
        assert!(r.l1_err_pp() < 2.0, "L1 err {:.2}pp", r.l1_err_pp());
        assert!(r.l2_err_pp() < 2.0, "L2 err {:.2}pp", r.l2_err_pp());
        assert!(r.classes_agree(), "sim {} vs mrc {}", r.sim_class, r.predicted_class);
    }

    #[test]
    fn summary_and_cache_profile_are_consistent() {
        let cpu = a53();
        let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 64 }, TraceBudget::new(64));
        let s = r.summary();
        assert_eq!(s.key, "gemm/n64");
        assert_eq!(s.working_set_bytes, r.working_set_bytes);
        assert!(s.render().contains("ws"));
        let p = r.cache_profile("syn_gemm_n64");
        assert_eq!(p.artifact, "syn_gemm_n64");
        assert_eq!(p.working_set_bytes, r.working_set_bytes);
    }

    #[test]
    fn synthetic_profile_working_set_grows_with_n() {
        let cpu = a53();
        let small = synthetic_gemm_profile(&cpu, "syn_gemm_n32", 32);
        let big = synthetic_gemm_profile(&cpu, "syn_gemm_n128", 128);
        assert!(
            big.working_set_bytes > small.working_set_bytes,
            "{} vs {}",
            big.working_set_bytes,
            small.working_set_bytes
        );
    }
}
