//! Per-workload cache profiles: one traced replay → reuse histograms, MRC,
//! knees, and predicted-vs-simulated classification.
//!
//! [`trace_workload`] is the single driver everything rides on: the CLI's
//! `cachebound trace`, the `JobSpec::Trace` coordinator job, the optional
//! `telemetry` section of `BENCH.json`, and the serving core's
//! [`CacheProfile`]s.  It replays one operator through `sim::Hierarchy`
//! with a `ReuseAnalyzer` sink attached, so the *same pass* yields both
//! the set-associative ground truth (cache stats) and the MRC prediction —
//! which is what makes predicted-vs-simulated a meaningful validation.
//!
//! Replays are row-budgeted ([`TraceBudget`]): the loop nests repeat the
//! same tile-level reuse pattern along their outer dimension, so tracing
//! `max_rows` of it and scaling linearly reproduces the full-shape traffic
//! at a fraction of the cost (the budget is recorded in the report).

use crate::analysis::predict::{
    classify_traffic, predict_workload, traffic_from_counts, MrcPrediction, TraceMeta,
};
use crate::bench::sweep::CLASSIFY_SLACK;
use crate::hw::CpuSpec;
use crate::operators::conv::ConvSchedule;
use crate::operators::gemm::GemmSchedule;
use crate::operators::workloads::BenchWorkload;
use crate::sim::hierarchy::{Hierarchy, LevelCounts};
use crate::sim::trace::{
    replay_bitserial_gemm_traced, replay_conv_spatial_pack_traced, replay_gemm_traced,
};
use crate::util::json::{self, Value};

use super::event::Operand;
use super::misscurve::{Knee, MissRatioCurve};
use super::reuse::{DistanceBucket, ReuseAnalyzer};

/// Fraction of the peak finite hit rate defining the working-set estimate.
/// High because the distance-0 (within-line) mass alone reaches ~90% for
/// streaming operators; the knee of interest is the last few percent.
pub const WORKING_SET_FRACTION: f64 = 0.98;

/// How much of a workload's outer dimension a trace replays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceBudget {
    /// Cap on the outer extent (GEMM/bit-serial rows, conv input rows).
    pub max_rows: usize,
}

impl TraceBudget {
    /// Budget capped at `max_rows` outer rows (min 1).
    pub fn new(max_rows: usize) -> Self {
        TraceBudget { max_rows: max_rows.max(1) }
    }
}

impl Default for TraceBudget {
    fn default() -> Self {
        TraceBudget { max_rows: 64 }
    }
}

/// Reuse profile of one operand stream.
#[derive(Clone, Debug, PartialEq)]
pub struct OperandProfile {
    /// Operand name ("A", "B", "C", "other").
    pub operand: String,
    /// Accesses attributed to this operand.
    pub accesses: u64,
    /// Cold first touches (infinite reuse distance).
    pub cold: u64,
    /// Median reuse distance in lines (None when cold/far dominates).
    pub p50_lines: Option<u64>,
    /// Log₂-bucketed distance histogram rows.
    pub buckets: Vec<DistanceBucket>,
}

/// Everything one traced replay produced.
#[derive(Clone, Debug)]
pub struct TraceReport {
    /// Operator family label ("gemm", "conv", "qnn", "bitserial").
    pub family: String,
    /// Shape label ("n512", "C2", "n64b2").
    pub shape: String,
    /// Name of the CPU profile the replay ran against.
    pub cpu_name: String,
    /// The workload that was replayed.
    pub workload: BenchWorkload,
    /// Row budget the replay ran under.
    pub max_rows: usize,
    /// Full-shape work / traced work.
    pub scale: f64,
    /// Core accesses in the traced replay.
    pub accesses: u64,
    /// Distinct cache lines the replay touched.
    pub lines_touched: u64,
    /// `lines_touched × line_bytes` — the traced memory footprint (what the
    /// replay would occupy in an infinite cache).  Row-budgeted replays
    /// undercount the truncated operand rows but always cover the dominant
    /// shared panel in full.
    pub footprint_bytes: u64,
    /// What the traced replay measured, plus the truncation scale — enough
    /// to re-run the rates → traffic extrapolation at a different cache
    /// capacity (`analysis::interference`).
    pub meta: TraceMeta,
    /// The miss-ratio curve at every sample capacity (no dedup), as
    /// `(capacity_bytes, hit_rate)` — the lossless series behind
    /// [`CacheProfile::mrc_points`].
    pub mrc_sampled: Vec<(u64, f64)>,
    /// Trace-simulator per-level byte counts (the ground truth).
    pub counts: LevelCounts,
    /// Set-associative simulated hit rates (L1 over all accesses, L2 over
    /// the L1-miss stream).
    pub sim_l1_hit_rate: f64,
    /// Simulated L2 hit rate over the L1-miss stream.
    pub sim_l2_hit_rate: f64,
    /// Full-simulation roofline time and class (same classifier as the
    /// prediction — agreement is the validation).
    pub sim_time_s: f64,
    /// Boundness class of the full-simulation time.
    pub sim_class: String,
    /// The MRC-side prediction.
    pub prediction: MrcPrediction,
    /// Boundness class of the MRC prediction.
    pub predicted_class: String,
    /// Smallest capacity reaching [`WORKING_SET_FRACTION`] of the peak
    /// finite hit rate.
    pub working_set_bytes: u64,
    /// Per-operand reuse profiles (A/B/C split).
    pub operands: Vec<OperandProfile>,
    /// `(capacity_bytes, predicted_hit_rate)` — the MRC data series.
    pub mrc_points: Vec<(u64, f64)>,
    /// Working-set knees of the miss-ratio curve.
    pub knees: Vec<Knee>,
}

/// Trace one workload on one CPU profile: replay through the hierarchy
/// with a reuse-analyzer sink, then predict and classify both ways.
pub fn trace_workload(cpu: &CpuSpec, w: &BenchWorkload, budget: TraceBudget) -> TraceReport {
    let mut h = Hierarchy::new(cpu);
    // Track per-set stack distances at the target L1's geometry alongside
    // the fully-associative histogram, so the MRC can price the 2-way
    // A72's conflict misses exactly (misscurve::predict_set_aware).
    let mut analyzer = ReuseAnalyzer::with_sets(cpu.l1.line_bytes, cpu.l1.sets());
    let (scale, max_rows) = match w {
        BenchWorkload::Gemm { n } | BenchWorkload::QnnGemm { n } => {
            // int8 shares the tiled loop nest at 1-byte operands (the C
            // accumulator stays 4 bytes — i32), which is the layout story
            // the serving tiers rest on: same MACs, a quarter the panel
            // traffic
            let elem = if matches!(w, BenchWorkload::QnnGemm { .. }) { 1 } else { 4 };
            let m = (*n).min(budget.max_rows);
            replay_gemm_traced(
                &mut h,
                m,
                *n,
                *n,
                GemmSchedule::default_tuned(),
                elem,
                &mut analyzer,
            );
            (*n as f64 / m as f64, m)
        }
        BenchWorkload::Conv { layer } | BenchWorkload::QnnConv { layer } => {
            let elem = if matches!(w, BenchWorkload::QnnConv { .. }) { 1 } else { 4 };
            let mut traced = *layer;
            traced.h = traced.h.min(budget.max_rows);
            replay_conv_spatial_pack_traced(
                &mut h,
                &traced,
                ConvSchedule::default_tuned(),
                elem,
                &mut analyzer,
            );
            (
                layer.macs_exact() as f64 / traced.macs_exact() as f64,
                traced.h,
            )
        }
        BenchWorkload::Bitserial { n, bits } => {
            let m = (*n).min(budget.max_rows);
            let kw = n.div_ceil(32);
            replay_bitserial_gemm_traced(&mut h, m, *n, kw, *bits, *bits, &mut analyzer);
            (*n as f64 / m as f64, m)
        }
    };

    let meta = TraceMeta {
        traced_accesses: analyzer.accesses(),
        traced_bytes: analyzer.bytes_accessed,
        traced_write_accesses: analyzer.write_accesses,
        scale,
    };
    let mrc = match analyzer.take_set_histograms() {
        Some(sets) => MissRatioCurve::with_sets(analyzer.combined(), cpu.l1.line_bytes, sets),
        None => MissRatioCurve::new(analyzer.combined(), cpu.l1.line_bytes),
    };
    let prediction = predict_workload(cpu, w, &mrc, &meta, CLASSIFY_SLACK);

    let sim_traffic = traffic_from_counts(cpu, w, &h.counts, analyzer.write_accesses, scale);
    let (sim_time, sim_class) = classify_traffic(cpu, w, &sim_traffic, CLASSIFY_SLACK);

    let operands = Operand::ALL
        .iter()
        .filter_map(|&op| {
            let hist = analyzer.histogram(op);
            if hist.total() == 0 {
                return None;
            }
            Some(OperandProfile {
                operand: op.name().to_string(),
                accesses: hist.total(),
                cold: hist.cold(),
                p50_lines: hist.percentile(50.0),
                buckets: hist.log_buckets(),
            })
        })
        .collect();

    TraceReport {
        family: w.family().to_string(),
        shape: w.shape(),
        cpu_name: cpu.name.clone(),
        workload: *w,
        max_rows,
        scale,
        accesses: analyzer.accesses(),
        lines_touched: analyzer.lines_touched() as u64,
        footprint_bytes: analyzer.lines_touched() as u64 * cpu.l1.line_bytes as u64,
        meta,
        mrc_sampled: mrc.sampled(),
        counts: h.counts,
        sim_l1_hit_rate: h.l1.stats.hit_rate(),
        sim_l2_hit_rate: h.l2.stats.hit_rate(),
        sim_time_s: sim_time.total_s,
        sim_class: sim_class.name(),
        predicted_class: prediction.class.name(),
        working_set_bytes: mrc.capacity_for_fraction(WORKING_SET_FRACTION),
        prediction,
        operands,
        mrc_points: mrc.points(),
        knees: mrc.knees(0.05),
    }
}

impl TraceReport {
    /// "family/shape" — the stable identity used in job keys and BENCH
    /// records.
    pub fn key(&self) -> String {
        format!("{}/{}", self.family, self.shape)
    }

    /// |predicted − simulated| L1 hit rate, percentage points.
    pub fn l1_err_pp(&self) -> f64 {
        (self.prediction.rates.l1_hit_rate - self.sim_l1_hit_rate).abs() * 100.0
    }

    /// Fully-assoc-minus-set-aware L1 hit-rate gap in percentage points:
    /// what ignoring set conflicts would have cost this workload (signed —
    /// negative on anti-conflict knife-edges where the per-set view hits
    /// *more* than the fully-associative stack does).
    pub fn conflict_pp(&self) -> f64 {
        self.prediction.conflict_pp
    }

    /// |predicted − simulated| L2 hit rate, percentage points.
    pub fn l2_err_pp(&self) -> f64 {
        (self.prediction.rates.l2_hit_rate - self.sim_l2_hit_rate).abs() * 100.0
    }

    /// Did prediction and full simulation reach the same boundness class?
    pub fn classes_agree(&self) -> bool {
        self.predicted_class == self.sim_class
    }

    /// The compact record the coordinator store and `BENCH.json` carry.
    pub fn summary(&self) -> TraceSummary {
        TraceSummary {
            key: self.key(),
            profile: self.cpu_name.clone(),
            accesses: self.accesses,
            sim_l1_hit_rate: self.sim_l1_hit_rate,
            sim_l2_hit_rate: self.sim_l2_hit_rate,
            mrc_l1_hit_rate: self.prediction.rates.l1_hit_rate,
            mrc_l2_hit_rate: self.prediction.rates.l2_hit_rate,
            conflict_pp: self.prediction.conflict_pp,
            sim_class: self.sim_class.clone(),
            predicted_class: self.predicted_class.clone(),
            working_set_bytes: self.working_set_bytes,
        }
    }

    /// Per-artifact profile for the serving core — carries the full
    /// sampled MRC and trace meta so the placement layer can re-price the
    /// artifact at a reduced effective L2 (`analysis::interference`).
    pub fn cache_profile(&self, artifact: &str) -> CacheProfile {
        CacheProfile {
            artifact: artifact.to_string(),
            accesses: self.accesses,
            l1_hit_rate: self.prediction.rates.l1_hit_rate,
            l2_hit_rate: self.prediction.rates.l2_hit_rate,
            working_set_bytes: self.working_set_bytes,
            footprint_bytes: self.footprint_bytes,
            predicted_class: self.predicted_class.clone(),
            solo_time_s: self.prediction.time.total_s,
            workload: Some(self.workload),
            meta: Some(self.meta),
            mrc_points: self.mrc_sampled.clone(),
            knees: self.knees.clone(),
        }
    }

    /// Full JSON document (the `cachebound trace --json` payload).
    pub fn to_json(&self) -> Value {
        let bucket_json = |b: &DistanceBucket| {
            json::obj(vec![
                (
                    "lo",
                    if b.lo == u64::MAX { Value::Null } else { json::num(b.lo as f64) },
                ),
                (
                    "hi",
                    if b.hi == u64::MAX { Value::Null } else { json::num(b.hi as f64) },
                ),
                ("count", json::num(b.count as f64)),
            ])
        };
        let operands = self
            .operands
            .iter()
            .map(|o| {
                json::obj(vec![
                    ("operand", json::s(o.operand.as_str())),
                    ("accesses", json::num(o.accesses as f64)),
                    ("cold", json::num(o.cold as f64)),
                    (
                        "p50_lines",
                        o.p50_lines.map_or(Value::Null, |d| json::num(d as f64)),
                    ),
                    (
                        "histogram",
                        Value::Arr(o.buckets.iter().map(bucket_json).collect()),
                    ),
                ])
            })
            .collect();
        let mrc = self
            .mrc_points
            .iter()
            .map(|&(bytes, rate)| json::arr(vec![json::num(bytes as f64), json::num(rate)]))
            .collect();
        let knees = self
            .knees
            .iter()
            .map(|k| {
                json::obj(vec![
                    ("capacity_bytes", json::num(k.capacity_bytes as f64)),
                    ("hit_rate", json::num(k.hit_rate)),
                    ("gain", json::num(k.gain)),
                ])
            })
            .collect();
        json::obj(vec![
            ("version", json::num(1.0)),
            ("workload", json::s(self.key())),
            ("family", json::s(self.family.as_str())),
            ("shape", json::s(self.shape.as_str())),
            ("profile", json::s(self.cpu_name.as_str())),
            ("max_rows", json::num(self.max_rows as f64)),
            ("scale", json::num(self.scale)),
            ("accesses", json::num(self.accesses as f64)),
            ("lines_touched", json::num(self.lines_touched as f64)),
            ("working_set_bytes", json::num(self.working_set_bytes as f64)),
            ("operands", Value::Arr(operands)),
            ("mrc", Value::Arr(mrc)),
            ("knees", Value::Arr(knees)),
            (
                "simulated",
                json::obj(vec![
                    ("l1_hit_rate", json::num(self.sim_l1_hit_rate)),
                    ("l2_hit_rate", json::num(self.sim_l2_hit_rate)),
                    ("time_s", json::num(self.sim_time_s)),
                    ("class", json::s(self.sim_class.as_str())),
                ]),
            ),
            (
                "predicted",
                json::obj(vec![
                    ("l1_hit_rate", json::num(self.prediction.rates.l1_hit_rate)),
                    ("l2_hit_rate", json::num(self.prediction.rates.l2_hit_rate)),
                    ("ram_fraction", json::num(self.prediction.rates.ram_fraction)),
                    ("fa_l1_hit_rate", json::num(self.prediction.fa_l1_hit_rate)),
                    ("conflict_pp", json::num(self.prediction.conflict_pp)),
                    ("time_s", json::num(self.prediction.time.total_s)),
                    ("class", json::s(self.predicted_class.as_str())),
                    ("l1_err_pp", json::num(self.l1_err_pp())),
                    ("l2_err_pp", json::num(self.l2_err_pp())),
                    ("classes_agree", Value::Bool(self.classes_agree())),
                ]),
            ),
        ])
    }
}

/// Compact per-trace record: what `JobOutput::Traced`, the result store
/// and `BENCH.json` carry.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSummary {
    /// "family/shape" identity of the traced workload.
    pub key: String,
    /// CPU profile the trace ran against.
    pub profile: String,
    /// Core accesses in the traced replay.
    pub accesses: u64,
    /// Set-associative simulated L1 hit rate.
    pub sim_l1_hit_rate: f64,
    /// Simulated L2 hit rate over the L1-miss stream.
    pub sim_l2_hit_rate: f64,
    /// MRC-predicted L1 hit rate.
    pub mrc_l1_hit_rate: f64,
    /// MRC-predicted L2 hit rate.
    pub mrc_l2_hit_rate: f64,
    /// Fully-assoc-minus-set-aware L1 hit-rate gap, percentage points
    /// (signed; see [`TraceReport::conflict_pp`]).
    pub conflict_pp: f64,
    /// Boundness class of the full-simulation time.
    pub sim_class: String,
    /// Boundness class of the MRC prediction.
    pub predicted_class: String,
    /// Working-set estimate (98% of peak hit rate).
    pub working_set_bytes: u64,
}

impl TraceSummary {
    /// Did prediction and simulation reach the same class?
    pub fn classes_agree(&self) -> bool {
        self.sim_class == self.predicted_class
    }

    /// One-line rendering for result-store details and logs.
    pub fn render(&self) -> String {
        format!(
            "L1 {:.1}%/{:.1}% L2 {:.1}%/{:.1}% (sim/mrc), conflict {:+.2}pp, ws {} KiB, class {}/{}",
            self.sim_l1_hit_rate * 100.0,
            self.mrc_l1_hit_rate * 100.0,
            self.sim_l2_hit_rate * 100.0,
            self.mrc_l2_hit_rate * 100.0,
            self.conflict_pp,
            self.working_set_bytes / 1024,
            self.sim_class,
            self.predicted_class,
        )
    }
}

/// Per-artifact cache profile for the serving core: what a worker's cache
/// working set looks like when this artifact is resident.
///
/// Beyond the scalar summary the serving metrics consume, the profile
/// carries the sampled miss-ratio curve, the working-set knees and the
/// trace meta — everything `analysis::interference` needs to re-price the
/// artifact's traffic at a *reduced* effective L2 capacity when it shares
/// the cache with co-resident artifacts.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheProfile {
    /// Artifact name this profile describes.
    pub artifact: String,
    /// Core accesses in the traced replay.
    pub accesses: u64,
    /// MRC-predicted L1 hit rate at the profiled CPU's geometry.
    pub l1_hit_rate: f64,
    /// MRC-predicted L2 hit rate over the L1-miss stream.
    pub l2_hit_rate: f64,
    /// Estimated working-set size (bytes of cache for
    /// [`WORKING_SET_FRACTION`] of the peak hit rate).
    pub working_set_bytes: u64,
    /// Traced memory footprint (`lines_touched × line_bytes`) — what the
    /// artifact *occupies* in a large cache, as opposed to what it *reuses*
    /// ([`Self::working_set_bytes`]).  Streaming operators occupy far more
    /// than they reuse; L2 partitioning uses the larger of the two.
    pub footprint_bytes: u64,
    /// `analysis::classify` verdict of the solo prediction.
    pub predicted_class: String,
    /// MRC-predicted solo execution time (full L2 to itself), seconds.
    pub solo_time_s: f64,
    /// The replayed workload (None for hand-built profiles — such profiles
    /// cannot be re-priced and are treated as interference-neutral).
    pub workload: Option<BenchWorkload>,
    /// The replay's [`TraceMeta`] (None for hand-built profiles).
    pub meta: Option<TraceMeta>,
    /// Sampled miss-ratio curve `(capacity_bytes, hit_rate)`, ascending,
    /// no dedup — step-left lookup reproduces the histogram's hit rate
    /// exactly at every power-of-two line count.
    pub mrc_points: Vec<(u64, f64)>,
    /// Working-set knees of the curve (≥ 5 p.p. hit-rate gains).
    pub knees: Vec<Knee>,
}

impl CacheProfile {
    /// Can this profile be re-priced at a reduced capacity?  True for
    /// profiles built by [`trace_workload`]; false for hand-assembled ones,
    /// which the interference model treats as occupying their working set
    /// but running at their solo time.
    pub fn repriceable(&self) -> bool {
        self.workload.is_some() && self.meta.is_some() && !self.mrc_points.is_empty()
    }
}

/// Profile a synthetic serving artifact (`syn_gemm_n<N>`) by tracing its
/// tiled GEMM untruncated (serving GEMMs are small).
pub fn synthetic_gemm_profile(cpu: &CpuSpec, artifact: &str, n: usize) -> CacheProfile {
    trace_workload(cpu, &BenchWorkload::Gemm { n }, TraceBudget::new(n)).cache_profile(artifact)
}

/// Profile a synthetic serving artifact of *any* tier
/// (`syn_gemm_n<N>` / `syn_gemm_i8_n<N>` / `syn_gemm_bs_n<N>`) by tracing
/// its tier's kernel untruncated — the tier-aware generalization of
/// [`synthetic_gemm_profile`].  The tier ↔ workload mapping lives on
/// [`crate::operators::workloads::Tier::workload`], so the traced replay,
/// the analytic predictor and the serving executor can never disagree
/// about what an artifact runs.  `None` for non-synthetic names.
pub fn synthetic_tier_profile(cpu: &CpuSpec, artifact: &str) -> Option<CacheProfile> {
    let (tier, n) = crate::operators::workloads::synthetic_tier(artifact)?;
    Some(trace_workload(cpu, &tier.workload(n), TraceBudget::new(n)).cache_profile(artifact))
}

/// Cache profiles for the whole synthetic serving mix
/// (`operators::workloads::serving_mix`), traced once per CPU profile
/// *name* and shared behind an `Arc` — the single map every cache-aware
/// serving consumer (the CLI, `ServeMix` jobs, benches, tests) hands to
/// `ServeConfig::with_profiles`.  Cached because the traced replays
/// dominate a serving run's setup cost: a `Pipeline::serve_scaling`
/// sweep would otherwise re-trace the identical mix once per worker
/// count.
pub fn serving_mix_profiles(
    cpu: &CpuSpec,
) -> std::sync::Arc<std::collections::BTreeMap<String, CacheProfile>> {
    use std::collections::{BTreeMap, HashMap};
    use std::sync::{Arc, Mutex, OnceLock};

    type MixMap = Arc<BTreeMap<String, CacheProfile>>;
    static CACHE: OnceLock<Mutex<HashMap<String, MixMap>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("serving-mix profile cache poisoned");
    if let Some(profiles) = guard.get(&cpu.name) {
        return profiles.clone();
    }
    let profiles: MixMap = Arc::new(
        crate::operators::workloads::serving_mix()
            .into_iter()
            .map(|m| {
                let p = synthetic_gemm_profile(cpu, &m.artifact, m.n);
                (m.artifact, p)
            })
            .collect(),
    );
    guard.insert(cpu.name.clone(), profiles.clone());
    profiles
}

/// Cache profiles for the mixed-tier serving mix
/// (`operators::workloads::serving_mix_tiered`), traced once per CPU
/// profile name like [`serving_mix_profiles`].  The quantized twins trace
/// through their own kernels (`QnnGemm` / `Bitserial`), so their smaller
/// working sets are visible to the interference model and the greedy
/// packer — quantized artifacts pack denser per worker, which is the
/// whole point of the tiered mix.
pub fn serving_tier_mix_profiles(
    cpu: &CpuSpec,
) -> std::sync::Arc<std::collections::BTreeMap<String, CacheProfile>> {
    use std::collections::{BTreeMap, HashMap};
    use std::sync::{Arc, Mutex, OnceLock};

    type MixMap = Arc<BTreeMap<String, CacheProfile>>;
    static CACHE: OnceLock<Mutex<HashMap<String, MixMap>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("tier-mix profile cache poisoned");
    if let Some(profiles) = guard.get(&cpu.name) {
        return profiles.clone();
    }
    let profiles: MixMap = Arc::new(
        crate::operators::workloads::serving_mix_tiered()
            .into_iter()
            .map(|m| {
                let p = synthetic_tier_profile(cpu, &m.artifact)
                    .expect("tiered mix artifacts are always synthetic");
                (m.artifact, p)
            })
            .collect(),
    );
    guard.insert(cpu.name.clone(), profiles.clone());
    profiles
}

/// [`synthetic_gemm_profile`] with an explicit row budget — for larger
/// artifacts (the adversarial co-run mix) where an untruncated replay is
/// needlessly slow.  Budgets must cover at least two M-tiles (128 rows for
/// the default 64-row tile), or the trace misses the cross-tile panel
/// reuse that defines the L2-scale footprint.
pub fn synthetic_gemm_profile_budgeted(
    cpu: &CpuSpec,
    artifact: &str,
    n: usize,
    max_rows: usize,
) -> CacheProfile {
    trace_workload(cpu, &BenchWorkload::Gemm { n }, TraceBudget::new(max_rows))
        .cache_profile(artifact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::profile_by_name;
    use crate::operators::workloads::ConvLayer;

    fn a53() -> CpuSpec {
        profile_by_name("a53").unwrap().cpu
    }

    fn tiny_conv() -> ConvLayer {
        ConvLayer {
            name: "tiny",
            b: 1,
            cin: 8,
            cout: 8,
            h: 12,
            w: 12,
            k: 3,
            stride: 1,
            pad: 1,
        }
    }

    #[test]
    fn gemm_trace_produces_consistent_report() {
        let cpu = a53();
        let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 96 }, TraceBudget::new(32));
        assert_eq!(r.key(), "gemm/n96");
        assert_eq!(r.max_rows, 32);
        assert!((r.scale - 3.0).abs() < 1e-12);
        assert_eq!(r.accesses, r.counts.accesses);
        assert!(r.lines_touched > 0);
        // operand split covers A, B and C
        let names: Vec<&str> = r.operands.iter().map(|o| o.operand.as_str()).collect();
        assert_eq!(names, vec!["A", "B", "C"]);
        // histogram mass equals accesses
        let total: u64 = r.operands.iter().map(|o| o.accesses).sum();
        assert_eq!(total, r.accesses);
        assert!(r.working_set_bytes > 0);
    }

    #[test]
    fn every_family_traces_and_serializes() {
        let cpu = a53();
        let layer = tiny_conv();
        let workloads = [
            BenchWorkload::Gemm { n: 48 },
            BenchWorkload::Conv { layer },
            BenchWorkload::QnnConv { layer },
            BenchWorkload::QnnGemm { n: 48 },
            BenchWorkload::Bitserial { n: 48, bits: 2 },
        ];
        for w in &workloads {
            let r = trace_workload(&cpu, w, TraceBudget::default());
            assert!(r.accesses > 0, "{}", r.key());
            assert!(!r.mrc_points.is_empty(), "{}", r.key());
            let text = json::to_string_pretty(&r.to_json());
            let v = json::parse(&text).expect("valid JSON");
            assert_eq!(v.req("workload").unwrap().as_str().unwrap(), r.key());
            assert!(v.req("predicted").unwrap().req("class").is_ok());
            assert!(!v.req("mrc").unwrap().as_arr().unwrap().is_empty());
        }
    }

    #[test]
    fn small_gemm_prediction_matches_simulation_closely() {
        // 48³ f32 fits comfortably in L2 and mostly in L1: the MRC and the
        // set-associative simulation must agree tightly.
        let cpu = a53();
        let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 48 }, TraceBudget::new(48));
        assert!(r.l1_err_pp() < 2.0, "L1 err {:.2}pp", r.l1_err_pp());
        assert!(r.l2_err_pp() < 2.0, "L2 err {:.2}pp", r.l2_err_pp());
        assert!(r.classes_agree(), "sim {} vs mrc {}", r.sim_class, r.predicted_class);
    }

    #[test]
    fn summary_and_cache_profile_are_consistent() {
        let cpu = a53();
        let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 64 }, TraceBudget::new(64));
        let s = r.summary();
        assert_eq!(s.key, "gemm/n64");
        assert_eq!(s.working_set_bytes, r.working_set_bytes);
        assert!(s.render().contains("ws"));
        let p = r.cache_profile("syn_gemm_n64");
        assert_eq!(p.artifact, "syn_gemm_n64");
        assert_eq!(p.working_set_bytes, r.working_set_bytes);
    }

    #[test]
    fn tier_profiles_shrink_down_the_precision_lattice() {
        // the placement story: at the same N, each quantization step must
        // show the packer a strictly smaller working set *and* footprint
        use crate::operators::workloads::{tier_artifact, Tier};
        let cpu = a53();
        let f32p = synthetic_tier_profile(&cpu, &tier_artifact(Tier::F32, 128)).unwrap();
        let i8p = synthetic_tier_profile(&cpu, &tier_artifact(Tier::Int8, 128)).unwrap();
        let bsp = synthetic_tier_profile(&cpu, &tier_artifact(Tier::BitSerial, 128)).unwrap();
        assert!(
            i8p.working_set_bytes < f32p.working_set_bytes,
            "int8 ws {} vs f32 ws {}",
            i8p.working_set_bytes,
            f32p.working_set_bytes
        );
        assert!(i8p.footprint_bytes < f32p.footprint_bytes);
        assert!(
            bsp.footprint_bytes < i8p.footprint_bytes,
            "2-bit planes {} vs int8 panels {}",
            bsp.footprint_bytes,
            i8p.footprint_bytes
        );
        // all three are repriceable by the interference model
        for p in [&f32p, &i8p, &bsp] {
            assert!(p.repriceable(), "{}", p.artifact);
        }
        // non-synthetic names have no tier profile
        assert!(synthetic_tier_profile(&cpu, "resnet50").is_none());
    }

    #[test]
    fn tier_mix_profiles_cover_the_tiered_mix() {
        use crate::operators::workloads::serving_mix_tiered;
        let cpu = a53();
        let profiles = serving_tier_mix_profiles(&cpu);
        let mix = serving_mix_tiered();
        assert_eq!(profiles.len(), mix.len());
        for item in &mix {
            let p = profiles.get(&item.artifact).expect("every mix artifact profiled");
            assert_eq!(p.artifact, item.artifact);
            assert!(p.working_set_bytes > 0);
        }
        // cached: the second call returns the same Arc
        assert!(std::sync::Arc::ptr_eq(&profiles, &serving_tier_mix_profiles(&cpu)));
    }

    #[test]
    fn synthetic_profile_working_set_grows_with_n() {
        let cpu = a53();
        let small = synthetic_gemm_profile(&cpu, "syn_gemm_n32", 32);
        let big = synthetic_gemm_profile(&cpu, "syn_gemm_n128", 128);
        assert!(
            big.working_set_bytes > small.working_set_bytes,
            "{} vs {}",
            big.working_set_bytes,
            small.working_set_bytes
        );
    }
}
