//! Cache telemetry: event traces, reuse-distance profiles, and
//! miss-ratio-curve boundness prediction.
//!
//! The simulator (`sim`) measures *what happened* in one cache
//! configuration; this subsystem explains *why* and predicts what would
//! happen in any other:
//!
//! * [`event`]/[`sink`] — structured cache events
//!   (hit/miss/eviction/writeback, operand-tagged) emitted by
//!   `sim::SetAssocCache::access_traced` and `sim::Hierarchy::access_traced`
//!   into a pluggable [`sink::EventSink`].  The no-op [`sink::NullSink`]
//!   keeps the untraced hot path allocation-free and branch-identical.
//! * [`reuse`] — streaming, bounded-memory stack-distance analysis over
//!   cache lines, with per-operand histograms and optional per-set
//!   histograms ([`reuse::SetHistograms`]) at a target L1 geometry.
//! * [`misscurve`] — the Mattson stack property turns one distance
//!   histogram into hit rates for **every** cache capacity: the miss-ratio
//!   curve, its working-set knees, and L1/L2 predictions for a concrete
//!   CPU.  [`misscurve::MissRatioCurve::predict_set_aware`] additionally
//!   prices conflict misses: exact per-set Mattson curves when the traced
//!   geometry matches, a Smith associativity factor otherwise.
//! * [`profile`] — the [`profile::trace_workload`] driver tying it
//!   together: one traced replay yields the set-associative ground truth
//!   *and* the MRC prediction, per-operand histograms, an optional JSON
//!   report, and the per-artifact [`profile::CacheProfile`]s the serving
//!   core uses for working-set-pressure accounting.
//!
//! The [`crate::analysis::predict`] module consumes the MRC to derive
//! boundness classes (L1/L2/RAM/compute) for arbitrary shapes without
//! re-simulating; `rust/tests/telemetry_mrc.rs` validates prediction
//! against full simulation on the paper's Tables IV/V GEMM grid.  The
//! per-artifact [`CacheProfile`]s carry the sampled curve onward to the
//! serving layer, where [`crate::analysis::interference`] re-reads it at
//! reduced capacities and [`crate::coordinator::placement`] packs
//! artifacts onto workers accordingly.
//!
//! One traced replay, end to end:
//!
//! ```
//! use cachebound::hw::profile_by_name;
//! use cachebound::operators::workloads::BenchWorkload;
//! use cachebound::telemetry::{trace_workload, TraceBudget};
//!
//! let cpu = profile_by_name("a53").unwrap().cpu;
//! let r = trace_workload(&cpu, &BenchWorkload::Gemm { n: 48 }, TraceBudget::new(16));
//! assert!(r.accesses > 0);
//! assert!(!r.mrc_points.is_empty());
//! // the same replay yields the simulated ground truth *and* the prediction
//! assert!(r.sim_l1_hit_rate > 0.0 && r.prediction.rates.l1_hit_rate > 0.0);
//! ```

pub mod event;
pub mod misscurve;
pub mod profile;
pub mod reuse;
pub mod sink;

pub use event::{CacheEvent, EventKind, Operand};
pub use misscurve::{
    conflict_capacity_fraction, smith_factor, Knee, MissRatioCurve, PredictedRates,
    SetAwarePrediction,
};
pub use profile::{
    serving_mix_profiles, serving_tier_mix_profiles, synthetic_gemm_profile,
    synthetic_gemm_profile_budgeted, synthetic_tier_profile, trace_workload, CacheProfile,
    TraceBudget, TraceReport, TraceSummary,
};
pub use reuse::{ReuseAnalyzer, ReuseHistogram, SetHistograms};
pub use sink::{CountingSink, EventSink, NullSink, TeeSink, VecSink};
