//! Cache telemetry: event traces, reuse-distance profiles, and
//! miss-ratio-curve boundness prediction.
//!
//! The simulator (`sim`) measures *what happened* in one cache
//! configuration; this subsystem explains *why* and predicts what would
//! happen in any other:
//!
//! * [`event`]/[`sink`] — structured cache events
//!   (hit/miss/eviction/writeback, operand-tagged) emitted by
//!   `sim::SetAssocCache::access_traced` and `sim::Hierarchy::access_traced`
//!   into a pluggable [`sink::EventSink`].  The no-op [`sink::NullSink`]
//!   keeps the untraced hot path allocation-free and branch-identical.
//! * [`reuse`] — streaming, bounded-memory stack-distance analysis over
//!   cache lines, with per-operand histograms.
//! * [`misscurve`] — the Mattson stack property turns one distance
//!   histogram into hit rates for **every** cache capacity: the miss-ratio
//!   curve, its working-set knees, and L1/L2 predictions for a concrete
//!   CPU.
//! * [`profile`] — the [`profile::trace_workload`] driver tying it
//!   together: one traced replay yields the set-associative ground truth
//!   *and* the MRC prediction, per-operand histograms, an optional JSON
//!   report, and the per-artifact [`profile::CacheProfile`]s the serving
//!   core uses for working-set-pressure accounting.
//!
//! The `analysis::predict` module consumes the MRC to derive boundness
//! classes (L1/L2/RAM/compute) for arbitrary shapes without
//! re-simulating; `rust/tests/telemetry_mrc.rs` validates prediction
//! against full simulation on the paper's Tables IV/V GEMM grid.

pub mod event;
pub mod misscurve;
pub mod profile;
pub mod reuse;
pub mod sink;

pub use event::{CacheEvent, EventKind, Operand};
pub use misscurve::{Knee, MissRatioCurve, PredictedRates};
pub use profile::{
    synthetic_gemm_profile, trace_workload, CacheProfile, TraceBudget, TraceReport, TraceSummary,
};
pub use reuse::{ReuseAnalyzer, ReuseHistogram};
pub use sink::{CountingSink, EventSink, NullSink, TeeSink, VecSink};
