//! Miss-ratio curves: one traced replay → predicted hit rates for *any*
//! cache size.
//!
//! The stack-distance property of LRU (Mattson et al., 1970): an access
//! with reuse distance `d` hits a fully-associative LRU cache of capacity
//! `C` lines iff `d < C`.  So the cumulative distribution of the distances
//! recorded by `telemetry::reuse` *is* the hit-rate-versus-capacity curve,
//! for every capacity at once — the single-pass alternative to
//! re-simulating `sim::Hierarchy` per cache configuration.
//!
//! Two-level prediction uses the same property twice: an access misses L1
//! iff `d >= C_L1`, and that miss hits L2 iff `d < C_L2` (the filtered L2
//! stream inherits the global LRU stack order).  Both are exact for
//! fully-associative LRU and approximations for the set-associative
//! hardware `sim` models; the gap *is* the conflict-miss contribution,
//! which the A53's 4-way L1 keeps small for blocked operators while the
//! A72's 2-way L1 can blow it wide open on power-of-two strides — a
//! set-conflict sensitivity this module makes measurable (see
//! `DESIGN.md` §Telemetry).

use crate::hw::CpuSpec;

use super::reuse::{MAX_EXACT_DISTANCE, ReuseHistogram};

/// A miss-ratio curve over line-granular capacities.
#[derive(Clone, Debug)]
pub struct MissRatioCurve {
    hist: ReuseHistogram,
    line_bytes: usize,
}

/// Hit rates predicted for a concrete two-level hierarchy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredictedRates {
    /// Predicted L1 hit rate over all accesses.
    pub l1_hit_rate: f64,
    /// Predicted L2 hit rate over the L1-miss stream (the quantity
    /// `sim::Hierarchy`'s L2 `CacheStats` measures).
    pub l2_hit_rate: f64,
    /// Fraction of all accesses served by RAM.
    pub ram_fraction: f64,
}

/// One working-set knee: the capacity at which the hit rate jumps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Knee {
    /// Capacity at the knee, in cache lines.
    pub capacity_lines: usize,
    /// Capacity at the knee, in bytes.
    pub capacity_bytes: u64,
    /// Hit rate just past the knee.
    pub hit_rate: f64,
    /// Hit-rate gain across the knee.
    pub gain: f64,
}

impl MissRatioCurve {
    /// Curve over `hist` with `line_bytes`-sized lines.
    pub fn new(hist: ReuseHistogram, line_bytes: usize) -> Self {
        MissRatioCurve { hist, line_bytes }
    }

    /// Cache-line size the distances were measured in.
    pub fn line_bytes(&self) -> usize {
        self.line_bytes
    }

    /// Total accesses behind the curve.
    pub fn accesses(&self) -> u64 {
        self.hist.total()
    }

    /// Predicted hit rate of a fully-associative LRU cache of
    /// `capacity_bytes`.
    pub fn hit_rate_at_bytes(&self, capacity_bytes: usize) -> f64 {
        self.hist.hit_rate(capacity_bytes / self.line_bytes)
    }

    /// Predicted hit rate at a line-granular capacity.
    pub fn hit_rate_at_lines(&self, capacity_lines: usize) -> f64 {
        self.hist.hit_rate(capacity_lines)
    }

    /// Hit rates for a concrete CPU's L1/L2 geometry.
    pub fn predict(&self, cpu: &CpuSpec) -> PredictedRates {
        let p1 = self.hit_rate_at_bytes(cpu.l1.size_bytes);
        let p2 = self.hit_rate_at_bytes(cpu.l2.size_bytes);
        let miss1 = 1.0 - p1;
        let l2_hit_rate = if miss1 > 1e-12 { (p2 - p1) / miss1 } else { 1.0 };
        PredictedRates {
            l1_hit_rate: p1,
            l2_hit_rate,
            ram_fraction: 1.0 - p2,
        }
    }

    /// The curve sampled at log-spaced capacities (4 points per octave
    /// from one line to [`MAX_EXACT_DISTANCE`]), as `(bytes, hit_rate)` —
    /// the data series of the MRC figure and the `--json` dump.  Adjacent
    /// duplicate rates are collapsed to keep the series compact.
    pub fn points(&self) -> Vec<(u64, f64)> {
        let mut out: Vec<(u64, f64)> = Vec::new();
        for lines in sample_capacities() {
            let rate = self.hist.hit_rate(lines);
            let bytes = (lines * self.line_bytes) as u64;
            if let Some(&(_, last)) = out.last() {
                if (rate - last).abs() < 1e-9 {
                    continue;
                }
            }
            out.push((bytes, rate));
        }
        out
    }

    /// The curve at every sample capacity, *without* collapsing adjacent
    /// duplicate rates — the lossless series a [`super::CacheProfile`]
    /// carries so the co-run interference model (`analysis::interference`)
    /// can re-read the curve at arbitrary effective capacities after the
    /// histogram itself is gone.  Because the sample grid contains every
    /// power-of-two line count, a step-left lookup over these points
    /// reproduces [`Self::predict`] exactly for the built-in profiles
    /// (whose L1/L2 capacities are powers of two).
    pub fn sampled(&self) -> Vec<(u64, f64)> {
        sample_capacities()
            .into_iter()
            .map(|lines| ((lines * self.line_bytes) as u64, self.hist.hit_rate(lines)))
            .collect()
    }

    /// Working-set knees: capacities where the hit rate gains at least
    /// `min_gain` over the previous sample point.
    pub fn knees(&self, min_gain: f64) -> Vec<Knee> {
        let mut out = Vec::new();
        let mut prev_rate = 0.0;
        for lines in sample_capacities() {
            let rate = self.hist.hit_rate(lines);
            if rate - prev_rate >= min_gain {
                out.push(Knee {
                    capacity_lines: lines,
                    capacity_bytes: (lines * self.line_bytes) as u64,
                    hit_rate: rate,
                    gain: rate - prev_rate,
                });
            }
            prev_rate = rate;
        }
        out
    }

    /// Smallest capacity (bytes) reaching `fraction` of the curve's
    /// maximum finite hit rate — the working-set-size estimate behind
    /// `CacheProfile::working_set_bytes`.
    pub fn capacity_for_fraction(&self, fraction: f64) -> u64 {
        let max_rate = self.hist.hit_rate(MAX_EXACT_DISTANCE);
        let target = max_rate * fraction;
        for lines in sample_capacities() {
            if self.hist.hit_rate(lines) >= target - 1e-12 {
                return (lines * self.line_bytes) as u64;
            }
        }
        (MAX_EXACT_DISTANCE * self.line_bytes) as u64
    }
}

/// Log-spaced line capacities: 4 per octave from 1 line to the exact-count
/// ceiling.
fn sample_capacities() -> Vec<usize> {
    let mut caps = Vec::new();
    let mut c = 1usize;
    while c < MAX_EXACT_DISTANCE {
        caps.push(c);
        for num in [5usize, 6, 7] {
            let mid = c * num / 4;
            if mid > c && mid < c * 2 {
                caps.push(mid);
            }
        }
        c *= 2;
    }
    caps.push(MAX_EXACT_DISTANCE);
    caps.dedup();
    caps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::profile_by_name;

    /// Histogram of a cyclic sweep: `far_misses` cold + everything else at
    /// distance `ws - 1`.
    fn sweep_hist(ws: u64, rounds: u64) -> ReuseHistogram {
        let mut h = ReuseHistogram::new();
        for _ in 0..ws {
            h.record(None);
        }
        for _ in 0..(rounds - 1) * ws {
            h.record(Some(ws - 1));
        }
        h
    }

    #[test]
    fn step_curve_has_the_sweep_knee() {
        // 100-line working set swept 10 times (reuse distance 99): the
        // hit rate steps from 0 to 0.9 exactly at a 100-line capacity.
        let mrc = MissRatioCurve::new(sweep_hist(100, 10), 64);
        assert_eq!(mrc.hit_rate_at_lines(99), 0.0);
        assert!((mrc.hit_rate_at_lines(100) - 0.9).abs() < 1e-12);
        let knees = mrc.knees(0.5);
        assert_eq!(knees.len(), 1);
        // first sampled capacity past 100 lines is 112 (= 64 * 7/4)
        assert!(knees[0].capacity_lines > 100 && knees[0].capacity_lines <= 128);
        assert!((knees[0].hit_rate - 0.9).abs() < 1e-12);
    }

    #[test]
    fn predict_places_sweep_between_l1_and_l2() {
        // A 64 KiB working set: misses the A53's 16 KiB L1, fits the
        // 512 KiB L2 -> L1 ~0, conditional L2 ~1 (minus cold misses).
        let cpu = profile_by_name("a53").unwrap().cpu;
        let lines = (64 * 1024 / 64) as u64; // 1024 lines
        let mrc = MissRatioCurve::new(sweep_hist(lines, 20), 64);
        let p = mrc.predict(&cpu);
        assert!(p.l1_hit_rate < 0.01, "{p:?}");
        assert!(p.l2_hit_rate > 0.9, "{p:?}");
        assert!(p.ram_fraction < 0.1, "{p:?}");
    }

    #[test]
    fn predict_all_hits_saturates_l2_rate() {
        // tiny working set: everything hits L1; conditional L2 rate
        // defined as 1.0 rather than 0/0
        let cpu = profile_by_name("a53").unwrap().cpu;
        let mut h = ReuseHistogram::new();
        h.record(None);
        for _ in 0..999 {
            h.record(Some(0));
        }
        let p = MissRatioCurve::new(h, 64).predict(&cpu);
        assert!(p.l1_hit_rate > 0.99);
        assert!(p.l2_hit_rate <= 1.0);
    }

    #[test]
    fn points_are_monotone_and_capped() {
        let mrc = MissRatioCurve::new(sweep_hist(300, 4), 64);
        let pts = mrc.points();
        assert!(!pts.is_empty());
        for w in pts.windows(2) {
            assert!(w[1].0 > w[0].0, "capacities increase");
            assert!(w[1].1 >= w[0].1 - 1e-12, "hit rate is monotone");
        }
        assert!(pts.iter().all(|&(_, r)| (0.0..=1.0).contains(&r)));
    }

    #[test]
    fn capacity_for_fraction_finds_the_working_set() {
        let mrc = MissRatioCurve::new(sweep_hist(100, 10), 64);
        let ws = mrc.capacity_for_fraction(0.9);
        // the sweep's working set is 100 lines = 6400 bytes
        assert!(ws >= 100 * 64 && ws <= 128 * 64, "{ws}");
    }
}
